"""Shared benchmark plumbing: timing, CSV row emission, quick mode."""
from __future__ import annotations

import os
import time


def quick() -> bool:
    """True when the harness runs in smoke-test mode (``run.py --quick`` /
    ``REPRO_BENCH_QUICK=1``): modules shrink cycle counts and sweeps."""
    return os.environ.get("REPRO_BENCH_QUICK", "") not in ("", "0")


def time_us(fn, *args, repeat: int = 5, warmup: int = 1, **kw) -> float:
    if quick():
        repeat, warmup = 1, 0
    for _ in range(warmup):
        fn(*args, **kw)
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def row(name: str, us: float, derived) -> tuple[str, float, str]:
    return (name, round(us, 2), str(derived))


def emit(rows):
    for name, us, derived in rows:
        print(f"{name},{us},{derived}")
