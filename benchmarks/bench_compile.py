"""Compile economics of the compiled engine (repro.sim.xengine).

Three measurements, appended to ``benchmarks/BENCH_sim.json`` (run this
module after ``bench_simulation``, as ``benchmarks/run.py`` does):

* ``compile_cache`` block — the cold/warm/disk split for a bundled
  spec: the numpy oracle wall time vs (a) a **fresh process** that must
  compile, (b) a **second fresh process** that restores the executable
  from the persistent disk cache (`docs/compile_cache.md`), and (c) an
  in-process seed re-run that reuses the bucketed program outright.
  The headline number is ``speedup_vs_numpy_with_compile`` measured in
  the *second* process — the compile tax is paid once per machine, so
  a fresh process now keeps the compiled engine's win.
* ``xl_scale`` block — a 1040-switch Dragonfly (a=16, p=8, h=8, g=65;
  8320 terminals) pushed through the *cycle* engine (int16 state diet +
  shape bucketing), recording cycles/sec, cold-vs-warm wall time, and
  cost per grid point.  Beyond this scale the ``backend="auto"`` ladder
  still escalates to the flow tier (``bench_flow.py``).

Both subprocesses share one throwaway ``LACIN_CACHE_DIR``, so the block
also doubles as an end-to-end check that serialized executables survive
process boundaries (the CI ``cache-smoke`` lane asserts it every push).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time

from repro import sim, studies
from repro.core.dragonfly import DragonflyConfig
from repro.sim import xengine
from repro.sim.topology import dragonfly_topology

from .common import quick, row

_ARTIFACT = os.path.join(os.path.dirname(__file__), "BENCH_sim.json")

#: The subprocess payload: run a spec file through the compiled Study
#: backend and report the study wall time + the engine's own telemetry.
_CHILD = """
import json, sys, time
from repro import studies

t0 = time.perf_counter()
out = studies.Study(sys.argv[1], backend="jax").run()
wall = time.perf_counter() - t0
# One experiment -> one batched program -> one shared timing dict.
t = out.results[0].provenance["timings"]
from repro.obs.telemetry import cache_stats, disk_cache_entries
print(json.dumps({
    "study_wall_s": round(wall, 4),
    "compile_s": t["compile_s"],
    "compile_cached": t["compile_cached"],
    "points": len(out.results),
    "cache_entries": len(disk_cache_entries()),
    "cache_stats": cache_stats(),
}))
"""


def _child_run(spec_path: str, cache_dir: str) -> dict:
    env = dict(os.environ, LACIN_CACHE_DIR=cache_dir)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = os.pathsep.join(
        [src, env.get("PYTHONPATH", "")])
    proc = subprocess.run([sys.executable, "-c", _CHILD, spec_path],
                          env=env, capture_output=True, text=True,
                          timeout=1800, check=True)
    return json.loads(proc.stdout.splitlines()[-1])


def _speed_spec() -> studies.ExperimentSpec:
    """The bundled cin16_saturation uniform/minimal experiment, widened
    to a realistic 8-seed confidence sweep.  Like ``bench_simulation``'s
    headline speed row, this workload is identical in quick and full
    modes so the recorded cold/warm/disk trajectory is comparable run
    over run (and big enough that the numpy oracle's wall time is the
    thing being beaten, not process noise)."""
    [exp] = [e for e in studies.load_specs(
                 studies.bundled_spec_path("cin16_saturation"))
             if e.traffic.pattern == "uniform"
             and e.routing.policy == "minimal"]
    return exp.with_sweep(seeds=tuple(range(23, 31)))


def compile_cache_rows(out: list, blocks: dict) -> None:
    exp = _speed_spec()
    cache_dir = tempfile.mkdtemp(prefix="lacin-bench-cache-")
    spec_path = os.path.join(cache_dir, "speed.spec.json")
    with open(spec_path, "w") as f:
        f.write(exp.to_json())

    t0 = time.perf_counter()
    studies.Study(exp, backend="numpy").run()
    numpy_s = time.perf_counter() - t0

    cold = _child_run(spec_path, cache_dir)
    second = _child_run(spec_path, cache_dir)

    # In-process tiers, sharing the children's cache dir: this (third)
    # process restores from disk, and a seed re-run of the restored
    # program lands in the same shape bucket — nothing compiles at all.
    saved = os.environ.get("LACIN_CACHE_DIR")
    os.environ["LACIN_CACHE_DIR"] = cache_dir
    try:
        t0 = time.perf_counter()
        inproc = studies.Study(exp, backend="jax").run()
        inproc_s = time.perf_counter() - t0
        rerun_exp = exp.with_sweep(
            seeds=tuple(s + 100 for s in exp.sweep.seeds))
        t0 = time.perf_counter()
        rerun = studies.Study(rerun_exp, backend="jax").run()
        rerun_s = time.perf_counter() - t0
    finally:
        if saved is None:
            os.environ.pop("LACIN_CACHE_DIR", None)
        else:
            os.environ["LACIN_CACHE_DIR"] = saved
    inproc_t = inproc.results[0].provenance["timings"]
    rerun_t = rerun.results[0].provenance["timings"]

    blocks["compile_cache"] = {
        "workload": (f"cin16/uniform/minimal {len(exp.sweep.loads)} loads"
                     f" x {len(exp.sweep.seeds)} seeds x"
                     f" {exp.sweep.cycles} cycles (bundled spec, 8-seed"
                     f" sweep)"),
        "numpy_s": round(numpy_s, 4),
        "cold_process": cold,
        "second_process": second,
        "third_process_compile_cached": inproc_t["compile_cached"],
        "third_process_s": round(inproc_s, 4),
        "seed_rerun_compile_cached": rerun_t["compile_cached"],
        "seed_rerun_s": round(rerun_s, 4),
        "speedup_vs_numpy": round(numpy_s / rerun_s, 2),
        "speedup_vs_numpy_with_compile":
            round(numpy_s / second["study_wall_s"], 2),
        "speedup_vs_numpy_cold": round(numpy_s / cold["study_wall_s"], 2),
    }
    out.append(row("compile/cache/cold_process", cold["study_wall_s"] * 1e6,
                   f"compile_cached={cold['compile_cached']} "
                   f"compile={cold['compile_s']}s "
                   f"entries={cold['cache_entries']}"))
    out.append(row("compile/cache/second_process",
                   second["study_wall_s"] * 1e6,
                   f"compile_cached={second['compile_cached']} "
                   f"speedup_vs_numpy_with_compile="
                   f"{numpy_s / second['study_wall_s']:.1f}x "
                   f"(cold={numpy_s / cold['study_wall_s']:.1f}x)"))
    out.append(row("compile/cache/seed_rerun", rerun_s * 1e6,
                   f"compile_cached={rerun_t['compile_cached']} "
                   f"compile_s={rerun_t['compile_s']} (bucketed program "
                   f"reused across seeds; steady speedup="
                   f"{numpy_s / rerun_s:.1f}x)"))


def xl_scale_rows(out: list, blocks: dict) -> None:
    cycles = 64 if quick() else 256
    cfg = DragonflyConfig(group_size=16, terminals_per_switch=8,
                          global_ports_per_switch=8, num_groups=65)
    topo = dragonfly_topology(cfg)

    def tf(load, seed):
        return sim.uniform(topo.num_switches, offered=load, cycles=cycles,
                           terminals=cfg.terminals_per_switch, seed=seed)

    def run():
        return xengine.sweep(topo, "minimal", tf, [0.05], seeds=(0,),
                             terminals=cfg.terminals_per_switch,
                             cycles=cycles, warmup=cycles // 4)

    t0 = time.perf_counter()
    grid = run()
    cold_s = time.perf_counter() - t0
    cold_stats = grid[0][0]
    t0 = time.perf_counter()
    warm_stats = run()[0][0]
    warm_s = time.perf_counter() - t0

    blocks["xl_scale"] = {
        "fabric": (f"dragonfly a={cfg.group_size} "
                   f"p={cfg.terminals_per_switch} "
                   f"h={cfg.global_ports_per_switch} g={cfg.num_groups}"),
        "switches": topo.num_switches,
        "terminals": topo.num_switches * cfg.terminals_per_switch,
        "cycles": cycles,
        "cold_wall_s": round(cold_s, 4),
        "warm_wall_s": round(warm_s, 4),
        "compile_s": cold_stats.timing["compile_s"],
        "execute_s": cold_stats.timing["execute_s"],
        "cold_compile_cached": cold_stats.timing["compile_cached"],
        "cycles_per_sec": round(cycles / warm_s, 1),
        "cost_per_point_s": round(warm_s, 4),
        "packets_delivered": int(warm_stats.packets_delivered),
    }
    assert topo.num_switches >= 1024
    assert warm_stats.packets_delivered > 0
    out.append(row(f"compile/xl_scale/dragonfly{topo.num_switches}",
                   cold_s * 1e6,
                   f"cycle engine at {topo.num_switches} switches: "
                   f"cold={cold_s:.1f}s warm={warm_s:.2f}s "
                   f"({cycles / warm_s:.0f} cyc/s) "
                   f"delivered={int(warm_stats.packets_delivered)}"))


def rows():
    out: list = []
    blocks: dict = {}
    compile_cache_rows(out, blocks)
    xl_scale_rows(out, blocks)
    if os.path.exists(_ARTIFACT):
        with open(_ARTIFACT) as f:
            payload = json.load(f)
        payload.update(blocks)
        with open(_ARTIFACT, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
    return out


def main():
    from .common import emit
    emit(rows())


if __name__ == "__main__":
    main()
