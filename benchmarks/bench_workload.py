"""Real-workload benchmarks: extraction, replay, and serving SLOs.

Three measurements of the :mod:`repro.workload` subsystem:

* **Extraction** — wall time to compile an 8-device MoE training step
  (a subprocess, since XLA_FLAGS must precede jax imports) and lower
  its collective sequence into a phased workload.
* **Replay** — the extracted workload through the numpy oracle and the
  compiled engine: completion vs the contention-free bound, exact
  cross-engine agreement, per-backend wall time.
* **Serving** — the bundled ``serving_slo`` spec at cycle (numpy) and
  flow fidelity: request-latency p50/p99, SLO attainment, per-tier
  wall time, plus an ``slo_capacity`` bisection on the CIN-16 Poisson
  experiment.

Results land in a ``workload`` block of ``benchmarks/BENCH_sim.json``
(appended to the artifact ``bench_simulation`` writes — run after it,
as ``benchmarks/run.py`` does).  Quick mode (CI) shrinks the MoE step
to 4 devices and skips the capacity bisection.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

from repro.fabric import make_fabric
from repro.sim.workloads import Workload, replay

from .common import quick, row

_ARTIFACT = os.path.join(os.path.dirname(__file__), "BENCH_sim.json")
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

BYTES_PER_PACKET = 256
SLO_EXPERIMENT = "cin-xor-16/serving-poisson-r0.05/minimal"

_EXTRACT_CHILD = """
import json, sys
devices = int(sys.argv[1])
from repro.workload import moe_step_hlo, workload_from_hlo
hlo = moe_step_hlo(devices, d_model=32, d_ff=16, batch=4, seq=8)
w = workload_from_hlo(hlo, ("xor", devices), bytes_per_packet=%d)
print("RESULT " + json.dumps(w.to_dict()))
""" % BYTES_PER_PACKET


def _extract(devices: int) -> tuple[dict, float]:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH="src")
    t0 = time.perf_counter()
    res = subprocess.run(
        [sys.executable, "-c", _EXTRACT_CHILD, str(devices)], env=env,
        capture_output=True, text=True, timeout=600, cwd=_REPO)
    extract_s = time.perf_counter() - t0
    if res.returncode != 0:
        raise RuntimeError(f"extraction failed: {res.stderr[-2000:]}")
    line = [l for l in res.stdout.splitlines()
            if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):]), extract_s


def _replay_block(wd: dict) -> dict:
    w = Workload.from_dict(wd)
    topo = make_fabric("xor", w.num_switches).sim_topology()
    out = {}
    for backend in ("numpy", "jax"):
        t0 = time.perf_counter()
        stats = replay(topo, "minimal", w, backend=backend)
        out[backend] = {
            "completion_cycles": int(stats.completion_cycles),
            "ideal_cycles": int(stats.ideal_cycles),
            "replay_s": round(time.perf_counter() - t0, 4),
        }
        assert stats.completion_cycles >= stats.ideal_cycles, backend
    out["agree"] = (out["numpy"]["completion_cycles"]
                    == out["jax"]["completion_cycles"])
    assert out["agree"], f"cross-engine replay mismatch: {out}"
    return out


def _serving_block() -> dict:
    from repro.studies import Study, bundled_spec_path
    spec = bundled_spec_path("serving_slo")
    tiers = {}
    for backend in ("numpy", "flow"):
        t0 = time.perf_counter()
        result = Study(spec, backend=backend).run()
        wall = time.perf_counter() - t0
        rows_ = {}
        for r in result.results:
            e = rows_.setdefault(r.experiment, {
                "requests": 0, "p50": 0.0, "p99": 0.0, "attainment": 1.0})
            e["requests"] += r.request_count or 0
            e["p50"] = max(e["p50"], r.request_latency_p50 or 0.0)
            e["p99"] = max(e["p99"], r.request_latency_p99 or 0.0)
            if r.slo_attainment is not None:
                e["attainment"] = min(e["attainment"], r.slo_attainment)
        tiers[backend] = {"wall_s": round(wall, 4), "experiments": rows_}
    block = {"spec": "serving_slo", "tiers": tiers}
    if not quick():
        study = Study(spec, backend="numpy")
        block["slo_capacity"] = study.slo_capacity(
            SLO_EXPERIMENT, percentile=99.0, lo=0.1, hi=2.0, tol=0.1)
    return block


def rows():
    devices = 4 if quick() else 8
    wd, extract_s = _extract(devices)
    packets = sum(len(p["src"]) * p["messages"] for p in wd["phases"])
    replay_b = _replay_block(wd)
    serving = _serving_block()
    block = {
        "quick": quick(),
        "extract": {
            "step": "moe", "devices": devices,
            "bytes_per_packet": BYTES_PER_PACKET,
            "phases": len(wd["phases"]), "packets": packets,
            "extract_s": round(extract_s, 3),
        },
        "replay": replay_b,
        "serving": serving,
    }
    payload = {}
    if os.path.exists(_ARTIFACT):
        with open(_ARTIFACT) as f:
            payload = json.load(f)
    payload["workload"] = block
    with open(_ARTIFACT, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")

    out = [row(f"sim/workload/extract/moe{devices}", extract_s * 1e6,
               f"phases={len(wd['phases'])} packets={packets}")]
    for backend in ("numpy", "jax"):
        b = replay_b[backend]
        out.append(row(
            f"sim/workload/replay/{backend}", b["replay_s"] * 1e6,
            f"completion={b['completion_cycles']} "
            f"ideal={b['ideal_cycles']}"))
    for backend, tier in serving["tiers"].items():
        for name, e in sorted(tier["experiments"].items()):
            out.append(row(
                f"sim/workload/serving/{backend}/{name}", 0.0,
                f"requests={e['requests']} p99={e['p99']} "
                f"att={e['attainment']}"))
    if "slo_capacity" in serving:
        cap = serving["slo_capacity"]
        out.append(row("sim/workload/slo_capacity", 0.0,
                       f"exp={cap['experiment']} capacity={cap['capacity']}"))
    return out


def main():
    from .common import emit
    emit(rows())


if __name__ == "__main__":
    main()
