"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  The roofline section reads
the dry-run JSONs if present (run ``python -m repro.launch.dryrun --all``
first for the full table).

``--quick`` (the CI configuration) drops all ``time_us`` timings to a
single repeat with no warmup, and modules that opt in via
``common.quick()`` additionally shrink their workloads (the simulator
module shortens its sweeps; the multi-device collective subprocesses run
at full size either way).  The simulator module drives every sweep
through :mod:`repro.studies` (the bundled spec files, shrunk via
``ExperimentSpec.with_sweep`` in quick mode) and writes the unified
result records to the ``benchmarks/BENCH_sim.json`` artifact, so the
latency/throughput trajectory it records per run is exactly what
``python -m repro.studies run cin16_saturation`` (etc.) reproduces.
"""
from __future__ import annotations

import json
import os
import sys
import time
import traceback


MODULES = [
    "bench_port_matrices",   # Figure 2
    "bench_table1",          # Table 1
    "bench_layout",          # §4 wire length + crossings
    "bench_routing",         # §3 + Algorithm 2
    "bench_hyperx",          # §5 + Figure 4
    "bench_dragonfly",       # Figure 3 + §5
    "bench_simulation",      # §1/§2 link loads + step schedules
    "bench_flow",            # flow-model scale tiers (after _simulation: appends to its artifact)
    "bench_faults",          # degraded-fabric survivability (after _simulation: appends to its artifact)
    "bench_collective_replay",  # schedule -> simulator replay (after _simulation: appends to its artifact)
    "bench_workload",        # extracted-step replay + serving SLOs (after _simulation: appends to its artifact)
    "bench_compile",         # compile cache cold/warm/disk split + 1040-switch xl point (appends to the artifact)
    "bench_collectives",     # §2 refs [8,9]: LACIN collectives vs XLA
    "roofline",              # §Roofline (from dry-run JSONs)
]


def _stamp_environment(block_wall_s: dict[str, float]) -> None:
    """Merge an environment/provenance block into the BENCH_sim.json
    artifact: host + library versions, per-module wall time, and the
    simulator's measured xengine compile-vs-execute split — the context
    that makes a recorded trajectory comparable run over run."""
    artifact = os.path.join(os.path.dirname(__file__), "BENCH_sim.json")
    if not os.path.exists(artifact):
        return
    from repro.obs.telemetry import provenance
    with open(artifact) as f:
        payload = json.load(f)
    env = provenance()
    env["block_wall_s"] = block_wall_s
    speed = payload.get("sim_speed", {})
    env["xengine"] = {
        "compile_s": speed.get("jax_compile_s"),
        "execute_s": speed.get("jax_execute_s"),
        "cold_s": speed.get("jax_cold_s"),
        "steady_s": speed.get("jax_steady_s"),
    }
    payload["environment"] = env
    with open(artifact, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")


def main() -> None:
    if "--quick" in sys.argv[1:]:
        os.environ["REPRO_BENCH_QUICK"] = "1"
    print("name,us_per_call,derived")
    failures = 0
    block_wall_s: dict[str, float] = {}
    for name in MODULES:
        t0 = time.perf_counter()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["rows"])
            from benchmarks.common import emit
            emit(mod.rows())
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},0,ERROR {type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
        block_wall_s[name] = round(time.perf_counter() - t0, 3)
    try:
        _stamp_environment(block_wall_s)
    except Exception as e:  # noqa: BLE001
        failures += 1
        print(f"environment,0,ERROR {type(e).__name__}: {e}")
        traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
