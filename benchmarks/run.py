"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  The roofline section reads
the dry-run JSONs if present (run ``python -m repro.launch.dryrun --all``
first for the full table).
"""
from __future__ import annotations

import sys
import traceback


MODULES = [
    "bench_port_matrices",   # Figure 2
    "bench_table1",          # Table 1
    "bench_layout",          # §4 wire length + crossings
    "bench_routing",         # §3 + Algorithm 2
    "bench_hyperx",          # §5 + Figure 4
    "bench_dragonfly",       # Figure 3 + §5
    "bench_simulation",      # §1/§2 link loads + step schedules
    "bench_collectives",     # §2 refs [8,9]: LACIN collectives vs XLA
    "roofline",              # §Roofline (from dry-run JSONs)
]


def main() -> None:
    print("name,us_per_call,derived")
    failures = 0
    for name in MODULES:
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["rows"])
            from benchmarks.common import emit
            emit(mod.rows())
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},0,ERROR {type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
