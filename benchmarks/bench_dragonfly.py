"""Paper Figure 3 + §5 Dragonfly: partitioned-CIN bundles and LACIN
dragonfly deployment arithmetic (incl. the HPE 28-bundles-of-16 layout)."""
from __future__ import annotations

import itertools

from repro.core import (DragonflyConfig, dragonfly_link_loads, fig3_16,
                        frontier_like, hpe_dragonfly_group)
from repro.fabric import make_fabric
from .common import row, time_us


def rows():
    out = []
    us = time_us(lambda: fig3_16().report())
    r = fig3_16().report()
    assert (r["total_links"], r["intra_links"], r["inter_links"],
            r["bundles"], r["wires_per_bundle"]) == (120, 24, 96, 6, 16)
    out.append(row("fig3/partitioned16", us,
                   "links=120=24intra+96inter bundles=6x16w"))
    r = hpe_dragonfly_group().report()
    assert (r["bundles"], r["wires_per_bundle"]) == (28, 16)
    out.append(row("sec4/hpe_group", 0.0, "bundles=28x16w (2x4 partitions)"))
    df = frontier_like()
    out.append(row("sec5/dragonfly/frontier_like", 0.0,
                   f"groups={df.num_groups} switches={df.switches} "
                   f"endpoints={df.endpoints} radix={df.radix} "
                   f"links={df.total_links}"))
    # routing validation: l-g-l minimality on a small dragonfly
    d = DragonflyConfig(group_size=8, terminals_per_switch=4,
                        global_ports_per_switch=2, num_groups=16)
    def _validate():
        for ga, gb in itertools.product(range(8), repeat=2):
            for sa, sb in ((0, 7), (3, 3), (5, 1)):
                hops = d.route_packet((ga, sa, 0), (gb, sb, 1))
                kinds = [h[0] for h in hops]
                assert kinds.count("global") <= 1 and len(hops) <= 4
    us = time_us(_validate, repeat=1)
    out.append(row("sec5/dragonfly/lgl_routing", us,
                   "l-g-l minimal, <=1 global hop, isoport colour match"))
    # closed-form link loads (local/global split) via the Fabric surface,
    # cross-checked link-for-link vs the simulator in tests/test_fabric.py
    fab = make_fabric(d)
    us = time_us(fab.link_loads, repeat=1)
    loads = dragonfly_link_loads(d)
    ll = loads["summary"]
    # check the computed per-link global loads, not the summary constant
    assert set(loads["global"].values()) == {d.group_size ** 2}
    out.append(row("sec5/dragonfly/link_loads_closed_form", us,
                   f"global=a^2={ll['global_link_load']} "
                   f"local_max={ll['local_max']} "
                   f"local_mean={ll['local_mean']:.1f}"))
    assert fab.verify()["ok"]
    out.append(row("sec5/dragonfly/fabric_verify", 0.0,
                   f"Fabric.verify ok ({fab.name})"))
    return out


def main():
    from .common import emit
    emit(rows())


if __name__ == "__main__":
    main()
