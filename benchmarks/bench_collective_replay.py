"""Collective replay: the schedule -> simulator seam, measured (§2/§3).

Replays each fabric's own LACIN all-to-all schedule (the bundled
``collective_replay`` study spec: CIN-16, HyperX-256, Dragonfly-72 under
minimal vs adaptive routing) through the packet simulator and records
measured completion cycles against the schedule algebra's
contention-free bound (``num_steps x message_size``):

* the flat CIN and dimension-order HyperX replays must meet the bound
  *exactly* — every phase is a 1-factor of the links it rides, the
  paper's §2 claim under real queueing;
* the Dragonfly (local x global) grid replay exceeds it by the
  ``group_size``-flows-per-global-link serialization the two-level
  hierarchy trades for 1/a payloads (§5).

Results land in a ``collective_replay`` block of
``benchmarks/BENCH_sim.json`` (appended to the artifact
``bench_simulation`` writes — run this module after it, as
``benchmarks/run.py`` does), so the predicted-vs-measured trajectory is
recorded run over run.
"""
from __future__ import annotations

import json
import os
import time

from repro import studies
from .common import quick, row

_ARTIFACT = os.path.join(os.path.dirname(__file__), "BENCH_sim.json")


def _run_replay_study(backend: str) -> studies.StudyResult:
    specs = studies.load_specs(studies.bundled_spec_path("collective_replay"))
    if quick():
        # Quick mode drops the adaptive arm (same workloads, halves the
        # wall clock); the minimal arm carries the exactness claim.
        specs = [e for e in specs if e.routing.policy == "minimal"]
    return studies.Study(specs, backend=backend).run()


def rows():
    out = []
    t0 = time.perf_counter()
    res_jax = _run_replay_study("jax")
    jax_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    res_np = _run_replay_study("numpy")
    np_s = time.perf_counter() - t0

    jax_pts = res_jax.replay_points()
    np_pts = res_np.replay_points()
    # Minimal-routing replays are deterministic modulo arbitration, and
    # their completion is work-conserving: both engines must agree on
    # every measured completion cycle count.
    minimal = [n for n in jax_pts if n.endswith("/minimal")]
    backends_agree = all(jax_pts[n] == np_pts[n] for n in minimal)
    cin_hx_exact = all(
        jax_pts[n]["measured"] == jax_pts[n]["ideal"]
        for n in jax_pts if "dragonfly" not in n and n.endswith("/minimal"))

    block = {
        "spec": "collective_replay",
        "quick": quick(),
        "jax_s": round(jax_s, 4),
        "numpy_s": round(np_s, 4),
        "backends_agree_minimal": backends_agree,
        "cin_hyperx_meet_bound": cin_hx_exact,
        "experiments": {
            name: {**pts, "numpy_measured": np_pts[name]["measured"]}
            for name, pts in jax_pts.items()},
    }
    payload = {}
    if os.path.exists(_ARTIFACT):
        with open(_ARTIFACT) as f:
            payload = json.load(f)
    payload["collective_replay"] = block
    with open(_ARTIFACT, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    # The artifact records the evidence either way; a regression still
    # fails the bench run (and CI's perf-smoke lane) loudly.
    assert backends_agree, f"engines disagree on replay completion: {block}"
    assert cin_hx_exact, f"CIN/HyperX replay missed the bound: {block}"

    per_exp = jax_s * 1e6 / max(len(jax_pts), 1)
    for name, pts in jax_pts.items():
        out.append(row(f"sim/replay/{name}", per_exp,
                       f"measured={pts['measured']} ideal={pts['ideal']} "
                       f"ratio={pts['ratio']}"))
    out.append(row("sim/replay/validate", np_s * 1e6,
                   f"backends_agree={backends_agree} "
                   f"cin_hyperx_meet_bound={cin_hx_exact}"))
    return out


def main():
    from .common import emit
    emit(rows())


if __name__ == "__main__":
    main()
