"""Roofline reader: aggregates results/dryrun/*.json into the §Roofline
table (also emitted as markdown for EXPERIMENTS.md)."""
from __future__ import annotations

import glob
import json
from pathlib import Path

from .common import row


def load(results_dir: str = "results/dryrun"):
    recs = []
    for p in sorted(glob.glob(f"{results_dir}/*.json")):
        recs.append(json.loads(Path(p).read_text()))
    return recs


def rows(results_dir: str = "results/dryrun"):
    out = []
    for r in load(results_dir):
        cell = f"{r['arch']}/{r['shape']}/{r.get('mesh', '?')}"
        if r.get("skipped"):
            out.append(row(f"roofline/{cell}", 0.0, f"SKIP: {r['reason']}"))
            continue
        if not r.get("ok"):
            out.append(row(f"roofline/{cell}", 0.0,
                           f"FAIL: {r.get('error', '?')[:120]}"))
            continue
        t = r["roofline"]
        out.append(row(
            f"roofline/{cell}", r.get("compile_s", 0.0) * 1e6,
            f"dominant={t['dominant']} compute={t['compute_s']*1e3:.2f}ms "
            f"memory={t['memory_s']*1e3:.2f}ms "
            f"collective={t['collective_s']*1e3:.2f}ms "
            f"useful={t['useful_ratio']:.2f} "
            f"peakGB={r['memory']['peak_estimate_bytes']/1e9:.1f}"))
    return out


def markdown_table(results_dir: str = "results/dryrun",
                   mesh_filter: str | None = None) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | MODEL GF | useful | peak GB | fits |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in load(results_dir):
        mesh = r.get("mesh")
        mesh_s = (mesh if isinstance(mesh, str)
                  else "x".join(str(v) for v in mesh.values()))
        mesh_s = str(mesh_s).replace("pod2x16x16", "2x16x16") \
                            .replace("pod16x16", "16x16")
        if mesh_filter and mesh_s != mesh_filter:
            continue
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | {mesh_s} | — | — | "
                         f"— | SKIPPED | — | — | — | — |")
            continue
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | {mesh_s} | — | — | "
                         f"— | FAILED | — | — | — | — |")
            continue
        t = r["roofline"]
        peak = r["memory"]["peak_estimate_bytes"] / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | {mesh_s} "
            f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} "
            f"| {t['collective_s']:.4f} | **{t['dominant']}** "
            f"| {t['model_gflops_total']:.0f} | {t['useful_ratio']:.2f} "
            f"| {peak:.1f} | {'y' if peak < 16 else 'NO'} |")
    return hdr + "\n".join(lines) + "\n"


def main():
    from .common import emit
    emit(rows())


if __name__ == "__main__":
    main()
