"""Paper §4: LACIN wire lengths ((N^3-N)/6), the sqrt(2) anisoport factor,
and the crossing analysis (Circle's closed form + zero-crossing rule vs
XOR's growth)."""
from __future__ import annotations

from repro.core import (circle_layout_crossings_with_rule,
                        circle_predicted_crossings, instance_crossings,
                        lacin_total_wire_length,
                        lacin_total_wire_length_enumerated,
                        wire_length_histogram)
from .common import row, time_us


def rows():
    out = []
    for n in (8, 16, 64, 256):
        us = time_us(lacin_total_wire_length_enumerated, n)
        formula = lacin_total_wire_length(n)
        enum = lacin_total_wire_length_enumerated(n)
        assert formula == enum
        out.append(row(f"sec4/wire_total/N{n}", us,
                       f"(N^3-N)/6={formula} enumerated={enum}"))
        hist = wire_length_histogram(n)
        assert all(hist[d] == n - d for d in hist)
        out.append(row(f"sec4/wire_hist/N{n}", 0.0,
                       f"w wires of length N-w verified ({len(hist)} lengths)"))
    for n in (8, 16, 32):
        us = time_us(instance_crossings, "circle", n, repeat=1)
        got = instance_crossings("circle", n)
        pred = circle_predicted_crossings(n)
        assert got == pred, (got, pred)
        out.append(row(f"sec4/circle_crossings/N{n}", us,
                       f"naive={sum(got)} predicted={sum(pred)} "
                       f"with_rule={circle_layout_crossings_with_rule(n)}"))
    for n in (8, 16, 32):
        xc = sum(instance_crossings("xor", n))
        out.append(row(f"sec4/xor_crossings/N{n}", 0.0,
                       f"total={xc} (grows with N; Circle rule-> 0)"))
    return out


def main():
    from .common import emit
    emit(rows())


if __name__ == "__main__":
    main()
