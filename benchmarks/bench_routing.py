"""Paper §3 + Algorithm 2: table-free minimal routing.

Measures vectorized routing throughput (all N^2 pairs at once) for each
instance and reports the hardware cost model (Table 1's routing column).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (ROUTING_COST, port_matrix, route, route_jnp,
                        routing_ops)
from .common import row, time_us


def _all_pairs(n):
    a = np.arange(n)[:, None].repeat(n, 1)
    b = np.arange(n)[None, :].repeat(n, 0)
    return a, b


def rows():
    out = []
    for inst, n in (("swap", 1024), ("circle", 1024), ("circle", 1023),
                    ("xor", 1024)):
        a, b = _all_pairs(n)
        us = time_us(route, inst, a, b, n)
        # correctness on the full pair set
        P = port_matrix(inst, n)
        i = np.asarray(route(inst, a, b, n))
        mask = a != b
        ok = (P[a[mask], i[mask]] == b[mask]).all()
        assert ok
        out.append(row(f"sec3/route_numpy/{inst}/N{n}", us,
                       f"{us * 1e3 / (n * n):.2f}ns/route all-pairs-correct"))
        # jnp (trace-safe) variant, jitted
        fn = jax.jit(lambda a_, b_, inst=inst, n=n: route_jnp(inst, a_, b_, n))
        aj, bj = jnp.asarray(a), jnp.asarray(b)
        fn(aj, bj).block_until_ready()
        us = time_us(lambda: fn(aj, bj).block_until_ready())
        out.append(row(f"sec3/route_jit/{inst}/N{n}", us,
                       f"{us * 1e3 / (n * n):.2f}ns/route"))
    for inst in ("xor", "swap", "circle"):
        ops = routing_ops(inst)
        assert ops["total_extra_vs_xor"] == ROUTING_COST[inst]
        out.append(row(f"table1/routing_cost/{inst}", 0.0,
                       f"extra_adders_comparators={ROUTING_COST[inst]} ({ops})"))
    return out


def main():
    from .common import emit
    emit(rows())


if __name__ == "__main__":
    main()
