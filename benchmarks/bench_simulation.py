"""Paper §1/§2 closed forms + the packet-level simulator (repro.sim).

Static section (flow counting):

* Under all-to-all traffic every directed CIN link carries exactly one
  flow (diameter-1 perfect balance, Fig. 1's premise).
* Isoport step schedules (1-factors) are contention-free: one flow per
  link per step.  The Swap columns concentrate endpoints — the serialized
  all-to-all needs Theta(N^2/...) steps vs N-1 for isoport (refs [8, 9]).

Packet section (cycle-driven, queueing + credits + VCs):

* cross-validates the one-shot all-to-all against `cin_link_loads`;
* offered-load sweeps of minimal / Valiant / adaptive routing on a CIN
  under uniform and hot-pair traffic (the §3 trade-off);
* a 256-switch HyperX uniform sweep and the Dragonfly same-group
  adversary.  Results are also written to ``benchmarks/BENCH_sim.json``
  so the perf trajectory is recorded run over run.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro import sim
from repro.core import (all_to_all_steps, cin_link_loads, column_contention,
                        port_matrix, schedule_step_report)
from repro.core.dragonfly import DragonflyConfig
from repro.core.hyperx import HyperXConfig
from repro.fabric import make_fabric
from .common import quick, row, time_us

_ARTIFACT = os.path.join(os.path.dirname(__file__), "BENCH_sim.json")


def rows():
    out = []
    for inst in ("swap", "circle", "xor"):
        us = time_us(cin_link_loads, inst, 64, repeat=1)
        loads = cin_link_loads(inst, 64)
        assert set(loads.values()) == {1}
        out.append(row(f"sec1/link_loads/{inst}/N64", us,
                       "all-to-all: every directed link carries exactly 1"))
    for inst in ("circle", "xor"):
        reps = schedule_step_report(inst, 64)
        assert all(r.max_link_load == 1 and r.max_endpoint_in == 1
                   for r in reps)
        out.append(row(f"sec2/steps/{inst}/N64", 0.0,
                       f"steps={len(reps)} max_link_load=1 (matching/step)"))
    for n in (8, 16, 64):
        iso = all_to_all_steps("xor", n)
        swap = all_to_all_steps("swap", n)
        cont = column_contention(port_matrix("swap", n)).max()
        out.append(row(f"sec2/a2a_steps/N{n}", 0.0,
                       f"isoport={iso} swap_serialized={swap} "
                       f"swap_max_endpoint_multiplicity={int(cont)}"))
    # diameter-1 advantage: datum-hops of LACIN vs ring all-to-all
    from repro.core import schedule_hop_counts, valiant_link_loads
    for n in (16, 64):
        h = schedule_hop_counts(n)
        out.append(row(f"sec1/hops/N{n}", 0.0,
                       f"lacin=1 ring_max={h['ring_max_hops']} "
                       f"ring/lacin total={h['ratio']:.1f}x"))
    # §3 adaptive sketch: Valiant 2-hop spread of a hot flow
    v = valiant_link_loads("xor", 16, [(0, 1, 16.0)])
    out.append(row("sec3/valiant_hotflow/N16", 0.0,
                   f"minimal_max={v['max_min']} "
                   f"valiant_max={v['max_valiant']:.2f} VCs={v['vc_required']}"))
    out.extend(sim_rows())
    return out


# ---------------------------------------------------------------------------
# Packet-level simulator benchmarks.
# ---------------------------------------------------------------------------

def _timed(fn, best_of: int = 1):
    """(elapsed_us, result) of a call — simulator runs are deterministic
    per seed, so one timed run serves both purposes.  ``best_of`` repeats
    the call and keeps the fastest time (for noise-sensitive speed rows)."""
    best = float("inf")
    result = None
    for _ in range(best_of):
        t0 = time.perf_counter()
        result = fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6, result


def sim_rows():
    q = quick()
    cycles = 400 if q else 1200
    warmup = cycles // 4
    t = 12
    out = []
    all_stats = []

    # cross-validation: packets reproduce the closed-form link loads, and
    # the compiled engine reproduces the oracle exactly (minimal routes
    # are unique, so drained link loads are arbitration-independent).
    fab16 = make_fabric("xor", 16)
    topo16 = fab16.sim_topology()
    eng = sim.Engine(topo16, sim.MinimalPolicy(), sim.one_shot_all_to_all(16),
                     terminals=4)
    us, _ = _timed(eng.run)
    exact = eng.load.by_switch_pair() == cin_link_loads("xor", 16)
    out.append(row("sim/validate/a2a_vs_closed_form/N16", us,
                   f"exact_match={exact}"))
    us, xs = _timed(lambda: sim.simulate_jax(
        topo16, sim.MinimalPolicy(), sim.one_shot_all_to_all(16),
        terminals=4))
    out.append(row("sim/validate/xengine_vs_oracle/N16", us,
                   f"delivered_match={xs.packets_delivered == 240} "
                   f"loads_match={np.array_equal(xs.link_loads, eng.load.total)}"))

    # Headline speed benchmark: the same (loads x seeds) uniform-minimal
    # saturation sweep through both backends — a realistic
    # confidence-interval sweep (multiple seeds per point, horizon long
    # enough for steady-state statistics), identical in quick and full
    # modes so the recorded trajectory is comparable run over run.  The
    # jax number is the steady-state wall-clock of the batched compiled
    # program (compile time reported separately — it amortizes across
    # every later sweep of the same shape in the process).
    speed_cycles = 1600
    speed_loads = [0.5, 0.7, 0.9]
    speed_seeds = tuple(range(31, 39))

    def tf_speed(load, seed):
        return sim.uniform(16, offered=load, cycles=speed_cycles,
                           terminals=t, seed=seed)

    us_np, grid_np = _timed(lambda: fab16.sim_sweep(
        "minimal", tf_speed, speed_loads, seeds=speed_seeds,
        backend="numpy", terminals=t, cycles=speed_cycles,
        warmup=speed_cycles // 4), best_of=2)
    us_cold, _ = _timed(lambda: fab16.sim_sweep(
        "minimal", tf_speed, speed_loads, seeds=speed_seeds,
        backend="jax", terminals=t, cycles=speed_cycles,
        warmup=speed_cycles // 4))
    us_jax, grid_jax = _timed(lambda: fab16.sim_sweep(
        "minimal", tf_speed, speed_loads, seeds=speed_seeds,
        backend="jax", terminals=t, cycles=speed_cycles,
        warmup=speed_cycles // 4), best_of=2)
    lane_cycles = len(speed_loads) * len(speed_seeds) * speed_cycles
    acc_np = np.mean([[s.accepted for s in ss] for ss in grid_np], axis=1)
    acc_jx = np.mean([[s.accepted for s in ss] for ss in grid_jax], axis=1)
    agree = bool(np.allclose(acc_np, acc_jx, rtol=0.05, atol=0.01))
    sim_speed = {
        "workload": (f"cin16/uniform/minimal {len(speed_loads)} loads x "
                     f"{len(speed_seeds)} seeds x {speed_cycles} cycles"),
        "numpy_s": round(us_np / 1e6, 4),
        "jax_steady_s": round(us_jax / 1e6, 4),
        "jax_cold_s": round(us_cold / 1e6, 4),
        "sim_cycles_per_sec_numpy": round(lane_cycles / (us_np / 1e6), 1),
        "sim_cycles_per_sec_jax": round(lane_cycles / (us_jax / 1e6), 1),
        "speedup_vs_numpy": round(us_np / us_jax, 2),
        "speedup_vs_numpy_with_compile": round(us_np / us_cold, 2),
        "backends_agree": agree,
    }
    out.append(row("sim/speed/cin16_sweep/numpy", us_np,
                   f"{lane_cycles / (us_np / 1e6):.0f} cyc/s"))
    out.append(row("sim/speed/cin16_sweep/jax", us_jax,
                   f"{lane_cycles / (us_jax / 1e6):.0f} cyc/s "
                   f"speedup={us_np / us_jax:.1f}x "
                   f"(with_compile={us_np / us_cold:.1f}x) agree={agree}"))

    # CIN sweeps: minimal vs valiant vs adaptive, uniform + hot-pair —
    # each sweep is one compiled batched program now.
    uni_loads = [0.5, 0.9] if q else [0.3, 0.5, 0.7, 0.9]
    hot_loads = [0.2, 0.4] if q else [0.05, 0.2, 0.4, 0.6]
    patterns = {
        "uniform": (uni_loads, lambda load: sim.uniform(
            16, offered=load, cycles=cycles, terminals=t, seed=21)),
        "hotspot": (hot_loads, lambda load: sim.hotspot(
            16, offered=load, cycles=cycles, terminals=t, hot_fraction=0.9,
            seed=22)),
    }
    for pat, (loads, tf) in patterns.items():
        for pol in ("minimal", "valiant", "adaptive"):
            us, stats = _timed(lambda: sim.saturation_sweep(
                topo16, lambda: sim.make_policy(pol), tf, loads,
                terminals=t, cycles=cycles, warmup=warmup, seed=23,
                backend="jax"))
            all_stats.extend(stats)
            knee = sim.saturation_point(stats)
            acc = " ".join(f"{s.offered:.2f}:{s.accepted:.3f}" for s in stats)
            out.append(row(f"sim/cin16/{pat}/{pol}", us,
                           f"accepted[{acc}] knee={knee}"))

    # 256-switch HyperX saturation sweep, batched into one program.
    hx = make_fabric(HyperXConfig(dims=(16, 16), terminals=8))
    hx_cycles = 300 if q else 600
    hx_loads = [0.5] if q else [0.3, 0.6]

    def hx_tf(load, seed):
        return sim.uniform(256, offered=load, cycles=hx_cycles, terminals=8,
                           seed=seed)

    us, grid = _timed(lambda: hx.sim_sweep(
        "minimal", hx_tf, hx_loads, seeds=(24,), terminals=8,
        cycles=hx_cycles, warmup=hx_cycles // 4))
    stats = [ss[0] for ss in grid]
    all_stats.extend(stats)
    acc = " ".join(f"{s.offered:.2f}:{s.accepted:.3f}" for s in stats)
    out.append(row("sim/hyperx256/uniform/minimal", us,
                   f"accepted[{acc}] lat_p99={stats[-1].latency_p99:.0f}"))

    # Dragonfly same-group adversary: minimal chokes, valiant doesn't
    dcfg = DragonflyConfig(group_size=4, terminals_per_switch=2,
                           global_ports_per_switch=2, num_groups=8)
    dtopo = make_fabric(dcfg).sim_topology()
    d_cycles = 400 if q else 1000
    for pol in ("minimal", "valiant", "adaptive"):
        tr = sim.adversarial_same_group(dcfg, offered=0.3, cycles=d_cycles,
                                        terminals=2, seed=25)
        us, stats = _timed(lambda: sim.simulate(
            dtopo, sim.make_policy(pol), tr, terminals=2, cycles=d_cycles,
            warmup=d_cycles // 4, seed=25, backend="jax"))
        all_stats.append(stats)
        out.append(row(f"sim/dragonfly/adversarial/{pol}", us,
                       f"accepted={stats.accepted:.3f} "
                       f"lat_mean={stats.latency_mean:.1f}"))

    # 72-switch Dragonfly (a=6, h=2, g=12) — the sweep size the
    # interpreted engine made impractical to iterate on.
    d72 = make_fabric(DragonflyConfig(group_size=6, terminals_per_switch=3,
                                      global_ports_per_switch=2,
                                      num_groups=12))
    d72_cycles = 300 if q else 800
    d72_loads = [0.2, 0.4] if q else [0.1, 0.2, 0.3, 0.4]

    def d72_tf(load, seed):
        return sim.uniform(72, offered=load, cycles=d72_cycles, terminals=3,
                           seed=seed)

    for pol in ("minimal", "valiant"):
        us, grid = _timed(lambda: d72.sim_sweep(
            pol, d72_tf, d72_loads, seeds=(26, 27), terminals=3,
            cycles=d72_cycles, warmup=d72_cycles // 4))
        stats = [s for ss in grid for s in ss]
        all_stats.extend(stats)
        acc = " ".join(f"{ss[0].offered:.2f}:"
                       f"{sum(s.accepted for s in ss) / len(ss):.3f}"
                       for ss in grid)
        out.append(row(f"sim/dragonfly72/uniform/{pol}", us,
                       f"accepted[{acc}] ({len(stats)} runs, one program)"))

    sim.save_json(all_stats, _ARTIFACT,
                  extra={"quick": q, "sim_speed": sim_speed})
    return out


def main():
    from .common import emit
    emit(rows())


if __name__ == "__main__":
    main()
