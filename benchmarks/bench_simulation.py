"""Paper §1/§2 closed forms + the packet-level simulator (repro.sim).

Static section (flow counting):

* Under all-to-all traffic every directed CIN link carries exactly one
  flow (diameter-1 perfect balance, Fig. 1's premise).
* Isoport step schedules (1-factors) are contention-free: one flow per
  link per step.  The Swap columns concentrate endpoints — the serialized
  all-to-all needs Theta(N^2/...) steps vs N-1 for isoport (refs [8, 9]).

Packet section (cycle-driven, queueing + credits + VCs):

* cross-validates the one-shot all-to-all against `cin_link_loads`;
* offered-load sweeps of minimal / Valiant / adaptive routing on a CIN
  under uniform and hot-pair traffic (the §3 trade-off);
* a 256-switch HyperX uniform sweep and the Dragonfly same-group
  adversary.  Results are also written to ``benchmarks/BENCH_sim.json``
  so the perf trajectory is recorded run over run.

Every sweep is driven through :mod:`repro.studies`: the grids are the
*bundled spec files* (``repro/studies/specs/*.json``) — shrunk via
``ExperimentSpec.with_sweep`` in quick mode — so ``python -m
repro.studies run cin16_saturation`` reproduces exactly the saturation
knees this module records.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro import sim, studies
from repro.core import (all_to_all_steps, cin_link_loads, column_contention,
                        port_matrix, schedule_step_report)
from repro.fabric import make_fabric
from .common import quick, row, time_us

_ARTIFACT = os.path.join(os.path.dirname(__file__), "BENCH_sim.json")


def rows():
    out = []
    for inst in ("swap", "circle", "xor"):
        us = time_us(cin_link_loads, inst, 64, repeat=1)
        loads = cin_link_loads(inst, 64)
        assert set(loads.values()) == {1}
        out.append(row(f"sec1/link_loads/{inst}/N64", us,
                       "all-to-all: every directed link carries exactly 1"))
    for inst in ("circle", "xor"):
        reps = schedule_step_report(inst, 64)
        assert all(r.max_link_load == 1 and r.max_endpoint_in == 1
                   for r in reps)
        out.append(row(f"sec2/steps/{inst}/N64", 0.0,
                       f"steps={len(reps)} max_link_load=1 (matching/step)"))
    for n in (8, 16, 64):
        iso = all_to_all_steps("xor", n)
        swap = all_to_all_steps("swap", n)
        cont = column_contention(port_matrix("swap", n)).max()
        out.append(row(f"sec2/a2a_steps/N{n}", 0.0,
                       f"isoport={iso} swap_serialized={swap} "
                       f"swap_max_endpoint_multiplicity={int(cont)}"))
    # diameter-1 advantage: datum-hops of LACIN vs ring all-to-all
    from repro.core import schedule_hop_counts, valiant_link_loads
    for n in (16, 64):
        h = schedule_hop_counts(n)
        out.append(row(f"sec1/hops/N{n}", 0.0,
                       f"lacin=1 ring_max={h['ring_max_hops']} "
                       f"ring/lacin total={h['ratio']:.1f}x"))
    # §3 adaptive sketch: Valiant 2-hop spread of a hot flow
    v = valiant_link_loads("xor", 16, [(0, 1, 16.0)])
    out.append(row("sec3/valiant_hotflow/N16", 0.0,
                   f"minimal_max={v['max_min']} "
                   f"valiant_max={v['max_valiant']:.2f} VCs={v['vc_required']}"))
    out.extend(sim_rows())
    return out


# ---------------------------------------------------------------------------
# Packet-level simulator benchmarks.
# ---------------------------------------------------------------------------

def _timed(fn, best_of: int = 1):
    """(elapsed_us, result) of a call — simulator runs are deterministic
    per seed, so one timed run serves both purposes.  ``best_of`` repeats
    the call and keeps the fastest time (for noise-sensitive speed rows)."""
    best = float("inf")
    result = None
    for _ in range(best_of):
        t0 = time.perf_counter()
        result = fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6, result


def _bundled(name: str) -> list[studies.ExperimentSpec]:
    return studies.load_specs(studies.bundled_spec_path(name))


def _run_study(specs, backend="jax") -> studies.StudyResult:
    """One benchmark study run — no store, so every point really runs."""
    return studies.Study(specs, backend=backend).run()


def sim_rows():
    q = quick()
    out = []
    all_results: list[studies.Result] = []

    # cross-validation: packets reproduce the closed-form link loads, and
    # the compiled engine reproduces the oracle exactly (minimal routes
    # are unique, so drained link loads are arbitration-independent).
    fab16 = make_fabric("xor", 16)
    topo16 = fab16.sim_topology()
    eng = sim.Engine(topo16, sim.MinimalPolicy(), sim.one_shot_all_to_all(16),
                     terminals=4)
    us, _ = _timed(eng.run)
    exact = eng.load.by_switch_pair() == cin_link_loads("xor", 16)
    out.append(row("sim/validate/a2a_vs_closed_form/N16", us,
                   f"exact_match={exact}"))
    us, xs = _timed(lambda: sim.simulate_jax(
        topo16, sim.MinimalPolicy(), sim.one_shot_all_to_all(16),
        terminals=4))
    out.append(row("sim/validate/xengine_vs_oracle/N16", us,
                   f"delivered_match={xs.packets_delivered == 240} "
                   f"loads_match={np.array_equal(xs.link_loads, eng.load.total)}"))

    # Headline speed benchmark: one ExperimentSpec, run through both Study
    # backends — a realistic confidence-interval sweep (multiple seeds per
    # point, horizon long enough for steady-state statistics), identical
    # in quick and full modes so the recorded trajectory is comparable run
    # over run.  The jax number is the steady-state wall-clock of the
    # batched compiled program (compile time reported separately — it
    # amortizes across every later same-shape study in the process).
    speed_cycles = 1600
    speed_exp = studies.ExperimentSpec(
        fabric=studies.FabricSpec("cin", {"instance": "xor", "n": 16}),
        traffic=studies.TrafficSpec("uniform"),
        routing=studies.RoutingSpec("minimal"),
        sweep=studies.SweepSpec(loads=(0.5, 0.7, 0.9),
                                seeds=tuple(range(31, 39)),
                                cycles=speed_cycles,
                                warmup=speed_cycles // 4),
        terminals=12, name="speed/cin16/uniform/minimal")
    us_np, out_np = _timed(lambda: _run_study(speed_exp, "numpy"), best_of=2)
    us_cold, out_cold = _timed(lambda: _run_study(speed_exp, "jax"))
    us_jax, out_jx = _timed(lambda: _run_study(speed_exp, "jax"), best_of=2)
    # The engine's own telemetry (repro.obs) splits the cold run into
    # program build vs device execution — the measured compile tax, not
    # the cold-minus-warm estimate the wall clocks imply.
    cold_telemetry = out_cold.telemetry().get(speed_exp.name, {})
    lane_cycles = len(speed_exp.sweep.loads) * len(speed_exp.sweep.seeds) \
        * speed_cycles
    acc_np = np.mean([[r.accepted for r in ss] for ss in out_np.grid()],
                     axis=1)
    acc_jx = np.mean([[r.accepted for r in ss] for ss in out_jx.grid()],
                     axis=1)
    agree = bool(np.allclose(acc_np, acc_jx, rtol=0.05, atol=0.01))
    sim_speed = {
        "workload": (f"cin16/uniform/minimal {len(speed_exp.sweep.loads)} "
                     f"loads x {len(speed_exp.sweep.seeds)} seeds x "
                     f"{speed_cycles} cycles"),
        "numpy_s": round(us_np / 1e6, 4),
        "jax_steady_s": round(us_jax / 1e6, 4),
        "jax_cold_s": round(us_cold / 1e6, 4),
        "sim_cycles_per_sec_numpy": round(lane_cycles / (us_np / 1e6), 1),
        "sim_cycles_per_sec_jax": round(lane_cycles / (us_jax / 1e6), 1),
        "speedup_vs_numpy": round(us_np / us_jax, 2),
        "speedup_vs_numpy_with_compile": round(us_np / us_cold, 2),
        "jax_compile_s": cold_telemetry.get("compile_s"),
        "jax_execute_s": cold_telemetry.get("execute_s"),
        "backends_agree": agree,
    }
    out.append(row("sim/speed/cin16_sweep/numpy", us_np,
                   f"{lane_cycles / (us_np / 1e6):.0f} cyc/s"))
    out.append(row("sim/speed/cin16_sweep/jax", us_jax,
                   f"{lane_cycles / (us_jax / 1e6):.0f} cyc/s "
                   f"speedup={us_np / us_jax:.1f}x "
                   f"(with_compile={us_np / us_cold:.1f}x) agree={agree}"))

    # CIN sweeps: minimal vs valiant vs adaptive, uniform + hot-pair —
    # the bundled cin16_saturation spec, one compiled program per
    # experiment (quick mode shrinks the grids).
    for exp in _bundled("cin16_saturation"):
        if q:
            loads = ((0.5, 0.9) if exp.traffic.pattern == "uniform"
                     else (0.2, 0.4))
            exp = exp.with_sweep(loads=loads, cycles=400, warmup=100)
        us, res = _timed(lambda e=exp: _run_study(e))
        all_results.extend(res.results)
        knee = res.saturation_points()[exp.name]
        acc = " ".join(f"{r.offered:.2f}:{r.accepted:.3f}"
                       for r in res.results)
        out.append(row(f"sim/cin16/{exp.traffic.pattern}"
                       f"/{exp.routing.policy}", us,
                       f"accepted[{acc}] knee={knee}"))

    # 256-switch HyperX saturation sweep, batched into one program.
    [hx_exp] = _bundled("hyperx256_uniform")
    if q:
        hx_exp = hx_exp.with_sweep(loads=(0.5,), cycles=300, warmup=75)
    us, res = _timed(lambda: _run_study(hx_exp))
    all_results.extend(res.results)
    acc = " ".join(f"{r.offered:.2f}:{r.accepted:.3f}" for r in res.results)
    out.append(row("sim/hyperx256/uniform/minimal", us,
                   f"accepted[{acc}] "
                   f"lat_p99={res.results[-1].latency_p99:.0f}"))

    # Dragonfly same-group adversary: minimal chokes, valiant doesn't.
    for exp in _bundled("dragonfly_adversarial"):
        if q:
            exp = exp.with_sweep(cycles=400, warmup=100)
        us, res = _timed(lambda e=exp: _run_study(e))
        all_results.extend(res.results)
        r = res.results[0]
        out.append(row(f"sim/dragonfly/adversarial/{exp.routing.policy}", us,
                       f"accepted={r.accepted:.3f} "
                       f"lat_mean={r.latency_mean:.1f}"))

    # 72-switch Dragonfly (a=6, h=2, g=12) — the sweep size the
    # interpreted engine made impractical to iterate on.
    for exp in _bundled("dragonfly72_uniform"):
        if q:
            exp = exp.with_sweep(loads=(0.2, 0.4), cycles=300, warmup=75)
        us, res = _timed(lambda e=exp: _run_study(e))
        all_results.extend(res.results)
        grid = res.grid()
        acc = " ".join(f"{ss[0].offered:.2f}:"
                       f"{sum(r.accepted for r in ss) / len(ss):.3f}"
                       for ss in grid)
        out.append(row(f"sim/dragonfly72/uniform/{exp.routing.policy}", us,
                       f"accepted[{acc}] ({len(res.results)} runs, "
                       f"one program)"))

    payload = {"records": [r.record() for r in all_results],
               "quick": q, "sim_speed": sim_speed}
    with open(_ARTIFACT, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return out


def main():
    from .common import emit
    emit(rows())


if __name__ == "__main__":
    main()
