"""Paper §1/§2: CIN uniform-traffic balance and step-schedule contention.

* Under all-to-all traffic every directed CIN link carries exactly one
  flow (diameter-1 perfect balance, Fig. 1's premise).
* Isoport step schedules (1-factors) are contention-free: one flow per
  link per step.  The Swap columns concentrate endpoints — the serialized
  all-to-all needs Theta(N^2/...) steps vs N-1 for isoport (refs [8, 9]).
"""
from __future__ import annotations

from repro.core import (all_to_all_steps, cin_link_loads, column_contention,
                        port_matrix, schedule_step_report)
from .common import row, time_us


def rows():
    out = []
    for inst in ("swap", "circle", "xor"):
        us = time_us(cin_link_loads, inst, 64, repeat=1)
        loads = cin_link_loads(inst, 64)
        assert set(loads.values()) == {1}
        out.append(row(f"sec1/link_loads/{inst}/N64", us,
                       "all-to-all: every directed link carries exactly 1"))
    for inst in ("circle", "xor"):
        reps = schedule_step_report(inst, 64)
        assert all(r.max_link_load == 1 and r.max_endpoint_in == 1
                   for r in reps)
        out.append(row(f"sec2/steps/{inst}/N64", 0.0,
                       f"steps={len(reps)} max_link_load=1 (matching/step)"))
    for n in (8, 16, 64):
        iso = all_to_all_steps("xor", n)
        swap = all_to_all_steps("swap", n)
        cont = column_contention(port_matrix("swap", n)).max()
        out.append(row(f"sec2/a2a_steps/N{n}", 0.0,
                       f"isoport={iso} swap_serialized={swap} "
                       f"swap_max_endpoint_multiplicity={int(cont)}"))
    # diameter-1 advantage: datum-hops of LACIN vs ring all-to-all
    from repro.core import schedule_hop_counts, valiant_link_loads
    for n in (16, 64):
        h = schedule_hop_counts(n)
        out.append(row(f"sec1/hops/N{n}", 0.0,
                       f"lacin=1 ring_max={h['ring_max_hops']} "
                       f"ring/lacin total={h['ratio']:.1f}x"))
    # §3 adaptive sketch: Valiant 2-hop spread of a hot flow
    v = valiant_link_loads("xor", 16, [(0, 1, 16.0)])
    out.append(row("sec3/valiant_hotflow/N16", 0.0,
                   f"minimal_max={v['max_min']} "
                   f"valiant_max={v['max_valiant']:.2f} VCs={v['vc_required']}"))
    return out


def main():
    from .common import emit
    emit(rows())


if __name__ == "__main__":
    main()
