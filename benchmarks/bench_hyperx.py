"""Paper §5 + Figure 4: HyperX deployments wired with XOR LACINs.

Reproduces the 16x16x16 flagship arithmetic (65,536 end-points, radix 61,
120 Z-links in 15 colour columns of 8, 120 hoses of 16 wires in 15 colour
classes of 8) and validates DOR routing + uniform link-load balance on the
Figure-4-sized 4x4x4 instance.
"""
from __future__ import annotations

from repro.core import HyperXConfig, all_pairs_max_hops, paper_16cubed
from repro.fabric import make_fabric
from .common import row, time_us


def rows():
    out = []
    us = time_us(lambda: paper_16cubed().report())
    r = make_fabric(paper_16cubed().config).deployment()
    assert (r["switches"], r["endpoints"], r["radix"]) == (4096, 65536, 61)
    assert (r["z_links_per_rack"], r["z_columns_per_rack"],
            r["z_wires_per_column"]) == (120, 15, 8)
    assert (r["hoses_per_rack_row"], r["hose_colours_x"]) == (120, (15, 8))
    out.append(row("sec5/hyperx16/report", us,
                   f"switches=4096 endpoints=65536 radix=61 "
                   f"z=15cols*8wires hoses=120*16w colours=15*8"))
    fab4 = make_fabric(HyperXConfig(dims=(4, 4, 4), terminals=4))
    r4 = fab4.deployment()
    out.append(row("fig4/hyperx4/report", 0.0,
                   f"switches={r4['switches']} endpoints={r4['endpoints']} "
                   f"radix={r4['radix']} hoses={r4['hoses_per_rack_row']}"))
    cfg = fab4.config
    us = time_us(all_pairs_max_hops, cfg, repeat=1)
    assert all_pairs_max_hops(cfg) == 3
    out.append(row("sec5/hyperx4/dor_diameter", us, "max_hops=3 == D"))
    fab2 = make_fabric(HyperXConfig(dims=(4, 4), terminals=4))
    us = time_us(fab2.link_loads, repeat=1)
    ll = fab2.link_loads()
    assert ll["load_cv"] == 0.0
    out.append(row("sec5/hyperx/link_load_uniform", us,
                   f"cv={ll['load_cv']} max={ll['max_link_load']} "
                   f"avg_hops={ll['avg_hops']}"))
    assert fab2.verify()["ok"] and fab4.verify()["ok"]
    out.append(row("sec5/hyperx/fabric_verify", 0.0,
                   "Fabric.verify ok for 4x4 and 4x4x4"))
    return out


def main():
    from .common import emit
    emit(rows())


if __name__ == "__main__":
    main()
