"""Paper Figure 2: P-matrix construction for the three CIN instances.

Verifies (and times) Swap/Circle/XOR construction across sizes; derived
column records the structural verification (complete / isoport / #links).
"""
from __future__ import annotations

import numpy as np

from repro.core import port_matrix, verify_instance
from .common import row, time_us


def rows():
    out = []
    for inst, sizes in (("swap", (8, 64, 256, 1024)),
                        ("circle", (8, 63, 256, 1023)),
                        ("xor", (8, 64, 256, 1024))):
        for n in sizes:
            us = time_us(port_matrix, inst, n)
            rep = verify_instance(inst, n)
            assert rep["ok"], rep
            out.append(row(
                f"fig2/pmatrix/{inst}/N{n}", us,
                f"links={rep['num_links']} isoport={rep['isoport']} "
                f"complete={rep['complete']}"))
    # The exact Figure-2 N=8 matrices, flattened checksum for reproducibility
    for inst in ("swap", "circle", "xor"):
        P = port_matrix(inst, 8)
        out.append(row(f"fig2/pmatrix/{inst}/N8_checksum", 0.0,
                       int(np.sum(P * np.arange(1, P.size + 1).reshape(P.shape)))))
    return out


def main():
    from .common import emit
    emit(rows())


if __name__ == "__main__":
    main()
