"""Degraded-fabric survivability: throughput retention + table build cost.

Two questions the :mod:`repro.faults` subsystem answers quantitatively:

* **Throughput retention** — for each paper family at its bundled-spec
  size, the flow-model saturation knee on the degraded fabric at
  f ∈ {0, 1%, 5%, 10%} random link failures (seeded, ``strict`` policy
  so nothing is dropped: the curves measure pure rerouting cost), as a
  fraction of the pristine knee.
* **Fallback-table build time** — wall seconds for
  :func:`repro.faults.degrade` (connectivity check + vectorized BFS +
  dense fallback table) at ~1k and ~4k switches, the scales the flow
  backend sweeps routinely.

Results land in a ``failure_sweep`` block of
``benchmarks/BENCH_sim.json`` (appended to the artifact
``bench_simulation`` writes — run after it, as ``benchmarks/run.py``
does).  Quick mode (CI) drops the 4k build tier and coarsens the knee
bisection.
"""
from __future__ import annotations

import json
import os
import time

from repro.core.dragonfly import DragonflyConfig
from repro.core.hyperx import HyperXConfig
from repro.fabric import make_fabric
from repro.faults import FailureSpec, degrade
from repro.flow import FlowParams, saturation_load
from repro.sim.topology import hyperx_topology

from .common import quick, row

_ARTIFACT = os.path.join(os.path.dirname(__file__), "BENCH_sim.json")

#: Link-failure fractions of the retention curve (the satellite's grid).
FRACTIONS = (0.0, 0.01, 0.05, 0.1)
FAIL_SEED = 3

#: (label, terminals, builder) per paper family at bundled-spec size.
FAMILIES = [
    ("cin-16", 12, lambda: make_fabric("xor", 16).sim_topology()),
    ("hyperx-256", 8, lambda: make_fabric(
        HyperXConfig(dims=(16, 16), terminals=8)).sim_topology()),
    ("dragonfly-72", 3, lambda: make_fabric(DragonflyConfig(
        group_size=6, terminals_per_switch=3, global_ports_per_switch=2,
        num_groups=12)).sim_topology()),
]

#: (label, builder) for the degraded-table build-time tiers.
BUILD_TIERS = [
    ("hyperx-1k", lambda: hyperx_topology(HyperXConfig(
        dims=(32, 32), terminals=1))),
    ("hyperx-4k", lambda: hyperx_topology(HyperXConfig(
        dims=(64, 64), terminals=1))),
]


def _retention(label: str, terminals: int, build) -> dict:
    params = FlowParams()
    topo = build()
    tol = 0.1 if quick() else 0.05
    knees = {}
    for f in FRACTIONS:
        t = topo if f == 0 else degrade(
            topo, FailureSpec(link_fraction=f, seed=FAIL_SEED))
        k = saturation_load(t, routing="minimal", pattern="uniform",
                            terminals=terminals, params=params,
                            lo=0.05, hi=1.0, tol=tol)
        # None = no saturation below the search ceiling; clamp to it so
        # the retention ratio stays defined (and conservative).
        knees[f] = 1.0 if k is None else float(k)
    pristine = knees[0.0]
    return {
        "family": label,
        "topology": topo.name,
        "switches": int(topo.num_switches),
        "terminals": terminals,
        "seed": FAIL_SEED,
        "knees": {f"{f:g}": round(k, 4) for f, k in knees.items()},
        "retention": {f"{f:g}": round(k / pristine, 4)
                      for f, k in knees.items()},
    }


def _build_time(label: str, build) -> dict:
    topo = build()
    spec = FailureSpec(link_fraction=0.01, seed=FAIL_SEED)
    t0 = time.perf_counter()
    degraded = degrade(topo, spec)
    build_s = time.perf_counter() - t0
    return {
        "tier": label,
        "switches": int(topo.num_switches),
        "build_s": round(build_s, 4),
        "degraded_diameter": int(degraded.diameter),
        "pristine_diameter": int(topo.diameter),
    }


def rows():
    out = []
    families = [_retention(*fam) for fam in FAMILIES]
    tiers = BUILD_TIERS[:1] if quick() else BUILD_TIERS
    builds = [_build_time(label, build) for label, build in tiers]
    block = {
        "quick": quick(),
        "fractions": list(FRACTIONS),
        "routing": "minimal",
        "pattern": "uniform",
        "policy": "strict",
        "families": families,
        "table_build": builds,
    }
    payload = {}
    if os.path.exists(_ARTIFACT):
        with open(_ARTIFACT) as f:
            payload = json.load(f)
    payload["failure_sweep"] = block
    with open(_ARTIFACT, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    for fam in families:
        # Retention is monotone non-increasing by construction of the
        # knee; a violation means the fallback tables mis-route.
        rets = [fam["retention"][f"{f:g}"] for f in FRACTIONS]
        assert all(a >= b - 1e-9 for a, b in zip(rets, rets[1:])), (
            f"throughput retention not monotone for {fam['family']}: {fam}")
        out.append(row(
            f"sim/faults/{fam['family']}", 0.0,
            " ".join(f"f{f:g}={fam['retention'][f'{f:g}']}"
                     for f in FRACTIONS)))
    for b in builds:
        out.append(row(f"sim/faults/build/{b['tier']}",
                       b["build_s"] * 1e6,
                       f"switches={b['switches']} build_s={b['build_s']}"))
    return out


def main():
    from .common import emit
    emit(rows())


if __name__ == "__main__":
    main()
