"""Flow-backend scale: grid points the cycle engines cannot reach.

Solves one uniform-traffic grid point and a saturation-knee bisection
on ~1k/4k/10k-switch Dragonfly and HyperX fabrics with the
:mod:`repro.flow` fair-share model, recording wall-seconds (topology
build vs per-point solve, separately — the Python-loop topology builds
dominate at 10k and are amortized across a study's grid by the
``Study`` topology cache) and the predicted saturation load.

Results land in a ``flow_scale`` block of ``benchmarks/BENCH_sim.json``
(appended to the artifact ``bench_simulation`` writes — run this module
after it, as ``benchmarks/run.py`` does).  The headline acceptance
number is ``max_point_seconds``: a 10k-switch grid point must solve in
under 10 seconds.  Quick mode (CI) keeps the ~1k fabrics only.
"""
from __future__ import annotations

import json
import os
import time

from repro.core.dragonfly import DragonflyConfig
from repro.core.hyperx import HyperXConfig
from repro.flow import FlowParams, pattern_demands, saturation_load, \
    solve_flows
from repro.sim.topology import dragonfly_topology, hyperx_topology

from .common import quick, row

_ARTIFACT = os.path.join(os.path.dirname(__file__), "BENCH_sim.json")

TERMINALS = 16
POINT_LOAD = 0.6        # the single timed grid point's offered load

#: (label, builder) per scale tier; quick mode keeps the ~1k tier.
FABRICS = [
    ("dragonfly-1k", lambda: dragonfly_topology(DragonflyConfig(
        group_size=16, terminals_per_switch=TERMINALS,
        global_ports_per_switch=8, num_groups=64))),
    ("hyperx-1k", lambda: hyperx_topology(HyperXConfig(
        dims=(32, 32), terminals=TERMINALS))),
    ("dragonfly-4k", lambda: dragonfly_topology(DragonflyConfig(
        group_size=16, terminals_per_switch=TERMINALS,
        global_ports_per_switch=16, num_groups=256))),
    ("hyperx-4k", lambda: hyperx_topology(HyperXConfig(
        dims=(64, 64), terminals=TERMINALS))),
    ("dragonfly-10k", lambda: dragonfly_topology(DragonflyConfig(
        group_size=32, terminals_per_switch=TERMINALS,
        global_ports_per_switch=10, num_groups=313))),
    ("hyperx-10k", lambda: hyperx_topology(HyperXConfig(
        dims=(100, 100), terminals=TERMINALS, instance="circle"))),
]


def _bench_fabric(label: str, build) -> dict:
    params = FlowParams()
    t0 = time.perf_counter()
    topo = build()
    build_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    src, dst, rate = pattern_demands(topo, "uniform", POINT_LOAD,
                                     TERMINALS, params, None)
    sol = solve_flows(topo, "minimal", src, dst, rate, params=params)
    point_s = time.perf_counter() - t0
    accepted = sol.delivered_rate / (topo.num_switches * TERMINALS)
    t0 = time.perf_counter()
    # Coarse bisection: the knee to ~0.05 per-terminal load, each probe
    # one full solve on the (cached) topology.
    knee = saturation_load(topo, routing="minimal", pattern="uniform",
                           terminals=TERMINALS, params=params,
                           lo=0.05, hi=1.0, tol=0.05)
    knee_s = time.perf_counter() - t0
    return {
        "fabric": label,
        "topology": topo.name,
        "switches": int(topo.num_switches),
        "endpoints": int(topo.num_switches * TERMINALS),
        "build_s": round(build_s, 4),
        "point_s": round(point_s, 4),
        "point_load": POINT_LOAD,
        "point_accepted": round(accepted, 4),
        "saturation_load": knee,
        "saturation_search_s": round(knee_s, 4),
    }


def rows():
    out = []
    fabrics = [f for f in FABRICS if f[0].endswith("-1k")] if quick() \
        else FABRICS
    results = [_bench_fabric(label, build) for label, build in fabrics]
    max_point = max(r["point_s"] for r in results)
    block = {
        "quick": quick(),
        "terminals": TERMINALS,
        "routing": "minimal",
        "pattern": "uniform",
        "rows": results,
        "max_point_seconds": round(max_point, 4),
    }
    payload = {}
    if os.path.exists(_ARTIFACT):
        with open(_ARTIFACT) as f:
            payload = json.load(f)
    payload["flow_scale"] = block
    with open(_ARTIFACT, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    # Recorded either way; a regression still fails the bench loudly.
    assert max_point < 10.0, (
        f"flow grid point exceeded the 10s budget: {block}")
    for r in results:
        out.append(row(
            f"sim/flow/{r['fabric']}", r["point_s"] * 1e6,
            f"switches={r['switches']} knee={r['saturation_load']} "
            f"build_s={r['build_s']} point_s={r['point_s']}"))
    out.append(row("sim/flow/max_point", max_point * 1e6,
                   f"budget_s=10 quick={quick()}"))
    return out


def main():
    from .common import emit
    emit(rows())


if __name__ == "__main__":
    main()
