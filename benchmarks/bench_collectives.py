"""Paper §2 refs [8,9] applied: LACIN-scheduled collectives vs XLA's.

Runs in a subprocess with 8 host devices (the bench harness itself keeps
the default single-device environment).  Measures wall time of the XOR /
Circle / cyclic(anisoport) ppermute schedules against lax.psum /
lax.all_to_all for a few payload sizes, and counts the collective-permute
steps in the compiled HLO (must be N-1 per matching schedule).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import row

_CHILD = r"""
import json, time
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro._compat.jaxapi import shard_map
from repro.core import all_reduce_lacin, all_to_all_lacin

devs = jax.devices(); n = len(devs)
mesh = Mesh(np.array(devs), ("x",))
out = []

def timeit(fn, *args):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else None
    r = fn(*args); jax.block_until_ready(r)
    best = 1e9
    for _ in range(10):
        t0 = time.perf_counter(); jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6

for size in (1 << 16, 1 << 20, 1 << 22):
    x = jnp.arange(n * size, dtype=jnp.float32).reshape(n, size)
    for inst in ("xor", "circle", "cyclic"):
        f = jax.jit(shard_map(
            lambda xl, inst=inst: all_reduce_lacin(xl[0], "x", axis_size=n,
                                                   instance=inst)[None],
            mesh=mesh, in_specs=P("x"), out_specs=P("x")))
        us = timeit(f, x)
        out.append((f"collective/all_reduce/{inst}/{4*size}B", us, "lacin"))
    f = jax.jit(shard_map(lambda xl: jax.lax.psum(xl[0], "x")[None],
                          mesh=mesh, in_specs=P("x"), out_specs=P("x")))
    us = timeit(f, x)
    out.append((f"collective/all_reduce/xla_psum/{4*size}B", us, "xla"))

# hierarchical schedules on a HyperX/Dragonfly-shaped (2, 4) mesh:
# dimension-order grid all-to-all and two-level all-reduce
from repro.fabric import LacinCollectives
mesh2d = Mesh(np.array(devs).reshape(2, 4), ("g", "l"))
coll = LacinCollectives(mesh=mesh2d)
for size in (1 << 16, 1 << 20):
    x = jnp.arange(n * size, dtype=jnp.float32).reshape(n, size)
    f = jax.jit(shard_map(
        lambda xl: coll.all_reduce_two_level(xl[0], "l", "g")[None],
        mesh=mesh2d, in_specs=P(("g", "l")), out_specs=P(("g", "l"))))
    out.append((f"collective/two_level_all_reduce/2x4/{4*size}B",
                timeit(f, x), "local RS -> global AR -> local AG"))
    xa = jnp.arange(n * n * (size // n), dtype=jnp.float32).reshape(
        n, n, size // n)
    f = jax.jit(shard_map(
        lambda xl: coll.all_to_all_grid(xl[0], ("g", "l"))[None],
        mesh=mesh2d, in_specs=P(("g", "l")), out_specs=P(("g", "l"))))
    out.append((f"collective/grid_a2a/2x4/{4*size}B",
                timeit(f, xa), "per-dimension LACIN schedules, composed"))

# step counts in HLO: N-1 ppermutes per matching collective chain
import re
def count_cp(inst):
    f = jax.jit(shard_map(
        lambda xl: all_to_all_lacin(xl[0], "x", axis_size=n,
                                    instance=inst)[None],
        mesh=mesh, in_specs=P("x"), out_specs=P("x")))
    txt = f.lower(jax.ShapeDtypeStruct((n, n, 64), jnp.float32)).compile().as_text()
    # match op instances only — the bare name also appears in metadata
    return len(re.findall(r"collective-permute\(", txt))
for inst in ("xor", "circle"):
    out.append((f"collective/a2a_steps_hlo/{inst}", float(count_cp(inst)),
                f"expect {n-1}"))
print(json.dumps(out))
"""


def rows():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.environ.get("PYTHONPATH", "src"))
    res = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                         capture_output=True, text=True, timeout=600)
    if res.returncode != 0:
        return [row("collective/subprocess", 0.0,
                    f"FAILED: {res.stderr[-300:]}")]
    data = json.loads(res.stdout.strip().splitlines()[-1])
    return [row(name, us, derived) for name, us, derived in data]


def main():
    from .common import emit
    emit(rows())


if __name__ == "__main__":
    main()
