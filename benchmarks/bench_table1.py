"""Paper Table 1: isoport property, sizes, normalized wire length, routing
cost of the three 1-D CIN layouts."""
from __future__ import annotations

from repro.core import ROUTING_COST, swap_to_lacin_ratio, table1
from .common import row, time_us


def rows():
    out = []
    us = time_us(table1, 64)
    for r in table1(n=64):
        out.append(row(
            f"table1/{r.instance}", us / 3,
            f"isoport={r.isoport} sizes={r.sizes} "
            f"wire_norm={r.wire_length_norm:.4f} routing_cost={r.routing_cost}"))
    # asymptotic sqrt(2) check for Swap
    for n in (64, 256, 1024):
        out.append(row(f"table1/swap_ratio/N{n}",
                       time_us(swap_to_lacin_ratio, n, repeat=1),
                       f"{swap_to_lacin_ratio(n):.5f} (-> sqrt2=1.41421)"))
    return out


def main():
    from .common import emit
    emit(rows())


if __name__ == "__main__":
    main()
