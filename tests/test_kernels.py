"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracle,
executed in interpret mode on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.ops import flash_attention
from repro.kernels.ref import reference_attention


def make_qkv(key, b, t, s, h, kvh, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    q = jax.random.normal(ks[0], (b, t, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, kvh, d), dtype)
    v = jax.random.normal(ks[2], (b, s, kvh, d), dtype)
    return q, k, v


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,t,s,h,kvh,d", [
    (1, 128, 128, 2, 2, 64),      # MHA square
    (2, 128, 256, 4, 2, 64),      # GQA, S > T (cache-extended)
    (1, 256, 256, 4, 1, 128),     # MQA, d = 128
    (2, 96, 160, 4, 2, 64),       # non-multiples of block -> padding path
    (1, 8, 8, 2, 2, 32),          # tiny
])
def test_flash_vs_ref_causal(dtype, b, t, s, h, kvh, d):
    q, k, v = make_qkv(0, b, t, s, h, kvh, d, dtype)
    # offset q positions so q attends to the cache prefix (s >= t)
    q_pos = jnp.arange(s - t, s, dtype=jnp.int32)
    out = flash_attention(q, k, v, q_pos=q_pos, block_q=64, block_k=64)
    ref = reference_attention(q, k, v, q_pos=q_pos)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               **TOL[dtype])


@pytest.mark.parametrize("window", [1, 7, 64, 1000])
def test_flash_sliding_window(window):
    q, k, v = make_qkv(1, 2, 128, 128, 4, 2, 64, jnp.float32)
    out = flash_attention(q, k, v, window=window, block_q=64, block_k=64)
    ref = reference_attention(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_noncausal():
    q, k, v = make_qkv(2, 1, 64, 96, 2, 2, 64, jnp.float32)
    out = flash_attention(q, k, v, causal=False, block_q=32, block_k=32)
    ref = reference_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_decode_shape():
    """T=1 decode against a long cache."""
    q, k, v = make_qkv(3, 4, 1, 512, 8, 2, 64, jnp.float32)
    q_pos = jnp.asarray([511], jnp.int32)
    out = flash_attention(q, k, v, q_pos=q_pos, block_q=8, block_k=128)
    ref = reference_attention(q, k, v, q_pos=q_pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_fully_masked_rows_are_zero():
    """Queries with position before every key -> all-masked -> zeros."""
    q, k, v = make_qkv(4, 1, 16, 32, 2, 2, 32, jnp.float32)
    q_pos = jnp.full((16,), -5, jnp.int32)    # before all kv positions
    out = flash_attention(q, k, v, q_pos=q_pos)
    assert np.allclose(np.asarray(out), 0.0)


@settings(max_examples=20, deadline=None)
@given(
    t=st.sampled_from([16, 48, 128]),
    h=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2]),
    d=st.sampled_from([32, 64]),
    window=st.sampled_from([0, 5, 33]),
)
def test_flash_property_sweep(t, h, g, d, window):
    kvh = h
    q, k, v = make_qkv(t * h + d, 1, t, t, h * g, kvh, d, jnp.float32)
    out = flash_attention(q, k, v, window=window, block_q=32, block_k=32)
    ref = reference_attention(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def _mlstm_inputs(key, b, t, h, d, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(key), 5)
    q = jax.random.normal(ks[0], (b, t, h, d), dtype)
    k = jax.random.normal(ks[1], (b, t, h, d), dtype)
    v = jax.random.normal(ks[2], (b, t, h, d), dtype)
    li = jax.random.normal(ks[3], (b, t, h)) * 2
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, t, h)) * 2 + 1)
    return q, k, v, li, lf


def test_mlstm_chunkwise_vs_sequential_oracle():
    from repro.models.xlstm import mlstm_chunkwise
    from repro.kernels.ref import reference_mlstm
    q, k, v, li, lf = _mlstm_inputs(7, 2, 128, 2, 32)
    h1, s1 = reference_mlstm(q, k, v, li, lf)
    h2, s2 = mlstm_chunkwise(q, k, v, li, lf, chunk=32)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=5e-4, atol=5e-5)
    for a, b_ in zip(s1, s2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=5e-4, atol=5e-5)


@pytest.mark.parametrize("b,t,h,d,chunk", [
    (1, 64, 1, 16, 16),
    (2, 128, 3, 32, 32),
    (2, 256, 2, 64, 64),     # MXU-aligned head dim
    (1, 96, 2, 32, 48),      # chunk not a power of two
])
def test_mlstm_pallas_kernel_vs_oracle(b, t, h, d, chunk):
    from repro.kernels.ops import mlstm_scan
    from repro.kernels.ref import reference_mlstm
    q, k, v, li, lf = _mlstm_inputs(b * t + d, b, t, h, d)
    out = mlstm_scan(q, k, v, li, lf, chunk=chunk)
    ref, _ = reference_mlstm(q, k, v, li, lf)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-4, atol=5e-5)


def test_mlstm_pallas_kernel_bf16():
    from repro.kernels.ops import mlstm_scan
    from repro.kernels.ref import reference_mlstm
    q, k, v, li, lf = _mlstm_inputs(11, 1, 64, 2, 32, jnp.bfloat16)
    out = mlstm_scan(q, k, v, li, lf, chunk=32)
    ref, _ = reference_mlstm(q, k, v, li, lf)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2, atol=5e-2)
