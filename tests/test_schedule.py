"""1-factor step schedules (the paper's isoport property as a collective
schedule)."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import make_schedule, partner_table


@pytest.mark.parametrize("inst,n", [("xor", 8), ("xor", 16), ("circle", 8),
                                    ("circle", 7), ("cyclic", 8)])
def test_schedule_structure(inst, n):
    s = make_schedule(inst, n)
    assert s.is_contention_free()
    assert s.covers_all_pairs()
    if inst in ("xor", "circle"):
        assert s.is_matching_per_step()       # isoport <=> involution/step
    if inst == "cyclic":
        assert not s.is_matching_per_step()   # anisoport baseline


def test_auto_selects_xor_for_pow2_else_circle():
    assert make_schedule("auto", 16).instance == "xor"
    assert make_schedule("auto", 12).instance == "circle"


def test_step_counts():
    assert make_schedule("xor", 16).num_steps == 15
    assert make_schedule("circle", 16).num_steps == 15
    assert make_schedule("circle", 9).num_steps == 9   # odd: one idle/step


def test_inverse_table_is_inverse():
    import numpy as np
    for inst, n in (("cyclic", 8), ("xor", 8), ("circle", 7)):
        s = make_schedule(inst, n)
        for step in range(s.num_steps):
            send = np.asarray(s.table[step])
            recv = np.asarray(s.inv_table[step])
            assert np.array_equal(send[recv], np.arange(n))


@settings(max_examples=30, deadline=None)
@given(n=st.integers(2, 64))
def test_schedule_property_auto(n):
    s = make_schedule("auto", n)
    assert s.is_contention_free() and s.covers_all_pairs()
    assert s.is_matching_per_step()
