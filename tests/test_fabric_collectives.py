"""Hierarchical LACIN collectives vs ``lax`` references on an 8-host-device
mesh (subprocess keeps the main test process single-device).

* multi-axis dimension-order all-to-all over HyperX-shaped meshes
  ((2,4) and (2,2,2)) — bit-identical to ``lax.all_to_all`` with a tuple
  of axis names (pure permutation, so exact equality is required);
* two-level Dragonfly all-reduce (local RS -> global AR -> local AG) —
  bit-identical to ``lax.psum`` over both axes on integer-valued floats
  (exact summation) and allclose on gaussians;
* mesh-aware size inference: no ``axis_size=`` anywhere in the child —
  sizes come from the bound mesh or the axis environment, including an
  odd local axis (3) that exercises the idle-step Circle schedule;
* ``DragonflyFabric.collectives(mesh, ...)`` binding local/global
  instances per axis.
"""
import json
import os
import subprocess
import sys

import pytest

_CHILD = r"""
import json
import numpy as np, jax, jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from repro._compat.jaxapi import shard_map
from repro.core import DragonflyConfig
from repro.fabric import LacinCollectives, make_fabric

devs = jax.devices()
assert len(devs) == 8, len(devs)
results = {}


def run(mesh, axes, fn, x):
    return shard_map(lambda xl: fn(xl[0])[None], mesh=mesh,
                     in_specs=P(axes), out_specs=P(axes))(x)


# ---- multi-axis dimension-order all-to-all (HyperX-shaped meshes) ----------
for shape, names in (((2, 4), ("a", "b")), ((2, 2, 2), ("a", "b", "c")),
                     ((4, 2), ("a", "b"))):
    mesh = Mesh(np.array(devs).reshape(shape), names)
    coll = LacinCollectives(mesh=mesh)
    n = 8
    x = jax.random.normal(jax.random.PRNGKey(0), (n, n, 3, 2))
    got = run(mesh, names, lambda xl: coll.all_to_all_grid(xl, names), x)
    ref = run(mesh, names,
              lambda xl: lax.all_to_all(xl[:, None], names, split_axis=0,
                                        concat_axis=0).reshape(n, 3, 2), x)
    tag = "x".join(map(str, shape))
    results[f"grid_a2a_{tag}"] = bool(jnp.array_equal(got, ref))

# meshless variant: sizes inferred from the axis environment inside the
# shard_map body (no mesh bound, no axis_size threading).
mesh = Mesh(np.array(devs).reshape(2, 4), ("a", "b"))
free = LacinCollectives()
x = jax.random.normal(jax.random.PRNGKey(4), (8, 8, 5))
got = run(mesh, ("a", "b"), lambda xl: free.all_to_all_grid(xl, ("a", "b")), x)
ref = run(mesh, ("a", "b"),
          lambda xl: lax.all_to_all(xl[:, None], ("a", "b"), split_axis=0,
                                    concat_axis=0).reshape(8, 5), x)
results["grid_a2a_meshless"] = bool(jnp.array_equal(got, ref))

# ---- two-level Dragonfly all-reduce ----------------------------------------
# mesh (g, l) = (2, 4): groups of 4 under a global CIN of 2.
meshd = Mesh(np.array(devs).reshape(2, 4), ("g", "l"))
colld = LacinCollectives(mesh=meshd,
                         axis_instances=(("l", "circle"), ("g", "circle")))

xi = jnp.asarray(np.random.default_rng(0).integers(-8, 8, (8, 7, 3)),
                 jnp.float32)
got = run(meshd, ("g", "l"),
          lambda xl: colld.all_reduce_two_level(xl, "l", "g"), xi)
ref = run(meshd, ("g", "l"), lambda xl: lax.psum(xl, ("g", "l")), xi)
results["two_level_ar_exact"] = bool(jnp.array_equal(got, ref))

xg = jax.random.normal(jax.random.PRNGKey(1), (8, 6, 5))
got = run(meshd, ("g", "l"),
          lambda xl: colld.all_reduce_two_level(xl, "l", "g"), xg)
ref = run(meshd, ("g", "l"), lambda xl: lax.psum(xl, ("g", "l")), xg)
results["two_level_ar_close"] = bool(jnp.allclose(got, ref, rtol=1e-5,
                                                  atol=1e-6))

# odd local axis (3 of the 8 devices unused): mesh (2, 3), Circle with an
# idle device per local step.
mesh6 = Mesh(np.array(devs[:6]).reshape(2, 3), ("g", "l"))
coll6 = LacinCollectives(mesh=mesh6)
xo = jnp.asarray(np.random.default_rng(2).integers(-4, 4, (6, 5)),
                 jnp.float32)
got = run(mesh6, ("g", "l"),
          lambda xl: coll6.all_reduce_two_level(xl, "l", "g"), xo)
ref = run(mesh6, ("g", "l"), lambda xl: lax.psum(xl, ("g", "l")), xo)
results["two_level_ar_odd_exact"] = bool(jnp.array_equal(got, ref))

# ---- fabric-bound collectives ----------------------------------------------
# A dragonfly whose group_size matches the mesh's local axis; instances
# bound per axis by the fabric (mirror globally exercises the registered
# instance end to end).
fab = make_fabric(DragonflyConfig(4, 2, 1, 5, local_instance="circle",
                                  global_instance="mirror"))
try:
    fab.collectives(meshd, local_axis="l", global_axis="g")
    results["fabric_mesh_check"] = False      # g axis is 2 != 5 groups
except ValueError:
    results["fabric_mesh_check"] = True
collf = fab.collectives(meshd, local_axis="l")
assert collf.axis_instance("l") == "circle"
got = run(meshd, ("g", "l"),
          lambda xl: collf.all_reduce_two_level(xl, "l", "g"), xi)
refi = run(meshd, ("g", "l"), lambda xl: lax.psum(xl, ("g", "l")), xi)
results["fabric_two_level_ar"] = bool(jnp.array_equal(got, refi))

print("RESULT " + json.dumps(results))
"""


@pytest.fixture(scope="module")
def ref_results():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    res = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert res.returncode == 0, res.stderr[-2000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


@pytest.mark.parametrize("key", ["grid_a2a_2x4", "grid_a2a_2x2x2",
                                 "grid_a2a_4x2", "grid_a2a_meshless"])
def test_grid_all_to_all_bit_identical_to_lax(ref_results, key):
    assert ref_results[key], key


@pytest.mark.parametrize("key", ["two_level_ar_exact", "two_level_ar_close",
                                 "two_level_ar_odd_exact"])
def test_two_level_dragonfly_all_reduce_matches_psum(ref_results, key):
    assert ref_results[key], key


def test_fabric_bound_collectives(ref_results):
    assert ref_results["fabric_mesh_check"]
    assert ref_results["fabric_two_level_ar"]
