"""Flow-level simulator: the paper's balance/contention claims."""
import pytest

from repro.core import (all_to_all_steps, cin_link_loads, hyperx_link_loads,
                        schedule_step_report)
from repro.core.hyperx import HyperXConfig


@pytest.mark.parametrize("inst,n", [("swap", 16), ("circle", 16),
                                    ("circle", 9), ("xor", 16)])
def test_all_to_all_uses_every_link_once(inst, n):
    loads = cin_link_loads(inst, n)
    assert set(loads.values()) == {1}
    assert len(loads) == n * (n - 1)


@pytest.mark.parametrize("inst", ["circle", "xor"])
def test_isoport_steps_are_contention_free(inst):
    for r in schedule_step_report(inst, 16):
        assert r.max_link_load == 1 and r.max_endpoint_in == 1


def test_swap_steps_serialize():
    reps = schedule_step_report("swap", 8)
    assert [r.max_endpoint_in for r in reps] == [7, 6, 5, 4, 5, 6, 7]
    assert all_to_all_steps("swap", 8) == 40
    assert all_to_all_steps("xor", 8) == 7


def test_hyperx_dor_loads_balanced():
    ll = hyperx_link_loads(HyperXConfig(dims=(4, 4, 4), terminals=4))
    assert ll["load_cv"] == 0.0
    assert ll["avg_hops"] <= 3


def test_valiant_relieves_hot_links():
    from repro.core import valiant_link_loads
    hot = [(0, 1, 16.0)]                      # one 16x-overloaded pair
    r = valiant_link_loads("xor", 16, hot)
    assert r["max_min"] == 16.0
    assert r["max_valiant"] == pytest.approx(16.0 / 14)   # spread over N-2
    assert r["vc_required"] == 2              # paper §3 deadlock condition


def test_lacin_schedule_is_single_hop():
    from repro.core import schedule_hop_counts
    h = schedule_hop_counts(16)
    assert h["lacin_max_hops"] == 1 and h["ring_max_hops"] == 15
    assert h["ratio"] == pytest.approx(8.0)   # ring avg hops = N/2
