"""Paper §2: isoport instances are 1-factorizations of K_N."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (column_contention, factorization,
                        is_one_factorization, is_perfect_matching,
                        port_matrix)


@pytest.mark.parametrize("inst,n", [("circle", 4), ("circle", 8),
                                    ("circle", 32), ("xor", 4), ("xor", 8),
                                    ("xor", 64)])
def test_isoport_instances_are_one_factorizations(inst, n):
    assert is_one_factorization(port_matrix(inst, n))


def test_factor_count_matches_ports():
    f = factorization("circle", 16)
    assert len(f) == 15                    # N-1 1-factors
    assert all(len(fac) == 8 for fac in f)  # N/2 links each


def test_swap_columns_are_not_matchings():
    cont = column_contention(port_matrix("swap", 8))
    assert cont.max() > 1
    assert cont.tolist() == [7, 6, 5, 4, 5, 6, 7]  # concentration on i, i+1


def test_odd_circle_factors_are_near_perfect():
    f = factorization("circle", 9)
    for fac in f:
        assert is_perfect_matching(fac, 9)
        assert len(fac) == 4               # (N-1)/2 links, one idle switch


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 64).filter(lambda x: x % 2 == 0))
def test_circle_factorization_property(n):
    assert is_one_factorization(port_matrix("circle", n))
