"""repro.faults: failure injection, fallback routing, degraded engines.

The satellite property suite runs over every ``repro.fabric`` registry
instance: zero-failure fallback tables must be bit-identical to the
closed-form ``minimal_port_table``, surviving pairs must route without
ever touching a dead link, and degraded path lengths can never beat the
pristine shortest distance.  The cross-backend tests assert the
acceptance contract: numpy == xengine link-for-link on drained
deterministic workloads, and no delivered packet crosses a failed link
or switch on either cycle engine.
"""
import dataclasses
import json

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.fabric.mirror  # noqa: F401  (registers the mirror instance)
from repro.core.dragonfly import DragonflyConfig
from repro.core.hyperx import HyperXConfig
from repro.fabric import instance_names, make_fabric
from repro.fabric.registry import get_instance
from repro.faults import (FabricDisconnectedError, FailureSpec,
                          bfs_distances, build_fallback_table, degrade,
                          failure_grid, filter_pairs, mask_traffic,
                          mask_workload, packet_keep, residual_report)
from repro.sim.workloads import collective_workload, replay
from repro.studies import (ExperimentSpec, FabricSpec, RoutingSpec, Study,
                           SweepSpec, TrafficSpec)
from repro.studies.runner import _select_backend


def _supported_n(name: str) -> int:
    spec = get_instance(name)
    for n in (16, 12, 9, 8):
        if spec.supports(n):
            return n
    raise AssertionError(f"no test size for instance {name}")


def _topos():
    """One representative topology per family (CIN for every registry
    instance, plus HyperX and Dragonfly compositions)."""
    out = [(name, make_fabric(name, _supported_n(name)).sim_topology())
           for name in instance_names()]
    out.append(("hyperx", make_fabric(
        HyperXConfig((4, 4), 1)).sim_topology()))
    out.append(("dragonfly", make_fabric(
        DragonflyConfig(4, 2, 3, 9)).sim_topology()))
    return out

TOPOS = _topos()


def _connected_spec(topo, fraction, seed):
    """A link-failure spec on ``topo`` whose residual graph is connected
    (walks the seed forward until the BFS check passes)."""
    for s in range(seed, seed + 50):
        spec = FailureSpec(link_fraction=fraction, seed=s)
        if residual_report(topo, spec)["connected"]:
            return spec
    raise AssertionError(f"no connected {fraction} spec found for "
                         f"{topo.name}")


# ---------------------------------------------------------------------------
# FailureSpec: validation, canonicalization, JSON round trip.
# ---------------------------------------------------------------------------

def test_failure_spec_round_trips_exactly():
    spec = FailureSpec(link_fraction=0.05, switch_fraction=0.02, seed=4,
                       dead_links=((2, 1), (1, 2), (0, 3)),
                       dead_switches=(9, 4), policy="drop")
    rt = FailureSpec.from_json(spec.to_json())
    assert rt == spec
    assert rt.to_json() == spec.to_json()
    # endpoints canonicalize to sorted deduped (min, max) pairs
    assert spec.dead_links == ((0, 3), (1, 2))
    assert spec.dead_switches == (4, 9)


def test_failure_spec_validation():
    with pytest.raises(ValueError, match="link_fraction"):
        FailureSpec(link_fraction=1.0)
    with pytest.raises(ValueError, match="switch_fraction"):
        FailureSpec(switch_fraction=-0.1)
    with pytest.raises(ValueError, match="policy"):
        FailureSpec(policy="ignore")
    with pytest.raises(ValueError, match="self-loop"):
        FailureSpec(dead_links=((3, 3),))
    with pytest.raises(TypeError):
        FailureSpec.coerce(42)
    assert FailureSpec.coerce(None) is None
    assert FailureSpec.coerce({"seed": 7, "link_fraction": 0.1}) == \
        FailureSpec(link_fraction=0.1, seed=7)


def test_failure_spec_labels():
    assert FailureSpec().is_null and FailureSpec().label == "f0"
    assert FailureSpec(link_fraction=0.05, seed=3).label == "L0.05-s3"
    assert FailureSpec(dead_switches=(1,), policy="drop").label == \
        "ds1-drop"
    assert not FailureSpec(dead_links=((0, 1),)).is_null


# ---------------------------------------------------------------------------
# Satellite property suite: every registry instance / every family.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family,topo", TOPOS, ids=[t[0] for t in TOPOS])
def test_zero_failure_table_bit_identical(family, topo):
    """f=0 fallback tables collapse to the closed-form minimal routes."""
    assert np.array_equal(build_fallback_table(topo),
                          topo.minimal_port_table())
    assert degrade(topo, None) is topo
    assert degrade(topo, FailureSpec()) is topo


def _walk_all_pairs(topo2):
    """Walk every reachable pair through the degraded table; returns the
    per-pair hop counts.  Asserts no walk touches a dead/unwired slot."""
    n = topo2.num_switches
    table = topo2.minimal_port_table()
    faults = topo2.meta["faults"]
    nbr = topo2.neighbor
    dist = bfs_distances(nbr)
    cur = np.arange(n)[:, None] * np.ones(n, dtype=np.int64)[None, :]
    cols = np.arange(n)[None, :] * np.ones(n, dtype=np.int64)[:, None]
    hops = np.zeros((n, n), dtype=np.int64)
    reachable = dist >= 0
    for _ in range(topo2.diameter + 1):
        pending = (cur != cols) & reachable
        if not pending.any():
            break
        port = table[cur, cols]
        nxt = nbr[cur, port]
        # the walk must never step onto a dead or unwired slot
        assert (nxt[pending] >= 0).all(), topo2.name
        assert not faults["dead_links"][cur[pending],
                                       port[pending]].any(), topo2.name
        cur = np.where(pending, nxt, cur)
        hops += pending
    assert ((cur == cols) | ~reachable).all(), \
        f"{topo2.name}: walks unfinished after diameter rounds"
    return hops, dist


@pytest.mark.parametrize("family,topo", TOPOS, ids=[t[0] for t in TOPOS])
@settings(max_examples=5, deadline=None)
@given(seed=st.integers(min_value=0, max_value=1000),
       fraction=st.sampled_from([0.02, 0.05, 0.1]))
def test_degraded_routes_avoid_dead_links_and_respect_distance(
        family, topo, seed, fraction):
    """Surviving pairs route dead-link-free, in >= pristine-distance
    hops, terminating within the degraded diameter."""
    spec = FailureSpec(link_fraction=fraction, seed=seed, policy="drop")
    topo2 = degrade(topo, spec)
    if topo2 is topo:       # fraction rounded to zero dead links
        return
    hops, ddist = _walk_all_pairs(topo2)
    pristine = bfs_distances(topo.neighbor)
    reach = ddist >= 0
    # degraded hops == degraded shortest distance for broken pairs and
    # == pristine route length for intact ones; both are >= the pristine
    # graph distance and bounded by the recorded degraded diameter
    assert (hops[reach] >= pristine[reach]).all()
    assert hops.max() <= topo2.diameter


@pytest.mark.parametrize("family,topo", TOPOS, ids=[t[0] for t in TOPOS])
def test_dead_switch_isolates_and_masks(family, topo):
    spec = FailureSpec(dead_switches=(1,), policy="drop")
    topo2 = degrade(topo, spec)
    faults = topo2.meta["faults"]
    assert not faults["alive"][1] and faults["alive"].sum() == \
        topo.num_switches - 1
    # every slot into or out of the dead switch is unwired
    assert (topo2.neighbor[1] < 0).all()
    assert not (topo2.neighbor == 1).any()
    src = np.arange(topo.num_switches)
    keep = packet_keep(topo2, src, np.roll(src, 1))
    assert not keep[1] and not keep[(np.roll(src, 1) == 1)].any()


def test_explicit_dead_link_masks_both_directions():
    topo = make_fabric("xor", 16).sim_topology()
    topo2 = degrade(topo, FailureSpec(dead_links=((2, 9),)))
    assert not (topo2.neighbor[2] == 9).any()
    assert not (topo2.neighbor[9] == 2).any()
    with pytest.raises(ValueError, match="does not exist"):
        degrade(topo, FailureSpec(dead_links=((0, topo.num_switches - 1),
                                              (1, 1 + 64))))


def test_strict_disconnection_raises_with_component_sizes():
    topo = make_fabric("xor", 16).sim_topology()
    iso = tuple((0, j) for j in range(1, 16))
    with pytest.raises(FabricDisconnectedError, match="2 components"):
        degrade(topo, FailureSpec(dead_links=iso))
    topo2 = degrade(topo, FailureSpec(dead_links=iso, policy="drop"))
    faults = topo2.meta["faults"]
    assert faults["num_components"] == 2
    assert faults["unreachable_pairs"] == 2 * 15      # 0 <-> everyone
    rep = residual_report(topo, FailureSpec(dead_links=iso))
    assert not rep["connected"] and rep["num_components"] == 2


def test_degrading_a_degraded_topology_is_rejected():
    topo = make_fabric("xor", 16).sim_topology()
    topo2 = degrade(topo, FailureSpec(link_fraction=0.05, seed=3))
    with pytest.raises(ValueError, match="already degraded"):
        degrade(topo2, FailureSpec(link_fraction=0.01))


# ---------------------------------------------------------------------------
# Cycle engines: numpy == xengine, and no dead-link traversal.
# ---------------------------------------------------------------------------

def _dead_slot_loads(stats, topo2):
    faults = topo2.meta["faults"]
    dead_flat = faults["dead_links"].reshape(-1)
    return np.asarray(stats.link_loads)[dead_flat]


@pytest.mark.parametrize("policy", ["minimal", "valiant", "adaptive"])
def test_replay_never_crosses_dead_links_both_engines(policy):
    """Acceptance: no delivered packet ever crosses a failed link, on
    either cycle engine, for every policy."""
    fab = make_fabric("xor", 16)
    topo = fab.sim_topology()
    spec = _connected_spec(topo, 0.08, 3)
    topo2 = degrade(topo, spec)
    wl = collective_workload(fab, "all_to_all")
    for backend in ("numpy", "jax"):
        stats = replay(topo2, policy, wl, backend=backend)
        assert stats.packets_delivered == stats.packets_generated > 0
        assert _dead_slot_loads(stats, topo2).sum() == 0, \
            (policy, backend)


def test_drained_replay_numpy_equals_xengine_link_for_link():
    """Acceptance: numpy == xengine exactly (every directed link's
    traversal count) on a drained deterministic workload with injected
    failures, under deterministic minimal routing."""
    fab = make_fabric("xor", 16)
    topo2 = degrade(fab.sim_topology(), _connected_spec(
        fab.sim_topology(), 0.08, 3))
    wl = collective_workload(fab, "all_to_all")
    np_stats = replay(topo2, "minimal", wl, backend="numpy")
    jx_stats = replay(topo2, "minimal", wl, backend="jax")
    assert np.array_equal(np.asarray(np_stats.link_loads),
                          np.asarray(jx_stats.link_loads))
    assert np_stats.completion_cycles == jx_stats.completion_cycles
    # and the degradation was real: slower than the contention-free bound
    assert np_stats.completion_cycles > np_stats.ideal_cycles


def test_fabric_replay_failures_seam():
    fab = make_fabric("xor", 16)
    pristine = fab.replay("all_to_all")
    spec = _connected_spec(fab.sim_topology(), 0.08, 3)
    degraded = fab.replay("all_to_all", failures=spec)
    assert pristine.completion_cycles == pristine.ideal_cycles
    assert degraded.completion_cycles > pristine.completion_cycles
    # dict form works at the seam too
    again = fab.replay("all_to_all", failures=json.loads(spec.to_json()))
    assert again.completion_cycles == degraded.completion_cycles


def test_simulate_failures_kwarg_masks_dead_endpoints():
    from repro.sim import uniform
    from repro.sim.engine import simulate
    from repro.sim.policies import make_policy
    topo = make_fabric("xor", 16).sim_topology()
    traffic = uniform(16, offered=0.2, cycles=120, terminals=2, seed=5)
    stats = simulate(topo, make_policy("minimal"), traffic, cycles=120,
                     warmup=0,
                     failures=FailureSpec(dead_switches=(3,),
                                          policy="drop"))
    assert stats.packets_delivered > 0
    # nothing was generated to or from the dead switch (the open-loop
    # window ends before the tail drains, so compare generation counts)
    assert stats.packets_generated < traffic.src.size
    assert stats.topology == "cin-xor-16+ds1-drop"


# ---------------------------------------------------------------------------
# Traffic / workload masking.
# ---------------------------------------------------------------------------

def test_mask_workload_filters_dead_pairs_and_preserves_pristine():
    fab = make_fabric("xor", 16)
    wl = collective_workload(fab, "all_to_all")
    topo2 = degrade(fab.sim_topology(),
                    FailureSpec(dead_switches=(5,), policy="drop"))
    masked = mask_workload(wl, topo2)
    assert masked is not wl
    for phase in masked.phases:
        assert 5 not in phase.src and 5 not in phase.dst
    # pristine topology: masking is the identity
    assert mask_workload(wl, fab.sim_topology()) is wl
    # the masked workload still drains on both engines
    stats = replay(topo2, "minimal", masked, backend="numpy")
    assert stats.packets_delivered == stats.packets_generated > 0


def test_filter_pairs_drops_unreachable_demands():
    topo = make_fabric("xor", 16).sim_topology()
    topo2 = degrade(topo, FailureSpec(dead_switches=(2,), policy="drop"))
    src = np.array([0, 2, 4, 1])
    dst = np.array([1, 3, 2, 0])
    rate = np.ones(4)
    s, d, r = filter_pairs(topo2, src, dst, rate)
    assert s.tolist() == [0, 1] and d.tolist() == [1, 0]
    assert r.tolist() == [1.0, 1.0]


# ---------------------------------------------------------------------------
# Studies integration: spec field, digest, backend guard, end to end.
# ---------------------------------------------------------------------------

def _study_spec(policy="minimal", *, failures=None, loads=(0.3,),
                name=""):
    return ExperimentSpec(
        fabric=FabricSpec("cin", {"instance": "xor", "n": 16}),
        traffic=TrafficSpec("uniform", {"seed": 21}),
        routing=RoutingSpec(policy),
        sweep=SweepSpec(loads=loads, seeds=(23,), cycles=160, warmup=40),
        terminals=2, name=name, failures=failures)


def test_experiment_spec_failures_field_round_trip_and_digest():
    base = _study_spec()
    assert base.failures is None
    assert "failures" not in base.to_dict()
    rt = ExperimentSpec.from_json(base.to_json())
    assert rt == base and rt.digest() == base.digest()

    spec = FailureSpec(link_fraction=0.05, seed=3)
    deg = _study_spec(failures={"link_fraction": 0.05, "seed": 3})
    assert deg.failures == spec
    assert deg.digest() != base.digest()
    rt2 = ExperimentSpec.from_json(deg.to_json())
    assert rt2 == deg and rt2.failures == spec
    # a null FailureSpec normalizes to None: identical digest/behaviour
    assert _study_spec(failures={"link_fraction": 0.0}).digest() == \
        base.digest()
    assert "failures" in deg.describe() or "L0.05" in deg.describe()


def test_failure_grid_expands_with_single_f0():
    grid = failure_grid(_study_spec(name="base"), [0.0, 0.05], seeds=(0, 1))
    names = [g.name for g in grid]
    assert names == ["base/f0", "base/L0.05-s0", "base/L0.05-s1"]
    assert grid[0].failures is None
    assert all(g.failures is not None for g in grid[1:])


def test_select_backend_flow_replay_strict_disconnected_raises():
    """Satellite: the backend guard names the experiment and the fix."""
    iso = tuple((0, j) for j in range(1, 16))
    rep = ExperimentSpec(
        fabric=FabricSpec("cin", {"instance": "xor", "n": 16}),
        traffic=TrafficSpec("workload", {"collective": "all_to_all"}),
        routing=RoutingSpec("minimal"),
        name="replay-strict", failures=FailureSpec(dead_links=iso))
    with pytest.raises(ValueError, match="replay-strict.*drop"):
        _select_backend("flow", experiment=rep)
    # drop policy sails through; so does a cycle backend (whose own
    # degrade() raises later, naming the experiment)
    assert _select_backend(
        "flow", experiment=dataclasses.replace(
            rep, failures=FailureSpec(dead_links=iso, policy="drop"))
    ) == "flow"
    assert _select_backend("numpy", experiment=rep) == "numpy"
    with pytest.raises(FabricDisconnectedError, match="replay-strict"):
        Study([rep], backend="numpy").run()


def test_study_with_failures_end_to_end_and_resume(tmp_path):
    store = str(tmp_path / "f.jsonl")
    spec = _study_spec(failures={"link_fraction": 0.05, "seed": 3},
                       loads=(0.2, 0.4), name="deg")
    first = Study([spec], store=store, backend="numpy").run()
    assert first.executed == 2
    again = Study([spec], store=store, backend="numpy").run()
    assert again.executed == 0 and again.restored == 2
    # numpy resume is bit-identical
    assert {r.key: r.accepted for r in first.results} == \
        {r.key: r.accepted for r in again.results}
    # editing the FailureSpec changes the digest -> stale store refused
    edited = _study_spec(failures={"link_fraction": 0.05, "seed": 4},
                         loads=(0.2, 0.4), name="deg")
    with pytest.raises(ValueError, match="different version"):
        Study([edited], store=store, backend="numpy").run()


def test_study_zero_failure_bit_identical_to_pristine():
    """Acceptance: failures=None and a null FailureSpec produce results
    bit-identical to the pre-faults path (same keys, same stats)."""
    pristine = Study([_study_spec(name="p")], backend="numpy").run()
    null = Study([_study_spec(name="p",
                              failures={"link_fraction": 0.0})],
                 backend="numpy").run()
    for a, b in zip(pristine.results, null.results):
        assert a.accepted == b.accepted
        assert a.packets_delivered == b.packets_delivered
        assert a.latency_mean == b.latency_mean


def test_flow_knee_matches_cycle_knee_on_degraded_grid():
    """Acceptance: flow-backend saturation knees on a degraded fabric
    within the flow-smoke lane's tolerance of the cycle engine's."""
    spec = ExperimentSpec(
        fabric=FabricSpec("cin", {"instance": "xor", "n": 16}),
        traffic=TrafficSpec("uniform", {"seed": 21}),
        routing=RoutingSpec("minimal"),
        sweep=SweepSpec(loads=(0.3, 0.5, 0.7, 0.9), seeds=(23,),
                        cycles=1200, warmup=300),
        terminals=12, name="deg",
        failures={"link_fraction": 0.05, "seed": 3})
    cycle = Study([spec], backend="numpy").run().saturation_points()["deg"]
    flow = Study([spec], backend="flow").run() \
        .saturation_points(fidelity="flow")["deg"]
    assert cycle is not None and flow is not None
    assert abs(flow - cycle) <= 0.1 * cycle, (flow, cycle)


def test_flow_trace_routes_raises_clearly_on_unreachable_pair():
    from repro.flow.model import trace_routes
    topo = make_fabric("xor", 16).sim_topology()
    iso = tuple((0, j) for j in range(1, 16))
    topo2 = degrade(topo, FailureSpec(dead_links=iso, policy="drop"))
    with pytest.raises(RuntimeError, match="unwired port"):
        trace_routes(topo2, np.array([0]), np.array([5]))


# ---------------------------------------------------------------------------
# Observability: the rerouted link class.
# ---------------------------------------------------------------------------

def test_link_classes_rerouted_disjoint_and_only_when_degraded():
    from repro.obs.export import link_classes
    topo = make_fabric("xor", 16).sim_topology()
    assert "rerouted" not in link_classes(topo)
    topo2 = degrade(topo, _connected_spec(topo, 0.08, 3))
    classes = link_classes(topo2)
    assert classes["rerouted"].any()
    # classes partition the wired slots: pairwise disjoint, union = wired
    masks = list(classes.values())
    union = np.zeros_like(masks[0])
    for i, a in enumerate(masks):
        for b in masks[i + 1:]:
            assert not (a & b).any()
        union |= a
    from repro.sim.link import LinkTable
    wired = np.asarray(
        LinkTable.for_topology(topo2, 1).neighbor_flat) >= 0
    assert np.array_equal(union, wired)
    # no rerouted slot is dead
    assert not (classes["rerouted"]
                & topo2.meta["faults"]["dead_links"].reshape(-1)).any()
