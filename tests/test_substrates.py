"""Substrate tests: data determinism, checkpoint atomicity, optimizer,
fault-tolerant loop (failure injection), serving engine."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, Prefetcher, host_batch
from repro.models import get_config
from repro.models.layers import AxisRules
from repro.models.transformer import init_params
from repro.optim import (OptConfig, adamw_update, clip_by_global_norm,
                         init_opt_state, schedule)
from repro.runtime.loop import LoopConfig, run_training


# -- data ---------------------------------------------------------------------

def test_data_is_pure_function_of_step():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4)
    a = host_batch(cfg, 7)
    b = host_batch(cfg, 7)
    assert np.array_equal(a["tokens"], b["tokens"])
    c = host_batch(cfg, 8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_host_sharding_disjoint_and_deterministic():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=8)
    h0 = host_batch(cfg, 3, host_index=0, num_hosts=2)
    h1 = host_batch(cfg, 3, host_index=1, num_hosts=2)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_labels_shift_tokens():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2)
    b = host_batch(cfg, 0)
    assert b["tokens"].shape == b["labels"].shape


def test_prefetcher_delivers_in_order():
    cfg = DataConfig(vocab_size=50, seq_len=8, global_batch=2)
    pf = Prefetcher(cfg, start_step=5)
    try:
        s0, b0 = pf.next()
        s1, _ = pf.next()
        assert (s0, s1) == (5, 6)
        assert np.array_equal(b0["tokens"], host_batch(cfg, 5)["tokens"])
    finally:
        pf.close()


# -- checkpoint ---------------------------------------------------------------

def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    state = {"w": jnp.arange(6.0).reshape(2, 3), "n": jnp.asarray(3)}
    for step in (10, 20, 30):
        mgr.save(step, jax.tree_util.tree_map(lambda a: a + step, state),
                 blocking=True)
    assert mgr.steps() == [20, 30]            # keep=2 garbage-collected 10
    like = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
    restored = mgr.restore(30, like)
    assert np.allclose(restored["w"], np.arange(6.0).reshape(2, 3) + 30)


def test_checkpoint_detects_corruption(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(1, {"w": jnp.ones(4)}, blocking=True)
    blob = tmp_path / "step_00000001" / "data.npz"
    data = bytearray(blob.read_bytes())
    data[-1] ^= 0xFF
    blob.write_bytes(bytes(data))
    with pytest.raises(IOError):
        mgr.restore(1, {"w": jax.ShapeDtypeStruct((4,), jnp.float32)})


def test_checkpoint_tmp_dirs_invisible(tmp_path):
    mgr = CheckpointManager(tmp_path)
    (tmp_path / "step_00000099.tmp").mkdir()
    assert mgr.latest_step() is None          # partial save never published


# -- optimizer ----------------------------------------------------------------

def test_adamw_minimizes_quadratic():
    opt = OptConfig(lr=0.1, warmup_steps=0, total_steps=200,
                    weight_decay=0.0, clip_norm=100.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = init_opt_state(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(params, grads, state, opt)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-6
    assert np.allclose(np.asarray(clipped["a"]), [0.6, 0.8])


def test_schedule_warmup_and_decay():
    opt = OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                    min_lr_ratio=0.1)
    assert float(schedule(opt, jnp.asarray(0))) == 0.0
    assert abs(float(schedule(opt, jnp.asarray(10))) - 1.0) < 1e-6
    assert float(schedule(opt, jnp.asarray(100))) <= 0.1 + 1e-6


# -- fault-tolerant loop --------------------------------------------------------

def test_training_survives_injected_failures(tmp_path):
    cfg = get_config("lacin-demo").reduced()
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4)
    loop = LoopConfig(total_steps=12, ckpt_every=4,
                      ckpt_dir=str(tmp_path / "ckpt"), log_every=2,
                      fail_at_steps=(6, 9))
    report = run_training(cfg, OptConfig(lr=1e-3, warmup_steps=2,
                                         total_steps=12), loop, data)
    assert report.restarts == 2
    assert report.restored_from == [4, 8]     # resumed from latest ckpts
    # completed all steps despite two injected crashes
    assert report.losses[-1][0] == 11
    assert all(np.isfinite(l) for _, l in report.losses)


def test_training_loss_decreases(tmp_path):
    cfg = get_config("lacin-demo").reduced()
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8,
                      repeat_p=0.8)
    loop = LoopConfig(total_steps=30, ckpt_every=50,
                      ckpt_dir=str(tmp_path / "ckpt2"), log_every=1)
    report = run_training(cfg, OptConfig(lr=3e-3, warmup_steps=5,
                                         total_steps=30), loop, data)
    first = np.mean([l for _, l in report.losses[:3]])
    last = np.mean([l for _, l in report.losses[-3:]])
    assert last < first, (first, last)


# -- serving -------------------------------------------------------------------

def test_serving_engine_completes_requests():
    from repro.serving.engine import Request, ServingEngine
    cfg = get_config("lacin-demo").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, slots=2, max_seq=48)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 8,
                                               dtype=np.int32),
                    max_new_tokens=5) for i in range(2)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 2
    for r in done:
        assert len(r.out_tokens) == 5
        assert all(0 <= t < cfg.vocab_padded for t in r.out_tokens)
