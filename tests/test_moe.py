"""MoE: dispatch correctness and dense == LACIN-EP equivalence."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import AxisRules
from repro.models.moe import (_capacity, _dispatch_indices, _moe_local,
                              apply_moe, expert_store_count, init_moe)


def tiny_moe_cfg(num_experts=8, top_k=2, pad=1):
    return ModelConfig(
        name="tiny-moe", family="moe", num_layers=1, d_model=32,
        num_heads=4, num_kv_heads=2, d_ff=16, vocab_size=64,
        num_experts=num_experts, top_k=top_k, expert_pad_to=pad,
        capacity_factor=2.0)


def test_dispatch_indices_rank_within_expert():
    eidx = jnp.asarray([3, 1, 3, 3, 0, 1], jnp.int32)
    slot, valid = _dispatch_indices(eidx, 4, capacity=2)
    slots = np.asarray(slot)
    assert slots[4] == 0 * 2 + 0           # expert 0 first
    assert slots[1] == 1 * 2 + 0 and slots[5] == 1 * 2 + 1
    assert slots[0] == 3 * 2 + 0 and slots[2] == 3 * 2 + 1
    assert not bool(valid[3])              # third token for expert 3 dropped


def test_capacity_rounding():
    cfg = tiny_moe_cfg()
    assert _capacity(64, cfg) % 4 == 0
    assert _capacity(64, cfg) >= 64 * cfg.top_k / cfg.num_experts


def test_expert_store_padding():
    cfg = tiny_moe_cfg(num_experts=40, pad=16)
    assert expert_store_count(cfg) == 48
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    assert p["wi"].shape[0] == 48 and p["router"].shape[1] == 40


def test_moe_dense_forward_finite_and_balanced():
    cfg = tiny_moe_cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32))
    y, aux = apply_moe(p, x, cfg, AxisRules())
    assert y.shape == x.shape and bool(jnp.isfinite(y).all())
    assert float(aux["moe_aux"]) > 0


_CHILD = r"""
import json
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P, NamedSharding
from repro.models.config import ModelConfig
from repro.models.layers import AxisRules
from repro.models.moe import apply_moe, init_moe
import dataclasses

cfg = ModelConfig(name="tiny-moe", family="moe", num_layers=1, d_model=32,
                  num_heads=4, num_kv_heads=2, d_ff=16, vocab_size=64,
                  num_experts=8, top_k=2, expert_pad_to=1,
                  capacity_factor=8.0)  # big cf: nothing dropped -> exact
from repro._compat.jaxapi import make_auto_mesh, set_mesh
mesh = make_auto_mesh((2, 4), ("data", "model"))
rules = AxisRules(dp=("data",), tp="model", mesh=mesh)
p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32))

y_dense, aux_d = apply_moe(p, x, dataclasses.replace(cfg, moe_impl="dense"),
                           AxisRules())
with set_mesh(mesh):
    y_ep, aux_e = jax.jit(lambda p_, x_: apply_moe(p_, x_, cfg, rules))(p, x)

ok_y = bool(jnp.allclose(y_dense, y_ep, rtol=2e-4, atol=2e-5))
# aux is a per-dp-shard statistic averaged with pmean; it estimates (not
# equals) the global load-balance loss -> compare loosely.
ok_aux = bool(jnp.abs(aux_d["moe_aux"] - aux_e["moe_aux"])
              / jnp.abs(aux_d["moe_aux"]) < 0.2)

# gradients through the EP path
def loss(p_):
    y, _ = apply_moe(p_, x, cfg, rules)
    return (y ** 2).sum()
with set_mesh(mesh):
    g = jax.grad(loss)(p)
ok_g = all(bool(jnp.isfinite(v).all()) for v in jax.tree_util.tree_leaves(g))
print("RESULT " + json.dumps({"y": ok_y, "aux": ok_aux, "grads": ok_g}))
"""


@pytest.fixture(scope="module")
def ep_results():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    res = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert res.returncode == 0, res.stderr[-2000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_ep_matches_dense(ep_results):
    assert ep_results["y"], "LACIN-EP output != dense MoE output"
    assert ep_results["aux"]


def test_ep_gradients_finite(ep_results):
    assert ep_results["grads"]
