"""Compiled engine (repro.sim.xengine) vs the numpy oracle.

Two tiers of agreement:

* **Exact** — properties arbitration order cannot change: delivered
  packet counts of drained (closed) workloads, and per-link load totals
  under minimal routing (the minimal path of every packet is unique, so
  the drained traversal multiset is engine-independent).
* **Statistical** — open-loop sweeps driven by the *same* traffic object
  through both engines: accepted throughput, delivered counts, mean
  latency, and the latency histogram mass agree within seed-matched
  tolerances (the engines draw arbitration tie-breaks from different RNG
  streams).
"""
import numpy as np
import pytest

import repro.fabric.mirror  # noqa: F401  (registers the mirror instance)
from repro import sim
from repro.core.dragonfly import DragonflyConfig
from repro.core.hyperx import HyperXConfig
from repro.core.simulate import cin_link_loads
from repro.fabric import make_fabric
from repro.sim import xengine

CYCLES = 240
WARMUP = 60
T = 6


def _both(topo, policy_name, traffic, *, terminals=T, cycles=CYCLES,
          warmup=WARMUP, seed=3, **kw):
    """Run one traffic object through both engines."""
    s_np = sim.simulate(topo, sim.make_policy(policy_name), traffic,
                        terminals=terminals, cycles=cycles, warmup=warmup,
                        seed=seed, backend="numpy", **kw)
    s_jx = sim.simulate(topo, sim.make_policy(policy_name), traffic,
                        terminals=terminals, cycles=cycles, warmup=warmup,
                        seed=seed, backend="jax", **kw)
    return s_np, s_jx


def _assert_statistical_match(s_np, s_jx, rtol=0.12):
    assert s_jx.packets_generated == s_np.packets_generated
    assert s_jx.packets_delivered == pytest.approx(
        s_np.packets_delivered, rel=rtol, abs=25)
    assert s_jx.accepted == pytest.approx(s_np.accepted, rel=rtol, abs=0.02)
    if s_np.latency_mean > 0:
        assert s_jx.latency_mean == pytest.approx(
            s_np.latency_mean, rel=0.25, abs=2.0)
    # Same histogram support scale: total mass within tolerance.
    assert s_jx.latency_histogram.sum() == pytest.approx(
        s_np.latency_histogram.sum(), rel=rtol, abs=25)
    # Conservation: link-load totals count the same flows modulo detour
    # randomness.
    assert s_jx.link_loads.sum() == pytest.approx(
        s_np.link_loads.sum(), rel=rtol, abs=50)


# ---------------------------------------------------------------------------
# Exact agreement on drained minimal workloads (every instance).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("inst,n", [("swap", 8), ("circle", 8),
                                    ("circle", 9), ("mirror", 9),
                                    ("xor", 16)])
def test_one_shot_a2a_exactly_matches_oracle(inst, n):
    topo = sim.cin_topology(inst, n)
    tr = sim.one_shot_all_to_all(n)
    s_jx = xengine.simulate_jax(topo, sim.MinimalPolicy(), tr, terminals=4)
    eng = sim.Engine(topo, sim.MinimalPolicy(), tr, terminals=4)
    s_np = eng.run()
    assert s_jx.packets_delivered == s_np.packets_delivered == n * (n - 1)
    assert np.array_equal(s_jx.link_loads, s_np.link_loads)
    assert eng.load.by_switch_pair() == cin_link_loads(inst, n)


def test_one_shot_a2a_exact_on_compositions():
    hx = make_fabric(HyperXConfig(dims=(4, 4), terminals=4)).sim_topology()
    tr = sim.one_shot_all_to_all(16)
    s_jx = xengine.simulate_jax(hx, sim.MinimalPolicy(), tr, terminals=4)
    eng = sim.Engine(hx, sim.MinimalPolicy(), tr, terminals=4)
    s_np = eng.run()
    assert s_jx.packets_delivered == s_np.packets_delivered
    assert np.array_equal(s_jx.link_loads, s_np.link_loads)

    cfg = DragonflyConfig(group_size=4, terminals_per_switch=2,
                          global_ports_per_switch=2, num_groups=6)
    dtopo = make_fabric(cfg).sim_topology()
    tr = sim.one_shot_all_to_all(cfg.switches)
    s_jx = xengine.simulate_jax(dtopo, sim.MinimalPolicy(), tr, terminals=4)
    eng = sim.Engine(dtopo, sim.MinimalPolicy(), tr, terminals=4)
    s_np = eng.run()
    assert s_jx.packets_delivered == s_np.packets_delivered
    assert np.array_equal(s_jx.link_loads, s_np.link_loads)


def test_drain_mode_deadlock_freedom_nonminimal():
    """Closed Valiant workload must fully drain on the compiled engine —
    the distance-class VC ladder argument holds there too."""
    topo = sim.cin_topology("xor", 16)
    tr = sim.one_shot_all_to_all(16)
    s = xengine.simulate_jax(topo, sim.ValiantPolicy(), tr, terminals=4,
                             max_cycles=20_000)
    assert s.packets_delivered == s.packets_generated == 240


# ---------------------------------------------------------------------------
# Statistical agreement: instances x policies (uniform traffic).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("inst,n", [("swap", 8), ("circle", 9),
                                    ("mirror", 9), ("xor", 8)])
@pytest.mark.parametrize("policy", ["minimal", "valiant", "adaptive"])
def test_uniform_equivalence_instances_policies(inst, n, policy):
    topo = sim.cin_topology(inst, n)
    tr = sim.uniform(n, offered=0.5, cycles=CYCLES, terminals=T, seed=5)
    s_np, s_jx = _both(topo, policy, tr)
    _assert_statistical_match(s_np, s_jx)


# ---------------------------------------------------------------------------
# Statistical agreement: traffic patterns.
# ---------------------------------------------------------------------------

def test_permutation_equivalence():
    topo = sim.cin_topology("xor", 16)
    tr = sim.permutation(16, offered=0.6, cycles=CYCLES, terminals=T, seed=6)
    s_np, s_jx = _both(topo, "minimal", tr)
    _assert_statistical_match(s_np, s_jx)


def test_hotspot_equivalence():
    topo = sim.cin_topology("xor", 16)
    tr = sim.hotspot(16, offered=0.3, cycles=CYCLES, terminals=T,
                     hot_fraction=0.9, seed=7)
    s_np, s_jx = _both(topo, "valiant", tr)
    _assert_statistical_match(s_np, s_jx)


def test_adversarial_equivalence_on_dragonfly():
    cfg = DragonflyConfig(group_size=4, terminals_per_switch=2,
                          global_ports_per_switch=2, num_groups=8)
    topo = make_fabric(cfg).sim_topology()
    for policy in ("minimal", "valiant"):
        tr = sim.adversarial_same_group(cfg, offered=0.3, cycles=400,
                                        terminals=2, seed=8)
        s_np, s_jx = _both(topo, policy, tr, terminals=2, cycles=400,
                           warmup=100)
        _assert_statistical_match(s_np, s_jx)
    # and the §3 story survives the backend: valiant >> minimal here
    tr = sim.adversarial_same_group(cfg, offered=0.3, cycles=400,
                                    terminals=2, seed=8)
    s_min = sim.simulate(topo, sim.MinimalPolicy(), tr, terminals=2,
                         cycles=400, warmup=100, backend="jax")
    s_val = sim.simulate(topo, sim.ValiantPolicy(), tr, terminals=2,
                         cycles=400, warmup=100, backend="jax")
    assert s_val.accepted > 1.5 * s_min.accepted


# ---------------------------------------------------------------------------
# Batched sweeps.
# ---------------------------------------------------------------------------

def test_batched_sweep_matches_pointwise_runs():
    """One compiled (loads x seeds) program reports the same statistics
    as running its points separately (identical traffic per point; the
    shared arbitration key differs, hence statistical tolerance)."""
    topo = sim.cin_topology("xor", 16)

    def tf(load, seed):
        return sim.uniform(16, offered=load, cycles=CYCLES, terminals=T,
                           seed=seed)

    loads, seeds = [0.3, 0.8], (1, 2)
    grid = xengine.sweep(topo, "minimal", tf, loads, seeds=seeds,
                         terminals=T, cycles=CYCLES, warmup=WARMUP)
    assert len(grid) == len(loads) and len(grid[0]) == len(seeds)
    for li, load in enumerate(loads):
        for si, seed in enumerate(seeds):
            ref = sim.simulate(topo, sim.MinimalPolicy(), tf(load, seed),
                               terminals=T, cycles=CYCLES, warmup=WARMUP,
                               backend="numpy", seed=seed)
            got = grid[li][si]
            assert got.offered == load
            assert got.accepted == pytest.approx(ref.accepted, rel=0.12,
                                                 abs=0.02)


def test_fabric_sim_sweep_backends_agree():
    """The deprecated Fabric.sim_sweep shim still works on both backends
    (it routes through repro.studies.Study internally)."""
    fab = make_fabric("xor", 16)

    def tf(load, seed):
        return sim.uniform(16, offered=load, cycles=CYCLES, terminals=T,
                           seed=seed)

    kw = dict(seeds=(4,), terminals=T, cycles=CYCLES, warmup=WARMUP)
    from repro.fabric import LacinDeprecationWarning
    with pytest.warns(LacinDeprecationWarning):
        jx = fab.sim_sweep("minimal", tf, [0.4, 0.8], backend="jax", **kw)
    with pytest.warns(LacinDeprecationWarning):
        np_ = fab.sim_sweep("minimal", tf, [0.4, 0.8], backend="numpy", **kw)
    for row_jx, row_np in zip(jx, np_):
        assert row_jx[0].accepted == pytest.approx(row_np[0].accepted,
                                                   rel=0.12, abs=0.02)


def test_sweep_derives_shared_horizon_from_traffic():
    """cycles=None on a batched sweep: the shared horizon is the max
    generation window over the grid (no ValueError, no explicit cycles)."""
    topo = sim.cin_topology("xor", 8)

    def tf(load):
        return sim.uniform(8, offered=load, cycles=100 + int(load * 100),
                           terminals=2, seed=0)

    with pytest.warns(UserWarning, match="shared horizon"):
        grid = xengine.sweep(topo, "minimal", tf, [0.1, 0.9], terminals=2)
    assert [row[0].cycles for row in grid] == [190, 190]
    assert [row[0].warmup for row in grid] == [190 // 4] * 2
    # sanity: the derived-horizon run matches the same sweep pinned
    # explicitly to that horizon
    pinned = xengine.sweep(topo, "minimal", tf, [0.1, 0.9], terminals=2,
                           cycles=190)
    for a, b in zip(grid, pinned):
        assert a[0].accepted == b[0].accepted


def test_saturation_sweep_backend_switch():
    topo = sim.cin_topology("xor", 8)

    def tf(load):
        return sim.uniform(8, offered=load, cycles=CYCLES, terminals=4,
                           seed=9)

    from repro.fabric import LacinDeprecationWarning
    with pytest.warns(LacinDeprecationWarning):
        stats = sim.saturation_sweep(topo, sim.MinimalPolicy, tf, [0.2, 0.6],
                                     terminals=4, cycles=CYCLES,
                                     warmup=WARMUP, backend="jax")
    assert [s.offered for s in stats] == [0.2, 0.6]
    assert all(0 < s.accepted <= 1.2 for s in stats)


# ---------------------------------------------------------------------------
# Engine construction memoization (satellite).
# ---------------------------------------------------------------------------

def test_link_table_memoized_per_topology_and_vcs():
    topo = sim.cin_topology("xor", 8)
    tr = sim.uniform(8, offered=0.2, cycles=50, terminals=2, seed=0)
    e1 = sim.Engine(topo, sim.MinimalPolicy(), tr, terminals=2)
    e2 = sim.Engine(topo, sim.MinimalPolicy(), tr, terminals=2)
    assert e1.links is e2.links
    e3 = sim.Engine(topo, sim.ValiantPolicy(), tr, terminals=2)
    assert e3.links is not e1.links          # different VC count
    assert e3.num_vcs != e1.num_vcs


def test_minimal_port_table_matches_routing():
    topo = sim.cin_topology("circle", 9)
    tbl = topo.minimal_port_table()
    assert tbl is topo.minimal_port_table()  # cached
    rng = np.random.default_rng(0)
    cur = rng.integers(0, 9, 64)
    tgt = rng.integers(0, 9, 64)
    off = cur != tgt
    assert np.array_equal(tbl[cur[off], tgt[off]],
                          topo.minimal_port(cur[off], tgt[off]))


def test_engine_pressure_updates_every_cycle_when_blocked():
    """The EWMA congestion signal decays/updates on every step path,
    including fully-blocked cycles (regression for the early-return
    skip)."""
    topo = sim.cin_topology("xor", 4)
    tr = sim.uniform(4, offered=0.9, cycles=60, terminals=8, seed=1)
    eng = sim.Engine(topo, sim.MinimalPolicy(), tr, terminals=8,
                     queue_capacity=1, seed=1)
    pressures = []
    for _ in range(60):
        eng.step()
        pressures.append(eng.pressure.copy())
    # pressure must keep moving cycle-over-cycle (no frozen stale reads)
    diffs = [np.abs(a - b).sum() for a, b in zip(pressures, pressures[1:])]
    assert np.count_nonzero(diffs) >= len(diffs) // 2
