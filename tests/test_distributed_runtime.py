"""Distributed-runtime behaviours that need multiple devices (subprocess
with 8 host devices): elastic re-mesh restore, manual-DP LACIN training,
int8-compressed gradient all-reduce."""
import json
import os
import subprocess
import sys

import pytest

_CHILD = r"""
import json, tempfile
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

results = {}
devs = jax.devices()

# ---------------------------------------------------------------------------
# 1) elastic re-mesh: save on a (4,2) mesh, restore+reshard on (2,2)
# ---------------------------------------------------------------------------
from repro.checkpoint.manager import CheckpointManager
from repro.models import get_config
from repro.runtime.trainer import init_train_state

cfg = get_config("lacin-demo").reduced()
state = init_train_state(jax.random.PRNGKey(0), cfg)

mesh_a = Mesh(np.array(devs).reshape(4, 2), ("data", "model"))
mesh_b = Mesh(np.array(devs[:4]).reshape(2, 2), ("data", "model"))

with tempfile.TemporaryDirectory() as td:
    mgr = CheckpointManager(td)
    # place embed on mesh A sharded over model
    sh_a = NamedSharding(mesh_a, P("model", None))
    emb = jax.device_put(state["params"]["embed"]["table"], sh_a)
    state["params"]["embed"]["table"] = emb
    mgr.save(5, state, blocking=True)

    like = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
    sh_b = jax.tree_util.tree_map(lambda a: NamedSharding(mesh_b, P()), like)
    sh_b["params"]["embed"]["table"] = NamedSharding(mesh_b, P("model", None))
    restored = mgr.restore(5, like, shardings=sh_b)
    t = restored["params"]["embed"]["table"]
    results["elastic_devices"] = len(t.sharding.device_set)
    results["elastic_equal"] = bool(jnp.allclose(
        jax.device_get(t), jax.device_get(emb)))

# ---------------------------------------------------------------------------
# 2) manual-DP training with LACIN gradient all-reduce (+ int8 compression)
# ---------------------------------------------------------------------------
from repro.optim import OptConfig
from repro.runtime.manual_dp import (lacin_grad_allreduce,
                                     make_manual_dp_train_step)
from repro._compat.jaxapi import shard_map

mesh = Mesh(np.array(devs), ("data",))
rng = np.random.default_rng(0)
tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (16, 32)), jnp.int32)
batch = {"tokens": tok, "labels": tok}

losses = {}
for compress in (False, True):
    step = make_manual_dp_train_step(cfg, mesh, OptConfig(lr=2e-3),
                                     axis_name="data", compress=compress)
    st = init_train_state(jax.random.PRNGKey(1), cfg)
    ls = []
    for _ in range(6):
        st, m = step(st, batch)
        ls.append(float(m["loss"]))
    losses[compress] = ls
results["dp_loss_decreases"] = losses[False][-1] < losses[False][0]
results["dp_compressed_decreases"] = losses[True][-1] < losses[True][0]
results["dp_losses_close"] = abs(losses[True][-1] - losses[False][-1]) < 0.3

# compressed all-reduce error bound: <= ~1/127 of per-tensor max
# (mesh-aware API: the collective set reads the axis size from the bound
# axis environment — no hand-threaded count)
from repro.fabric import LacinCollectives
coll = LacinCollectives()
g = {"w": jax.random.normal(jax.random.PRNGKey(2), (8, 1000))}
def body(gl):
    return lacin_grad_allreduce(gl, "data", coll, compress=True)
out = shard_map(body, mesh=mesh, in_specs=({"w": P("data")},),
                out_specs={"w": P("data")})(g)
def body0(gl):
    return lacin_grad_allreduce(gl, "data", coll, compress=False)
ref = shard_map(body0, mesh=mesh, in_specs=({"w": P("data")},),
                out_specs={"w": P("data")})(g)
err = float(jnp.max(jnp.abs(out["w"] - ref["w"])))
scale = float(jnp.max(jnp.abs(ref["w"])))
results["int8_err_ratio"] = err / max(scale, 1e-9)
print("RESULT " + json.dumps(results))
"""


@pytest.fixture(scope="module")
def dist_results():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    res = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert res.returncode == 0, res.stderr[-3000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_elastic_restore_onto_smaller_mesh(dist_results):
    assert dist_results["elastic_devices"] == 4   # resharded to the new mesh
    assert dist_results["elastic_equal"]          # values survive round-trip


def test_manual_dp_lacin_training_decreases_loss(dist_results):
    assert dist_results["dp_loss_decreases"]


def test_int8_compressed_training_works(dist_results):
    assert dist_results["dp_compressed_decreases"]
    assert dist_results["dp_losses_close"]


def test_int8_allreduce_error_bounded(dist_results):
    assert dist_results["int8_err_ratio"] < 0.02
