"""Cross-engine / cross-program conformance suite.

The compiled engine earns its keep only if the same physics falls out of
every way of running it.  This suite pins the two contracts the
persistent-compile-cache + shape-bucketing + sharding rebuild rests on:

* **Engines.**  The numpy oracle and the compiled jax engine agree
  *exactly* on drained minimal workloads — delivered counts and per-link
  load totals, where unique minimal paths make the traversal multiset
  arbitration-independent — across registry instances and workload
  shapes: open-loop drains, collective replays, serving request fans,
  and degraded (failure-masked) fabrics.
* **Programs.**  Within the jax engine, every program variant must be
  *bit-identical* to the exact-shape, freshly-compiled, single-device
  reference: the bucket-padded program (:func:`xengine._bucket_count`
  shape bucketing), the executable restored from the persistent disk
  cache (``repro.obs.telemetry``), and — in a subprocess with forced
  host devices — the ``shard_map``-sharded program.  Bit-identical means
  every :class:`RunStats` field, not statistics within tolerance: the
  per-copy RNG keying guarantees padding and sharding never perturb a
  single arbitration draw.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro
import repro.fabric.mirror  # noqa: F401  (registers the mirror instance)
from repro import sim
from repro.fabric import make_fabric
from repro.faults import FailureSpec
from repro.sim import xengine
from repro.sim.metrics import RunStats
from repro.workload import ArrivalSpec, serving_traffic

INSTANCES = [("swap", 8), ("circle", 9), ("xor", 8), ("mirror", 9)]

#: RunStats fields excluded from bit-identity: both are run *metadata*
#: (wall-clock timings, sampled observability series), not simulation
#: results, and both are declared compare=False on the dataclass.
_META_FIELDS = {"timing", "trace"}


def _assert_bit_identical(a: RunStats, b: RunStats) -> None:
    for f in dataclasses.fields(RunStats):
        if f.name in _META_FIELDS:
            continue
        x, y = getattr(a, f.name), getattr(b, f.name)
        if isinstance(x, np.ndarray) or isinstance(y, np.ndarray):
            assert np.array_equal(np.asarray(x), np.asarray(y)), f.name
        else:
            assert x == y, (f.name, x, y)


def _assert_grids_bit_identical(ga, gb) -> None:
    assert len(ga) == len(gb)
    for row_a, row_b in zip(ga, gb):
        assert len(row_a) == len(row_b)
        for a, b in zip(row_a, row_b):
            _assert_bit_identical(a, b)


# ---------------------------------------------------------------------------
# Engines: numpy oracle vs compiled engine, exact on drained minimal
# workloads, across registry instances x workload shapes.
# ---------------------------------------------------------------------------

def _drained_scenario(kind: str, inst: str, n: int):
    """(traffic, failures) for one drained minimal workload shape."""
    if kind == "open_loop":
        return sim.one_shot_all_to_all(n), None
    if kind == "serving":
        return serving_traffic(ArrivalSpec(rate=0.03, seed=1), n,
                               cycles=60, terminals=4,
                               packets_per_request=2,
                               slo=40.0, seed=7), None
    if kind == "degraded":
        return (sim.one_shot_all_to_all(n),
                FailureSpec(link_fraction=0.08, seed=3))
    raise AssertionError(kind)


@pytest.mark.parametrize("inst,n", INSTANCES)
@pytest.mark.parametrize("kind", ["open_loop", "serving", "degraded"])
def test_engines_agree_exactly_on_drained_minimal(kind, inst, n):
    topo = sim.cin_topology(inst, n)
    traffic, failures = _drained_scenario(kind, inst, n)
    kw = dict(terminals=4, drain=True, seed=5, failures=failures)
    s_np = sim.simulate(topo, sim.MinimalPolicy(), traffic,
                        backend="numpy", **kw)
    s_jx = sim.simulate(topo, sim.MinimalPolicy(), traffic,
                        backend="jax", **kw)
    assert s_jx.packets_generated == s_np.packets_generated
    assert s_jx.packets_delivered == s_np.packets_delivered
    assert s_jx.packets_delivered > 0
    assert np.array_equal(np.asarray(s_jx.link_loads),
                          np.asarray(s_np.link_loads))
    if kind == "serving":
        # Request accounting (completed-request count) is also
        # arbitration-independent under drain: every packet delivers.
        assert s_jx.request_count == s_np.request_count


@pytest.mark.parametrize("inst,n", [("xor", 8), ("circle", 9)])
def test_engines_agree_on_collective_replay(inst, n):
    fab = make_fabric(inst, n)
    s_np = fab.replay("all_to_all", message_size=2, backend="numpy")
    s_jx = fab.replay("all_to_all", message_size=2, backend="jax")
    assert s_jx.packets_delivered == s_np.packets_delivered
    # LACIN 1-factor schedules are contention-free, so phase completion
    # is deterministic and both engines must land on the ideal bound.
    assert (s_jx.completion_cycles == s_np.completion_cycles
            == s_np.ideal_cycles)
    assert s_jx.phase_cycles == s_np.phase_cycles
    assert np.array_equal(np.asarray(s_jx.link_loads),
                          np.asarray(s_np.link_loads))


# ---------------------------------------------------------------------------
# Programs: bucketed == exact, bit for bit.
# ---------------------------------------------------------------------------

def _sweep(**kw):
    """An open-loop sweep whose grid (9 copies), horizon (90 cycles) and
    packet count all land strictly inside bucket boundaries, so the
    bucketed program genuinely pads every axis."""
    topo = sim.cin_topology("xor", 16)

    def tf(load, seed):
        return sim.uniform(16, offered=load, cycles=90, terminals=2,
                           seed=seed)

    return xengine.sweep(topo, "minimal", tf, [0.25, 0.55, 0.85],
                         seeds=(0, 1, 2), terminals=2, cycles=90,
                         warmup=20, **kw)


def test_bucketed_sweep_bit_identical_to_exact():
    _assert_grids_bit_identical(_sweep(bucket=False), _sweep())


def test_bucketed_drain_bit_identical_to_exact():
    topo = sim.cin_topology("circle", 9)
    tr = sim.one_shot_all_to_all(9)
    exact = xengine.simulate_jax(topo, sim.MinimalPolicy(), tr,
                                 terminals=4, bucket=False)
    bucketed = xengine.simulate_jax(topo, sim.MinimalPolicy(), tr,
                                    terminals=4)
    _assert_bit_identical(exact, bucketed)


def test_bucketed_replay_bit_identical_to_exact():
    fab = make_fabric("xor", 8)
    a = fab.replay("all_to_all", message_size=2, backend="jax",
                   bucket=False)
    b = fab.replay("all_to_all", message_size=2, backend="jax")
    _assert_bit_identical(a, b)


@settings(max_examples=6, deadline=None)
@given(points=st.integers(1, 5), cycles=st.integers(40, 88))
def test_bucketing_invariance_property(points, cycles):
    """Any grid width x any horizon: padding the batch, the packet axis,
    and the cycle loop never changes a single statistic."""
    topo = sim.cin_topology("xor", 8)

    def tf(load, seed):
        return sim.uniform(8, offered=load, cycles=cycles, terminals=2,
                           seed=seed)

    loads = [round(0.2 + 0.15 * i, 2) for i in range(points)]
    kw = dict(seeds=(0,), terminals=2, cycles=cycles, warmup=cycles // 4)
    _assert_grids_bit_identical(
        xengine.sweep(topo, "minimal", tf, loads, bucket=False, **kw),
        xengine.sweep(topo, "minimal", tf, loads, **kw))


# ---------------------------------------------------------------------------
# Programs: disk-restored executable == freshly compiled, bit for bit.
# ---------------------------------------------------------------------------

def test_disk_restored_executable_bit_identical(tmp_path, monkeypatch):
    from repro.obs import telemetry
    monkeypatch.setenv("LACIN_CACHE_DIR", str(tmp_path))
    telemetry.clear_caches(memory=True)
    fresh = _sweep()
    assert fresh[0][0].timing["compile_cached"] is False
    assert telemetry.disk_cache_entries(), "compile did not persist"
    # Drop the in-process layer: the rerun must come back from disk and
    # reproduce every statistic byte for byte.
    telemetry.clear_caches(memory=True)
    restored = _sweep()
    assert restored[0][0].timing["compile_cached"] == "disk"
    _assert_grids_bit_identical(fresh, restored)


# ---------------------------------------------------------------------------
# Programs: device-sharded == single-device, bit for bit (subprocess —
# CPU devices are fixed by XLA_FLAGS before jax initializes).
# ---------------------------------------------------------------------------

_SHARD_SCRIPT = textwrap.dedent("""
    import dataclasses
    import numpy as np
    from repro import sim
    from repro.sim import xengine
    from repro.sim.metrics import RunStats

    topo = sim.cin_topology("xor", 16)

    def tf(load, seed):
        return sim.uniform(16, offered=load, cycles=80, terminals=2,
                           seed=seed)

    kw = dict(seeds=(0, 1), terminals=2, cycles=80, warmup=20)
    ref = xengine.sweep(topo, "minimal", tf, [0.3, 0.7], **kw)
    shr = xengine.sweep(topo, "minimal", tf, [0.3, 0.7], devices=2, **kw)
    for row_r, row_s in zip(ref, shr):
        for r, s in zip(row_r, row_s):
            for f in dataclasses.fields(RunStats):
                if f.name in ("timing", "trace"):
                    continue
                x, y = getattr(r, f.name), getattr(s, f.name)
                if isinstance(x, np.ndarray) or isinstance(y, np.ndarray):
                    assert np.array_equal(np.asarray(x),
                                          np.asarray(y)), f.name
                else:
                    assert x == y, (f.name, x, y)
    drain = sim.one_shot_all_to_all(16)
    a = xengine.simulate_jax(topo, sim.MinimalPolicy(), drain, terminals=4)
    b = xengine.simulate_jax(topo, sim.MinimalPolicy(), drain, terminals=4,
                             devices=2)
    assert a.packets_delivered == b.packets_delivered
    assert np.array_equal(np.asarray(a.link_loads),
                          np.asarray(b.link_loads))
    assert a.latency_mean == b.latency_mean
    print("SHARD-CONFORMANCE-OK")
""")


def test_sharded_program_bit_identical(tmp_path):
    src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               LACIN_CACHE_DIR=str(tmp_path),
               PYTHONPATH=os.pathsep.join(
                   [src, os.environ.get("PYTHONPATH", "")]))
    proc = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "SHARD-CONFORMANCE-OK" in proc.stdout


def test_devices_validation():
    topo = sim.cin_topology("xor", 8)
    tr = sim.one_shot_all_to_all(8)
    with pytest.raises(ValueError, match="devices"):
        xengine.simulate_jax(topo, sim.MinimalPolicy(), tr, terminals=4,
                             devices=0)
    import jax
    too_many = jax.local_device_count() + 1
    with pytest.raises(ValueError, match="visible"):
        xengine.simulate_jax(topo, sim.MinimalPolicy(), tr, terminals=4,
                             devices=too_many)
