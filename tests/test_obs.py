"""Observability (repro.obs): traces, spans, telemetry, provenance.

The contract under test: tracing is *free of semantic side effects*
(trace-on and trace-off runs produce identical dynamics on both
engines), the two engines' time series agree **exactly** on drained
deterministic workloads (collective replays whose phases are matchings,
one-shot permutations), a stride-k trace is precisely the stride-1
trace downsampled, and every run carries compile-vs-execute telemetry
that survives the studies store round-trip.  Plus the Perfetto export
schema, the Dragonfly serialization plateau made visible, and the CLI.
"""
import json

import numpy as np
import pytest

from repro.core.dragonfly import DragonflyConfig
from repro.fabric import make_fabric
from repro.obs import (Trace, TraceConfig, counter_events, derive_backlog,
                       export_perfetto, link_classes, packet_events,
                       phase_events, replay_trace_events, timed_compiled,
                       validate_trace_events)
from repro.sim import simulate
from repro.sim.policies import make_policy
from repro.sim.traffic import one_shot_permutation


def _cin16():
    return make_fabric("xor", 16)


def _replay(backend, **kw):
    return _cin16().replay("all_to_all", message_size=2, backend=backend,
                           **kw)


# ---------------------------------------------------------------------------
# TraceConfig / Trace containers.
# ---------------------------------------------------------------------------

def test_trace_config_coerce_forms():
    assert TraceConfig.coerce(None) is None
    assert TraceConfig.coerce(False) is None
    assert TraceConfig.coerce(True) == TraceConfig()
    cfg = TraceConfig(stride=4, packets=2)
    assert TraceConfig.coerce(cfg) is cfg
    assert TraceConfig.coerce({"stride": 4, "packets": 2}) == cfg
    with pytest.raises(TypeError):
        TraceConfig.coerce("yes")
    with pytest.raises(ValueError):
        TraceConfig(stride=0)
    with pytest.raises(ValueError):
        TraceConfig(max_samples=0)


def test_trace_round_trips_through_dict():
    tr = _replay("numpy", trace=TraceConfig(packets=4)).trace
    back = Trace.from_dict(json.loads(json.dumps(tr.to_dict())))
    assert back.equals(tr)
    assert back.events == tr.events
    assert back.meta["backend"] == "numpy"
    assert tr.diff_summary(back) == "traces are equal"


def test_trace_diff_summary_localizes_mismatch():
    tr = _replay("numpy", trace=True).trace
    other = Trace.from_dict(tr.to_dict())
    other.delivered[3] += 7
    assert not tr.equals(other)
    assert "delivered" in tr.diff_summary(other)


# ---------------------------------------------------------------------------
# Numpy engine tracing semantics.
# ---------------------------------------------------------------------------

def test_numpy_trace_channels_are_consistent():
    stats = _replay("numpy", trace=TraceConfig(packets=8))
    tr = stats.trace
    # end-of-cycle sampling over exactly the executed cycles [0, completion]
    assert tr.cycles[0] == 0
    assert tr.cycles[-1] == stats.completion_cycles
    assert tr.num_samples == stats.completion_cycles + 1
    # cumulative channels are monotone; the drained run ends settled
    for ch in (tr.link_load, tr.injected):
        assert (np.diff(ch, axis=0) >= 0).all()
    assert (np.diff(tr.delivered) >= 0).all()
    assert tr.delivered[-1] == stats.packets_generated
    assert tr.in_flight[-1] == 0
    assert tr.backlog.min() >= 0 and tr.backlog[-1].sum() == 0
    # injected counts every packet exactly once by the end
    assert tr.injected[-1].sum() == stats.packets_generated
    # utilization is a fraction of link-cycles
    util = tr.link_util()
    assert util.shape == (tr.num_samples,)
    assert 0 <= util.min() and util.max() <= 1


def test_numpy_packet_spans_follow_sampled_packets():
    k = 6
    tr = _replay("numpy", trace=TraceConfig(packets=k)).trace
    pids = {ev[0] for ev in tr.events}
    assert len(pids) == k
    by_pid = {}
    for pid, cycle, frm, to in tr.events:
        by_pid.setdefault(pid, []).append((cycle, frm, to))
    for pid, hops in by_pid.items():
        hops.sort()
        # every traced packet's record ends with its ejection...
        assert hops[-1][2] == -1
        # ...and consecutive hops chain: each move arrives where the
        # next one departs.
        for (c0, f0, t0), (c1, f1, _t1) in zip(hops, hops[1:]):
            assert c0 < c1
            assert t0 == -1 or t0 == f1


def test_trace_off_is_bitwise_identical_numpy():
    base = _replay("numpy")
    traced = _replay("numpy", trace=TraceConfig(packets=4))
    assert base.completion_cycles == traced.completion_cycles
    assert base.phase_cycles == traced.phase_cycles
    assert np.array_equal(base.link_loads, traced.link_loads)
    assert np.array_equal(base.latency_histogram, traced.latency_histogram)
    assert base.latency_mean == traced.latency_mean


def test_trace_off_is_bitwise_identical_jax():
    base = _replay("jax")
    traced = _replay("jax", trace=True)
    assert base.completion_cycles == traced.completion_cycles
    assert base.phase_cycles == traced.phase_cycles
    assert np.array_equal(base.link_loads, traced.link_loads)
    assert np.array_equal(base.latency_histogram, traced.latency_histogram)
    assert base.latency_mean == traced.latency_mean


# ---------------------------------------------------------------------------
# Cross-engine exact agreement (deterministic drained workloads).
# ---------------------------------------------------------------------------

def test_engines_trace_equal_on_cin_replay():
    a = _replay("numpy", trace=True).trace
    b = _replay("jax", trace=True).trace
    assert a.equals(b), a.diff_summary(b)
    assert b.meta["backend"] == "jax" and b.events == []


def test_engines_trace_equal_on_drained_permutation():
    topo = _cin16().sim_topology()
    pol = make_policy("minimal")
    traces = {}
    partners = (np.arange(16) + 5) % 16
    for be in ("numpy", "jax"):
        traces[be] = simulate(topo, pol, one_shot_permutation(partners),
                              backend=be, trace=True).trace
    assert traces["numpy"].equals(traces["jax"]), \
        traces["numpy"].diff_summary(traces["jax"])


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_stride_k_is_downsampled_stride_1(backend):
    fine = _replay(backend, trace=TraceConfig(stride=1)).trace
    coarse = _replay(backend, trace=TraceConfig(stride=3)).trace
    assert coarse.stride == 3
    assert coarse.equals(fine.downsample(3)), \
        coarse.diff_summary(fine.downsample(3))


def test_max_samples_caps_rows_identically():
    cfg = TraceConfig(max_samples=7)
    a = _replay("numpy", trace=cfg).trace
    b = _replay("jax", trace=cfg).trace
    assert a.num_samples == b.num_samples == 7
    assert a.equals(b), a.diff_summary(b)


def test_batched_sweep_traces_slice_per_point():
    """Two copies of the same deterministic replay batched into one
    compiled program must each reproduce the oracle's trace — pinning
    the per-copy column slicing of the flat ring buffers."""
    from repro.sim import xengine
    from repro.sim.workloads import collective_workload
    fab = _cin16()
    oracle = _replay("numpy", trace=True).trace
    w = collective_workload(fab, "all_to_all", message_size=2)
    grid = xengine.sweep(fab.sim_topology(), make_policy("minimal"),
                         lambda _l, _s: w.traffic(), [0.0], seeds=(0, 1),
                         warmup=0, trace=True)
    for stats in grid[0]:
        assert stats.trace.equals(oracle), stats.trace.diff_summary(oracle)
        assert stats.timing["grid_points"] == 2


# ---------------------------------------------------------------------------
# Backlog derivation.
# ---------------------------------------------------------------------------

def test_derive_backlog_open_loop_math():
    # 2 switches; switch 0 owns gens [0, 2, 2], switch 1 owns [1]
    gen = np.array([0, 2, 2, 1])
    blk_start, blk_end = np.array([0, 3]), np.array([3, 4])
    cycles = np.array([0, 1, 2, 3])
    injected = np.zeros((4, 2), np.int64)
    out = derive_backlog(cycles, injected, gen, blk_start, blk_end)
    assert out.tolist() == [[1, 0], [1, 1], [3, 1], [3, 1]]
    # injections subtract
    injected[:, 0] = [1, 1, 2, 3]
    out = derive_backlog(cycles, injected, gen, blk_start, blk_end)
    assert out[:, 0].tolist() == [0, 0, 1, 0]


def test_derive_backlog_replay_gates_on_phases():
    gen = np.array([0, 1, 2])          # phase ordinals, one switch
    blk_start, blk_end = np.array([0]), np.array([3])
    phase_done = np.array([4, 9, -1])  # phase 2 incomplete
    cycles = np.array([0, 4, 5, 9, 10])
    injected = np.zeros((5, 1), np.int64)
    out = derive_backlog(cycles, injected, gen, blk_start, blk_end,
                         phase_done=phase_done)
    # eligible = packets whose phase < completed-phase count at the cycle
    assert out[:, 0].tolist() == [1, 2, 2, 3, 3]


# ---------------------------------------------------------------------------
# Spans + Perfetto export.
# ---------------------------------------------------------------------------

def test_phase_events_cover_the_replay():
    stats = _replay("numpy")
    evs = [e for e in phase_events(stats) if e["ph"] == "X"]
    assert len(evs) == len(stats.phase_cycles)
    assert sum(e["dur"] for e in evs) == stats.completion_cycles
    assert evs[-1]["ts"] + evs[-1]["dur"] == stats.completion_cycles


def test_export_perfetto_payload_loads(tmp_path):
    stats = _replay("numpy", trace=TraceConfig(packets=8))
    out = tmp_path / "replay.json"
    payload = export_perfetto(str(out),
                              replay_trace_events(stats,
                                                  topo=_cin16().sim_topology()))
    on_disk = json.loads(out.read_text())
    assert on_disk == payload
    events = on_disk["traceEvents"]
    validate_trace_events(events)
    phs = {e["ph"] for e in events}
    assert phs <= {"X", "C", "M"}
    assert any(e["ph"] == "X" and e.get("cat") == "packet" for e in events)
    counters = {e["name"] for e in events if e["ph"] == "C"}
    assert {"in_flight", "inj_backlog"} <= counters
    assert any(n.startswith("link_util") for n in counters)


def test_validate_trace_events_rejects_bad_events():
    ok = [{"name": "a", "ph": "X", "ts": 0, "dur": 1, "pid": 1, "tid": 0}]
    assert validate_trace_events(ok) is ok
    for bad, msg in [
            ([{"name": "a", "ph": "Z", "ts": 0}], "unknown phase"),
            ([{"ph": "X", "ts": 0, "dur": 1}], "missing name"),
            ([{"name": "a", "ph": "X", "ts": 0.5, "dur": 1}], "ts"),
            ([{"name": "a", "ph": "X", "ts": 0, "dur": -1}], "dur"),
            ([{"name": "a", "ph": "X", "ts": 0}], "dur"),
            ([{"name": "a", "ph": "C", "ts": 0}], "args"),
            ("nope", "list"),
    ]:
        with pytest.raises(ValueError, match=msg):
            validate_trace_events(bad)


def test_counter_events_round_values():
    evs = counter_events("u", [0, 2], [0.123456789, 1.0])
    samples = [e for e in evs if e["ph"] == "C"]
    assert [e["args"]["u"] for e in samples] == [0.123457, 1.0]
    assert [e["ts"] for e in samples] == [0, 2]


def test_packet_events_lane_per_switch():
    tr = _replay("numpy", trace=TraceConfig(packets=8)).trace
    evs = packet_events(tr)
    spans = [e for e in evs if e["ph"] == "X"]
    lanes = {e["tid"] for e in spans}
    assert spans and all(e["dur"] >= 1 for e in spans)
    # each span sits on the lane of the switch the hop arrived at
    assert all(e["tid"] == e["args"]["to"] for e in spans)
    named = {e["tid"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert lanes <= named


# ---------------------------------------------------------------------------
# The Dragonfly serialization plateau, measured from the trace.
# ---------------------------------------------------------------------------

def test_dragonfly72_trace_shows_serialization_plateau():
    fab = make_fabric(DragonflyConfig(group_size=6, terminals_per_switch=2,
                                      global_ports_per_switch=2,
                                      num_groups=12))
    stats = fab.replay("all_to_all", message_size=2,
                       trace=TraceConfig(packets=8))
    ratio = stats.completion_cycles / stats.ideal_cycles
    assert ratio > 3, ratio          # the ~4.4x headline serialization
    topo = fab.sim_topology()
    classes = link_classes(topo)
    assert classes["global"].any() and classes["local"].any()
    tr = stats.trace
    # per-cycle traversals over the scarce global wires
    g_load = tr.link_load[:, classes["global"]].sum(axis=1)
    g_rate = np.diff(np.concatenate([[0], g_load]))
    busy = g_rate > 0
    # global phases dominate the run (that's where the 4.4x comes from)...
    assert busy.mean() > 0.5
    # ...and while one is active, every group's chosen global link is
    # saturated: the plateau sits at exactly num_groups traversals/cycle.
    assert g_rate.max() == fab.config.num_groups
    assert np.median(g_rate[busy]) == fab.config.num_groups
    # the exported trace carries the split as separate counter tracks
    names = {e["name"] for e in replay_trace_events(stats, topo=topo)
             if e["ph"] == "C"}
    assert {"link_util/global", "link_util/local"} <= names


def test_link_classes_flat_fabric_is_all_local():
    topo = _cin16().sim_topology()
    classes = link_classes(topo)
    assert set(classes) == {"local"}
    assert classes["local"].sum() == np.count_nonzero(
        topo.neighbor.reshape(-1) >= 0)


# ---------------------------------------------------------------------------
# Telemetry: compile-vs-execute, provenance, store round-trip.
# ---------------------------------------------------------------------------

def test_numpy_runs_carry_wall_clock_timing():
    stats = _replay("numpy")
    t = stats.timing
    assert t["backend"] == "numpy" and t["compile_s"] == 0.0
    assert t["execute_s"] > 0 and t["total_s"] == t["execute_s"]


def test_jax_runs_split_compile_from_execute():
    from repro.obs.telemetry import clear_caches
    clear_caches(memory=True, disk=True)
    # message_size=4 gives this test a program no other test in the
    # session compiles, so the cold run is genuinely cold (jax keeps its
    # own in-process HLO-level compile cache that clear_caches cannot
    # reach — a shape-identical program compiled elsewhere would make
    # "cold" compile in milliseconds and invert the timing assertions).
    _cache_replay = lambda: _cin16().replay(  # noqa: E731
        "all_to_all", message_size=4, backend="jax")
    cold = _cache_replay()
    warm = _cache_replay()
    assert cold.timing["backend"] == "jax"
    assert not cold.timing["compile_cached"]
    assert cold.timing["compile_s"] > 0 and cold.timing["execute_s"] > 0
    assert warm.timing["compile_cached"] == "memory"
    assert warm.timing["compile_s"] == 0.0
    # dropping the memory layer falls back to the persistent disk layer:
    # same program, deserialized in milliseconds instead of recompiled
    clear_caches(memory=True, disk=False)
    disk = _cache_replay()
    assert disk.timing["compile_cached"] == "disk"
    assert disk.timing["compile_s"] < cold.timing["compile_s"]


def test_timed_compiled_caches_per_signature():
    import jax
    import jax.numpy as jnp
    from functools import partial

    calls = []

    @partial(jax.jit, static_argnums=0)
    def f(k, x):
        calls.append(k)
        return x * k

    x = jnp.arange(4.0)
    out1, t1 = timed_compiled(f, 3, x)
    out2, t2 = timed_compiled(f, 3, x)
    _, t3 = timed_compiled(f, 4, x)
    assert np.array_equal(np.asarray(out1), np.asarray(out2))
    assert not t1["compile_cached"] and t2["compile_cached"]
    assert not t3["compile_cached"]       # new static arg -> new program
    _, t4 = timed_compiled(f, 3, jnp.arange(8.0))
    assert not t4["compile_cached"]       # new shape -> new program


def test_result_provenance_round_trips_through_store(tmp_path):
    from repro.studies import JsonlStore, Result
    stats = _replay("numpy")
    res = Result.from_stats(stats, key="k", experiment="e", load=0.0,
                            seed=0, backend="numpy", spec_digest="d1")
    assert res.in_flight_at_end == 0
    prov = res.provenance
    assert prov["backend"] == "numpy" and prov["spec_digest"] == "d1"
    assert prov["timings"] == stats.timing
    assert prov["numpy"] == np.__version__
    store = JsonlStore(tmp_path / "r.jsonl")
    store.append(res)
    back = store.load()["k"]
    assert back.provenance == prov
    assert back.in_flight_at_end == 0
    # records from stores written before the telemetry fields existed
    # still load (defaulted fields)
    old = dict(json.loads(res.to_line()))
    old.pop("provenance")
    old.pop("in_flight_at_end")
    legacy = Result.from_record(old)
    assert legacy.provenance is None and legacy.in_flight_at_end == 0


def test_to_record_carries_replay_and_residue_fields():
    from repro.sim.report import to_record
    stats = _replay("numpy")
    rec = to_record(stats)
    assert rec["completion_cycles"] == stats.completion_cycles
    assert rec["ideal_cycles"] == stats.ideal_cycles
    assert rec["phase_cycles"] == list(stats.phase_cycles)
    assert rec["in_flight_at_end"] == 0
    assert rec["timing"] == stats.timing
    json.dumps(rec)                       # everything JSON-scalar
    # open-loop runs omit the replay keys but keep the residue count
    open_stats = simulate(_cin16().sim_topology(), make_policy("minimal"),
                          one_shot_permutation((np.arange(16) + 1) % 16),
                          backend="numpy")
    open_rec = to_record(open_stats)
    assert "completion_cycles" not in open_rec
    assert "in_flight_at_end" in open_rec


def test_study_telemetry_counts_batched_programs_once(tmp_path):
    from repro import studies
    exp = studies.ExperimentSpec(
        fabric=studies.FabricSpec("cin", {"instance": "xor", "n": 8}),
        traffic=studies.TrafficSpec("uniform"),
        routing=studies.RoutingSpec("minimal"),
        sweep=studies.SweepSpec(loads=(0.2, 0.4), seeds=(0, 1),
                                cycles=120, warmup=30))
    out = studies.Study(exp, backend="jax").run()
    tel = out.telemetry()[exp.name]
    assert tel["points"] == 4
    assert tel["programs"] == 1           # one batched program, counted once
    assert tel["backend"] == "jax"


# ---------------------------------------------------------------------------
# CLI.
# ---------------------------------------------------------------------------

def test_cli_trace_export_both_backends(tmp_path, capsys):
    from repro.studies.__main__ import main as cli
    out = tmp_path / "trace.json"
    rc = cli(["trace", "export", "collective_replay",
              "--experiment", "cin-xor-16/replay-all_to_all/minimal",
              "--backend", "both", "--packets", "4",
              "--out", str(out)])
    assert rc == 0
    text = capsys.readouterr().out
    assert "cross-engine traces agree exactly" in text
    assert "ratio=1.000" in text
    payload = json.loads(out.read_text())
    validate_trace_events(payload["traceEvents"])


def test_cli_trace_export_rejects_unknown_experiment(tmp_path):
    from repro.studies.__main__ import main as cli
    with pytest.raises(SystemExit, match="no experiment named"):
        cli(["trace", "export", "collective_replay",
             "--experiment", "nope", "--out", str(tmp_path / "t.json")])


def test_cli_show_trace_reads_store(tmp_path, capsys, monkeypatch):
    from repro.studies.__main__ import main as cli
    monkeypatch.chdir(tmp_path)
    store = tmp_path / "s.jsonl"
    rc = cli(["run", "studies_smoke", "--backend", "numpy",
              "--store", str(store)])
    assert rc == 0
    capsys.readouterr()
    rc = cli(["show", "studies_smoke", "--trace", "--store", str(store)])
    assert rc == 0
    text = capsys.readouterr().out
    assert "backend=numpy" in text
    assert "compile tax per experiment" in text
    # without a store: a pointer, not a crash
    rc = cli(["show", "studies_smoke", "--trace"])
    assert rc == 0
    assert "no result store" in capsys.readouterr().out
