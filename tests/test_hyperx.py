"""Paper §5 + Figure 4: HyperX with LACIN wiring."""
import pytest

from repro.core import (HyperXConfig, all_pairs_max_hops, fig4_4cubed,
                        hyperx_link_loads, paper_16cubed)


def test_paper_16cubed_flagship_numbers():
    r = paper_16cubed().report()
    assert r["switches"] == 4096
    assert r["endpoints"] == 65536
    assert r["radix"] == 61                      # 16 edge + 3*15 network
    assert r["network_ports_per_switch"] == 45
    assert r["z_links_per_rack"] == 120          # 15 columns of 8 wires
    assert r["z_columns_per_rack"] == 15
    assert r["z_wires_per_column"] == 8
    assert r["super_ports_per_rack_x"] == 15
    assert r["wires_per_super_port"] == 16
    assert r["hoses_per_rack_row"] == 120        # of 16 wires each
    assert r["hose_colours_x"] == (15, 8)        # 15 colours x 8 hoses
    assert r["racks"] == 256 and r["rack_grid"] == (16, 16)


def test_fig4_4cubed():
    r = fig4_4cubed().report()
    assert r["switches"] == 64 and r["endpoints"] == 256
    assert r["radix"] == 13                      # 4 + 3*3
    assert r["hoses_per_rack_row"] == 6 and r["hose_colours_x"] == (3, 2)


def test_dor_routing_diameter():
    cfg = HyperXConfig(dims=(4, 4, 4), terminals=4)
    assert cfg.diameter == 3
    assert all_pairs_max_hops(cfg) == 3


def test_dor_skips_matching_digits():
    cfg = HyperXConfig(dims=(4, 4, 4), terminals=4)
    hops = cfg.dor_route((1, 2, 3), (1, 2, 0))
    assert len(hops) == 1                        # only X differs
    hops = cfg.dor_route((1, 2, 3), (1, 2, 3))
    assert hops == []


def test_per_dimension_xor_ports():
    """§5: port P_{A_d xor B_d - 1} within the dimension's port block."""
    cfg = HyperXConfig(dims=(16, 16, 16), terminals=16)
    src, dst_digit, d = (3, 5, 9), 12, 2
    port = cfg.port_for(src, d, dst_digit)
    base = cfg.dim_port_base(d)
    assert port == base + (9 ^ 12) - 1


def test_endpoint_routing_ejects_at_b0():
    cfg = HyperXConfig(dims=(4, 4), terminals=4)
    hops = cfg.route_endpoint(0, 63)
    assert hops[-1][1] == 63 % 4                 # ejection port = C0


def test_uniform_traffic_perfectly_balanced():
    ll = hyperx_link_loads(HyperXConfig(dims=(4, 4), terminals=4))
    assert ll["load_cv"] == 0.0
    assert ll["max_link_load"] == ll["min_link_load"]


def test_xor_hyperx_rejects_non_pow2_dims():
    with pytest.raises(ValueError):
        HyperXConfig(dims=(6, 6), terminals=4, instance="xor")
    HyperXConfig(dims=(6, 6), terminals=4, instance="circle")  # ok
