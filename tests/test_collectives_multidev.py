"""LACIN collectives on 8 host devices (subprocess — the main test process
keeps the default single-device environment)."""
import json
import os
import subprocess
import sys

import pytest

_CHILD = r"""
import json
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro._compat.jaxapi import shard_map
from repro.core import (all_to_all_lacin, all_gather_lacin,
                        reduce_scatter_lacin, all_reduce_lacin)

devs = jax.devices(); n = len(devs)
assert n == 8, n
mesh = Mesh(np.array(devs), ("x",))
results = {}

for inst in ("xor", "circle", "cyclic"):
    x = jnp.arange(n * n * 12, dtype=jnp.float32).reshape(n, n, 4, 3)
    out = shard_map(lambda xl: all_to_all_lacin(xl[0], "x", axis_size=n,
                                                instance=inst)[None],
                    mesh=mesh, in_specs=P("x"), out_specs=P("x"))(x)
    results[f"a2a_{inst}"] = bool(jnp.array_equal(out, jnp.swapaxes(x, 0, 1)))

    xs = jnp.arange(n * 5, dtype=jnp.float32).reshape(n, 5)
    out = shard_map(lambda xl: all_gather_lacin(xl[0], "x", axis_size=n,
                                                instance=inst)[None],
                    mesh=mesh, in_specs=P("x"), out_specs=P("x"))(xs)
    results[f"ag_{inst}"] = bool(jnp.array_equal(out, jnp.broadcast_to(xs, (n, n, 5))))

    xr = jax.random.normal(jax.random.PRNGKey(0), (n, n, 6))
    out = shard_map(lambda xl: reduce_scatter_lacin(xl[0], "x", axis_size=n,
                                                    instance=inst)[None],
                    mesh=mesh, in_specs=P("x"), out_specs=P("x"))(xr)
    results[f"rs_{inst}"] = bool(jnp.allclose(out, jnp.sum(xr, 0), rtol=1e-4,
                                              atol=1e-5))

    xa = jax.random.normal(jax.random.PRNGKey(1), (n, 7, 3))
    out = shard_map(lambda xl: all_reduce_lacin(xl[0], "x", axis_size=n,
                                                instance=inst)[None],
                    mesh=mesh, in_specs=P("x"), out_specs=P("x"))(xa)
    want = jnp.broadcast_to(jnp.sum(xa, 0), (n, 7, 3))
    results[f"ar_{inst}"] = bool(jnp.allclose(out, want, rtol=1e-4, atol=1e-5))

# odd axis size with circle (5 devices of the 8)
mesh5 = Mesh(np.array(devs[:5]), ("x",))
x5 = jax.random.normal(jax.random.PRNGKey(2), (5, 5, 4))
out = shard_map(lambda xl: all_to_all_lacin(xl[0], "x", axis_size=5,
                                            instance="circle")[None],
                mesh=mesh5, in_specs=P("x"), out_specs=P("x"))(x5)
results["a2a_circle_odd"] = bool(jnp.allclose(out, jnp.swapaxes(x5, 0, 1)))

# gradient flows through the schedule (ppermute transpose)
def loss(x):
    def body(xl):
        return all_reduce_lacin(xl[0], "x", axis_size=n)[None]
    y = shard_map(body, mesh=mesh, in_specs=P("x"), out_specs=P("x"))(x)
    return (y ** 2).sum()
g = jax.grad(loss)(jnp.ones((n, 4)))
results["grad_finite"] = bool(jnp.isfinite(g).all())

# HLO step count: all-reduce = RS + AG = 2(N-1) collective-permutes
import re
txt = jax.jit(shard_map(lambda xl: all_reduce_lacin(xl[0], "x", axis_size=n,
                                                    instance="xor")[None],
              mesh=mesh, in_specs=P("x"), out_specs=P("x"))).lower(
    jax.ShapeDtypeStruct((n, 16, 16), jnp.float32)).compile().as_text()
# match op instances only ("collective-permute(") — the bare name also
# appears in metadata/op_name annotations on some XLA versions.
results["ar_permutes"] = len(re.findall(r"collective-permute\(", txt))
print("RESULT " + json.dumps(results))
"""


@pytest.fixture(scope="module")
def child_results():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    res = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert res.returncode == 0, res.stderr[-2000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


@pytest.mark.parametrize("op", ["a2a", "ag", "rs", "ar"])
@pytest.mark.parametrize("inst", ["xor", "circle", "cyclic"])
def test_collective_correct(child_results, op, inst):
    assert child_results[f"{op}_{inst}"], (op, inst)


def test_odd_axis_circle(child_results):
    assert child_results["a2a_circle_odd"]


def test_gradients_flow_through_schedule(child_results):
    assert child_results["grad_finite"]


def test_all_reduce_is_2_n_minus_1_matchings(child_results):
    assert child_results["ar_permutes"] == 2 * 7
