"""Paper Figure 3 + §5: Dragonfly with LACIN local/global wiring."""
import itertools

import pytest

from repro.core import (DragonflyConfig, fig3_16, hpe_dragonfly_group)


def test_fig3_partitioned_cin16():
    r = fig3_16().report()
    assert r["total_links"] == 120
    assert r["intra_links"] == 24                # 4 x C(4,2)
    assert r["inter_links"] == 96                # 6 hoses x 16 wires
    assert r["bundles"] == 6 and r["wires_per_bundle"] == 16


def test_hpe_dragonfly_rack():
    r = hpe_dragonfly_group().report()
    assert r["bundles"] == 28 and r["wires_per_bundle"] == 16
    assert r["switches"] == 32


def test_dragonfly_radix_and_counts():
    d = DragonflyConfig(group_size=8, terminals_per_switch=4,
                        global_ports_per_switch=2, num_groups=16)
    assert d.radix == 4 + 7 + 2
    assert d.switches == 128 and d.endpoints == 512
    assert d.total_links == 16 * 28 + 120


def test_dragonfly_rejects_too_many_groups():
    with pytest.raises(ValueError):
        DragonflyConfig(group_size=4, terminals_per_switch=2,
                        global_ports_per_switch=1, num_groups=6)


def test_lgl_minimal_routing_delivers():
    d = DragonflyConfig(group_size=8, terminals_per_switch=4,
                        global_ports_per_switch=2, num_groups=16)
    for ga, gb in itertools.product(range(16), repeat=2):
        for sa, sb, tb in ((0, 0, 0), (3, 6, 2), (7, 1, 3)):
            hops = d.route_packet((ga, sa, 0), (gb, sb, tb))
            kinds = [h[0] for h in hops]
            assert kinds[-1] == "eject"
            assert kinds.count("global") == (0 if ga == gb else 1)
            assert len(hops) <= 4                # l + g + l + eject
            assert hops[-1][1] == (gb, sb, tb)


def test_isoport_global_colour_matches_at_both_ends():
    """§5: an isoport global CIN gives the same colour at both group ends."""
    d = DragonflyConfig(group_size=8, terminals_per_switch=4,
                        global_ports_per_switch=2, num_groups=16,
                        global_instance="circle")
    from repro.core import route
    for ga, gb in itertools.combinations(range(16), 2):
        assert (route("circle", ga, gb, 16) == route("circle", gb, ga, 16))
