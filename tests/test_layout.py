"""Paper §4: LACIN wire lengths and crossing analysis."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (circle_layout_crossings_with_rule,
                        circle_predicted_crossings, instance_crossings,
                        lacin_total_wire_length,
                        lacin_total_wire_length_enumerated,
                        swap_to_lacin_ratio, table1, wire_length_histogram)


@settings(max_examples=30, deadline=None)
@given(n=st.integers(2, 256))
def test_wire_length_formula(n):
    assert lacin_total_wire_length(n) == lacin_total_wire_length_enumerated(n)
    hist = wire_length_histogram(n)
    # "w wires of length N - w"
    assert all(hist[n - w] == w for w in range(1, n))


def test_swap_ratio_approaches_sqrt2():
    r64, r256, r1024 = (swap_to_lacin_ratio(n) for n in (64, 256, 1024))
    assert r64 < r256 < r1024 < math.sqrt(2)
    assert abs(r1024 - math.sqrt(2)) < 0.01


@pytest.mark.parametrize("n", [8, 16, 32, 64])
def test_circle_crossing_closed_form(n):
    got = instance_crossings("circle", n)
    assert got == circle_predicted_crossings(n)
    # i parallel links crossed for i < N/2, N-2-i after
    assert got[0] == 0 and got[-1] == 0
    assert max(got) == n // 2 - 1


@pytest.mark.parametrize("n", [8, 16, 32])
def test_circle_left_right_rule_removes_all_crossings(n):
    assert circle_layout_crossings_with_rule(n) == 0


def test_xor_crossings_grow_with_n():
    c8 = sum(instance_crossings("xor", 8))
    c16 = sum(instance_crossings("xor", 16))
    c32 = sum(instance_crossings("xor", 32))
    assert 0 < c8 < c16 < c32


def test_table1_summary():
    rows = {r.instance: r for r in table1(n=256)}
    assert rows["circle"].isoport and rows["xor"].isoport
    assert not rows["swap"].isoport
    assert rows["circle"].wire_length_norm == 1.0
    assert rows["xor"].sizes == "N=2^n"
    assert 1.3 < rows["swap"].wire_length_norm < math.sqrt(2)
    assert (rows["xor"].routing_cost, rows["swap"].routing_cost,
            rows["circle"].routing_cost) == (0, 1, 5)
