"""Recurrent-state handoff: prefill-then-decode must equal the full
teacher-forced forward for stateful architectures (mLSTM/sLSTM/SSM caches
carry real state, unlike KV caches which are mere memoization)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models import NO_SHARD, decode_step, get_config, init_params, prefill
from repro.models import layers as L
from repro.models.transformer import apply_stack, build_runs


@pytest.mark.parametrize("arch", ["xlstm-350m", "hymba-1.5b"])
def test_prefill_decode_equals_full_forward(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    t_total = 24
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, t_total)),
                       jnp.int32)

    # reference: full forward over all tokens
    runs = build_runs(cfg)
    batchx = {"tokens": toks}
    from repro.models.transformer import _prepare_prefix
    x, prefix = _prepare_prefix(params, toks, cfg, NO_SHARD, batchx)
    pos = jnp.arange(x.shape[1], dtype=jnp.int32)
    x, _, _ = apply_stack(params["stack"], x, cfg, NO_SHARD, runs,
                          q_pos=pos, kv_pos=pos, mode="train")
    x = L.apply_norm(params["final_norm"], x)
    ref = L.logits_from_hidden(x, params["embed"], params.get("lm_head"),
                               cfg, NO_SHARD)

    # prefill on T-2, then decode tokens T-2 and T-1
    seq_len = t_total + prefix + 4
    cut = t_total - 2
    _, caches = prefill(params, {"tokens": toks[:, :cut]}, cfg, NO_SHARD,
                        seq_len)
    lp, caches = decode_step(params, toks[:, cut:cut + 1], caches,
                             jnp.asarray(cut + prefix, jnp.int32), cfg,
                             NO_SHARD, seq_len)
    lq, _ = decode_step(params, toks[:, cut + 1:cut + 2], caches,
                        jnp.asarray(cut + 1 + prefix, jnp.int32), cfg,
                        NO_SHARD, seq_len)
    np.testing.assert_allclose(
        np.asarray(lp[:, 0], np.float32),
        np.asarray(ref[:, prefix + cut], np.float32), rtol=4e-2, atol=4e-2)
    np.testing.assert_allclose(
        np.asarray(lq[:, 0], np.float32),
        np.asarray(ref[:, prefix + cut + 1], np.float32), rtol=4e-2,
        atol=4e-2)
