"""repro.workload: arrival processes, serving traffic, HLO extraction.

Property tests run under real hypothesis when installed and under the
deterministic ``repro._compat.hypothesis_fallback`` otherwise (see
conftest).  The extraction tests compile a real multi-device training
step in a subprocess (XLA_FLAGS must be set before jax imports), lower
its collective sequence, and replay the result on both cycle engines.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.fabric import make_fabric
from repro.workload import ArrivalSpec, serving_demands, serving_traffic

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# ArrivalSpec properties.
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(kind=st.sampled_from(["poisson", "mmpp"]),
       rate=st.floats(min_value=0.005, max_value=0.08),
       seed=st.integers(min_value=0, max_value=2**16))
def test_arrivals_deterministic_and_sorted(kind, rate, seed):
    spec = ArrivalSpec(kind=kind, rate=rate)
    src1, gen1 = spec.arrivals(n=8, horizon=64, seed=seed)
    src2, gen2 = spec.arrivals(n=8, horizon=64, seed=seed)
    np.testing.assert_array_equal(src1, src2)
    np.testing.assert_array_equal(gen1, gen2)
    # (src, gen)-sorted and in range: the order both engines rely on.
    order = np.lexsort((gen1, src1))
    np.testing.assert_array_equal(order, np.arange(order.size))
    if src1.size:
        assert 0 <= src1.min() and src1.max() < 8
        assert 0 <= gen1.min() and gen1.max() < 64


@settings(max_examples=10, deadline=None)
@given(kind=st.sampled_from(["poisson", "mmpp"]),
       rate=st.floats(min_value=0.01, max_value=0.06),
       seed=st.integers(min_value=0, max_value=2**16))
def test_arrivals_rate_conservation(kind, rate, seed):
    n, horizon = 16, 400
    spec = ArrivalSpec(kind=kind, rate=rate)
    src, _ = spec.arrivals(n=n, horizon=horizon, seed=seed)
    expected = spec.mean_rate * n * horizon
    # Poisson counts concentrate at sqrt(mean); the MMPP window mean has
    # extra variance from state correlation (~1/(p_on+p_off) cycles), so
    # the bound is loose — it still catches any systematic rate error.
    assert abs(src.size - expected) < 0.4 * expected + 40


@settings(max_examples=10, deadline=None)
@given(rate=st.floats(min_value=0.02, max_value=0.06),
       seed=st.integers(min_value=0, max_value=2**16))
def test_arrivals_scale_increases_volume(rate, seed):
    spec = ArrivalSpec(kind="poisson", rate=rate)
    base, _ = spec.arrivals(n=16, horizon=300, seed=seed)
    scaled, _ = spec.arrivals(n=16, horizon=300, seed=seed, scale=3.0)
    assert scaled.size > base.size


def test_arrivals_empty_window_and_zero_rate():
    src, gen = ArrivalSpec(rate=0.05).arrivals(n=4, horizon=0, seed=1)
    assert src.size == 0 and gen.size == 0
    for kind in ("poisson", "mmpp"):
        src, gen = ArrivalSpec(kind=kind, rate=0.0).arrivals(
            n=4, horizon=200, seed=1)
        assert src.size == 0 and gen.size == 0


def test_arrivals_pinned_seed_ignores_caller_seed():
    spec = ArrivalSpec(rate=0.05, seed=11)
    a = spec.arrivals(n=8, horizon=100, seed=1)
    b = spec.arrivals(n=8, horizon=100, seed=2)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


@settings(max_examples=15, deadline=None)
@given(kind=st.sampled_from(["poisson", "mmpp", "trace"]),
       rate=st.floats(min_value=0.001, max_value=0.2),
       seed=st.integers(min_value=0, max_value=99))
def test_arrival_spec_json_roundtrip(kind, rate, seed):
    kw = {"kind": kind, "rate": rate, "seed": seed}
    if kind == "trace":
        kw["times"] = (5, 1, 9)
        kw["sources"] = (2, 0, 1)
    spec = ArrivalSpec(**kw)
    assert ArrivalSpec.from_json(spec.to_json()) == spec


def test_trace_canonicalization_and_replay():
    spec = ArrivalSpec(kind="trace", times=(9, 1, 5), sources=(1, 2, 0))
    assert spec.times == (1, 5, 9)          # sorted by (time, source)
    assert spec.sources == (2, 0, 1)
    src, gen = spec.arrivals(n=4, horizon=6, seed=0)
    # 9 >= horizon dropped; output re-sorted by (src, gen) like every
    # arrival stream, so (t=5, s=0) precedes (t=1, s=2).
    np.testing.assert_array_equal(src, [0, 2])
    np.testing.assert_array_equal(gen, [5, 1])
    with pytest.raises(ValueError, match="rate-scaled"):
        spec.arrivals(n=4, horizon=6, seed=0, scale=2.0)


def test_trace_validation():
    with pytest.raises(ValueError, match="at least one"):
        ArrivalSpec(kind="trace")
    with pytest.raises(ValueError, match="match"):
        ArrivalSpec(kind="trace", times=(1, 2), sources=(0,))
    spec = ArrivalSpec(kind="trace", times=(0,), sources=(9,))
    with pytest.raises(ValueError, match="outside"):
        spec.arrivals(n=4, horizon=10)


def test_mmpp_mean_rate_matches_mixture():
    spec = ArrivalSpec(kind="mmpp", rate=0.02, burst=5.0, p_on=0.1,
                       p_off=0.3)
    pi = 0.1 / 0.4
    assert spec.mean_rate == pytest.approx(0.02 * (1 - pi) + 0.1 * pi)


# ---------------------------------------------------------------------------
# Serving traffic and per-request metrics.
# ---------------------------------------------------------------------------

def test_serving_traffic_shape_and_demands():
    tr = serving_traffic(ArrivalSpec(rate=0.04), 8, cycles=200,
                         packets_per_request=3, slo=25.0, seed=3)
    assert tr.request is not None and tr.slo == 25.0
    assert tr.num_packets % 3 == 0
    counts = np.bincount(tr.request)
    assert (counts == 3).all()              # every request fans 3 packets
    assert (tr.src != tr.dst).all()         # peers exclude the source
    s, d, rate = serving_demands(tr, 8)
    assert rate.sum() * tr.horizon == pytest.approx(tr.num_packets)
    assert (s != d).all()


def test_serving_cross_engine_exact_agreement():
    """The same Traffic through numpy and the compiled engine yields
    identical serving metrics (drained, deterministic packet order)."""
    from repro.sim import xengine
    from repro.sim.engine import simulate
    from repro.sim.policies import make_policy
    topo = make_fabric("xor", 8).sim_topology()
    tr = serving_traffic(ArrivalSpec(rate=0.04), 8, cycles=150,
                         packets_per_request=4, slo=30.0, seed=5)
    a = simulate(topo, make_policy("minimal"), tr, cycles=150, warmup=0,
                 drain=True)
    b = xengine.simulate_jax(topo, make_policy("minimal"), tr, cycles=150,
                             warmup=0, drain=True)
    assert a.request_count == b.request_count > 0
    assert a.request_latency_p50 == b.request_latency_p50
    assert a.request_latency_p95 == b.request_latency_p95
    assert a.request_latency_p99 == b.request_latency_p99
    assert a.slo_attainment == b.slo_attainment
    assert a.request_latency_p50 <= a.request_latency_p95 \
        <= a.request_latency_p99


def test_serving_engine_arrival_trace():
    """Submitted requests record their decode-step arrival and export a
    replayable trace-kind ArrivalSpec."""
    from repro.models import ModelConfig
    from repro.serving.engine import Request, ServingEngine
    cfg = ModelConfig(name="t", family="dense", num_layers=1, d_model=16,
                      num_heads=2, num_kv_heads=1, d_ff=32, vocab_size=32)
    eng = ServingEngine(cfg, None, slots=2, max_seq=16)
    eng.submit(Request(0, np.array([1, 2], np.int32)), at=3)
    eng.submit(Request(1, np.array([1], np.int32)))        # clock is 0
    trace = eng.arrival_trace()
    assert trace.kind == "trace" and trace.times == (0, 3)
    src, gen = trace.arrivals(n=4, horizon=8, seed=0)
    assert gen.size == 2 and src.size == 2


def test_request_latency_summary_incomplete_request():
    from repro.sim.metrics import request_latency_summary
    rs = request_latency_summary(request=[0, 0, 1, 1], gen=[2, 2, 5, 5],
                                 deliver=[4, 6, -1, 8])
    assert rs["count"] == 2 and rs["completed"] == 1
    np.testing.assert_array_equal(rs["arrival"], [2, 5])
    np.testing.assert_array_equal(rs["latency"], [5, -1])   # open req = -1


def test_request_events_spans():
    from repro.obs import request_events, validate_trace_events
    ev = request_events(request=[0, 0, 1], gen=[2, 2, 5],
                        deliver=[4, 6, -1], slo=4.0)
    validate_trace_events(ev)
    spans = [e for e in ev if e["ph"] == "X"]
    opens = [e for e in ev if e["ph"] == "I"]
    assert len(spans) == 1 and len(opens) == 1
    assert spans[0]["ts"] == 2 and spans[0]["dur"] == 5
    assert spans[0]["args"]["slo_met"] is False


# ---------------------------------------------------------------------------
# HLO parsing and lowering.
# ---------------------------------------------------------------------------

_SYNTH_HLO = textwrap.dedent("""\
    HloModule synth

    %cond.1 (arg.0: (s32[], f32[64])) -> pred[] {
      %p0 = (s32[], f32[64]) parameter(0)
      %i = s32[] get-tuple-element(%p0), index=0
      ROOT %lt = pred[] compare(%i, %i), direction=LT
    }

    %body.2 (arg.1: (s32[], f32[64])) -> (s32[], f32[64]) {
      %p1 = (s32[], f32[64]) parameter(0)
      %x = f32[64] get-tuple-element(%p1), index=1
      %cp = f32[64] collective-permute(%x), source_target_pairs={{0,1},{1,2},{2,3}}
      %j = s32[] get-tuple-element(%p1), index=0
      ROOT %tup = (s32[], f32[64]) tuple(%j, %cp)
    }

    ENTRY %main.3 (a: f32[64]) -> f32[64] {
      %z = s32[] constant(0)
      %t0 = (s32[], f32[64]) tuple(%z, %a)
      %w = (s32[], f32[64]) while(%t0), condition=%cond.1, body=%body.2, backend_config={"known_trip_count":{"n":"5"}}
      ROOT %out = f32[64] get-tuple-element(%w), index=1
    }
""")


def test_collective_sequence_sees_tuple_param_while_body():
    """Computation headers with tuple-typed params (every while body)
    must parse; their collectives carry the loop's trip multiplier."""
    from repro.launch.hlo_analysis import collective_sequence, parse_module
    comps, entry = parse_module(_SYNTH_HLO)
    assert entry == "main.3"
    assert "body.2" in comps and "cond.1" in comps
    ops = collective_sequence(_SYNTH_HLO, 4)
    assert len(ops) == 1
    op = ops[0]
    assert op.kind == "collective-permute"
    assert op.count == 5
    assert op.pairs == ((0, 1), (1, 2), (2, 3))
    assert op.raw_bytes == 64 * 4


def test_workload_from_hlo_permute_lowering():
    from repro.workload import workload_from_hlo
    from repro.sim.workloads import replay
    w = workload_from_hlo(_SYNTH_HLO, ("xor", 4), bytes_per_packet=128)
    # ceil(256 / 128) = 2 packets per pair, 5 loop trips.
    assert all(p.messages == 2 for p in w.phases)
    assert sum(len(p.src) for p in w.phases) == 3 * 5
    topo = make_fabric("xor", 4).sim_topology()
    stats = replay(topo, "minimal", w, backend="numpy")
    assert stats.completion_cycles >= stats.ideal_cycles
    assert stats.in_flight_at_end == 0


# ---------------------------------------------------------------------------
# Real extraction: compile an 8-device MoE step, lower, replay on both
# engines.  The compile needs XLA_FLAGS before jax imports -> subprocess.
# ---------------------------------------------------------------------------

_EXTRACT_CHILD = """
import json
from repro.workload import moe_step_hlo, workload_from_hlo
hlo = moe_step_hlo(8, d_model=32, d_ff=16, batch=4, seq=8)
w = workload_from_hlo(hlo, ("xor", 8), bytes_per_packet=256)
print("RESULT " + json.dumps(w.to_dict()))
"""


@pytest.fixture(scope="module")
def extracted_moe_workload():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    res = subprocess.run([sys.executable, "-c", _EXTRACT_CHILD], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=REPO)
    assert res.returncode == 0, res.stderr[-2000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_extracted_moe_workload_structure(extracted_moe_workload):
    from repro.sim.workloads import Workload
    w = Workload.from_dict(extracted_moe_workload)
    assert w.num_switches == 8
    assert len(w.phases) > 0
    assert all(p.messages >= 1 for p in w.phases)
    # JSON round-trip is exact (the store/CLI contract).
    assert Workload.from_dict(w.to_dict()).to_dict() == w.to_dict()


def test_extracted_moe_replay_cross_engine(extracted_moe_workload):
    from repro.sim.workloads import Workload, replay
    w = Workload.from_dict(extracted_moe_workload)
    topo = make_fabric("xor", 8).sim_topology()
    a = replay(topo, "minimal", w, backend="numpy")
    b = replay(topo, "minimal", w, backend="jax")
    assert a.completion_cycles >= a.ideal_cycles
    assert a.in_flight_at_end == 0
    assert a.completion_cycles == b.completion_cycles
    assert tuple(a.phase_cycles) == tuple(b.phase_cycles)


# ---------------------------------------------------------------------------
# Studies integration: serving specs, SLO capacity, flow cross-check,
# forward-compatible Result records.
# ---------------------------------------------------------------------------

def _serving_spec(slo=40.0, rate=0.05, cycles=150):
    from repro.studies import (ExperimentSpec, FabricSpec, RoutingSpec,
                               SweepSpec, TrafficSpec)
    return ExperimentSpec(
        fabric=FabricSpec(kind="cin", params={"instance": "xor", "n": 8}),
        traffic=TrafficSpec(pattern="serving",
                            params={"arrival": {"kind": "poisson",
                                                "rate": rate},
                                    "packets_per_request": 2, "slo": slo}),
        routing=RoutingSpec(policy="minimal"),
        sweep=SweepSpec(loads=(1.0,), seeds=(3,), cycles=cycles, warmup=0),
        terminals=1, engine={"drain": True})


def test_serving_spec_roundtrip_and_label():
    from repro.studies import ExperimentSpec
    spec = _serving_spec()
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    assert "serving-poisson" in spec.name


def test_serving_study_numpy_vs_flow():
    from repro.studies import Study
    spec = _serving_spec()
    cyc = Study(spec, backend="numpy").run()
    flow = Study(spec, backend="flow").run()
    rc = [r for r in cyc.results if r.request_count is not None]
    rf = [r for r in flow.results if r.request_count is not None]
    assert len(rc) == len(rf) == 1
    # Same seeded arrival stream on both tiers...
    assert rc[0].request_count == rf[0].request_count > 0
    # ...and the flow proxy is a lower bound on per-request latency.
    assert rf[0].request_latency_p99 <= rc[0].request_latency_p99
    assert rc[0].slo_attainment is not None
    assert rc[0].fidelity == "cycle" and rf[0].fidelity == "flow"


def test_slo_capacity_search():
    from repro.studies import Study
    cap = Study(_serving_spec(), backend="numpy").slo_capacity(
        percentile=99.0, lo=0.1, hi=1.0, tol=0.2)
    assert set(cap) >= {"experiment", "percentile", "slo", "probes",
                        "capacity"}
    assert cap["probes"]
    assert 0.0 <= cap["capacity"] <= 1.0


def test_result_record_preserves_unknown_fields():
    """A store written by a newer repo version round-trips through
    load -> append untouched (satellite: show must not drop fields)."""
    from repro.studies import Result
    from repro.studies import Study
    out = Study(_serving_spec(), backend="numpy").run()
    rec = out.results[0].record()
    assert "request_count" in rec and "slo_attainment" in rec
    rec2 = dict(rec, future_metric=1.5)
    r2 = Result.from_record(rec2)
    assert r2.extra == {"future_metric": 1.5}
    assert r2.record() == rec2
