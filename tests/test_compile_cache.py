"""Persistent compile cache (repro.obs.telemetry): robustness suite.

The disk layer's contract is *never crash, never trust*: any entry that
is truncated, bit-flipped, version-mismatched, or simply not a cache
entry at all is skipped (and evicted) with a silent fallback to
recompilation.  Writers are atomic (``os.replace``), so concurrent
processes racing on one key both leave valid blobs.  The in-process
layer is a bounded LRU.  ``LACIN_CACHE_DIR=""`` disables the disk layer
entirely.  Counters (:func:`cache_stats`) make all of it observable.
"""
import os
import pickle
import subprocess
import sys
import textwrap
import threading
from functools import partial

import numpy as np
import pytest

import repro
from repro.obs import telemetry
from repro.obs.telemetry import (CACHE_FORMAT, cache_dir, cache_stats,
                                 clear_caches, disk_cache_entries,
                                 reset_cache_stats, timed_compiled)


@pytest.fixture()
def cache(tmp_path, monkeypatch):
    """A fresh, isolated cache: empty tmp dir, empty memory LRU, zeroed
    counters."""
    monkeypatch.setenv("LACIN_CACHE_DIR", str(tmp_path))
    clear_caches(memory=True)
    reset_cache_stats()
    yield tmp_path
    clear_caches(memory=True)
    reset_cache_stats()


def _program(k=3):
    """A tiny jitted program; distinct static ``k`` = distinct program."""
    import jax
    import jax.numpy as jnp

    @partial(jax.jit, static_argnums=0)
    def poly(k, x):
        return x * k + jnp.cos(x)

    return poly, jnp.arange(8.0)


def test_miss_then_memory_then_disk(cache):
    poly, x = _program()
    out1, t1 = timed_compiled(poly, 3, x)
    assert t1["compile_cached"] is False and t1["compile_s"] > 0
    assert len(disk_cache_entries()) == 1
    out2, t2 = timed_compiled(poly, 3, x)
    assert t2["compile_cached"] == "memory" and t2["compile_s"] == 0.0
    clear_caches(memory=True)
    out3, t3 = timed_compiled(poly, 3, x)
    assert t3["compile_cached"] == "disk" and t3["compile_s"] > 0
    for out in (out2, out3):
        assert np.array_equal(np.asarray(out1), np.asarray(out))
    stats = cache_stats()
    assert stats["misses"] == 1 and stats["memory_hits"] == 1
    assert stats["disk_hits"] == 1 and stats["disk_writes"] == 1
    assert stats["disk_errors"] == 0


def test_entry_filename_is_versioned(cache):
    poly, x = _program()
    timed_compiled(poly, 3, x)
    (entry,) = disk_cache_entries()
    assert entry.name.endswith(f".v{CACHE_FORMAT}.exe")


@pytest.mark.parametrize("vandalize", [
    lambda p: p.write_bytes(p.read_bytes()[: p.stat().st_size // 2]),
    lambda p: p.write_bytes(b"\x00" * 64),
    lambda p: p.write_bytes(pickle.dumps(["not", "a", "dict"])),
    lambda p: p.write_bytes(pickle.dumps(
        {"format": CACHE_FORMAT + 1, "payload": b"stale"})),
], ids=["truncated", "garbage-bytes", "non-dict-pickle",
        "version-mismatch"])
def test_corrupt_entries_recompile_never_crash(cache, vandalize):
    poly, x = _program()
    out1, _ = timed_compiled(poly, 3, x)
    (entry,) = disk_cache_entries()
    vandalize(entry)
    clear_caches(memory=True)
    reset_cache_stats()
    out2, t2 = timed_compiled(poly, 3, x)
    assert t2["compile_cached"] is False          # skipped, recompiled
    assert np.array_equal(np.asarray(out1), np.asarray(out2))
    stats = cache_stats()
    assert stats["disk_errors"] >= 1 and stats["misses"] == 1
    # The bad blob was evicted and the fresh compile re-persisted over it.
    (entry,) = disk_cache_entries()
    assert pickle.loads(entry.read_bytes())["format"] == CACHE_FORMAT


def test_source_edit_invalidates_disk_entries(cache, monkeypatch):
    """The key covers a digest of the ``repro`` source tree: after a
    code change, the old executable must become unreachable (fresh
    compile under a new key), never a stale hit that silently computes
    the old program."""
    poly, x = _program()
    _, t1 = timed_compiled(poly, 3, x)
    assert t1["compile_cached"] is False
    clear_caches(memory=True)
    monkeypatch.setattr(telemetry, "_source_digest", lambda: "deadbeef")
    _, t2 = timed_compiled(poly, 3, x)
    assert t2["compile_cached"] is False
    # Both versions' entries coexist (distinct keys) until LRU pruning.
    assert len(disk_cache_entries()) == 2


def test_empty_cache_dir_disables_disk_layer(cache, monkeypatch):
    monkeypatch.setenv("LACIN_CACHE_DIR", "")
    assert cache_dir() is None
    poly, x = _program()
    _, t1 = timed_compiled(poly, 3, x)
    clear_caches(memory=True)
    _, t2 = timed_compiled(poly, 3, x)
    # No disk layer: both are fresh compiles and nothing was persisted.
    assert t1["compile_cached"] is False and t2["compile_cached"] is False
    assert disk_cache_entries() == []
    assert cache_stats()["disk_writes"] == 0


def test_cache_dir_env_resolution(monkeypatch, tmp_path):
    monkeypatch.setenv("LACIN_CACHE_DIR", str(tmp_path / "override"))
    assert cache_dir() == tmp_path / "override"
    monkeypatch.delenv("LACIN_CACHE_DIR")
    monkeypatch.setenv("XDG_CACHE_HOME", str(tmp_path / "xdg"))
    assert cache_dir() == tmp_path / "xdg" / "lacin-repro"


def test_memory_lru_is_bounded(cache, monkeypatch):
    monkeypatch.setattr(telemetry, "_CACHE_LIMIT", 3)
    poly, x = _program()
    for k in range(5):
        timed_compiled(poly, k, x)
    assert len(telemetry._CACHE) == 3
    assert cache_stats()["evictions"] == 2
    # Oldest program (k=0) was evicted from memory — but the disk layer
    # still has it, so re-acquisition is a disk hit, not a recompile.
    _, t = timed_compiled(poly, 0, x)
    assert t["compile_cached"] == "disk"
    # Most-recently-used (k=4) survived in memory.
    _, t = timed_compiled(poly, 4, x)
    assert t["compile_cached"] == "memory"


def test_disk_prune_bounds_entry_count(cache, monkeypatch):
    monkeypatch.setattr(telemetry, "_DISK_LIMIT", 3)
    poly, x = _program()
    for k in range(5):
        timed_compiled(poly, k, x)
        # mtime granularity: make the prune order deterministic.
        for i, p in enumerate(sorted(cache.glob("*.exe"))):
            os.utime(p, (k + i * 1e-3, k + i * 1e-3))
    assert len(disk_cache_entries()) <= 3


def test_concurrent_writers_and_readers_are_safe(cache):
    """Hammer one entry path from racing writer and reader threads:
    ``os.replace`` atomicity means a reader only ever observes a
    complete blob (or none), so every successful load must execute."""
    import jax

    poly, x = _program()
    timed_compiled(poly, 3, x)
    (path,) = disk_cache_entries()
    lowered = poly.lower(3, x)
    compiled = lowered.compile()
    expect = np.asarray(jax.block_until_ready(compiled(x)))
    failures = []

    def writer():
        for _ in range(20):
            telemetry._disk_store(path, compiled)

    def reader():
        for _ in range(20):
            loaded = telemetry._disk_load(path)
            if loaded is None:
                continue                      # racing unlink/replace: fine
            got = np.asarray(jax.block_until_ready(loaded(x)))
            if not np.array_equal(got, expect):
                failures.append(got)

    threads = [threading.Thread(target=writer) for _ in range(2)]
    threads += [threading.Thread(target=reader) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not failures
    assert telemetry._disk_load(path) is not None


def test_cli_cache_subcommand(cache, capsys):
    from repro.studies.__main__ import main
    poly, x = _program()
    timed_compiled(poly, 3, x)
    assert main(["cache"]) == 0
    out = capsys.readouterr().out
    assert str(cache) in out and "entries: 1" in out and "misses=1" in out
    assert main(["cache", "--clear"]) == 0
    assert "cleared 1 entries" in capsys.readouterr().out
    assert disk_cache_entries() == []


def test_second_process_restores_from_disk(cache):
    """The acceptance scenario end to end: a second interpreter, sharing
    only the cache directory, acquires the program from disk."""
    script = textwrap.dedent("""
        from functools import partial
        import jax
        import jax.numpy as jnp
        from repro.obs.telemetry import timed_compiled

        @partial(jax.jit, static_argnums=0)
        def poly(k, x):
            return x * k + jnp.cos(x)

        out, t = timed_compiled(poly, 11, jnp.arange(16.0))
        print("CACHED:", t["compile_cached"])
    """)
    src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    env = dict(os.environ, LACIN_CACHE_DIR=str(cache),
               PYTHONPATH=os.pathsep.join(
                   [src, os.environ.get("PYTHONPATH", "")]))
    runs = [subprocess.run([sys.executable, "-c", script], env=env,
                           capture_output=True, text=True, timeout=300)
            for _ in range(2)]
    for proc in runs:
        assert proc.returncode == 0, proc.stderr[-2000:]
    assert "CACHED: False" in runs[0].stdout
    assert "CACHED: disk" in runs[1].stdout
