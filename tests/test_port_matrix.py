"""Paper §2: port-pairing matrices (Figure 2).

The generic structural suite below parametrizes over the
``repro.fabric`` instance *registry*, so any instance registered through
the public API (e.g. ``mirror``) is automatically checked for
completeness, the isoport property, 1-factorization, and link inversion
— with zero edits here.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import fabric
from repro.core import (IDLE, circle_matrix, is_complete, is_isoport,
                        is_one_factorization, port_matrix, swap_matrix,
                        swap_neighbor, swap_peer_port, verify_instance,
                        xor_matrix)

# Candidate sizes; each instance keeps the ones its constraints support.
CANDIDATE_SIZES = (2, 3, 7, 8, 9, 16, 17, 33, 64)


def supported_sizes(name: str) -> list[int]:
    spec = fabric.get_instance(name)
    return [n for n in CANDIDATE_SIZES if spec.supports(n)]


@pytest.mark.parametrize("name", fabric.instance_names())
def test_registry_instance_structure(name):
    """Every registered instance: complete, K_N-covering, link-paired."""
    spec = fabric.get_instance(name)
    sizes = supported_sizes(name)
    assert sizes, f"{name} supports none of {CANDIDATE_SIZES}"
    for n in sizes:
        rep = verify_instance(name, n)
        assert rep["ok"], rep
        P = spec.matrix(n)
        assert is_complete(P)
        # The registry's isoport claim must match the matrix structure
        # (the trivial single-link N=2 CIN is isoport for any pairing).
        assert is_isoport(P) == (spec.isoport or n == 2)


@pytest.mark.parametrize("name", fabric.instance_names(isoport=True))
def test_registry_isoport_columns_are_one_factorization(name):
    for n in supported_sizes(name):
        assert is_one_factorization(fabric.get_instance(name).matrix(n))


@pytest.mark.parametrize("name", fabric.instance_names())
def test_registry_peer_port_is_link_inverse(name):
    """Following any link via (neighbor, peer_port) returns to the start."""
    spec = fabric.get_instance(name)
    for n in supported_sizes(name):
        P = spec.matrix(n)
        rev = spec.peer_matrix(n)
        for s in range(n):
            for i in range(P.shape[1]):
                t, j = int(P[s, i]), int(rev[s, i])
                if t == IDLE:
                    assert j == -1
                    continue
                assert int(P[t, j]) == s and int(rev[t, j]) == i


def test_registry_rejects_unknown_and_duplicate():
    with pytest.raises(ValueError, match="unknown CIN instance"):
        fabric.get_instance("moebius")
    with pytest.raises(ValueError, match="already registered"):
        fabric.register_instance("circle", neighbor=lambda s, i, n: s,
                                 route=lambda a, b, n: a)


def test_fig2_swap_n8():
    P = swap_matrix(8)
    # First row connects switch 0 to 1..7 in port order (first-available).
    assert P[0].tolist() == [1, 2, 3, 4, 5, 6, 7]
    assert P[7].tolist() == [0, 1, 2, 3, 4, 5, 6]
    assert is_complete(P) and not is_isoport(P)


def test_fig2_circle_n8():
    P = circle_matrix(8)
    # Last switch (N-1) sees switch i through port i (Algorithm 1).
    assert P[7].tolist() == [0, 1, 2, 3, 4, 5, 6]
    # 1-factor i=3 from the paper: highlighted parallel links + (3, 7).
    col3 = P[:, 3].tolist()
    assert col3[3] == 7 and col3[7] == 3
    assert is_complete(P) and is_isoport(P)


def test_fig2_xor_n8():
    P = xor_matrix(8)
    for s in range(8):
        for i in range(7):
            assert P[s, i] == s ^ (i + 1)
    assert is_complete(P) and is_isoport(P)


@pytest.mark.parametrize("inst,n", [
    ("swap", 2), ("swap", 17), ("swap", 64),
    ("circle", 2), ("circle", 7), ("circle", 9), ("circle", 64),
    ("xor", 2), ("xor", 32), ("xor", 128),
])
def test_verify_instances(inst, n):
    rep = verify_instance(inst, n)
    assert rep["ok"], rep
    # Swap is anisoport for N > 2 (the single-link N=2 CIN is trivially iso)
    assert rep["isoport"] == (inst != "swap" or n == 2)


def test_xor_rejects_non_power_of_two():
    with pytest.raises(ValueError):
        xor_matrix(12)


def test_odd_circle_has_one_idle_port_per_switch():
    P = circle_matrix(9)
    assert (P == IDLE).sum(axis=1).tolist() == [1] * 9
    # the idle port of switch i is port i (deleted link to virtual N)
    for s in range(9):
        assert P[s, s] == IDLE


def test_swap_peer_port_antisymmetry():
    """Swap pairing is an involution: following the link back returns."""
    n = 16
    P = swap_matrix(n)
    for s in range(n):
        for i in range(n - 1):
            t, j = int(P[s, i]), int(swap_peer_port(s, i))
            assert int(P[t, j]) == s and int(swap_peer_port(t, j)) == i


@settings(max_examples=30, deadline=None)
@given(n=st.integers(2, 96))
def test_circle_any_size_property(n):
    rep = verify_instance("circle", n)
    assert rep["ok"] and rep["isoport"]
