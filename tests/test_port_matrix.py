"""Paper §2: port-pairing matrices (Figure 2)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (IDLE, circle_matrix, is_complete, is_isoport,
                        port_matrix, swap_matrix, swap_neighbor,
                        swap_peer_port, verify_instance, xor_matrix)


def test_fig2_swap_n8():
    P = swap_matrix(8)
    # First row connects switch 0 to 1..7 in port order (first-available).
    assert P[0].tolist() == [1, 2, 3, 4, 5, 6, 7]
    assert P[7].tolist() == [0, 1, 2, 3, 4, 5, 6]
    assert is_complete(P) and not is_isoport(P)


def test_fig2_circle_n8():
    P = circle_matrix(8)
    # Last switch (N-1) sees switch i through port i (Algorithm 1).
    assert P[7].tolist() == [0, 1, 2, 3, 4, 5, 6]
    # 1-factor i=3 from the paper: highlighted parallel links + (3, 7).
    col3 = P[:, 3].tolist()
    assert col3[3] == 7 and col3[7] == 3
    assert is_complete(P) and is_isoport(P)


def test_fig2_xor_n8():
    P = xor_matrix(8)
    for s in range(8):
        for i in range(7):
            assert P[s, i] == s ^ (i + 1)
    assert is_complete(P) and is_isoport(P)


@pytest.mark.parametrize("inst,n", [
    ("swap", 2), ("swap", 17), ("swap", 64),
    ("circle", 2), ("circle", 7), ("circle", 9), ("circle", 64),
    ("xor", 2), ("xor", 32), ("xor", 128),
])
def test_verify_instances(inst, n):
    rep = verify_instance(inst, n)
    assert rep["ok"], rep
    # Swap is anisoport for N > 2 (the single-link N=2 CIN is trivially iso)
    assert rep["isoport"] == (inst != "swap" or n == 2)


def test_xor_rejects_non_power_of_two():
    with pytest.raises(ValueError):
        xor_matrix(12)


def test_odd_circle_has_one_idle_port_per_switch():
    P = circle_matrix(9)
    assert (P == IDLE).sum(axis=1).tolist() == [1] * 9
    # the idle port of switch i is port i (deleted link to virtual N)
    for s in range(9):
        assert P[s, s] == IDLE


def test_swap_peer_port_antisymmetry():
    """Swap pairing is an involution: following the link back returns."""
    n = 16
    P = swap_matrix(n)
    for s in range(n):
        for i in range(n - 1):
            t, j = int(P[s, i]), int(swap_peer_port(s, i))
            assert int(P[t, j]) == s and int(swap_peer_port(t, j)) == i


@settings(max_examples=30, deadline=None)
@given(n=st.integers(2, 96))
def test_circle_any_size_property(n):
    rep = verify_instance("circle", n)
    assert rep["ok"] and rep["isoport"]
