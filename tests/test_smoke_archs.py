"""Per-architecture smoke tests: a REDUCED config of the same family runs
one forward/train step on CPU, asserting output shapes and finiteness, and
decode steps run against prefilled caches."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (NO_SHARD, cross_entropy, decode_step, forward_train,
                          get_config, init_caches, init_params, list_archs,
                          prefill)

ARCHS = ["xlstm-350m", "hymba-1.5b", "nemotron-4-15b", "starcoder2-3b",
         "llama3.2-3b", "gemma3-1b", "internvl2-26b", "qwen3-moe-30b-a3b",
         "granite-moe-3b-a800m", "whisper-base"]

B, T = 2, 32


def make_batch(cfg, batch=B, seq=T, key=0):
    rng = np.random.default_rng(key)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32)
    out = {"tokens": tok, "labels": tok}
    if cfg.num_patch_tokens:
        out["patch_embeds"] = jnp.asarray(
            rng.normal(size=(batch, cfg.num_patch_tokens, cfg.d_model)),
            jnp.float32) * 0.02
    if cfg.is_encdec:
        out["frames"] = jnp.asarray(
            rng.normal(size=(batch, cfg.encoder_seq_len, cfg.d_model)),
            jnp.float32) * 0.02
    return out


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch).reduced()
            params = init_params(jax.random.PRNGKey(0), cfg)
            cache[arch] = (cfg, params)
        return cache[arch]
    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_registry_has_assigned_numbers(arch):
    cfg = get_config(arch)
    assert cfg.num_layers > 0 and cfg.vocab_size > 0
    # spot checks on the exact assigned shapes
    expect = {
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expect, f"{arch}: {got} != {expect}"


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch, arch_state):
    cfg, params = arch_state(arch)
    batch = make_batch(cfg)
    loss, metrics = jax.jit(
        lambda p, b: forward_train(p, b, cfg, NO_SHARD))(params, batch)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss {loss}"
    assert float(loss) > 0
    # one SGD step must also be finite (exercises the backward pass)
    grads = jax.grad(lambda p: forward_train(p, batch, cfg, NO_SHARD)[0])(params)
    gnorm = jax.tree_util.tree_reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))), grads, 0.0)
    assert jnp.isfinite(gnorm), f"{arch}: non-finite grads"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_smoke(arch, arch_state):
    cfg, params = arch_state(arch)
    seq_len = T + 8
    batch = make_batch(cfg)
    logits, caches = jax.jit(
        lambda p, b: prefill(p, b, cfg, NO_SHARD, seq_len))(params, batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    prefix = (cfg.num_meta_tokens
              + (cfg.num_patch_tokens if "patch_embeds" in batch else 0))
    cross_src = None
    step_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    pos = jnp.asarray(T + prefix, jnp.int32)
    logits2, caches2 = jax.jit(
        lambda p, t, c, q: decode_step(p, t, c, q, cfg, NO_SHARD, seq_len))(
        params, step_tok, caches, pos)
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2).all())


def test_reduced_keeps_family_variety():
    cfg = get_config("xlstm-350m").reduced()
    assert set(cfg.block_pattern) == {"mlstm", "slstm"}
    cfg = get_config("gemma3-1b").reduced()
    assert 0 in cfg.windows and any(w > 0 for w in cfg.windows)
