"""repro.studies: spec serialization, the Study runner, store resume,
backend selection, the legacy shims, and the CLI.

Spec round-trips must be *exact* (``from_json(to_json(s)) == s``) for
every registered CIN instance and for HyperX/Dragonfly parameter sets —
a spec file is the durable name of an experiment, so any drift silently
reruns (or worse, mislabels) grid points.
"""
import json
import os
import warnings

import numpy as np
import pytest

import repro.fabric.mirror  # noqa: F401  (registers the mirror instance)
from repro import sim, studies
from repro.fabric import LacinDeprecationWarning, instance_names, make_fabric
from repro.fabric.registry import get_instance
from repro.studies import (ExperimentSpec, FabricSpec, JsonlStore, Result,
                           RoutingSpec, Study, SweepSpec, TrafficSpec)

CYCLES = 160
WARMUP = 40


def _cin_spec(n=8, instance="xor", *, loads=(0.2, 0.6), seeds=(0,),
              policy="minimal", pattern="uniform", terminals=2,
              cycles=CYCLES, warmup=WARMUP, **traffic_params):
    return ExperimentSpec(
        fabric=FabricSpec("cin", {"instance": instance, "n": n}),
        traffic=TrafficSpec(pattern, traffic_params),
        routing=RoutingSpec(policy),
        sweep=SweepSpec(loads=loads, seeds=seeds, cycles=cycles,
                        warmup=warmup),
        terminals=terminals)


# ---------------------------------------------------------------------------
# Serialization: exact JSON round-trip.
# ---------------------------------------------------------------------------

def _supported_n(name: str) -> int:
    spec = get_instance(name)
    for n in (8, 9, 12, 16):
        if spec.supports(n):
            return n
    raise AssertionError(f"no test size for instance {name}")


@pytest.mark.parametrize("instance", instance_names())
def test_round_trip_exact_every_registry_instance(instance):
    n = _supported_n(instance)
    spec = _cin_spec(n=n, instance=instance, loads=(0.1, 0.35, 0.9),
                     seeds=(0, 3), policy="adaptive")
    rt = ExperimentSpec.from_json(spec.to_json())
    assert rt == spec
    assert rt.to_json() == spec.to_json()
    assert rt.fabric.resolve().num_switches == n


@pytest.mark.parametrize("fabric", [
    FabricSpec("hyperx", {"dims": (4, 4), "terminals": 4,
                          "instance": "xor"}),
    FabricSpec("hyperx", {"dims": [8, 4, 4], "terminals": 2,
                          "instance": "circle"}),
    FabricSpec("dragonfly", {"group_size": 4, "terminals_per_switch": 2,
                             "global_ports_per_switch": 2, "num_groups": 8}),
    FabricSpec("dragonfly", {"group_size": 6, "terminals_per_switch": 3,
                             "global_ports_per_switch": 2, "num_groups": 12,
                             "local_instance": "circle",
                             "global_instance": "mirror"}),
])
def test_round_trip_exact_hyperx_dragonfly(fabric):
    spec = ExperimentSpec(
        fabric=fabric, traffic=TrafficSpec("uniform", {"seed": 5}),
        routing=RoutingSpec("valiant"),
        sweep=SweepSpec(loads=(0.25,), seeds=(1, 2), cycles=80, warmup=20),
        terminals=2)
    rt = ExperimentSpec.from_json(spec.to_json())
    assert rt == spec
    # list/tuple params normalize to one canonical form
    assert rt.fabric.params == spec.fabric.params
    assert rt.fabric.resolve().num_switches == spec.fabric.resolve(
        ).num_switches


def test_round_trip_traffic_params_and_engine_kwargs():
    spec = _cin_spec(pattern="hotspot", hot_fraction=0.75, seed=7,
                     policy="adaptive")
    spec = ExperimentSpec(
        fabric=spec.fabric, traffic=spec.traffic,
        routing=RoutingSpec("adaptive", {"threshold": 2.0, "weight": 1.5}),
        sweep=spec.sweep, terminals=spec.terminals,
        engine={"queue_capacity": 8, "num_vcs": 2})
    rt = ExperimentSpec.from_json(spec.to_json())
    assert rt == spec
    assert rt.engine == {"queue_capacity": 8, "num_vcs": 2}
    pol = rt.routing.make()
    assert (pol.threshold, pol.weight) == (2.0, 1.5)


def test_spec_file_round_trip(tmp_path):
    specs = [_cin_spec(policy="minimal"), _cin_spec(policy="valiant")]
    path = tmp_path / "study.json"
    studies.dump_specs(specs, str(path), study="t", description="d")
    loaded = studies.load_specs(str(path))
    assert loaded == specs


def test_bundled_specs_load_and_round_trip():
    bundles = studies.bundled_specs()
    assert {"cin16_saturation", "hyperx256_uniform", "dragonfly72_uniform",
            "dragonfly_adversarial", "studies_smoke"} <= set(bundles)
    for name, path in bundles.items():
        for exp in studies.load_specs(path):
            assert ExperimentSpec.from_json(exp.to_json()) == exp, name


def test_resolved_declarative_specs_still_serialize(tmp_path):
    """Resolving (or running) a declarative spec must not flip it inline:
    run-then-save and share-then-save both work."""
    spec = _cin_spec()
    spec.fabric.resolve()
    spec.fabric.resolve_topology()
    assert not spec.is_inline
    assert ExperimentSpec.from_json(spec.to_json()) == spec

    from_fab = FabricSpec.from_fabric(make_fabric("xor", 8))
    assert not from_fab.is_inline
    assert FabricSpec.from_json(from_fab.to_json()) == from_fab

    specs = [_cin_spec(policy="minimal"), _cin_spec(policy="valiant")]
    Study(specs, backend="numpy").run()       # shares + resolves fabrics
    studies.dump_specs(specs, str(tmp_path / "after_run.json"))
    assert studies.load_specs(str(tmp_path / "after_run.json")) == specs


def test_inline_specs_refuse_to_serialize():
    spec = ExperimentSpec(
        fabric=FabricSpec("cin", {"instance": "xor", "n": 8}),
        traffic=TrafficSpec.custom(lambda load: sim.uniform(
            8, offered=load, cycles=50, terminals=1)),
        routing=RoutingSpec("minimal"),
        sweep=SweepSpec(loads=(0.2,), cycles=50))
    assert spec.is_inline
    with pytest.raises(ValueError, match="inline"):
        spec.to_dict()


# ---------------------------------------------------------------------------
# The Study runner.
# ---------------------------------------------------------------------------

def test_study_runs_grid_both_backends_agree():
    spec = _cin_spec(loads=(0.2, 0.6), seeds=(0, 1))
    out_np = Study(spec, backend="numpy").run()
    out_jx = Study(spec, backend="jax").run()
    assert out_np.executed == out_jx.executed == 4
    for a, b in zip(out_np.results, out_jx.results):
        assert a.key == b.key
        assert a.accepted == pytest.approx(b.accepted, rel=0.15, abs=0.02)
    # grid order: loads major, seeds minor
    assert [(r.load, r.seed) for r in out_np.results] == [
        (0.2, 0), (0.2, 1), (0.6, 0), (0.6, 1)]


def test_study_auto_backend_prefers_jax():
    out = Study(_cin_spec(loads=(0.3,))).run()
    assert out.backend == "jax"       # jax is a hard dependency in-repo
    assert out.results[0].backend == "jax"


def test_study_shares_fabric_resolution_across_experiments(monkeypatch):
    specs = [_cin_spec(policy="minimal"), _cin_spec(policy="valiant")]
    built = []
    orig = studies.FabricSpec.resolve_topology

    def counting(self):
        built.append(self)
        return orig(self)

    monkeypatch.setattr(studies.FabricSpec, "resolve_topology", counting)
    Study(specs, backend="numpy").run()
    assert len(built) == 1    # the second experiment reused the study cache


def test_study_rejects_duplicate_experiment_names():
    with pytest.raises(ValueError, match="unique"):
        Study([_cin_spec(), _cin_spec()])


def test_declarative_traffic_uses_grid_seed_unless_fixed():
    spec = _cin_spec(loads=(0.4,), seeds=(3, 4))
    topo = spec.fabric.resolve_topology()
    tf = spec.traffic.factory(topo, cycles=CYCLES, terminals=2)
    a, b = tf(0.4, 3), tf(0.4, 4)
    assert not np.array_equal(a.gen, b.gen) or not np.array_equal(a.dst,
                                                                  b.dst)
    fixed = _cin_spec(loads=(0.4,), seeds=(3, 4), seed=17)
    tf = fixed.traffic.factory(topo, cycles=CYCLES, terminals=2)
    a, b = tf(0.4, 3), tf(0.4, 4)
    assert np.array_equal(a.src, b.src) and np.array_equal(a.dst, b.dst)


# ---------------------------------------------------------------------------
# Store + resume.
# ---------------------------------------------------------------------------

def test_empty_sweep_grid_rejected():
    with pytest.raises(ValueError, match="at least one load"):
        SweepSpec(loads=(), cycles=50)
    with pytest.raises(ValueError, match="at least one load"):
        SweepSpec(loads=(0.5,), seeds=(), cycles=50)


def test_store_persists_and_resume_skips_everything(tmp_path):
    store = str(tmp_path / "r.jsonl")
    spec = _cin_spec(loads=(0.2, 0.6), seeds=(0, 1))
    first = Study(spec, store=store, backend="numpy").run()
    assert first.executed == 4 and first.restored == 0
    again = Study(spec, store=store, backend="numpy").run()
    assert again.executed == 0 and again.restored == 4
    # resume=False starts the store clean: no duplicate keys, no growth
    fresh = Study(spec, store=store, backend="numpy").run(resume=False)
    assert fresh.executed == 4 and fresh.restored == 0
    with open(store) as f:
        assert len(f.read().splitlines()) == 4
    # restored results carry the stored summary, not in-memory stats
    assert all(r.stats is None for r in again.results)
    for a, b in zip(first.results, again.results):
        assert a.key == b.key and a.accepted == b.accepted


def test_resume_half_written_store_runs_only_missing(tmp_path):
    """The satellite acceptance: a re-run over a half-written JSONL store
    executes only the missing grid points (for both backends)."""
    store = str(tmp_path / "r.jsonl")
    spec = _cin_spec(loads=(0.2, 0.4, 0.6), seeds=(0, 1))
    full = Study(spec, store=store, backend="numpy").run()
    assert full.executed == 6

    # keep the first 2 complete lines + one torn line (a killed writer)
    with open(store) as f:
        lines = f.read().splitlines()
    with open(store, "w") as f:
        f.write("\n".join(lines[:2]) + "\n" + lines[2][: len(lines[2]) // 2])

    for backend in ("numpy", "jax"):
        out = Study(spec, store=store, backend=backend).run()
        assert out.restored == 2
        assert out.executed == 4
        assert [r.key for r in out.results] == [r.key for r in full.results]
        accepted = {r.key: r.accepted for r in out.results}
        # numpy resume is bit-identical to the full run (same engine seeds)
        if backend == "numpy":
            assert accepted == {r.key: r.accepted for r in full.results}
        # next resume over the repaired store skips everything
        assert Study(spec, store=store, backend=backend).run().executed == 0
        os.unlink(store)
        JsonlStore(store).append(full.results[:2])


def test_resume_rejects_stale_results_from_an_edited_spec(tmp_path):
    """The store key names the grid point, not the spec's parameters —
    so resuming after editing cycles/warmup/params must refuse to restore
    the stale records instead of silently mislabeling them."""
    store = str(tmp_path / "r.jsonl")
    spec = _cin_spec(loads=(0.2, 0.6), cycles=CYCLES)
    Study(spec, store=store, backend="numpy").run()
    edited = spec.with_sweep(cycles=2 * CYCLES)
    with pytest.raises(ValueError, match="different version"):
        Study(edited, store=store, backend="numpy").run()
    # --no-resume is the documented way out
    out = Study(edited, store=store, backend="numpy").run(resume=False)
    assert out.executed == 2
    assert all(r.cycles == 2 * CYCLES for r in out.results)
    # and the unedited spec still resumes cleanly from its own records
    Study(edited, store=store, backend="numpy").run()


def test_growing_the_grid_resumes_cleanly(tmp_path):
    """loads/seeds are key-encoded, not digest-encoded: extending the
    sweep grid resumes the stored points and runs only the new ones."""
    store = str(tmp_path / "r.jsonl")
    base = _cin_spec(loads=(0.2,), seeds=(0,))
    Study(base, store=store, backend="numpy").run()
    grown = base.with_sweep(loads=(0.2, 0.6), seeds=(0, 1))
    out = Study(grown, store=store, backend="numpy").run()
    assert out.restored == 1 and out.executed == 3


def test_store_corrupt_middle_line_raises(tmp_path):
    store = str(tmp_path / "r.jsonl")
    spec = _cin_spec(loads=(0.2, 0.6))
    Study(spec, store=store, backend="numpy").run()
    with open(store) as f:
        lines = f.read().splitlines()
    with open(store, "w") as f:
        f.write(lines[0] + "\n{broken\n" + lines[1] + "\n")
    with pytest.raises(ValueError, match="corrupt"):
        JsonlStore(store).load()
    # a newline-terminated corrupt FINAL record is an error too (only a
    # true torn tail — no trailing newline — is tolerated)
    with open(store, "w") as f:
        f.write(lines[0] + "\n{broken\n")
    with pytest.raises(ValueError, match="corrupt"):
        JsonlStore(store).load()
    with open(store, "w") as f:
        f.write(lines[0] + "\n{broken")
    assert len(JsonlStore(store).load()) == 1


def test_append_preserves_parseable_unterminated_tail(tmp_path):
    """A record whose JSON was flushed but whose newline was not (killed
    at exactly the wrong moment) is restored by load() — so append() must
    terminate it, never truncate it away."""
    store = str(tmp_path / "r.jsonl")
    spec = _cin_spec(loads=(0.2, 0.4, 0.6))
    full = Study(spec, store=store, backend="numpy").run()
    with open(store) as f:
        text = f.read()
    with open(store, "w") as f:
        f.write(text.rstrip("\n"))           # strip the final newline only
    out = Study(spec, store=store, backend="numpy").run()
    assert out.restored == 3 and out.executed == 0
    # the append-free resume left all three records intact; a later append
    # keeps them too
    JsonlStore(store).append(
        Result.from_record({**full.results[0].record(), "key": "extra"}))
    kept = JsonlStore(store).load()
    assert set(kept) == {r.key for r in full.results} | {"extra"}


def test_result_record_round_trip():
    out = Study(_cin_spec(loads=(0.3,)), backend="numpy").run()
    r = out.results[0]
    rt = Result.from_record(json.loads(r.to_line()))
    assert rt.key == r.key and rt.accepted == r.accepted
    assert rt.stats is None


# ---------------------------------------------------------------------------
# Legacy shims: equal results, deprecation-warned.
# ---------------------------------------------------------------------------

def test_saturation_sweep_shim_equals_direct_study():
    """Acceptance: the shim routes through Study and returns results equal
    to a directly-constructed Study run — on both backends."""
    topo = sim.cin_topology("xor", 8)

    def tf(load):
        return sim.uniform(8, offered=load, cycles=CYCLES, terminals=4,
                           seed=9)

    direct = ExperimentSpec(
        fabric=FabricSpec.from_topology(topo),
        traffic=TrafficSpec.custom(tf),
        routing=RoutingSpec("minimal"),
        sweep=SweepSpec(loads=(0.2, 0.6), seeds=(0,), cycles=CYCLES,
                        warmup=WARMUP))
    for backend in ("numpy", "jax"):
        want = Study(direct, backend=backend).run()
        with pytest.warns(LacinDeprecationWarning):
            got = sim.saturation_sweep(topo, sim.MinimalPolicy, tf,
                                       [0.2, 0.6], cycles=CYCLES,
                                       warmup=WARMUP, backend=backend)
        for w, g in zip(want.results, got):
            assert g.accepted == w.stats.accepted
            assert g.latency_p99 == w.stats.latency_p99
            assert np.array_equal(g.link_loads, w.stats.link_loads)


def test_fabric_sim_sweep_shim_equals_direct_study():
    fab = make_fabric("xor", 8)

    def tf(load, seed):
        return sim.uniform(8, offered=load, cycles=CYCLES, terminals=4,
                           seed=seed)

    direct = ExperimentSpec(
        fabric=FabricSpec.from_fabric(fab),
        traffic=TrafficSpec.custom(tf),
        routing=RoutingSpec("minimal"),
        sweep=SweepSpec(loads=(0.3, 0.7), seeds=(1, 2), cycles=CYCLES,
                        warmup=WARMUP))
    want = Study(direct, backend="jax").run().grid()
    with pytest.warns(LacinDeprecationWarning):
        got = fab.sim_sweep("minimal", tf, [0.3, 0.7], seeds=(1, 2),
                            cycles=CYCLES, warmup=WARMUP, backend="jax")
    assert len(got) == 2 and len(got[0]) == 2
    for wrow, grow in zip(want, got):
        for w, g in zip(wrow, grow):
            assert g.accepted == w.stats.accepted
            assert np.array_equal(g.link_loads, w.stats.link_loads)


def test_compare_policies_shim_one_study():
    topo = sim.cin_topology("xor", 8)

    def tf(load):
        return sim.uniform(8, offered=load, cycles=CYCLES, terminals=4,
                           seed=2)

    with pytest.warns(LacinDeprecationWarning):
        got = sim.compare_policies(topo, ["minimal", "valiant"], tf,
                                   [0.2, 0.6], cycles=CYCLES, warmup=WARMUP,
                                   backend="jax")
    assert set(got) == {"minimal", "valiant"}
    assert all(len(v) == 2 for v in got.values())
    assert got["minimal"][0].policy == "minimal"
    assert got["valiant"][1].policy == "valiant"


# ---------------------------------------------------------------------------
# The CLI.
# ---------------------------------------------------------------------------

def test_cli_run_show_specs(tmp_path, capsys, monkeypatch):
    from repro.studies.__main__ import main
    monkeypatch.chdir(tmp_path)
    assert main(["specs"]) == 0
    assert "studies_smoke" in capsys.readouterr().out

    assert main(["show", "studies_smoke"]) == 0
    out = capsys.readouterr().out
    assert "4 grid points" in out

    store = str(tmp_path / "smoke.jsonl")
    assert main(["run", "studies_smoke", "--backend", "numpy",
                 "--store", store, "--table"]) == 0
    out = capsys.readouterr().out
    assert "ran 4 grid points" in out
    assert "saturation points:" in out
    # the store parses back into Result records
    stored = JsonlStore(store).load()
    assert len(stored) == 4
    assert all(isinstance(r, Result) for r in stored.values())
    # second run resumes
    assert main(["run", "studies_smoke", "--backend", "numpy",
                 "--store", store]) == 0
    assert "ran 0 grid points (4 restored" in capsys.readouterr().out


def test_cli_rejects_unknown_spec():
    from repro.studies.__main__ import main
    with pytest.raises(SystemExit):
        main(["run", "no_such_spec"])


# ---------------------------------------------------------------------------
# Satellite regressions living at the studies surface.
# ---------------------------------------------------------------------------

def test_terminals_footgun_mismatch_raises():
    topo = sim.cin_topology("xor", 8)
    tr = sim.uniform(8, offered=0.3, cycles=80, terminals=4, seed=0)
    with pytest.raises(ValueError, match="terminals=2 disagrees"):
        sim.simulate(topo, sim.MinimalPolicy(), tr, terminals=2)
    with pytest.raises(ValueError, match="disagrees"):
        sim.simulate(topo, sim.MinimalPolicy(), tr, terminals=2,
                     backend="jax")


def test_terminals_derived_from_traffic():
    topo = sim.cin_topology("xor", 8)
    tr = sim.uniform(8, offered=0.3, cycles=80, terminals=4, seed=0)
    s = sim.simulate(topo, sim.MinimalPolicy(), tr, cycles=80)
    assert s.terminals == 4
    s = sim.simulate(topo, sim.MinimalPolicy(), tr, cycles=80,
                     backend="jax")
    assert s.terminals == 4
    # one-shot traffic records nothing; explicit values pass through
    one = sim.one_shot_all_to_all(8)
    assert sim.simulate(topo, sim.MinimalPolicy(), one).terminals == 1
    assert sim.simulate(topo, sim.MinimalPolicy(), one,
                        terminals=3).terminals == 3


# ---------------------------------------------------------------------------
# flush_interval: amortized fsync + mid-write crash repair.
# ---------------------------------------------------------------------------

def test_flush_interval_validates_and_defaults():
    with pytest.raises(ValueError, match="flush_interval"):
        JsonlStore("x.jsonl", flush_interval=0)
    assert JsonlStore("x.jsonl").flush_interval == 1


def test_flush_interval_batches_fsyncs_but_loses_nothing(tmp_path,
                                                         monkeypatch):
    """With flush_interval=k the store fsyncs ~1/k as often, but every
    record is still written+flushed per append — a clean process exit
    (or Study.run's trailing sync()) loses nothing."""
    syncs = []
    real_fsync = os.fsync
    monkeypatch.setattr(os, "fsync", lambda fd: (syncs.append(fd),
                                                 real_fsync(fd)))
    store_path = str(tmp_path / "r.jsonl")
    spec = _cin_spec(loads=(0.2, 0.4, 0.6), seeds=(0, 1))
    batched = JsonlStore(store_path, flush_interval=4)
    out = Study(spec, store=batched, backend="numpy").run()
    assert out.executed == 6
    # 6 appended records at interval 4: one fsync mid-run, one from the
    # study-end sync() that settles the remaining 2
    assert len(syncs) == 2
    assert len(JsonlStore(store_path).load()) == 6
    assert batched._unsynced == 0


def test_flush_interval_mid_write_crash_repairs_and_resumes(tmp_path):
    """The satellite crash test: a writer killed mid-record between
    fsyncs leaves complete lines plus a torn tail; load() skips the
    fragment, append() repairs it in place, and a resumed study re-runs
    exactly the lost grid points."""
    store_path = str(tmp_path / "r.jsonl")
    spec = _cin_spec(loads=(0.2, 0.4, 0.6), seeds=(0, 1))
    full = Study(spec, store=JsonlStore(store_path, flush_interval=3),
                 backend="numpy").run()
    assert full.executed == 6
    with open(store_path) as f:
        lines = f.read().splitlines()

    # crash variant A: torn JSON fragment (killed mid-buffer-write)
    with open(store_path, "w") as f:
        f.write("\n".join(lines[:3]) + "\n" + lines[3][: 20])
    out = Study(spec, store=JsonlStore(store_path, flush_interval=3),
                backend="numpy").run()
    assert out.restored == 3 and out.executed == 3
    # numpy re-execution is bit-identical to the uninterrupted run
    assert {r.key: r.accepted for r in out.results} == \
        {r.key: r.accepted for r in full.results}
    repaired = JsonlStore(store_path).load()
    assert set(repaired) == {r.key for r in full.results}

    # crash variant B: complete final record, missing only its newline
    with open(store_path, "w") as f:
        f.write("\n".join(lines[:4]))         # no trailing newline
    out = Study(spec, store=JsonlStore(store_path, flush_interval=3),
                backend="numpy").run()
    assert out.restored == 4 and out.executed == 2
    assert len(JsonlStore(store_path).load()) == 6
