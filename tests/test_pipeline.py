"""Pipeline parallelism: the GPipe schedule over a mesh axis must produce
the SAME loss and gradients as the sequential forward (subprocess, 4 pipe
stages on host devices)."""
import json
import os
import subprocess
import sys

import pytest

_CHILD = r"""
import json
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.models import NO_SHARD, forward_train, get_config, init_params
from repro.runtime.pipeline import make_pipeline_loss_fn

cfg = get_config("lacin-demo").reduced()   # 4 uniform ATTN layers
params = init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)
batch = {"tokens": tok, "labels": tok}

devs = jax.devices()
mesh = Mesh(np.array(devs[:4]), ("pipe",))
pipe_loss = make_pipeline_loss_fn(cfg, mesh, n_micro=2)

l_seq, _ = forward_train(params, batch, cfg, NO_SHARD)
l_pipe = pipe_loss(params, batch)
res = {"loss_seq": float(l_seq), "loss_pipe": float(l_pipe)}

g_seq = jax.grad(lambda p: forward_train(p, batch, cfg, NO_SHARD)[0])(params)
g_pipe = jax.grad(lambda p: pipe_loss(p, batch))(params)
rels = []
for a, b in zip(jax.tree_util.tree_leaves(g_seq),
                jax.tree_util.tree_leaves(g_pipe)):
    denom = float(jnp.max(jnp.abs(a))) + 1e-9
    rels.append(float(jnp.max(jnp.abs(a - b))) / denom)
res["grad_max_rel"] = max(rels)
print("RESULT " + json.dumps(res))
"""


@pytest.fixture(scope="module")
def pipe_results():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_pipeline_loss_matches_sequential(pipe_results):
    assert abs(pipe_results["loss_pipe"] - pipe_results["loss_seq"]) \
        / pipe_results["loss_seq"] < 5e-3


def test_pipeline_gradients_match_sequential(pipe_results):
    """Autodiff through ppermute gives the reverse pipeline exactly."""
    assert pipe_results["grad_max_rel"] < 5e-2, pipe_results
