"""Collective replay (repro.sim.workloads): the schedule -> simulator seam.

The paper's contention-freedom claim, *measured*: replaying a fabric's
own LACIN schedule through the packet engines must complete in exactly
the schedule algebra's lower bound (``num_steps x message_size``) when
every phase is a matching on its links — and never beat it anywhere.
Plus: numpy/xengine agreement on replays, Workload round-trips through
ExperimentSpec JSON, studies/CLI integration, and the one-shot traffic
``terminals`` recording fix.
"""
import json

import numpy as np
import pytest

import repro.fabric.mirror  # noqa: F401  (registers the mirror instance)
from repro import sim, studies
from repro.core.dragonfly import DragonflyConfig
from repro.core.hyperx import HyperXConfig
from repro.core.schedule import make_schedule
from repro.fabric import instance_names, make_fabric
from repro.fabric.registry import get_instance
from repro.sim import workloads
from repro.sim.workloads import Phase, Workload, collective_workload, replay


def _supported_n(name: str) -> int:
    spec = get_instance(name)
    for n in (8, 9, 12, 16):
        if spec.supports(n):
            return n
    raise AssertionError(f"no test size for instance {name}")


# ---------------------------------------------------------------------------
# Workload construction.
# ---------------------------------------------------------------------------

def test_workload_from_schedule_structure():
    sched = make_schedule("xor", 8)
    w = Workload.from_schedule(sched, message_size=3)
    assert w.num_phases == sched.num_steps == 7
    assert w.ideal_cycles == 7 * 3
    assert w.num_packets == 7 * 8 * 3
    for k, ph in enumerate(w.phases):
        assert ph.messages == 3
        # each phase is exactly the schedule step's matching
        partners = dict(zip(ph.src, ph.dst))
        row = sched.partners(k)
        assert partners == {s: int(row[s]) for s in range(8) if row[s] != s}


def test_workload_odd_circle_drops_idles():
    w = Workload.from_schedule(make_schedule("circle", 9))
    assert w.num_phases == 9
    # odd-N Circle idles one device per step
    assert all(len(ph.src) == 8 for ph in w.phases)


def test_workload_validation():
    with pytest.raises(ValueError, match="distinct"):
        Phase((0, 1), (0, 2))
    with pytest.raises(ValueError, match="messages"):
        Phase((0,), (1,), messages=0)
    with pytest.raises(ValueError, match="outside"):
        Workload("bad", 4, (Phase((0,), (7,)),))
    with pytest.raises(ValueError, match="spans"):
        replay(sim.cin_topology("xor", 8), "minimal",
               Workload("w", 4, (Phase((0,), (1,)),)))


def test_workload_traffic_encodes_phases():
    w = collective_workload(make_fabric("xor", 8), "all_to_all",
                            message_size=2)
    tr = w.traffic()
    assert tr.workload is w
    assert tr.offered == 0.0
    assert tr.num_packets == w.num_packets
    # gen is the phase ordinal, counting each phase's packets
    assert np.array_equal(np.bincount(tr.gen),
                          [ph.num_packets for ph in w.phases])


def test_all_reduce_two_level_shape():
    fab = make_fabric(DragonflyConfig(group_size=4, terminals_per_switch=2,
                                      global_ports_per_switch=2,
                                      num_groups=6))
    w = collective_workload(fab, "all_reduce", message_size=4)
    sched = fab.schedule()
    nl, ng = sched["local"].num_steps, sched["global"].num_steps
    assert w.num_phases == 2 * nl + 2 * ng
    # global phases carry the 1/a-scaled shard payload (ceil(4/4) = 1)
    assert [ph.messages for ph in w.phases] == \
        [4] * nl + [1] * (2 * ng) + [4] * nl


# ---------------------------------------------------------------------------
# Contention-free equality: the paper's claim under queueing.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("instance", instance_names())
def test_cin_replay_meets_bound_exactly(instance):
    """Unit-message a2a replay on a bare CIN under minimal routing
    completes in exactly num_steps cycles — every phase in exactly 1."""
    n = _supported_n(instance)
    fab = make_fabric(instance, n)
    stats = fab.replay("all_to_all")
    assert stats.packets_delivered == stats.packets_generated
    assert stats.completion_cycles == stats.ideal_cycles
    assert set(stats.phase_cycles) == {1}


@pytest.mark.parametrize("message_size", [1, 3])
def test_cin_replay_scales_with_message_size(message_size):
    stats = make_fabric("xor", 16).replay("all_to_all",
                                          message_size=message_size)
    assert stats.completion_cycles == stats.ideal_cycles \
        == 15 * message_size
    assert set(stats.phase_cycles) == {message_size}


def test_hyperx_grid_replay_meets_bound_exactly():
    """Dimension-order grid schedule: each phase rides one dimension's
    1-factors, so the composed a2a is contention-free end to end."""
    fab = make_fabric(HyperXConfig(dims=(4, 8), terminals=2))
    stats = fab.replay("all_to_all", message_size=2)
    assert stats.completion_cycles == stats.ideal_cycles == (3 + 7) * 2
    assert set(stats.phase_cycles) == {2}


def test_dragonfly_replay_exceeds_bound_on_global_steps():
    """Global grid steps funnel group_size flows over one global link:
    measured completion must exceed the naive bound by the
    serialization, while local phases stay contention-free."""
    fab = make_fabric(DragonflyConfig(group_size=4, terminals_per_switch=2,
                                      global_ports_per_switch=2,
                                      num_groups=6))
    stats = fab.replay("all_to_all")
    sched = fab.schedule()
    nl = sched["local"].num_steps
    assert stats.completion_cycles > stats.ideal_cycles
    # local phases (first nl) are matchings on local links: 1 cycle each
    assert set(stats.phase_cycles[:nl]) == {1}
    # global phases serialize a flows per link (plus l-g-l pipelining)
    assert all(c >= fab.config.group_size for c in stats.phase_cycles[nl:])


def test_nonminimal_replay_cannot_beat_bound():
    for policy in ("valiant", "adaptive"):
        stats = make_fabric("xor", 16).replay("all_to_all", policy=policy)
        assert stats.packets_delivered == stats.packets_generated
        assert stats.completion_cycles >= stats.ideal_cycles


# ---------------------------------------------------------------------------
# numpy vs compiled engine on replays.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fab,msg", [
    (make_fabric("xor", 16), 2),
    (make_fabric("circle", 9), 1),
    (make_fabric(HyperXConfig(dims=(4, 4), terminals=2)), 2),
    (make_fabric(DragonflyConfig(group_size=4, terminals_per_switch=2,
                                 global_ports_per_switch=2, num_groups=6)),
     1),
])
def test_engines_agree_on_replay(fab, msg):
    """Minimal-routing replays are work-conserving with unique routes:
    both engines must report identical per-phase completion and
    link-for-link loads."""
    topo = fab.sim_topology()
    w = collective_workload(fab, "all_to_all", message_size=msg)
    s_np = replay(topo, "minimal", w, backend="numpy")
    s_jx = replay(topo, "minimal", w, backend="jax")
    assert s_np.packets_delivered == s_jx.packets_delivered == w.num_packets
    assert s_np.completion_cycles == s_jx.completion_cycles
    assert s_np.phase_cycles == s_jx.phase_cycles
    assert np.array_equal(s_np.link_loads, s_jx.link_loads)


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_replay_stats_measure_the_replay_timeline(backend):
    """Summary stats are framed by the replay itself, not by the phase
    count: cycles = completion, accepted normalizes by it, and latency
    measures from each phase's release cycle (so a drained 1-hop phase
    shows pipeline latency, not the phase ordinal)."""
    stats = make_fabric("xor", 16).replay("all_to_all", message_size=2,
                                          backend=backend)
    assert stats.cycles == stats.completion_cycles == 30
    # 1 terminal/switch injecting every cycle of the run
    assert stats.accepted == pytest.approx(1.0)
    # per phase: first packet lat 2 (inject+eject pipeline), second 3
    assert stats.latency_max <= 3
    assert stats.latency_mean == pytest.approx(2.5)
    # per-link utilization is per-completion, not per-phase-count
    assert stats.link_util_max == pytest.approx(2 / 30)


def test_compiled_replay_drains_nonminimal():
    w = collective_workload(make_fabric("xor", 16), "all_to_all")
    s = replay(sim.cin_topology("xor", 16), "valiant", w, backend="jax")
    assert s.packets_delivered == s.packets_generated
    assert s.completion_cycles >= s.ideal_cycles


def test_batched_sweep_rejects_mixed_replay_and_open_loop():
    topo = sim.cin_topology("xor", 8)
    w = collective_workload(make_fabric("xor", 8), "all_to_all")
    trs = [w.traffic(), sim.uniform(8, offered=0.2, cycles=50)]
    with pytest.raises(ValueError, match="mix"):
        sim.xengine.sweep(topo, "minimal", lambda i: trs[int(i)], [0, 1])


# ---------------------------------------------------------------------------
# Studies integration: serialization, resume, CLI.
# ---------------------------------------------------------------------------

def _replay_spec(traffic_params, name=""):
    return studies.ExperimentSpec(
        fabric=studies.FabricSpec("cin", {"instance": "xor", "n": 8}),
        traffic=studies.TrafficSpec("workload", traffic_params),
        routing=studies.RoutingSpec("minimal"),
        sweep=studies.SweepSpec(loads=(0.0,), seeds=(0,)),
        name=name)


def test_workload_round_trips_through_experiment_spec():
    """An explicit Workload embedded in a spec survives JSON exactly and
    resolves back to an equal Workload."""
    w = collective_workload(make_fabric("xor", 8), "all_to_all",
                            message_size=2)
    spec = _replay_spec({"workload": w.to_dict()})
    rt = studies.ExperimentSpec.from_json(spec.to_json())
    assert rt == spec
    topo = rt.fabric.resolve_topology()
    resolved = rt.traffic._resolve_workload(topo)
    assert resolved == w
    # and the JSON payload itself is the canonical to_dict form
    raw = json.loads(spec.to_json())
    assert Workload.from_dict(raw["traffic"]["params"]["workload"]) == w


def test_explicit_workload_spec_rejects_fabric_size_mismatch():
    w = collective_workload(make_fabric("xor", 32), "all_to_all")
    spec = _replay_spec({"workload": w.to_dict()})   # fabric is n=8
    with pytest.raises(ValueError, match="spans 32 switches"):
        studies.Study(spec, backend="numpy").run()


def test_named_collective_spec_round_trips_and_runs_both_backends(tmp_path):
    spec = _replay_spec({"collective": "all_to_all", "message_size": 2})
    assert studies.ExperimentSpec.from_json(spec.to_json()) == spec
    assert spec.name == "cin-xor-8/replay-all_to_all/minimal"
    for backend in ("numpy", "jax"):
        out = studies.Study(spec, backend=backend).run()
        [r] = out.results
        assert r.completion_cycles == r.ideal_cycles == 7 * 2
        assert r.phase_cycles == [2] * 7
        assert out.replay_points()[spec.name] == {
            "measured": 14, "ideal": 14, "ratio": 1.0}


def test_replay_study_persists_and_resumes(tmp_path):
    store = tmp_path / "replay.jsonl"
    spec = _replay_spec({"collective": "all_to_all"})
    out1 = studies.Study(spec, store=str(store), backend="numpy").run()
    assert (out1.executed, out1.restored) == (1, 0)
    out2 = studies.Study(spec, store=str(store), backend="numpy").run()
    assert (out2.executed, out2.restored) == (0, 1)
    # restored records keep the replay summary fields
    [r] = out2.results
    assert r.completion_cycles == r.ideal_cycles == 7
    assert r.phase_cycles == [1] * 7


def test_bundled_collective_replay_spec_loads_and_round_trips():
    path = studies.bundled_spec_path("collective_replay")
    specs = studies.load_specs(path)
    assert {e.fabric.kind for e in specs} == {"cin", "hyperx", "dragonfly"}
    assert {e.routing.policy for e in specs} == {"minimal", "adaptive"}
    for e in specs:
        assert studies.ExperimentSpec.from_json(e.to_json()) == e
        assert not e.is_inline


def test_replay_cli_end_to_end(tmp_path, capsys):
    from repro.studies.__main__ import main as cli
    spec = _replay_spec({"collective": "all_to_all"})
    spec_path = tmp_path / "replay_spec.json"
    studies.dump_specs([spec], str(spec_path))
    store = tmp_path / "cli.jsonl"
    assert cli(["run", str(spec_path), "--backend", "numpy",
                "--store", str(store)]) == 0
    out = capsys.readouterr().out
    assert "collective replay" in out
    assert "measured=7 ideal=7 ratio=1.0" in out
    stored = studies.JsonlStore(str(store)).load()
    [rec] = stored.values()
    assert rec.completion_cycles == 7 and rec.phase_cycles == [1] * 7


# ---------------------------------------------------------------------------
# Satellite: one-shot generators record terminals like open-loop ones.
# ---------------------------------------------------------------------------

def test_one_shot_records_terminals():
    tr = sim.one_shot_all_to_all(8, terminals=4)
    assert tr.terminals == 4
    eng = sim.Engine(sim.cin_topology("xor", 8), sim.MinimalPolicy(), tr)
    assert eng.terminals == 4                     # engine defaults to it
    with pytest.raises(ValueError, match="terminals=2 disagrees"):
        sim.Engine(sim.cin_topology("xor", 8), sim.MinimalPolicy(), tr,
                   terminals=2)
    # default stays None: legacy explicit-terminals callers still work
    legacy = sim.one_shot_all_to_all(8)
    assert legacy.terminals is None
    eng = sim.Engine(sim.cin_topology("xor", 8), sim.MinimalPolicy(),
                     legacy, terminals=3)
    assert eng.terminals == 3


def test_one_shot_permutation_records_terminals():
    tr = sim.one_shot_permutation(np.array([1, 0, 3, 2]), terminals=2)
    assert tr.terminals == 2
    with pytest.raises(ValueError, match="disagrees"):
        sim.xengine.simulate_jax(sim.cin_topology("xor", 4),
                                 sim.MinimalPolicy(), tr, terminals=4)
