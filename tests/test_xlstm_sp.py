"""Context-parallel mLSTM == sequential oracle (8-device subprocess)."""
import json
import os
import subprocess
import sys

import pytest

_CHILD = r"""
import json
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro._compat.jaxapi import shard_map
from repro.models.xlstm import mlstm_sequential
from repro.models.xlstm_sp import mlstm_context_parallel

devs = jax.devices(); S = len(devs)
mesh = Mesh(np.array(devs), ("seq",))
b, t, h, d = 2, 8 * 64, 2, 32        # 64 tokens per device
ks = jax.random.split(jax.random.PRNGKey(0), 5)
q = jax.random.normal(ks[0], (b, t, h, d))
k = jax.random.normal(ks[1], (b, t, h, d))
v = jax.random.normal(ks[2], (b, t, h, d))
li = jax.random.normal(ks[3], (b, t, h)) * 2
lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, t, h)) * 2 + 1)

ref, _ = mlstm_sequential(q, k, v, li, lf)

def body(qs, ks_, vs, lis, lfs):
    return mlstm_context_parallel(qs, ks_, vs, lis, lfs,
                                  axis_name="seq", axis_size=S, chunk=32)

sp = shard_map(body, mesh=mesh,
               in_specs=(P(None, "seq"), P(None, "seq"), P(None, "seq"),
                         P(None, "seq"), P(None, "seq")),
               out_specs=P(None, "seq"), check_vma=False)
out = sp(q, k, v, li, lf)
err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref)))
rel = err / float(jnp.max(jnp.abs(ref)))
# gradient flows through the distributed scan
g = jax.grad(lambda q_: (sp(q_, k, v, li, lf) ** 2).sum())(q)
print("RESULT " + json.dumps({
    "rel": rel, "grad_finite": bool(jnp.isfinite(g).all())}))
"""


@pytest.fixture(scope="module")
def sp_results():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_context_parallel_mlstm_matches_sequential(sp_results):
    assert sp_results["rel"] < 1e-4, sp_results


def test_context_parallel_gradients_finite(sp_results):
    assert sp_results["grad_finite"]
