"""Paper §3 + Algorithm 2: table-free minimal routing.

The generic suite parametrizes over the ``repro.fabric`` registry:
route/neighbor inversion, trace-safe-routing agreement, and isoport
route symmetry hold automatically for any registered instance.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro import fabric
from repro.core import (ROUTING_COST, port_matrix, route, route_circle,
                        route_circle_closed, route_jnp, route_packet,
                        routing_ops)

CANDIDATE_SIZES = (2, 3, 4, 8, 16, 17, 33, 64)


def supported_sizes(name: str) -> list[int]:
    spec = fabric.get_instance(name)
    return [n for n in CANDIDATE_SIZES if spec.supports(n)]


@pytest.mark.parametrize("name", fabric.instance_names())
def test_registry_route_inverts_neighbor_exhaustive(name):
    """route(a, b) is the port whose neighbor is b — for every pair."""
    for n in supported_sizes(name):
        P = port_matrix(name, n)
        for a in range(n):
            for b in range(n):
                if a == b:
                    continue
                i = int(route(name, a, b, n))
                assert 0 <= i < P.shape[1]
                assert P[a, i] == b, (name, n, a, b)


@pytest.mark.parametrize("name", fabric.instance_names())
def test_registry_route_jnp_matches_numpy(name):
    spec = fabric.get_instance(name)
    if spec.route_jnp is None:
        pytest.skip(f"{name} registered no trace-safe routing")
    for n in supported_sizes(name)[-2:]:
        a = jnp.arange(n)[:, None] * jnp.ones((1, n), jnp.int32)
        b = jnp.arange(n)[None, :] * jnp.ones((n, 1), jnp.int32)
        got = np.asarray(jax.jit(
            lambda a_, b_: route_jnp(name, a_, b_, n))(a, b))
        want = np.asarray(route(name, np.asarray(a), np.asarray(b), n))
        mask = ~np.eye(n, dtype=bool)
        assert np.array_equal(got[mask], want[mask])


@pytest.mark.parametrize("name", fabric.instance_names(isoport=True))
def test_registry_isoport_route_symmetric(name):
    """Isoport: both link ends use the same port index (§2 discipline)."""
    for n in supported_sizes(name):
        a = np.arange(n)[:, None]
        b = np.arange(n)[None, :]
        mask = ~np.eye(n, dtype=bool)
        ab = np.asarray(route(name, a, b, n))
        ba = np.asarray(route(name, b, a, n))
        assert np.array_equal(ab[mask], ba[mask])


@pytest.mark.parametrize("n", [4, 8, 16, 20, 64, 7, 9, 33])
def test_circle_closed_form_equals_algorithm2(n):
    a = np.arange(n)[:, None]
    b = np.arange(n)[None, :]
    mask = ~np.eye(n, dtype=bool)
    alg = np.asarray(route_circle(a, b, n))[mask]
    closed = np.asarray(route_circle_closed(a, b, n))[mask]
    assert np.array_equal(alg, closed)


@pytest.mark.parametrize("inst,n", [("swap", 16), ("circle", 16),
                                    ("circle", 9), ("xor", 16)])
def test_jnp_routing_matches_numpy(inst, n):
    a = jnp.arange(n)[:, None] * jnp.ones((1, n), jnp.int32)
    b = jnp.arange(n)[None, :] * jnp.ones((n, 1), jnp.int32)
    got = np.asarray(jax.jit(lambda a_, b_: route_jnp(inst, a_, b_, n))(a, b))
    want = np.asarray(route(inst, np.asarray(a), np.asarray(b), n))
    mask = ~np.eye(n, dtype=bool)
    assert np.array_equal(got[mask], want[mask])


def test_xor_routing_is_involution_free_symmetric():
    """Isoport: the same port index is used at both ends (i = A^B-1)."""
    n = 32
    for a in range(n):
        for b in range(n):
            if a != b:
                assert route("xor", a, b, n) == route("xor", b, a, n)


def test_circle_routing_symmetric():
    n = 16
    for a in range(n):
        for b in range(n):
            if a != b:
                assert route("circle", a, b, n) == route("circle", b, a, n)


def test_packet_routing_two_digit_addresses():
    hops = route_packet("xor", 8, (1, 3), (6, 2))
    assert hops == [(1, (1 ^ 6) - 1), (6, 2)]   # network hop + eject B0
    hops = route_packet("xor", 8, (5, 0), (5, 7))
    assert hops == [(5, 7)]                     # same switch: eject only


def test_table1_routing_costs():
    assert ROUTING_COST == {"xor": 0, "swap": 1, "circle": 5}
    assert routing_ops("circle")["total_extra_vs_xor"] == 5


@settings(max_examples=50, deadline=None)
@given(n=st.integers(2, 64), data=st.data())
def test_route_property_all_instances(n, data):
    a = data.draw(st.integers(0, n - 1))
    b = data.draw(st.integers(0, n - 1))
    if a == b:
        return
    for inst in ("swap", "circle"):
        P = port_matrix(inst, n)
        assert P[a, int(route(inst, a, b, n))] == b
    if n & (n - 1) == 0:
        P = port_matrix("xor", n)
        assert P[a, int(route("xor", a, b, n))] == b
