"""Packet-level simulator: cross-validation against closed forms + the
saturation behaviour the paper's §3 routing discussion predicts."""
import numpy as np
import pytest

from repro import sim
from repro.core import port_matrix, schedule_step_report
from repro.core.dragonfly import DragonflyConfig
from repro.core.hyperx import HyperXConfig
from repro.core.simulate import cin_link_loads


# ---------------------------------------------------------------------------
# Topology adapters.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("inst,n", [("swap", 8), ("circle", 8), ("circle", 9),
                                    ("xor", 16)])
def test_cin_topology_structure(inst, n):
    topo = sim.cin_topology(inst, n)
    topo.validate()
    assert topo.num_links == n * (n - 1) // 2


def test_hyperx_topology_matches_config():
    cfg = HyperXConfig(dims=(4, 4), terminals=4)
    topo = sim.hyperx_topology(cfg)
    topo.validate()
    assert topo.num_switches == cfg.num_switches
    assert topo.num_links == cfg.num_links


@pytest.mark.parametrize("g", [6, 8, 9])
def test_dragonfly_topology_structure(g):
    """Includes the config-allowed maximum num_groups == a*h + 1 (g=9,
    odd-circle global), where the per-group colour sets must be compacted
    around each group's idle column."""
    cfg = DragonflyConfig(group_size=4, terminals_per_switch=2,
                          global_ports_per_switch=2, num_groups=g)
    topo = sim.dragonfly_topology(cfg)
    topo.validate()
    assert topo.num_switches == cfg.switches
    assert topo.num_links == cfg.total_links
    eng = sim.Engine(topo, sim.MinimalPolicy(),
                     sim.one_shot_all_to_all(cfg.switches), terminals=4)
    stats = eng.run()
    assert stats.packets_delivered == cfg.switches * (cfg.switches - 1)


# ---------------------------------------------------------------------------
# Cross-validation against core.simulate closed forms.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("inst,n", [("swap", 16), ("circle", 16),
                                    ("circle", 9), ("xor", 16)])
def test_one_shot_all_to_all_reproduces_cin_link_loads(inst, n):
    """Uniform (all-to-all) traffic on a CIN must traverse exactly the
    flows `cin_link_loads` counts: one per directed link (the 2/N-
    normalized perfect balance of §1)."""
    topo = sim.cin_topology(inst, n)
    eng = sim.Engine(topo, sim.MinimalPolicy(), sim.one_shot_all_to_all(n),
                     terminals=4)
    stats = eng.run()
    assert stats.packets_delivered == n * (n - 1)
    assert eng.load.by_switch_pair() == cin_link_loads(inst, n)


@pytest.mark.parametrize("inst", ["circle", "xor"])
@pytest.mark.parametrize("n", [9, 16])
def test_one_factor_steps_are_contention_free(inst, n):
    """Each step of a 1-factor schedule, replayed as packets, uses every
    link at most once — matching `schedule_step_report`'s closed form."""
    if inst == "xor" and n == 9:
        pytest.skip("xor needs power-of-two N")
    P = port_matrix(inst, n)
    reports = schedule_step_report(inst, n)
    for i in range(P.shape[1]):
        topo = sim.cin_topology(inst, n)
        eng = sim.Engine(topo, sim.MinimalPolicy(),
                         sim.one_shot_permutation(P[:, i]))
        stats = eng.run()
        assert stats.packets_delivered == stats.packets_generated
        assert int(eng.load.total.max()) == reports[i].max_link_load <= 1


def test_one_factor_step_completes_in_two_cycles():
    """A matching step is fully contention-free: all packets cross in one
    cycle and eject the next — no queueing anywhere."""
    P = port_matrix("xor", 16)
    topo = sim.cin_topology("xor", 16)
    eng = sim.Engine(topo, sim.MinimalPolicy(),
                     sim.one_shot_permutation(P[:, 3]))
    stats = eng.run()
    assert eng.cycle == 2
    assert stats.latency_max == 2


# ---------------------------------------------------------------------------
# Queueing behaviour: credits, VCs, backpressure.
# ---------------------------------------------------------------------------

def test_credit_backpressure_bounds_queue_occupancy():
    topo = sim.cin_topology("xor", 8)
    tr = sim.uniform(8, offered=0.9, cycles=300, terminals=8, seed=0)
    eng = sim.Engine(topo, sim.MinimalPolicy(), tr, terminals=8,
                     queue_capacity=2, seed=0)
    eng.run(cycles=300)
    assert int(eng.fabric.occ.max()) <= 2


def test_valiant_uses_two_vcs_on_cin():
    """The §3 claim: non-minimal routing on a CIN needs exactly 2 VCs."""
    topo = sim.cin_topology("xor", 8)
    tr = sim.uniform(8, offered=0.3, cycles=200, terminals=2, seed=0)
    eng = sim.Engine(topo, sim.ValiantPolicy(), tr, terminals=2, seed=0)
    assert eng.num_vcs == 2
    eng.run(cycles=200)
    assert eng.load.total.sum() > 0
    mins = sim.Engine(topo, sim.MinimalPolicy(), tr, terminals=2, seed=0)
    assert mins.num_vcs == 1


def test_minimal_delivers_everything_under_low_load():
    topo = sim.cin_topology("circle", 12)
    tr = sim.uniform(12, offered=0.2, cycles=400, terminals=4, seed=1)
    stats = sim.simulate(topo, sim.MinimalPolicy(), tr, terminals=4,
                         cycles=400, warmup=100, drain=True, seed=1)
    assert stats.packets_delivered == stats.packets_generated
    assert stats.accepted == pytest.approx(0.2, rel=0.15)


# ---------------------------------------------------------------------------
# The acceptance sweep: minimal vs Valiant knees (paper §3 trade-off).
# ---------------------------------------------------------------------------

N16 = 16
T = 12          # injectors per switch: oversubscribed, so links can saturate
CYCLES = 1200
WARMUP = 300


def _sweep(policy_name, traffic_factory, loads, seed):
    """One offered-load sweep through the repro.studies surface (the
    replacement for the deprecated report.saturation_sweep), on the
    numpy oracle engine."""
    from repro import studies
    spec = studies.ExperimentSpec(
        fabric=studies.FabricSpec("cin", {"instance": "xor", "n": N16}),
        traffic=studies.TrafficSpec.custom(traffic_factory),
        routing=studies.RoutingSpec(policy_name),
        sweep=studies.SweepSpec(loads=tuple(loads), seeds=(seed,),
                                cycles=CYCLES, warmup=WARMUP),
        terminals=T)
    out = studies.Study(spec, backend="numpy").run()
    return [row[0].stats for row in out.grid()]


def test_uniform_sweep_minimal_saturates_later_than_valiant():
    """Under uniform traffic minimal routing rides the dedicated links
    (saturating late); Valiant doubles every packet's hop count and
    saturates at roughly half the load."""
    loads = [0.3, 0.5, 0.7, 0.9]

    def tf(load):
        return sim.uniform(N16, offered=load, cycles=CYCLES, terminals=T,
                           seed=11)

    s_min = _sweep("minimal", tf, loads, seed=11)
    s_val = _sweep("valiant", tf, loads, seed=11)
    knee_min = sim.saturation_point(s_min) or float("inf")
    knee_val = sim.saturation_point(s_val) or float("inf")
    assert knee_val < knee_min, (knee_val, knee_min)
    # at the highest load the gap is substantial
    assert s_min[-1].accepted > 1.3 * s_val[-1].accepted


def test_hotspot_sweep_valiant_saturates_later_than_minimal():
    """Under a hot-pair pattern the minimal route concentrates almost all
    demand on one dedicated link per source; Valiant spreads it over the
    N-2 two-hop paths and survives to much higher offered load."""
    loads = [0.05, 0.2, 0.4, 0.6]

    def tf(load):
        return sim.hotspot(N16, offered=load, cycles=CYCLES, terminals=T,
                           hot_fraction=0.9, seed=12)

    s_min = _sweep("minimal", tf, loads, seed=12)
    s_val = _sweep("valiant", tf, loads, seed=12)
    knee_min = sim.saturation_point(s_min) or float("inf")
    knee_val = sim.saturation_point(s_val) or float("inf")
    assert knee_min < knee_val, (knee_min, knee_val)
    assert s_val[-1].accepted > 1.8 * s_min[-1].accepted


def test_adaptive_tracks_best_policy_both_regimes():
    """The congestion-threshold policy stays minimal under uniform load and
    detours under the hot-pair pattern — within 15% of the better pure
    policy in both regimes."""
    def uni(load):
        return sim.uniform(N16, offered=load, cycles=CYCLES, terminals=T,
                           seed=13)

    def hot(load):
        return sim.hotspot(N16, offered=load, cycles=CYCLES, terminals=T,
                           hot_fraction=0.9, seed=13)

    a_uni = _sweep("adaptive", uni, [0.7], seed=13)[0]
    m_uni = _sweep("minimal", uni, [0.7], seed=13)[0]
    assert a_uni.accepted > 0.85 * m_uni.accepted
    a_hot = _sweep("adaptive", hot, [0.4], seed=13)[0]
    v_hot = _sweep("valiant", hot, [0.4], seed=13)[0]
    assert a_hot.accepted > 0.85 * v_hot.accepted


# ---------------------------------------------------------------------------
# Compositions.
# ---------------------------------------------------------------------------

def test_hyperx_uniform_tracks_offered_load():
    cfg = HyperXConfig(dims=(4, 4), terminals=4)
    topo = sim.hyperx_topology(cfg)
    tr = sim.uniform(16, offered=0.4, cycles=600, terminals=4, seed=5)
    stats = sim.simulate(topo, sim.MinimalPolicy(), tr, terminals=4,
                         cycles=600, warmup=150, seed=5)
    assert stats.accepted == pytest.approx(0.4, rel=0.1)
    assert stats.latency_p50 <= 8


def test_dragonfly_adversarial_valiant_beats_minimal():
    """The classic Dragonfly adversary: all of group g targets group g+1,
    funnelling through one global link.  Valiant detours through random
    intermediates and sustains ~the offered load."""
    cfg = DragonflyConfig(group_size=4, terminals_per_switch=2,
                          global_ports_per_switch=2, num_groups=8)
    topo = sim.dragonfly_topology(cfg)

    def run(policy):
        tr = sim.adversarial_same_group(cfg, offered=0.3, cycles=1000,
                                        terminals=2, seed=6)
        return sim.simulate(topo, sim.make_policy(policy), tr, terminals=2,
                            cycles=1000, warmup=250, seed=6)

    s_min, s_val = run("minimal"), run("valiant")
    assert s_val.accepted > 1.5 * s_min.accepted


def test_dragonfly_one_shot_all_pairs_delivery():
    cfg = DragonflyConfig(group_size=4, terminals_per_switch=2,
                          global_ports_per_switch=2, num_groups=6)
    topo = sim.dragonfly_topology(cfg)
    n = cfg.switches
    eng = sim.Engine(topo, sim.MinimalPolicy(), sim.one_shot_all_to_all(n),
                     terminals=4)
    stats = eng.run()
    assert stats.packets_delivered == n * (n - 1)
    assert stats.latency_max <= 3 + eng.cycle  # every path <= l-g-l


# ---------------------------------------------------------------------------
# Reporting plumbing.
# ---------------------------------------------------------------------------

def test_report_records_and_table(tmp_path):
    topo = sim.cin_topology("xor", 8)
    tr = sim.uniform(8, offered=0.3, cycles=300, terminals=4, seed=7)
    stats = sim.simulate(topo, sim.MinimalPolicy(), tr, terminals=4,
                         cycles=300, warmup=75, seed=7)
    rec = sim.to_record(stats)
    assert rec["policy"] == "minimal" and 0 < rec["accepted"] <= 1.5
    out = tmp_path / "sweep.json"
    sim.save_json([stats], str(out))
    assert out.exists() and "accepted" in out.read_text()
    table = sim.format_table([stats])
    assert "minimal" in table and "offered" in table


def test_engine_is_deterministic_for_fixed_seed():
    topo = sim.cin_topology("circle", 10)
    tr = sim.uniform(10, offered=0.5, cycles=300, terminals=4, seed=8)
    a = sim.simulate(topo, sim.ValiantPolicy(), tr, terminals=4, cycles=300,
                     warmup=75, seed=8)
    b = sim.simulate(topo, sim.ValiantPolicy(), tr, terminals=4, cycles=300,
                     warmup=75, seed=8)
    assert a.accepted == b.accepted
    assert a.latency_mean == b.latency_mean
    assert np.array_equal(a.link_loads, b.link_loads)
