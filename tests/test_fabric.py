"""The ``repro.fabric`` unified topology API.

Covers the instance registry (including the ``mirror`` instance that is
registered *only* through ``register_instance`` — the proof that no
dispatch edits are needed), the uniform ``Fabric`` surface over
CIN / HyperX / Dragonfly, the closed-form Dragonfly link loads against
the packet simulator's routed ground truth, and the deprecation shims.
"""
import numpy as np
import pytest

from repro import fabric
from repro.core import (DragonflyConfig, HyperXConfig, dragonfly_link_loads,
                        port_matrix)
from repro.sim.topology import dragonfly_topology, routed_link_loads


# ---------------------------------------------------------------------------
# Registry + mirror.
# ---------------------------------------------------------------------------

def test_builtins_and_mirror_registered():
    names = fabric.instance_names()
    assert set(names) >= {"swap", "circle", "xor", "mirror"}
    assert set(fabric.instance_names(isoport=True)) >= {"circle", "xor",
                                                        "mirror"}
    assert "swap" not in fabric.instance_names(isoport=True)


def test_mirror_is_a_distinct_matrix_with_the_same_factors():
    """mirror = Circle with reversed port colours: same 1-factor *set*,
    different P matrix (different colour per wire)."""
    for n in (8, 9, 16):
        Pm = port_matrix("mirror", n)
        Pc = port_matrix("circle", n)
        assert not np.array_equal(Pm, Pc)
        cols = Pm.shape[1]
        for i in range(cols):
            assert np.array_equal(Pm[:, i], Pc[:, (-i) % cols])


def test_registered_instance_reaches_every_layer():
    """mirror flows through matrix, routing, schedule, sim and Fabric —
    none of which mention it."""
    from repro.core import make_schedule, route, verify_instance
    from repro.sim.topology import cin_topology
    assert verify_instance("mirror", 12)["ok"]
    assert int(route("mirror", 3, 7, 12)) >= 0
    s = make_schedule("mirror", 12)
    assert s.is_matching_per_step() and s.covers_all_pairs()
    cin_topology("mirror", 12).validate()
    assert fabric.make_fabric("mirror", 12).verify()["ok"]


def test_register_and_unregister_custom_instance():
    """A throwaway instance registered at test time is fully usable."""
    # 'cyclic-pairing' on even n: partner = (i+1-s) mod n is an involution
    # iff ... use a relabelled xor to keep it simple and valid.
    fabric.register_instance(
        "xor_relabel",
        neighbor=lambda s, i, n: (s ^ (i + 1)),
        route=lambda a, b, n: (a ^ b) - 1,
        constraints=lambda n: fabric.get_instance("xor").check(n))
    try:
        rep = fabric.make_fabric("xor_relabel", 8).verify()
        assert rep["ok"] and rep["isoport"]
    finally:
        fabric.unregister_instance("xor_relabel")
    with pytest.raises(ValueError):
        fabric.get_instance("xor_relabel")


# ---------------------------------------------------------------------------
# The uniform Fabric surface.
# ---------------------------------------------------------------------------

FABRICS = [
    fabric.make_fabric("xor", 8),
    fabric.make_fabric("circle", 9),
    fabric.make_fabric("mirror", 8),
    fabric.make_fabric("swap", 8),
    fabric.make_fabric(HyperXConfig(dims=(4, 4), terminals=4)),
    fabric.make_fabric(DragonflyConfig(4, 2, 1, 5)),
]


@pytest.mark.parametrize("fab", FABRICS, ids=lambda f: f.name)
def test_fabric_uniform_surface(fab):
    assert fab.verify()["ok"], fab.name
    topo = fab.sim_topology()
    topo.validate()
    assert topo.num_switches == fab.num_switches
    assert fab.num_links == topo.num_links
    nb = fab.neighbor_matrix()
    pp = fab.peer_port_matrix()
    assert nb.shape == pp.shape == (topo.num_switches, topo.num_ports)
    assert isinstance(fab.link_loads(), dict)
    dep = fab.deployment()
    assert isinstance(dep, dict) and dep
    assert fab.diameter >= 1
    assert fab.schedule() is not None


def test_cin_fabric_uniform_loads():
    ll = fabric.make_fabric("xor", 16).link_loads()
    assert set(ll["per_link"].values()) == {1}
    assert ll["summary"]["links_used"] == 16 * 15


def test_hyperx_fabric_balanced_loads_and_deployment():
    fab = fabric.make_fabric(HyperXConfig(dims=(4, 4), terminals=4))
    assert fab.link_loads()["load_cv"] == 0.0
    assert fab.deployment()["switches"] == 16


def test_make_fabric_dispatch_errors():
    with pytest.raises(ValueError):
        fabric.make_fabric("xor")          # missing n
    with pytest.raises(TypeError):
        fabric.make_fabric(3.14)
    f = fabric.make_fabric("xor", 8)
    assert fabric.make_fabric(f) is f      # pass-through


# ---------------------------------------------------------------------------
# Dragonfly closed-form loads vs the packet simulator, link for link.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg", [
    DragonflyConfig(4, 2, 1, 5),
    DragonflyConfig(8, 4, 2, 16),
    DragonflyConfig(4, 2, 1, 5, local_instance="mirror",
                    global_instance="mirror"),
    DragonflyConfig(4, 2, 2, 8, local_instance="xor", global_instance="xor"),
    DragonflyConfig(4, 2, 2, 9, local_instance="swap",
                    global_instance="circle"),
], ids=lambda c: f"a{c.group_size}g{c.num_groups}-{c.local_instance}-"
                 f"{c.global_instance}")
def test_dragonfly_closed_form_matches_routed_ground_truth(cfg):
    """Every directed physical link: closed form == hop-by-hop routing."""
    cf = dragonfly_link_loads(cfg)
    routed = routed_link_loads(dragonfly_topology(cfg))
    a = cfg.group_size
    want: dict[tuple[int, int], int] = {}
    for (grp, s, t), v in cf["local"].items():
        key = (grp * a + s, grp * a + t)
        want[key] = want.get(key, 0) + v
    for (ga, gb), v in cf["global"].items():
        sa, _ = cfg.global_port_owner(ga, gb)
        sb, _ = cfg.global_port_owner(gb, ga)
        key = (ga * a + sa, gb * a + sb)
        want[key] = want.get(key, 0) + v
    assert want == routed


def test_dragonfly_global_links_perfectly_balanced():
    cfg = DragonflyConfig(8, 4, 2, 16)
    cf = dragonfly_link_loads(cfg)
    assert set(cf["global"].values()) == {64}      # a^2
    assert cf["summary"]["global_link_load"] == 64
    assert cf["summary"]["global_links_used"] == 16 * 15


# ---------------------------------------------------------------------------
# Mesh shape checking (the axis_size foot-gun, now a loud error).
# ---------------------------------------------------------------------------

def test_collectives_mesh_shape_check():
    jax = pytest.importorskip("jax")
    from jax.sharding import Mesh
    devs = np.array(jax.devices()[:1])
    mesh = Mesh(devs.reshape(1), ("x",))
    fab = fabric.make_fabric("xor", 8)
    with pytest.raises(ValueError, match="needs 8"):
        fab.collectives(mesh, axis_name="x")
    # HyperX checks every dimension and the axis count.
    hfab = fabric.make_fabric(HyperXConfig(dims=(4, 4), terminals=4))
    with pytest.raises(ValueError, match="dimensions"):
        hfab.collectives(mesh, axis_names=("x",))
    # Dragonfly checks local and global axes independently.
    dfab = fabric.make_fabric(DragonflyConfig(4, 2, 1, 5))
    with pytest.raises(ValueError, match="local CIN"):
        dfab.collectives(mesh, local_axis="x")


def test_collectives_instance_binding():
    fab = fabric.make_fabric(DragonflyConfig(
        4, 2, 1, 5, local_instance="circle", global_instance="mirror"))
    coll = fab.collectives(None, local_axis="l", global_axis="g")
    assert coll.axis_instance("l") == "circle"
    assert coll.axis_instance("g") == "mirror"
    assert coll.axis_instance("other") == "auto"


# ---------------------------------------------------------------------------
# Deprecation shims: old entry points warn but still work.
# ---------------------------------------------------------------------------

def test_instances_tuple_is_deprecated():
    import importlib

    import repro.core
    # (the package re-exports the port_matrix *function* under the same
    # name, so fetch the module object itself)
    pm = importlib.import_module("repro.core.port_matrix")
    with pytest.warns(fabric.LacinDeprecationWarning):
        assert pm.INSTANCES == ("swap", "circle", "xor")
    with pytest.warns(fabric.LacinDeprecationWarning):
        assert repro.core.INSTANCES == ("swap", "circle", "xor")


def test_collective_shims_warn():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.core import psum_or_lacin, tree_all_reduce_lacin

    # The warnings fire at call time, before any collective is traced:
    # an empty pytree exercises the tree shim with no bound axis needed,
    # and the xla psum path runs inside a trivial size-1 shard_map.
    with pytest.warns(fabric.LacinDeprecationWarning):
        assert tree_all_reduce_lacin({}, "x", axis_size=4) == {}

    from jax.sharding import Mesh, PartitionSpec as P
    from repro._compat.jaxapi import shard_map
    mesh = Mesh(np.array(jax.devices()[:1]), ("x",))

    def body(x):
        with pytest.warns(fabric.LacinDeprecationWarning):
            return psum_or_lacin(x, "x", axis_size=1, impl="xla")

    out = shard_map(body, mesh=mesh, in_specs=P(), out_specs=P())(
        jnp.ones((4,)))
    assert out.shape == (4,)
