"""LACIN collectives vs the XLA reference collectives, on an 8-host-device
mesh (subprocess keeps the main test process single-device).

Complements ``test_collectives_multidev.py`` (which checks algebraic
post-conditions): here every LACIN collective is compared against the
corresponding ``lax`` collective — ``all_to_all``, ``all_gather``, and
``psum``-derived references — for both even (8) and odd (5) axis sizes.
"""
import json
import os
import subprocess
import sys

import pytest

_CHILD = r"""
import json
import numpy as np, jax, jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from repro._compat.jaxapi import shard_map
from repro.core import (all_to_all_lacin, all_gather_lacin,
                        reduce_scatter_lacin, all_reduce_lacin)

devs = jax.devices()
assert len(devs) == 8, len(devs)
results = {}


def compare(n, inst, tag):
    mesh = Mesh(np.array(devs[:n]), ("x",))
    sm = lambda f: shard_map(f, mesh=mesh, in_specs=P("x"), out_specs=P("x"))

    # all-to-all: x[j] is this device's chunk for device j.
    x = jax.random.normal(jax.random.PRNGKey(0), (n, n, 3, 2))
    got = sm(lambda xl: all_to_all_lacin(xl[0], "x", axis_size=n,
                                         instance=inst)[None])(x)
    ref = sm(lambda xl: lax.all_to_all(xl[0][:, None], "x", split_axis=0,
                                       concat_axis=0).reshape(n, 3, 2)[None])(x)
    results[f"{tag}_a2a"] = bool(jnp.allclose(got, ref, rtol=1e-5, atol=1e-6))

    # all-gather of each device's shard.
    xs = jax.random.normal(jax.random.PRNGKey(1), (n, 4, 3))
    got = sm(lambda xl: all_gather_lacin(xl[0], "x", axis_size=n,
                                         instance=inst)[None])(xs)
    ref = sm(lambda xl: lax.all_gather(xl[0], "x")[None])(xs)
    results[f"{tag}_ag"] = bool(jnp.allclose(got, ref, rtol=1e-5, atol=1e-6))

    # reduce-scatter: reference = full psum, then take own shard.
    xr = jax.random.normal(jax.random.PRNGKey(2), (n, n, 5))
    got = sm(lambda xl: reduce_scatter_lacin(xl[0], "x", axis_size=n,
                                             instance=inst)[None])(xr)
    ref = sm(lambda xl: lax.psum(xl[0], "x")[lax.axis_index("x")][None])(xr)
    results[f"{tag}_rs"] = bool(jnp.allclose(got, ref, rtol=1e-4, atol=1e-5))

    # all-reduce vs lax.psum.
    xa = jax.random.normal(jax.random.PRNGKey(3), (n, 6, 3))
    got = sm(lambda xl: all_reduce_lacin(xl[0], "x", axis_size=n,
                                         instance=inst)[None])(xa)
    ref = sm(lambda xl: lax.psum(xl[0], "x")[None])(xa)
    results[f"{tag}_ar"] = bool(jnp.allclose(got, ref, rtol=1e-4, atol=1e-5))


compare(8, "xor", "even_xor")
compare(8, "circle", "even_circle")
compare(5, "circle", "odd_circle")    # odd axis: one idle device per step
compare(5, "auto", "odd_auto")
print("RESULT " + json.dumps(results))
"""


@pytest.fixture(scope="module")
def ref_results():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    res = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert res.returncode == 0, res.stderr[-2000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


@pytest.mark.parametrize("tag", ["even_xor", "even_circle", "odd_circle",
                                 "odd_auto"])
@pytest.mark.parametrize("op", ["a2a", "ag", "rs", "ar"])
def test_lacin_matches_lax_reference(ref_results, tag, op):
    assert ref_results[f"{tag}_{op}"], (tag, op)
