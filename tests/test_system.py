"""End-to-end behaviour tests for the paper's system.

The integration surface: LACIN schedules drive real collectives inside a
real model, training decreases loss, serving decodes consistently with the
teacher-forced forward pass, and the sharding layer produces legal specs
for every architecture.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models import (NO_SHARD, get_config, init_params, prefill,
                          decode_step, forward_train)
from repro.models.layers import AxisRules


def test_prefill_then_decode_matches_teacher_forcing():
    """Decoding token t with caches == forward pass logits at position t."""
    cfg = get_config("lacin-demo").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    # full forward over 13 tokens (teacher forcing)
    full = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 13)), jnp.int32)
    from repro.models.transformer import apply_stack, build_runs
    from repro.models import layers as L
    runs = build_runs(cfg)
    x = L.embed_tokens(params["embed"], full, cfg, NO_SHARD)
    pos = jnp.arange(13, dtype=jnp.int32)
    x, _, _ = apply_stack(params["stack"], x, cfg, NO_SHARD, runs,
                          q_pos=pos, kv_pos=pos, mode="train")
    x = L.apply_norm(params["final_norm"], x)
    ref_logits = L.logits_from_hidden(x, params["embed"],
                                      params.get("lm_head"), cfg, NO_SHARD)

    # prefill on the first 12, then decode token 12
    logits_p, caches = prefill(params, {"tokens": full[:, :12]}, cfg,
                               NO_SHARD, seq_len=16)
    np.testing.assert_allclose(np.asarray(logits_p[:, 0]),
                               np.asarray(ref_logits[:, 11]),
                               rtol=2e-2, atol=2e-2)
    logits_d, _ = decode_step(params, full[:, 12:13], caches,
                              jnp.asarray(12, jnp.int32), cfg, NO_SHARD,
                              seq_len=16)
    np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                               np.asarray(ref_logits[:, 12]),
                               rtol=2e-2, atol=2e-2)


def test_loss_gradient_nonzero_everywhere():
    cfg = get_config("lacin-demo").reduced()
    params = init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
    grads = jax.grad(lambda p: forward_train(
        p, {"tokens": tok, "labels": tok}, cfg, NO_SHARD)[0])(params)
    norms = [float(jnp.abs(g).sum())
             for g in jax.tree_util.tree_leaves(grads)]
    assert all(np.isfinite(norms))
    # most parameters receive gradient (norm scales may start at zero grad)
    assert np.mean([n > 0 for n in norms]) > 0.8


def test_loss_masking_ignores_negative_labels():
    cfg = get_config("lacin-demo").reduced()
    params = init_params(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(2)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)), jnp.int32)
    lab_full = tok
    lab_masked = lab_full.at[:, :4].set(-100)
    l1, _ = forward_train(params, {"tokens": tok, "labels": lab_full},
                          cfg, NO_SHARD)
    l2, _ = forward_train(params, {"tokens": tok, "labels": lab_masked},
                          cfg, NO_SHARD)
    assert not np.isclose(float(l1), float(l2))


def test_param_specs_cover_every_leaf_legally():
    """Spec builder produces divisibility-legal specs for all archs on the
    production mesh (structure-only; no devices needed)."""
    from repro.runtime.sharding import param_specs
    from jax.sharding import PartitionSpec as P

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    rules = AxisRules(dp=("data",), tp="model", mesh=FakeMesh())
    for arch in ("nemotron-4-15b", "qwen3-moe-30b-a3b", "xlstm-350m",
                 "hymba-1.5b", "whisper-base", "gemma3-1b"):
        cfg = get_config(arch)
        shapes = jax.eval_shape(lambda c=cfg: init_params(
            jax.random.PRNGKey(0), c))
        specs = param_specs(shapes, cfg, rules)
        flat_s = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        flat_a = jax.tree_util.tree_leaves(shapes)
        assert len(flat_s) == len(flat_a)
        for spec, leaf in zip(flat_s, flat_a):
            for dim, ax in zip(leaf.shape, tuple(spec)):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                extent = int(np.prod([FakeMesh.shape[a] for a in axes]))
                assert dim % extent == 0, (arch, spec, leaf.shape)


def test_grad_accum_close_to_full_batch():
    """ga=2 averaged grads ~= full-batch grads (same data)."""
    from repro.optim import OptConfig
    from repro.runtime.trainer import make_train_step, init_train_state
    cfg = get_config("lacin-demo").reduced()
    rng = np.random.default_rng(3)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)
    batch = {"tokens": tok, "labels": tok}
    opt = OptConfig(lr=0.0, warmup_steps=0, weight_decay=0.0)
    rules = AxisRules()
    s0 = init_train_state(jax.random.PRNGKey(4), cfg)
    s1 = init_train_state(jax.random.PRNGKey(4), cfg)
    st1, _ = make_train_step(cfg, rules, opt, grad_accum=1)(s0, batch)
    st2, _ = make_train_step(cfg, rules, opt, grad_accum=2)(s1, batch)
    g1 = jax.tree_util.tree_leaves(st1["opt"]["m"])
    g2 = jax.tree_util.tree_leaves(st2["opt"]["m"])
    rel = max(float(jnp.max(jnp.abs(a - b)) /
                    (jnp.max(jnp.abs(a)) + 1e-9)) for a, b in zip(g1, g2))
    assert rel < 0.15, rel   # CE normalization is per-microbatch


def test_flash_vjp_inside_model_matches_naive_grads():
    """Long-seq path (flash custom VJP) == naive attention gradients."""
    from repro.models import layers as L
    b, t, h, kvh, d = 1, 96, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (b, t, h, d))
    k = jax.random.normal(ks[1], (b, t, kvh, d))
    v = jax.random.normal(ks[2], (b, t, kvh, d))
    pos = jnp.arange(t, dtype=jnp.int32)

    from repro.models.flash import flash_attention_jnp

    def f_flash(q, k, v):
        o = flash_attention_jnp(q, k, v, pos, pos,
                                jnp.asarray(0, jnp.int32), True, 32, 32)
        return (o ** 2).sum()

    def f_naive(q, k, v):
        o = L.attention_naive(q, k, v, q_pos=pos, kv_pos=pos)
        return (o ** 2).sum()

    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=3e-4, atol=3e-5)
