"""Runtime telemetry: compile-vs-execute timing, the compile cache, and
environment provenance.

The compile tax is ROADMAP item 1's whole problem: the compiled engine's
steady-state speedup is real, but a cold program build eats it.  This
module makes the split *measurable everywhere* and — via a persistent
on-disk executable cache — makes the tax a once-per-machine cost instead
of once-per-process:

* :func:`timed_compiled` wraps a jit-compiled function's invocation in
  JAX's ahead-of-time path (``lower() -> compile() -> call``), timing
  the compile and the execute separately.  Program acquisition goes
  through two cache layers:

  1. an in-process **memory** cache (LRU-bounded — a long sweep of
     distinct shapes must not pin unbounded device executables), and
  2. an on-disk **AOT** layer: compiled executables serialized with
     ``jax.experimental.serialize_executable`` under
     :func:`cache_dir` (default ``~/.cache/lacin-repro``, override with
     ``LACIN_CACHE_DIR``, disable with ``LACIN_CACHE_DIR=""``), keyed by
     a content digest of the program identity (see :func:`_disk_key`).
     Entries are versioned, written atomically (concurrent writers are
     safe — last writer wins and both blobs are valid), and loads are
     corruption-tolerant: a truncated, bit-flipped, or
     version-mismatched entry is skipped and the program recompiled,
     never crashed on and never trusted.

  The timing dict records which layer served the program:
  ``compile_cached`` is ``"memory"``, ``"disk"``, or ``False`` (fresh
  compile).  :func:`repro.sim.xengine.sweep` routes every program build
  through this path, so the field lands on ``RunStats.timing`` and
  persists into ``Result.provenance``.

* :func:`provenance` is the environment block each
  :class:`repro.studies.store.Result` persists: host, interpreter and
  library versions, cpu count, plus the run's timing dict — enough to
  interpret a stored wall-clock number months later on different
  hardware.

Timing dicts are plain JSON-scalars so they serialize into JSONL stores
and BENCH artifacts unchanged::

    {"backend": "jax", "compile_s": 0.11, "execute_s": 0.74,
     "total_s": 0.85, "compile_cached": "disk", "grid_points": 24}
"""
from __future__ import annotations

import hashlib
import os
import pickle
import platform
import tempfile
import time
from collections import OrderedDict
from functools import lru_cache
from pathlib import Path

import numpy as np

__all__ = ["timed_compiled", "provenance", "timing_dict", "cache_dir",
           "cache_stats", "reset_cache_stats", "clear_caches",
           "disk_cache_entries", "CACHE_FORMAT"]

#: Bump when the on-disk entry layout changes: old entries become
#: unreadable garbage to the new code, so the version participates in
#: both the key digest and the in-entry header (belt and braces — a
#: digest collision must still fail closed).
CACHE_FORMAT = 1

#: Compiled executables keyed by (function, static arg, arg avals), in
#: LRU order (oldest first).  Bounded: a process that really builds this
#: many distinct programs is sweeping shapes, and caching them all would
#: pin device memory — see :data:`_CACHE_LIMIT`.
_CACHE: OrderedDict = OrderedDict()
_CACHE_LIMIT = 64

#: On-disk entries kept before the oldest (by mtime) are pruned on the
#: next write.  Generous: xengine programs serialize to ~100 KB-1 MB.
_DISK_LIMIT = 256

#: Cache-layer counters, exposed for tests and the studies CLI.  Keys:
#: ``memory_hits``/``disk_hits``/``misses`` partition program
#: acquisitions; ``evictions`` counts memory-LRU drops; ``disk_writes``
#: successful entry writes; ``disk_errors`` unreadable/unwritable
#: entries (each one is a silent fallback to recompilation, never a
#: crash).
_STATS = {"memory_hits": 0, "disk_hits": 0, "misses": 0, "evictions": 0,
          "disk_writes": 0, "disk_errors": 0}


def cache_stats() -> dict:
    """A snapshot copy of the cache counters (see :data:`_STATS`)."""
    return dict(_STATS)


def reset_cache_stats() -> None:
    for k in _STATS:
        _STATS[k] = 0


def cache_dir() -> Path | None:
    """The persistent compile-cache directory, or ``None`` when disabled.

    ``LACIN_CACHE_DIR`` overrides the default
    ``$XDG_CACHE_HOME/lacin-repro`` (``~/.cache/lacin-repro``); the
    empty string disables the disk layer entirely (the memory cache
    still applies).  The directory is created lazily on first write.
    """
    env = os.environ.get("LACIN_CACHE_DIR")
    if env is not None:
        return Path(env) if env else None
    xdg = os.environ.get("XDG_CACHE_HOME")
    base = Path(xdg) if xdg else Path.home() / ".cache"
    return base / "lacin-repro"


def disk_cache_entries() -> list[Path]:
    """The current cache directory's entry files (any format version)."""
    cdir = cache_dir()
    if cdir is None or not cdir.is_dir():
        return []
    return sorted(cdir.glob("*.exe"))


def clear_caches(*, memory: bool = True, disk: bool = False) -> None:
    """Drop cached executables.  ``disk=True`` also unlinks every entry
    in the current :func:`cache_dir` (tests use this to force cold
    compiles)."""
    if memory:
        _CACHE.clear()
    if disk:
        for p in disk_cache_entries():
            try:
                p.unlink()
            except OSError:
                pass


def timing_dict(backend: str, *, compile_s: float = 0.0,
                execute_s: float = 0.0, compile_cached=False,
                grid_points: int = 1) -> dict:
    """The canonical timing record (see the module docstring).  A batched
    program's dict is shared by every grid point it produced —
    ``grid_points`` says how many, so consumers can amortize.
    ``compile_cached`` is ``False`` for a fresh compile, else the cache
    layer that served the program (``"memory"`` or ``"disk"``)."""
    return {
        "backend": backend,
        "compile_s": round(float(compile_s), 6),
        "execute_s": round(float(execute_s), 6),
        "total_s": round(float(compile_s) + float(execute_s), 6),
        "compile_cached": (compile_cached if compile_cached else False),
        "grid_points": int(grid_points),
    }


def _aval_key(args) -> tuple:
    import jax
    leaves, treedef = jax.tree_util.tree_flatten(args)
    return (treedef,
            tuple((tuple(np.shape(leaf)),
                   str(getattr(leaf, "dtype", type(leaf).__name__)))
                  for leaf in leaves))


def _fn_ident(fn) -> str:
    inner = getattr(fn, "__wrapped__", fn)
    mod = getattr(inner, "__module__", "?")
    name = getattr(inner, "__qualname__",
                   getattr(inner, "__name__", repr(inner)))
    return f"{mod}.{name}"


@lru_cache(maxsize=1)
def _source_digest() -> str:
    """sha256 over every ``repro`` source file, computed once per
    process.  The function identity in :func:`_disk_key` names *which*
    program, not *which version of the code* built it — without this, an
    executable compiled from yesterday's engine silently satisfies
    today's edited one.  Hashing the whole package is deliberately
    conservative: an unrelated edit costs one recompile, while a stale
    executable computes the old program's results with no error."""
    import repro
    h = hashlib.sha256()
    for root in sorted(repro.__path__):
        root = Path(root)
        for p in sorted(root.rglob("*.py")):
            try:
                h.update(str(p.relative_to(root)).encode())
                h.update(p.read_bytes())
            except OSError:  # pragma: no cover - racing editor/cleanup
                continue
    return h.hexdigest()[:16]


def _env_header() -> dict:
    import jax
    try:
        import jaxlib
        jaxlib_ver = jaxlib.__version__
    except Exception:  # pragma: no cover - jaxlib ships with jax
        jaxlib_ver = None
    return {"format": CACHE_FORMAT, "jax": jax.__version__,
            "jaxlib": jaxlib_ver, "backend": jax.default_backend(),
            "src": _source_digest()}


def _disk_key(fn, static_arg, aval_key, key_extra) -> str:
    """Content digest naming a disk entry.  Anatomy (all parts must
    match for a hit): cache format version, jax + jaxlib versions, XLA
    backend, a digest of the ``repro`` source tree (so editing the
    engine invalidates executables it compiled — see
    :func:`_source_digest`), the wrapped function's qualified name, the
    static argument's ``repr`` (for xengine this is the :class:`XSpec` —
    every field of the compiled program's shape), the argument avals
    (treedef + shapes + dtypes), and the caller's ``key_extra`` (xengine
    passes a content digest of its topology tables, so two fabrics that
    merely share shapes do not share executables)."""
    payload = repr((sorted(_env_header().items()), _fn_ident(fn),
                    repr(static_arg), aval_key, key_extra))
    return hashlib.sha256(payload.encode()).hexdigest()[:40]


def _entry_path(digest: str) -> Path | None:
    cdir = cache_dir()
    if cdir is None:
        return None
    return cdir / f"{digest}.v{CACHE_FORMAT}.exe"


def _disk_load(path: Path):
    """Deserialize one entry; any failure — missing, truncated, corrupt,
    or version/backend-mismatched — returns ``None`` (recompile)."""
    try:
        with open(path, "rb") as f:
            entry = pickle.load(f)
        if not isinstance(entry, dict):
            raise ValueError("cache entry is not a dict")
        header = _env_header()
        if any(entry.get(k) != v for k, v in header.items()):
            # A well-formed entry at this path should match (the digest
            # covers the header); a mismatch means the file was tampered
            # with or collided — treat exactly like corruption.
            raise ValueError("cache entry header mismatch")
        from jax.experimental import serialize_executable as se
        payload = entry["payload"]
        return se.deserialize_and_load(*payload)
    except FileNotFoundError:
        return None
    except Exception:
        _STATS["disk_errors"] += 1
        try:
            path.unlink()
        except OSError:
            pass
        return None


def _disk_store(path: Path, compiled) -> None:
    """Serialize atomically: pickle to a unique temp file in the cache
    directory, then ``os.replace`` — readers never observe a partial
    entry, and two processes racing on one key both leave valid blobs
    (last writer wins).  Failures are counted, never raised."""
    tmp = None
    try:
        from jax.experimental import serialize_executable as se
        entry = dict(_env_header())
        entry["payload"] = se.serialize(compiled)
        entry["created"] = time.time()
        path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=str(path.parent),
                                   prefix=".tmp-" + path.stem)
        with os.fdopen(fd, "wb") as f:
            pickle.dump(entry, f)
        os.replace(tmp, path)
        tmp = None
        _STATS["disk_writes"] += 1
        _disk_prune(path.parent)
    except Exception:
        _STATS["disk_errors"] += 1
        if tmp is not None:
            try:
                os.unlink(tmp)
            except OSError:
                pass


def _disk_prune(cdir: Path) -> None:
    """Keep the directory bounded: drop oldest-by-mtime entries past
    :data:`_DISK_LIMIT` (best-effort; racing unlinks are fine)."""
    try:
        entries = sorted(cdir.glob("*.exe"), key=lambda p: p.stat().st_mtime)
        for p in entries[:-_DISK_LIMIT]:
            try:
                p.unlink()
            except OSError:
                pass
    except OSError:  # pragma: no cover - directory vanished mid-prune
        pass


def _memory_insert(key, compiled) -> None:
    while len(_CACHE) >= _CACHE_LIMIT:
        _CACHE.popitem(last=False)
        _STATS["evictions"] += 1
    _CACHE[key] = compiled


def timed_compiled(fn, static_arg, *args, grid_points: int = 1,
                   key_extra=None) -> tuple:
    """Call ``fn(static_arg, *args)`` — a ``jax.jit(...,
    static_argnums=0)`` function — through the AOT path, returning
    ``(output, timing)`` where ``timing`` separates program acquisition
    from execution (:func:`timing_dict`).

    Acquisition checks the in-process LRU first
    (``compile_cached="memory"``, ``compile_s`` 0.0), then the on-disk
    AOT layer (``compile_cached="disk"``, ``compile_s`` = deserialize
    time — milliseconds, not seconds), and only then lowers + compiles
    (``compile_cached`` ``False``), writing the fresh executable back to
    disk for the next process.  A disk-restored executable is the same
    machine code the fresh compile produced, so its results are
    byte-identical (``tests/test_conformance.py`` pins this).
    Execution is timed to completion (``block_until_ready``), so
    ``execute_s`` is device time, not dispatch time.

    ``static_arg=None`` calls ``fn(*args)`` / ``fn.lower(*args)`` — for
    pre-specialized jitted callables (e.g. xengine's sharded runners,
    whose static spec is baked into the function); pass the spec through
    ``key_extra`` so the disk key still covers it.  ``key_extra`` is any
    repr-able value mixed into the disk digest (see :func:`_disk_key`).
    """
    import jax
    key = (fn, static_arg, _aval_key(args), repr(key_extra))
    compile_s = 0.0
    cached: str | bool = False
    if key in _CACHE:
        _CACHE.move_to_end(key)
        compiled = _CACHE[key]
        cached = "memory"
        _STATS["memory_hits"] += 1
    else:
        digest = _disk_key(fn, static_arg, key[2], key_extra)
        path = _entry_path(digest)
        compiled = None
        if path is not None:
            t0 = time.perf_counter()
            compiled = _disk_load(path)
            if compiled is not None:
                compile_s = time.perf_counter() - t0
                cached = "disk"
                _STATS["disk_hits"] += 1
        if compiled is None:
            t0 = time.perf_counter()
            lowered = (fn.lower(*args) if static_arg is None
                       else fn.lower(static_arg, *args))
            compiled = lowered.compile()
            compile_s = time.perf_counter() - t0
            _STATS["misses"] += 1
            if path is not None:
                _disk_store(path, compiled)
        _memory_insert(key, compiled)
    t1 = time.perf_counter()
    out = jax.block_until_ready(compiled(*args))
    execute_s = time.perf_counter() - t1
    return out, timing_dict("jax", compile_s=compile_s,
                            execute_s=execute_s, compile_cached=cached,
                            grid_points=grid_points)


def provenance(timing: dict | None = None, *, backend: str | None = None,
               spec_digest: str | None = None) -> dict:
    """The environment/provenance block persisted with results and
    benchmark artifacts: where and with what a number was produced."""
    out = {
        "host": platform.node(),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "numpy": np.__version__,
        "cpu_count": os.cpu_count(),
    }
    try:
        import jax
        out["jax"] = jax.__version__
    except Exception:       # pragma: no cover - jax is a hard dep in-repo
        out["jax"] = None
    if backend is not None:
        out["backend"] = backend
    if spec_digest:
        out["spec_digest"] = spec_digest
    if timing is not None:
        out["timings"] = dict(timing)
    return out
