"""Runtime telemetry: compile-vs-execute timing and environment provenance.

The compile tax is ROADMAP item 1's whole problem: the compiled engine's
steady-state speedup is real, but a cold program build eats it.  This
module makes the split *measurable everywhere* instead of something the
speed benchmark reconstructs from cold-vs-warm wall clocks:

* :func:`timed_compiled` wraps a jit-compiled function's invocation in
  JAX's ahead-of-time path (``lower() -> compile() -> call``), timing
  the compile and the execute separately, with a process-level cache so
  repeat shapes pay compile once (the same contract ``jax.jit``'s own
  cache gives).  :func:`repro.sim.xengine.sweep` routes every program
  build through it.
* :func:`provenance` is the environment block each
  :class:`repro.studies.store.Result` persists: host, interpreter and
  library versions, cpu count, plus the run's timing dict — enough to
  interpret a stored wall-clock number months later on different
  hardware.

Timing dicts are plain JSON-scalars so they serialize into JSONL stores
and BENCH artifacts unchanged::

    {"backend": "jax", "compile_s": 6.51, "execute_s": 0.74,
     "total_s": 7.25, "compile_cached": false, "grid_points": 24}
"""
from __future__ import annotations

import os
import platform
import time

import numpy as np

__all__ = ["timed_compiled", "provenance", "timing_dict"]

#: Compiled executables keyed by (function, static arg, arg avals).
#: Bounded: a process that really builds this many distinct programs is
#: sweeping shapes, and caching them all would pin device memory.
_CACHE: dict = {}
_CACHE_LIMIT = 64


def timing_dict(backend: str, *, compile_s: float = 0.0,
                execute_s: float = 0.0, compile_cached: bool = False,
                grid_points: int = 1) -> dict:
    """The canonical timing record (see the module docstring).  A batched
    program's dict is shared by every grid point it produced —
    ``grid_points`` says how many, so consumers can amortize."""
    return {
        "backend": backend,
        "compile_s": round(float(compile_s), 6),
        "execute_s": round(float(execute_s), 6),
        "total_s": round(float(compile_s) + float(execute_s), 6),
        "compile_cached": bool(compile_cached),
        "grid_points": int(grid_points),
    }


def _aval_key(args) -> tuple:
    import jax
    leaves, treedef = jax.tree_util.tree_flatten(args)
    return (treedef,
            tuple((tuple(np.shape(leaf)),
                   str(getattr(leaf, "dtype", type(leaf).__name__)))
                  for leaf in leaves))


def timed_compiled(fn, static_arg, *args, grid_points: int = 1
                   ) -> tuple:
    """Call ``fn(static_arg, *args)`` — a ``jax.jit(...,
    static_argnums=0)`` function — through the AOT path, returning
    ``(output, timing)`` where ``timing`` separates program build from
    execution (:func:`timing_dict`).

    First call for a (static_arg, arg-shapes) signature lowers and
    compiles (``compile_s`` > 0, ``compile_cached`` False); repeats hit
    the process cache (``compile_s`` 0.0, ``compile_cached`` True).
    Execution is timed to completion (``block_until_ready``), so
    ``execute_s`` is device time, not dispatch time.
    """
    import jax
    key = (fn, static_arg, _aval_key(args))
    cached = key in _CACHE
    compile_s = 0.0
    if not cached:
        t0 = time.perf_counter()
        compiled = fn.lower(static_arg, *args).compile()
        compile_s = time.perf_counter() - t0
        if len(_CACHE) >= _CACHE_LIMIT:
            _CACHE.clear()
        _CACHE[key] = compiled
    t1 = time.perf_counter()
    out = jax.block_until_ready(_CACHE[key](*args))
    execute_s = time.perf_counter() - t1
    return out, timing_dict("jax", compile_s=compile_s,
                            execute_s=execute_s, compile_cached=cached,
                            grid_points=grid_points)


def provenance(timing: dict | None = None, *, backend: str | None = None,
               spec_digest: str | None = None) -> dict:
    """The environment/provenance block persisted with results and
    benchmark artifacts: where and with what a number was produced."""
    out = {
        "host": platform.node(),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "numpy": np.__version__,
        "cpu_count": os.cpu_count(),
    }
    try:
        import jax
        out["jax"] = jax.__version__
    except Exception:       # pragma: no cover - jax is a hard dep in-repo
        out["jax"] = None
    if backend is not None:
        out["backend"] = backend
    if spec_digest:
        out["spec_digest"] = spec_digest
    if timing is not None:
        out["timings"] = dict(timing)
    return out
