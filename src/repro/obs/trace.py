"""Time-series trace containers shared by both simulation engines.

A :class:`Trace` is the sampled dynamics of one run: at every sampled
cycle (``cycle % stride == 0``, capped at ``max_samples`` rows) the
engine records four *raw channels* —

==============  ===========  ==============================================
``link_load``   ``(S, L)``   cumulative lifetime traversals per directed
                             link (``L = num_switches * num_ports``)
``queue_occ``   ``(S, N)``   instantaneous total queue occupancy per
                             switch (all ports x VCs)
``injected``    ``(S, N)``   cumulative injections per switch
``delivered``   ``(S,)``     cumulative delivered packets
==============  ===========  ==============================================

Channels are cumulative counters or instantaneous state *by design*:
that makes a stride-``k`` trace exactly the stride-1 trace downsampled
(:meth:`Trace.downsample`), and cross-engine equality a plain array
comparison (:meth:`Trace.equals`).  Per-cycle *rates* — link
utilization, delivery rate — are derived by differencing
(:meth:`Trace.link_util`).

The injection backlog is derived, not sampled: for open-loop traffic the
eligible-packet count per switch is a pure function of the generation
timestamps, and for replays of the recorded phase-completion cycles —
so both engines call the same :func:`derive_backlog` on identical
inputs rather than each re-deriving it in-loop (the compiled engine
would pay an O(packets) reduction every cycle for a value the host can
reconstruct exactly).

The numpy engine additionally records per-packet span ``events`` for K
sampled packets (see :class:`TraceConfig.packets`); the compiled engine
leaves ``events`` empty — hop-by-hop packet following is inherently a
scatter, which its hot loop forbids.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

__all__ = ["TraceConfig", "Trace", "derive_backlog"]


@dataclass(frozen=True)
class TraceConfig:
    """What to record.  ``stride`` samples every k-th cycle;
    ``max_samples`` caps the rows (the compiled engine allocates its
    ring buffers statically, so an unbounded drain cannot grow them);
    ``packets`` asks the numpy engine to follow K sampled packets
    hop-by-hop (0 = off; ignored by the compiled engine)."""
    stride: int = 1
    max_samples: int = 4096
    packets: int = 0

    def __post_init__(self):
        if self.stride < 1:
            raise ValueError(f"trace stride must be >= 1, got {self.stride}")
        if self.max_samples < 1:
            raise ValueError(
                f"trace max_samples must be >= 1, got {self.max_samples}")
        if self.packets < 0:
            raise ValueError(f"trace packets must be >= 0, got {self.packets}")

    @classmethod
    def coerce(cls, value) -> "TraceConfig | None":
        """The engines' lenient ``trace=`` argument: ``None``/``False``
        -> off, ``True`` -> defaults, a mapping -> kwargs (the form a
        declarative ``ExperimentSpec.engine`` dict carries), or an
        existing config passed through."""
        if value is None or value is False:
            return None
        if value is True:
            return cls()
        if isinstance(value, cls):
            return value
        if isinstance(value, dict):
            return cls(**{k: int(v) for k, v in value.items()})
        raise TypeError(f"cannot build a TraceConfig from {value!r}")


def derive_backlog(cycles: np.ndarray, injected: np.ndarray,
                   gen: np.ndarray, blk_start: np.ndarray,
                   blk_end: np.ndarray, phase_done=None) -> np.ndarray:
    """Per-switch injection backlog at each sampled cycle: packets that
    are injection-eligible but not yet injected.

    ``gen``/``blk_start``/``blk_end`` are the engine's packet layout —
    generation timestamps sorted ascending within each switch's source
    block.  Open-loop traffic is eligible once ``gen <= cycle``; replays
    (``phase_done`` given) once their phase ordinal is below the count
    of phases completed by that cycle — exactly the engines' injection
    gates, evaluated at end-of-cycle.
    """
    cycles = np.asarray(cycles, dtype=np.int64)
    if phase_done is not None:
        pd = np.asarray(phase_done, dtype=np.int64)
        limit = ((pd[None, :] >= 0)
                 & (pd[None, :] <= cycles[:, None])).sum(axis=1)
    else:
        limit = cycles
    n = blk_start.size
    eligible = np.empty((cycles.size, n), dtype=np.int64)
    for sw in range(n):
        g = gen[blk_start[sw]:blk_end[sw]]
        eligible[:, sw] = np.searchsorted(g, limit, side="right")
    return eligible - np.asarray(injected, dtype=np.int64)


@dataclass
class Trace:
    """One run's sampled time series (see the module docstring for the
    channel semantics).  ``meta`` carries identifying context (topology
    name, switch/port counts, backend); ``events`` the numpy engine's
    per-packet span records as ``(pid, cycle, from_switch, to_switch)``
    tuples, ``to_switch == -1`` marking the ejection."""
    stride: int
    cycles: np.ndarray                  # (S,) sampled cycle indices
    link_load: np.ndarray               # (S, L) cumulative traversals
    queue_occ: np.ndarray               # (S, N) instantaneous occupancy
    injected: np.ndarray                # (S, N) cumulative injections
    delivered: np.ndarray               # (S,) cumulative deliveries
    backlog: np.ndarray                 # (S, N) eligible - injected
    meta: dict = field(default_factory=dict)
    events: list = field(default_factory=list)

    def __post_init__(self):
        self.cycles = np.asarray(self.cycles, dtype=np.int64)
        for name in ("link_load", "queue_occ", "injected", "backlog"):
            setattr(self, name,
                    np.asarray(getattr(self, name), dtype=np.int64))
        self.delivered = np.asarray(self.delivered, dtype=np.int64)

    # -- derived series ------------------------------------------------------

    @property
    def num_samples(self) -> int:
        return int(self.cycles.size)

    @property
    def in_flight(self) -> np.ndarray:
        """(S,) packets resident in fabric queues at each sample."""
        return self.queue_occ.sum(axis=1)

    def link_util(self, links=None) -> np.ndarray:
        """(S,) mean per-cycle utilization of ``links`` (an index array
        or boolean mask over the L link slots; default: every slot that
        ever carried traffic) across each inter-sample interval.  Row 0
        covers ``[0, cycles[0]]``; utilization of an idle interval is 0.
        """
        load = self.link_load
        if links is not None:
            load = load[:, np.asarray(links)]
        if load.shape[1] == 0 or self.num_samples == 0:
            return np.zeros(self.num_samples)
        if links is None:
            carried = self.link_load[-1] > 0
            if carried.any():
                load = load[:, carried]
        prev = np.concatenate(
            [np.zeros((1, load.shape[1]), np.int64), load[:-1]])
        prev_c = np.concatenate([[-1], self.cycles[:-1]])
        dt = np.maximum(self.cycles - prev_c, 1)
        return (load - prev).mean(axis=1) / dt

    def downsample(self, k: int) -> "Trace":
        """Every k-th sample — for a stride-1 trace this is exactly the
        trace a ``stride=k`` run of the same workload records (the
        invariance ``tests/test_obs.py`` pins)."""
        if k < 1:
            raise ValueError(f"downsample factor must be >= 1, got {k}")
        keep = np.flatnonzero(self.cycles % (self.stride * k) == 0)
        return Trace(
            stride=self.stride * k, cycles=self.cycles[keep],
            link_load=self.link_load[keep], queue_occ=self.queue_occ[keep],
            injected=self.injected[keep], delivered=self.delivered[keep],
            backlog=self.backlog[keep], meta=dict(self.meta),
            events=list(self.events))

    # -- comparison / serialization -----------------------------------------

    _CHANNELS = ("cycles", "link_load", "queue_occ", "injected",
                 "delivered", "backlog")

    def equals(self, other: "Trace") -> bool:
        """Exact channel-wise equality (the cross-engine agreement test
        for deterministic workloads); ``meta``/``events`` are excluded
        — they identify the recording, not the dynamics."""
        return (self.stride == other.stride
                and all(np.array_equal(getattr(self, ch), getattr(other, ch))
                        for ch in self._CHANNELS))

    def diff_summary(self, other: "Trace") -> str:
        """Where two traces first disagree — for test failure messages."""
        if self.stride != other.stride:
            return f"stride {self.stride} != {other.stride}"
        for ch in self._CHANNELS:
            a, b = getattr(self, ch), getattr(other, ch)
            if a.shape != b.shape:
                return f"{ch}: shape {a.shape} != {b.shape}"
            if not np.array_equal(a, b):
                bad = np.argwhere(a != b)
                return (f"{ch}: first mismatch at {tuple(bad[0])} "
                        f"({a[tuple(bad[0])]} != {b[tuple(bad[0])]}, "
                        f"{len(bad)} differing entries)")
        return "traces are equal"

    def to_dict(self) -> dict:
        d = {ch: getattr(self, ch).tolist() for ch in self._CHANNELS}
        d["stride"] = self.stride
        d["meta"] = dict(self.meta)
        d["events"] = [list(e) for e in self.events]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "Trace":
        return cls(stride=int(d["stride"]),
                   cycles=np.asarray(d["cycles"], np.int64),
                   link_load=np.asarray(d["link_load"], np.int64),
                   queue_occ=np.asarray(d["queue_occ"], np.int64),
                   injected=np.asarray(d["injected"], np.int64),
                   delivered=np.asarray(d["delivered"], np.int64),
                   backlog=np.asarray(d["backlog"], np.int64),
                   meta=dict(d.get("meta", {})),
                   events=[tuple(e) for e in d.get("events", [])])
