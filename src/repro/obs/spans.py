"""Chrome trace-event builders: spans and counter tracks for Perfetto.

Emits the JSON-object list of the Trace Event Format (the ``traceEvents``
array ``ui.perfetto.dev`` and ``chrome://tracing`` load): complete spans
(``"ph": "X"`` with ``ts``/``dur``), counter samples (``"ph": "C"``),
and the ``"M"`` metadata records that name process/thread lanes.
Timestamps are microseconds in the format; we map **1 simulated cycle =
1 us**, so a span's ``dur`` reads directly as cycles.

Three builders, composable by concatenation (see
:func:`repro.obs.export.replay_trace_events` for the one-call form):

* :func:`phase_events` — one span per collective-replay phase,
  barrier-to-barrier, on a dedicated "replay" process lane;
* :func:`packet_events` — the numpy engine's K sampled packets as
  hop-by-hop residence spans, one thread lane per switch;
* :func:`counter_events` — any derived time-series (link utilization,
  in-flight count, backlog) as a counter track.

:func:`validate_trace_events` checks the invariants the viewers rely on
and is run by the export CLI before anything is written.
"""
from __future__ import annotations

import json

import numpy as np

__all__ = ["phase_events", "packet_events", "counter_events",
           "request_events", "export_perfetto", "validate_trace_events",
           "PID_REPLAY", "PID_SWITCHES", "PID_COUNTERS", "PID_REQUESTS"]

#: Process ids of the lanes an exported replay / serving run shows.
PID_REPLAY, PID_SWITCHES, PID_COUNTERS, PID_REQUESTS = 1, 2, 3, 4

_VALID_PH = {"X", "C", "M", "B", "E", "I", "i"}


def _meta(pid: int, name: str, *, tid: int | None = None) -> dict:
    ev = {"ph": "M", "pid": pid, "ts": 0,
          "name": "process_name" if tid is None else "thread_name",
          "args": {"name": name}}
    if tid is not None:
        ev["tid"] = tid
    return ev


def phase_events(stats, *, pid: int = PID_REPLAY) -> list[dict]:
    """One ``"X"`` span per replay phase (barrier-to-barrier) from the
    ``phase_cycles`` record of a collective-replay
    :class:`~repro.sim.metrics.RunStats`; empty for open-loop runs."""
    if getattr(stats, "phase_cycles", None) is None:
        return []
    events = [_meta(pid, "replay"), _meta(pid, "phases", tid=0)]
    start = 0
    for k, dur in enumerate(stats.phase_cycles):
        events.append({
            "name": f"phase {k}", "cat": "phase", "ph": "X",
            "ts": start, "dur": max(int(dur), 0), "pid": pid, "tid": 0,
            "args": {"phase": k, "cycles": int(dur)},
        })
        start += int(dur)
    return events


def packet_events(trace, *, pid: int = PID_SWITCHES,
                  num_switches: int | None = None) -> list[dict]:
    """Residence spans of the traced packets: one thread lane per switch,
    one ``"X"`` span per hop covering the cycles the packet sat in that
    switch's queues (arrival cycle + 1 through its next departure).

    ``trace.events`` rows are ``(pid, cycle, from_switch, to_switch)``
    movement records (``to_switch == -1`` = ejected at ``from_switch``),
    as the numpy engine captures them; the compiled engine records none.
    """
    if not trace.events:
        return []
    n = num_switches if num_switches is not None \
        else int(trace.meta.get("num_switches", 0))
    by_pid: dict[int, list] = {}
    for ev in trace.events:
        by_pid.setdefault(int(ev[0]), []).append(ev)
    events = [_meta(pid, "switches")]
    lanes_used: set[int] = set()
    for pkt, evs in sorted(by_pid.items()):
        evs.sort(key=lambda e: e[1])
        for here, nxt in zip(evs, evs[1:] + [None]):
            _, cycle, frm, to = here
            if to < 0:          # ejection record: the span ended earlier
                continue
            depart = nxt[1] if nxt is not None else cycle + 1
            events.append({
                "name": f"pkt {pkt}", "cat": "packet", "ph": "X",
                "ts": int(cycle) + 1,
                "dur": max(int(depart) - int(cycle), 1),
                "pid": pid, "tid": int(to),
                "args": {"packet": pkt, "from": int(frm), "to": int(to)},
            })
            lanes_used.add(int(to))
    for sw in sorted(lanes_used):
        label = f"switch {sw}" if not n else f"switch {sw}/{n}"
        events.append(_meta(pid, label, tid=sw))
    return events


def request_events(request, gen, deliver, *, slo: float | None = None,
                   pid: int = PID_REQUESTS) -> list[dict]:
    """One ``"X"`` span per *completed* serving request — arrival cycle
    to last-packet delivery — and an ``"I"`` instant for each request
    still open when the run stopped.

    Inputs are the per-packet arrays a serving
    :class:`~repro.sim.traffic.Traffic` run produces (``request`` ids,
    ``gen`` cycles, ``deliver`` cycles, −1 = undelivered), the same
    triple :func:`repro.sim.metrics.attach_serving` summarizes.  When
    ``slo`` is given each span's args carry ``slo_met`` so Perfetto
    queries can split the lane by attainment.
    """
    from repro.sim.metrics import request_latency_summary
    rs = request_latency_summary(request, gen, deliver)
    if not rs["count"]:
        return []
    events = [_meta(pid, "requests"), _meta(pid, "serving", tid=0)]
    for k, (arr, lat) in enumerate(zip(rs["arrival"].tolist(),
                                       rs["latency"].tolist())):
        if lat < 0:
            events.append({
                "name": f"req {k} (open)", "cat": "request", "ph": "I",
                "ts": int(arr), "pid": pid, "tid": 0, "s": "t",
                "args": {"request": k},
            })
            continue
        args = {"request": k, "latency": int(lat)}
        if slo is not None:
            args["slo_met"] = bool(lat <= float(slo))
        events.append({
            "name": f"req {k}", "cat": "request", "ph": "X",
            "ts": int(arr), "dur": int(lat), "pid": pid, "tid": 0,
            "args": args,
        })
    return events


def counter_events(name: str, cycles, values, *,
                   pid: int = PID_COUNTERS) -> list[dict]:
    """A counter track (``"ph": "C"``): one sample per entry of
    ``cycles``/``values``.  Perfetto renders it as a stepped area chart
    — the shape link-utilization plateaus show up in."""
    cycles = np.asarray(cycles)
    values = np.asarray(values)
    events = [_meta(pid, "counters")]
    for c, v in zip(cycles.tolist(), values.tolist()):
        events.append({
            "name": name, "ph": "C", "ts": int(c), "pid": pid,
            "args": {name: round(float(v), 6)},
        })
    return events


def validate_trace_events(events: list[dict]) -> list[dict]:
    """Check the trace-event schema invariants the viewers rely on;
    returns ``events`` unchanged (so it chains) or raises ``ValueError``
    naming the first offending event."""
    if not isinstance(events, list):
        raise ValueError(f"traceEvents must be a list, got {type(events)}")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i}: not an object: {ev!r}")
        ph = ev.get("ph")
        if ph not in _VALID_PH:
            raise ValueError(f"event {i}: unknown phase {ph!r}")
        if "name" not in ev:
            raise ValueError(f"event {i}: missing name")
        if ph != "M" and not isinstance(ev.get("ts"), int):
            raise ValueError(f"event {i}: ts must be an integer, "
                             f"got {ev.get('ts')!r}")
        if ph == "X":
            if not isinstance(ev.get("dur"), int) or ev["dur"] < 0:
                raise ValueError(f"event {i}: X span needs dur >= 0, "
                                 f"got {ev.get('dur')!r}")
        if ph == "C" and not isinstance(ev.get("args"), dict):
            raise ValueError(f"event {i}: counter needs an args object")
        try:
            json.dumps(ev)
        except TypeError as e:
            raise ValueError(f"event {i}: not JSON-serializable: {e}") from e
    return events


def export_perfetto(path: str, events: list[dict], *,
                    validate: bool = True) -> dict:
    """Write ``events`` as a Perfetto/Chrome-loadable JSON object
    (``{"traceEvents": [...]}``); returns the payload."""
    if validate:
        validate_trace_events(events)
    payload = {"traceEvents": events, "displayTimeUnit": "ms"}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    return payload
