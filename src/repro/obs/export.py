"""One-call trace export: a traced run -> Perfetto-loadable events.

:func:`replay_trace_events` composes the :mod:`.spans` builders into the
full picture a replay opens with in ``ui.perfetto.dev``:

* one span per phase on the "replay" lane (barrier-to-barrier);
* one thread lane per switch carrying the sampled packets' hop spans
  (numpy-engine traces only — the compiled engine records no spans);
* counter tracks for the derived time series: mean link utilization
  (split by link class when the topology distinguishes local vs global
  wiring — the Dragonfly serialization plateau is the global-class
  track pinned at 1.0 while the replay runs ~4.4x past its bound),
  in-flight packets, and total injection backlog.

:func:`link_classes` is the split: it classifies each directed link slot
of a topology by *what it connects* — intra-group vs inter-group for
hierarchical fabrics — using only the construction metadata topologies
already carry (``topo.meta``), so no simulator state is needed.
"""
from __future__ import annotations

import numpy as np

from .spans import (counter_events, packet_events, phase_events,
                    validate_trace_events)

__all__ = ["link_classes", "replay_trace_events"]


def link_classes(topo) -> dict[str, np.ndarray]:
    """Boolean masks over the ``N * num_ports`` directed link slots,
    keyed by class name.

    Every wired slot is ``"local"`` unless the topology's construction
    metadata records a Dragonfly config, in which case links whose
    endpoints sit in different groups are ``"global"`` — the scarce
    wires whose serialization the replay measures.  Unwired slots (port
    not connected) are in neither class.

    On a degraded topology (built by :func:`repro.faults.degrade`) a
    third ``"rerouted"`` class carries the surviving links the fallback
    table press-ganged onto paths their pristine routes never used —
    the detour wires whose extra load explains a degraded replay's
    stretch.  The classes stay disjoint: a rerouted slot is subtracted
    from ``local``/``global``.
    """
    n, p = topo.num_switches, topo.num_ports
    from repro.sim.link import LinkTable
    nbr = np.asarray(LinkTable.for_topology(topo, 1).neighbor_flat,
                     dtype=np.int64)
    wired = nbr >= 0
    switch_of = np.arange(n * p) // p
    meta = getattr(topo, "meta", {}) or {}
    faults = meta.get("faults")
    rerouted = (wired & np.asarray(faults["rerouted"], dtype=bool)
                if faults is not None else None)
    cfg = meta.get("config")
    group_size = getattr(cfg, "group_size", None)
    if group_size:
        crosses = wired & (switch_of // group_size
                           != np.maximum(nbr, 0) // group_size)
        out = {"local": wired & ~crosses, "global": crosses}
    else:
        out = {"local": wired}
    if rerouted is not None:
        out = {cls: mask & ~rerouted for cls, mask in out.items()}
        out["rerouted"] = rerouted
    return out


def replay_trace_events(stats, *, topo=None, validate: bool = True
                        ) -> list[dict]:
    """The Chrome trace-event list of one traced run (see module
    docstring).  ``stats`` is the run's
    :class:`~repro.sim.metrics.RunStats`; its ``.trace`` must be set
    (run with ``trace=``).  ``topo`` enables the per-class link
    utilization split; without it one aggregate track is emitted.
    """
    trace = getattr(stats, "trace", None)
    if trace is None:
        raise ValueError(
            "stats carries no trace — run the simulation with trace= "
            "(e.g. trace=repro.obs.TraceConfig()) before exporting")
    events = phase_events(stats)
    events += packet_events(trace)
    if topo is not None:
        for cls, mask in link_classes(topo).items():
            if mask.any():
                events += counter_events(
                    f"link_util/{cls}", trace.cycles,
                    trace.link_util(mask))
    else:
        events += counter_events("link_util/mean", trace.cycles,
                                 trace.link_util())
    events += counter_events("in_flight", trace.cycles, trace.in_flight)
    events += counter_events("inj_backlog", trace.cycles,
                             trace.backlog.sum(axis=1))
    return validate_trace_events(events) if validate else events
