"""``repro.obs`` — observability for the simulation stack.

Everything the engines report elsewhere is an end-of-run aggregate
(:class:`~repro.sim.metrics.RunStats`).  This package adds the
*instruments*: time-series traces of the fabric's dynamics, per-packet /
per-phase spans exported as Chrome trace-event JSON (loadable in
``ui.perfetto.dev``), and wall-clock + compile-vs-execute telemetry
around every compiled-engine program build.

==================  =======================================================
:mod:`.trace`       :class:`TraceConfig` / :class:`Trace` — the sampled
                    time-series channels both engines record (link loads,
                    queue occupancy, injections, deliveries) and the
                    derived series (utilization, backlog, in-flight)
:mod:`.spans`       Chrome trace-event builders: phase spans, per-packet
                    hop spans, counter tracks, schema validation
:mod:`.telemetry`   compile-vs-execute timing of jit programs
                    (:func:`timed_compiled`) and the environment
                    :func:`provenance` block study records persist
:mod:`.export`      one-call composition: a traced replay ->
                    Perfetto-loadable JSON with one lane per switch and
                    one span per phase
==================  =======================================================

Capture is engine-native: the numpy :class:`~repro.sim.engine.Engine`
samples at the end of each cycle, and :mod:`repro.sim.xengine` compiles
statically-shaped ring buffers into its loop (contiguous
``dynamic_update_slice`` rows, like its delivery log — zero scatters in
the hot path).  On drained deterministic workloads (collective replays
whose phases are matchings, one-shot permutations) the two engines'
traces agree *exactly*; ``tests/test_obs.py`` pins that.

Quickstart::

    from repro import sim
    from repro.obs import TraceConfig, export_perfetto, replay_trace_events

    fab = fabric.make_fabric("xor", 16)
    stats = fab.replay("all_to_all", trace=TraceConfig(packets=8))
    export_perfetto("replay.json", replay_trace_events(stats))
    # -> open replay.json in ui.perfetto.dev
"""
from .trace import Trace, TraceConfig, derive_backlog
from .spans import (counter_events, export_perfetto, packet_events,
                    phase_events, request_events, validate_trace_events)
from .telemetry import (cache_dir, cache_stats, clear_caches, provenance,
                        reset_cache_stats, timed_compiled)
from .export import link_classes, replay_trace_events

__all__ = [
    "Trace", "TraceConfig", "derive_backlog",
    "counter_events", "export_perfetto", "packet_events", "phase_events",
    "request_events", "validate_trace_events",
    "provenance", "timed_compiled",
    "cache_dir", "cache_stats", "clear_caches", "reset_cache_stats",
    "link_classes", "replay_trace_events",
]
