"""Fault-tolerant checkpointing: atomic, async, mesh-agnostic.

* **Atomic**: write to ``step_K.tmp/`` then ``os.rename`` — a crash mid-save
  never corrupts the latest checkpoint.
* **Async**: device->host transfer happens on the caller thread (cheap),
  serialization + fsync on a background thread, so the train loop is not
  blocked by disk.
* **Mesh-agnostic**: arrays are saved UNSHARDED (gathered) with their
  pytree structure; ``restore`` reshards onto whatever mesh/spec the new
  job uses — this is what makes elastic restarts (different pod counts)
  possible.
* **Self-validating**: every file carries a checksum; ``latest_step`` only
  reports checkpoints whose MANIFEST round-trips.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from pathlib import Path

import numpy as np

import jax


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._lock = threading.Lock()
        self._pending: threading.Thread | None = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state, *, blocking: bool = False):
        """Snapshot ``state`` at ``step``.  Transfers to host now; writes on
        a background thread unless ``blocking``."""
        names, leaves, _ = _flatten_with_names(state)
        host_leaves = [np.asarray(x) for x in leaves]  # device->host now

        def _write():
            tmp = self.dir / f"step_{step:08d}.tmp"
            final = self.dir / f"step_{step:08d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            manifest = {"step": step, "arrays": {}}
            with open(tmp / "data.npz", "wb") as f:
                np.savez(f, **{f"a{i}": a for i, a in enumerate(host_leaves)})
                f.flush()
                os.fsync(f.fileno())
            digest = hashlib.sha256((tmp / "data.npz").read_bytes()).hexdigest()
            manifest["arrays"] = {f"a{i}": {"name": n, "shape": list(a.shape),
                                            "dtype": str(a.dtype)}
                                  for i, (n, a) in enumerate(zip(names,
                                                                 host_leaves))}
            manifest["sha256"] = digest
            (tmp / "MANIFEST.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)          # atomic publish
            self._gc()

        with self._lock:
            if self._pending is not None:
                self._pending.join()       # one in flight at a time
            t = threading.Thread(target=_write, daemon=True)
            t.start()
            self._pending = t
        if blocking:
            self.wait()

    def wait(self):
        with self._lock:
            if self._pending is not None:
                self._pending.join()
                self._pending = None

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -- load ---------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "MANIFEST.json").exists():
                continue
            try:
                man = json.loads((p / "MANIFEST.json").read_text())
                out.append(int(man["step"]))
            except Exception:
                continue
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int, like, *, shardings=None):
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: optional matching pytree of
        shardings to place (reshard) the arrays onto — THE elastic-restart
        hook: the saved ckpt knows nothing about the old mesh."""
        path = self.dir / f"step_{step:08d}"
        man = json.loads((path / "MANIFEST.json").read_text())
        blob = (path / "data.npz").read_bytes()
        if hashlib.sha256(blob).hexdigest() != man["sha256"]:
            raise IOError(f"checksum mismatch in {path}")
        data = np.load(path / "data.npz")
        names, leaves, treedef = _flatten_with_names(like)
        by_name = {v["name"]: k for k, v in man["arrays"].items()}
        out = []
        for n, leaf in zip(names, leaves):
            arr = data[by_name[n]]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"{n}: ckpt shape {arr.shape} != {leaf.shape}")
            out.append(arr.astype(leaf.dtype))
        restored = jax.tree_util.tree_unflatten(treedef, out)
        if shardings is not None:
            restored = jax.tree_util.tree_map(
                lambda a, s: jax.device_put(a, s), restored, shardings)
        return restored
