"""Pallas TPU chunkwise mLSTM (xLSTM matrix-memory cell).

TARGET: TPU.  Grid = (batch*heads, n_chunks) with
``dimension_semantics=("parallel", "arbitrary")``: the chunk axis is
sequential and the recurrent state (C: dk x dv matrix memory, n: dk
normalizer, m: scalar stabilizer) lives in VMEM scratch carried across
chunk steps — the HBM<->VMEM traffic per chunk is just the (C, d) q/k/v
tiles, and the state never leaves VMEM (the TPU-native answer to the
paper-adjacent GPU recurrence kernels: block the *time* axis, persist the
state in on-chip memory).

Semantics are exactly :func:`repro.models.xlstm.mlstm_sequential`
(stabilized exponential gating); equivalence is asserted in
tests/test_kernels.py over shape sweeps.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro._compat.jaxapi import tpu_compiler_params

_CompilerParams = tpu_compiler_params()


def _kernel(q_ref, k_ref, v_ref, li_ref, lf_ref, h_ref,
            c_scr, n_scr, m_scr, *, chunk: int, dk: int, dv: int,
            scale: float):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        c_scr[...] = jnp.zeros_like(c_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        m_scr[...] = jnp.full_like(m_scr, -1e30)

    q = q_ref[0].astype(jnp.float32) * scale              # (C, dk)
    k = k_ref[0].astype(jnp.float32)                      # (C, dk)
    v = v_ref[0].astype(jnp.float32)                      # (C, dv)
    li = li_ref[0].astype(jnp.float32)                    # (C,)
    lf = lf_ref[0].astype(jnp.float32)

    bcum = jnp.cumsum(lf)                                 # inclusive
    btot = bcum[-1]
    m0 = m_scr[0, 0]

    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = cols <= rows

    e = bcum[:, None] - bcum[None, :] + li[None, :]       # (C, C)
    e = jnp.where(tri, e, -1e30)
    g = bcum + m0                                          # (C,)
    m_row = jnp.maximum(jnp.max(e, axis=1), g)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    p = s * jnp.exp(e - m_row[:, None])
    p = jnp.where(tri, p, 0.0)
    c_in = jnp.exp(g - m_row)                              # (C,)
    num = (jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
           + c_in[:, None] * jax.lax.dot_general(
               q, c_scr[...], (((1,), (0,)), ((), ())),
               preferred_element_type=jnp.float32))
    dot = (p.sum(axis=1)
           + c_in * jax.lax.dot_general(
               q, n_scr[...], (((1,), (0,)), ((), ())),
               preferred_element_type=jnp.float32)[:, 0])
    den = jnp.maximum(jnp.abs(dot), jnp.exp(-m_row))[:, None]
    h_ref[0] = (num / den).astype(h_ref.dtype)

    # ---- chunk-end state update -----------------------------------------
    m_new = jnp.maximum(btot + m0, jnp.max(btot - bcum + li))
    w = jnp.exp(btot - bcum + li - m_new)                  # (C,)
    c_scr[...] = (jnp.exp(btot + m0 - m_new) * c_scr[...]
                  + jax.lax.dot_general(k * w[:, None], v,
                                        (((0,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32))
    n_scr[...] = (jnp.exp(btot + m0 - m_new) * n_scr[...]
                  + jnp.sum(k * w[:, None], axis=0)[:, None])
    m_scr[...] = jnp.full_like(m_scr, m_new)


def mlstm_scan(q, k, v, log_i, log_f, *, chunk: int = 256,
               interpret: bool = True):
    """q/k/v: (B, T, H, D); log_i/log_f: (B, T, H) -> h: (B, T, H, D).

    T must be a multiple of ``chunk`` (pad upstream).  State starts at
    zero (use the pure-JAX path for cross-call state carry).
    """
    b, t, h, d = q.shape
    if t % chunk:
        raise ValueError(f"T={t} must be a multiple of chunk={chunk}")
    nc = t // chunk

    def flat(x):
        return jnp.moveaxis(x, 2, 1).reshape(b * h, t, *x.shape[3:])

    qf, kf, vf = flat(q), flat(k), flat(v)
    lif = jnp.moveaxis(log_i, 2, 1).reshape(b * h, t)
    lff = jnp.moveaxis(log_f, 2, 1).reshape(b * h, t)

    kernel = functools.partial(_kernel, chunk=chunk, dk=d, dv=d,
                               scale=1.0 / np.sqrt(d))
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, d), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, d), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, d), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((1, chunk), lambda bh, ci: (bh, ci)),
        ],
        out_specs=pl.BlockSpec((1, chunk, d), lambda bh, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, t, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((d, d), jnp.float32),
            pltpu.VMEM((d, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf, lif, lff)
    return jnp.moveaxis(out.reshape(b, h, t, d), 1, 2)
