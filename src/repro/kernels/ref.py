"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels must reproduce (tests sweep shapes
and dtypes and assert allclose).  They are deliberately written in the
most obvious O(T*S)-memory way.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


def reference_attention(q, k, v, *, q_pos=None, kv_pos=None,
                        causal: bool = True, window: int = 0):
    """q: (B,T,H,D); k/v: (B,S,KV,D) -> (B,T,H,D).  fp32 softmax."""
    b, t, h, d = q.shape
    s, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    if q_pos is None:
        q_pos = jnp.arange(t, dtype=jnp.int32)
    if kv_pos is None:
        kv_pos = jnp.arange(s, dtype=jnp.int32)
    qg = q.reshape(b, t, kvh, g, d).astype(jnp.float32) / np.sqrt(d)
    logits = jnp.einsum("btkgd,bskd->bkgts", qg, k.astype(jnp.float32))
    ok = (kv_pos[None, :] >= 0)
    if causal:
        ok = ok & (kv_pos[None, :] <= q_pos[:, None])
    if window > 0:
        ok = ok & ((q_pos[:, None] - kv_pos[None, :]) < window)
    logits = jnp.where(ok[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    # fully-masked rows -> zeros (matches kernel's guarded 1/l)
    any_ok = ok.any(axis=-1)[None, None, None, :, None]
    o = jnp.einsum("bkgts,bskd->btkgd", w, v.astype(jnp.float32))
    o = jnp.where(jnp.moveaxis(any_ok, 3, 1)[..., 0][..., None, None]
                  if False else o == o, o, o)  # no-op; kept for clarity
    mask_rows = ok.any(axis=-1)                     # (t,)
    o = o * mask_rows[None, :, None, None, None]
    return o.reshape(b, t, h, d).astype(q.dtype)


def reference_mlstm(q, k, v, log_i, log_f, state=None):
    """Sequential stabilized mLSTM — re-export of the model-side oracle."""
    from repro.models.xlstm import mlstm_sequential
    return mlstm_sequential(q, k, v, log_i, log_f, state)
