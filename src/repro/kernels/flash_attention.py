"""Pallas TPU flash attention (causal / sliding-window GQA).

TARGET: TPU MXU/VMEM.  Grid = (batch*q_heads, T/block_q, S/block_k) with
``dimension_semantics=("parallel", "parallel", "arbitrary")``: the KV axis
is the innermost sequential dimension, and the running (max, sum, acc)
online-softmax state lives in VMEM scratch that persists across KV steps —
the classic FlashAttention-2 schedule adapted to the TPU memory hierarchy
(HBM -> VMEM block DMA via BlockSpec, fp32 accumulation in VREGs, MXU
matmuls on (block_q x d) x (d x block_k) tiles with d padded to 128).

Numerics contract (must match ``ref.reference_attention``):
* logits scaled by 1/sqrt(d), fp32 softmax, output cast back to q.dtype;
* causal masking by absolute positions (q_pos, kv_pos);
* optional sliding window: key visible iff 0 <= q_pos - kv_pos < window;
* fully-masked rows produce zeros (guarded 1/l).

Validated on CPU with ``interpret=True`` (the kernel body executes in
Python) across the shape/dtype sweep in tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro._compat.jaxapi import tpu_compiler_params

_CompilerParams = tpu_compiler_params()

NEG_INF = -1e30


def _kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale: float, causal: bool,
            window: int, block_q: int, block_k: int, n_k: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale            # (bq, d)
    k = k_ref[0].astype(jnp.float32)                    # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)

    qp = qpos_ref[...][:, None]                          # (bq, 1)
    kp = kpos_ref[...][None, :]                          # (1, bk)
    ok = kp >= 0                                         # padded kv slots < 0
    if causal:
        ok &= kp <= qp
    if window > 0:
        ok &= (qp - kp) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]                                  # (bq, 1)
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                               # (bq, bk)
    p = jnp.where(ok, p, 0.0)  # fully-masked rows: m_new == NEG_INF would
    #                            make exp(s - m_new) == 1, not 0 — mask again.
    l_new = l_prev * alpha + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = l_scr[...]
        o = acc_scr[...] / jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = o.astype(o_ref.dtype)


def flash_attention(q, k, v, *, q_pos=None, kv_pos=None, causal: bool = True,
                    window: int = 0, block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q: (B, T, H, D); k/v: (B, S, KV, D).  Returns (B, T, H, D).

    ``interpret=True`` by default in this repo: the container is CPU-only
    and Pallas TPU kernels only *execute* on TPU; interpret mode runs the
    identical kernel body for validation.
    """
    b, t, h, d = q.shape
    s, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    if q_pos is None:
        q_pos = jnp.arange(t, dtype=jnp.int32)
    if kv_pos is None:
        kv_pos = jnp.arange(s, dtype=jnp.int32)

    block_q = min(block_q, max(t, 8))
    block_k = min(block_k, max(s, 8))
    pad_t = (-t) % block_q
    pad_s = (-s) % block_k
    qq = jnp.moveaxis(q, 2, 1).reshape(b * h, t, d)       # (BH, T, D)
    kk = jnp.moveaxis(k, 2, 1).reshape(b * kvh, s, d)
    vv = jnp.moveaxis(v, 2, 1).reshape(b * kvh, s, d)
    if pad_t:
        qq = jnp.pad(qq, ((0, 0), (0, pad_t), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad_t))
    if pad_s:
        kk = jnp.pad(kk, ((0, 0), (0, pad_s), (0, 0)))
        vv = jnp.pad(vv, ((0, 0), (0, pad_s), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pad_s), constant_values=-1)
    tp, sp = t + pad_t, s + pad_s
    n_q, n_k = tp // block_q, sp // block_k
    grid = (b * h, n_q, n_k)

    kernel = functools.partial(
        _kernel, scale=1.0 / np.sqrt(d), causal=causal, window=int(window),
        block_q=block_q, block_k=block_k, n_k=n_k)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q,), lambda bh, qi, ki: (qi,)),
            pl.BlockSpec((block_k,), lambda bh, qi, ki: (ki,)),
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, qi, ki, g=g, kvh=kvh:
                         ((bh // (g * kvh)) * kvh + (bh % (g * kvh)) // g,
                          ki, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, qi, ki, g=g, kvh=kvh:
                         ((bh // (g * kvh)) * kvh + (bh % (g * kvh)) // g,
                          ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, tp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q_pos.astype(jnp.int32), kv_pos.astype(jnp.int32), qq, kk, vv)

    out = out[:, :t].reshape(b, h, t, d)
    return jnp.moveaxis(out, 1, 2)
