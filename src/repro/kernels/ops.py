"""jit'd public wrappers around the Pallas kernels.

On this CPU-only container kernels run in ``interpret=True`` mode (the
kernel body executes in Python); on TPU set ``interpret=False`` (the
default flips via the REPRO_PALLAS_INTERPRET env var).
"""
from __future__ import annotations

import os
from functools import partial

import jax

from .flash_attention import flash_attention as _flash_attention

_INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "1"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q, k, v, *, q_pos=None, kv_pos=None, causal: bool = True,
                    window: int = 0, block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    if interpret is None:
        interpret = _INTERPRET
    return _flash_attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                            causal=causal, window=window, block_q=block_q,
                            block_k=block_k, interpret=interpret)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_scan(q, k, v, log_i, log_f, *, chunk: int = 256,
               interpret: bool | None = None):
    from .mlstm_scan import mlstm_scan as _mlstm
    if interpret is None:
        interpret = _INTERPRET
    return _mlstm(q, k, v, log_i, log_f, chunk=chunk, interpret=interpret)
