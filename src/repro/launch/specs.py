"""``input_specs()``: ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, no device allocation.  Used by the dry-run
and by the roofline benchmarks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, ShapeConfig
from repro.models.transformer import init_caches


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    text = s
    out = {}
    if cfg.num_patch_tokens:
        text = s - cfg.num_patch_tokens
        out["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.num_patch_tokens, cfg.d_model), jnp.float32)
    if cfg.num_meta_tokens:
        text = text - cfg.num_meta_tokens
    if cfg.is_encdec:
        out["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq_len, cfg.d_model), jnp.float32)
    out["tokens"] = jax.ShapeDtypeStruct((b, text), jnp.int32)
    out["labels"] = jax.ShapeDtypeStruct((b, text), jnp.int32)
    return out


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    out = train_input_specs(cfg, shape)
    out.pop("labels")
    return out


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """One new token against a KV cache of ``shape.seq_len``."""
    b, s = shape.global_batch, shape.seq_len
    caches = jax.eval_shape(lambda: init_caches(cfg, b, s))
    out = {
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
        "caches": caches,
    }
    if cfg.is_encdec:
        out["cross_src"] = None  # cross K/V live in the caches
    return out


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    if shape.kind == "train":
        return train_input_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_input_specs(cfg, shape)
    if shape.kind == "decode":
        return decode_input_specs(cfg, shape)
    raise ValueError(shape.kind)
