import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimb driver: re-lowers the three selected cells with one
optimization at a time and records before/after roofline terms.

    PYTHONPATH=src python -m repro.launch.hillclimb [--cell qwen|xlstm|gemma]

Results land in results/hillclimb/*.json; EXPERIMENTS.md §Perf narrates
them.
"""
import argparse
from pathlib import Path

from repro.launch.dryrun import run_cell

OUT = Path("results/hillclimb")


def climb_qwen():
    """qwen3-moe train_4k 16x16 — the paper-representative EP cell."""
    # baseline (paper-faithful; re-measured with the corrected analytics)
    run_cell("qwen3-moe-30b-a3b", "train_4k", False, OUT, tag="base")
    # it1: skip above-diagonal KV blocks in causal attention
    run_cell("qwen3-moe-30b-a3b", "train_4k", False, OUT, tag="it1_diag",
             extra_cfg={"attn_skip_diagonal": True})
    # it2: + relax remat full -> dots (4x -> 3x fwd FLOPs, more live acts)
    run_cell("qwen3-moe-30b-a3b", "train_4k", False, OUT, tag="it2_remat",
             extra_cfg={"attn_skip_diagonal": True, "remat": "dots"})
    # it3: + capacity factor 1.25 -> 1.0 (EP dispatch waste)
    run_cell("qwen3-moe-30b-a3b", "train_4k", False, OUT, tag="it3_cf1",
             extra_cfg={"attn_skip_diagonal": True, "remat": "dots",
                        "capacity_factor": 1.0})


def climb_xlstm():
    """xlstm-350m train_4k on 512 chips — most collective-bound cell."""
    run_cell("xlstm-350m", "train_4k", True, OUT, tag="base")
    # it1: re-label the 512-chip fabric (2,64,4): TP = 4 mLSTM heads
    # (inner shards align with head boundaries -> no state gathers),
    # DP widens 32 -> 128 (activation all-reduce shrinks 4x).
    run_cell("xlstm-350m", "train_4k", True, OUT, tag="it1_mesh2x64x4",
             mesh_shape=(2, 64, 4), mesh_axes=("pod", "data", "model"))
    # it2: pure-DP relabel (2,256,1): no TP at all; params replicated,
    # only gradient reduction remains.  batch 256 over 512 chips does NOT
    # divide -> expected to fail or pad; measured for the record.
    run_cell("xlstm-350m", "train_4k", True, OUT, tag="it2_mesh2x128x2",
             mesh_shape=(2, 128, 2), mesh_axes=("pod", "data", "model"))


def climb_gemma():
    """gemma3-1b prefill_32k 16x16 — worst winnable roofline fraction."""
    run_cell("gemma3-1b", "prefill_32k", False, OUT, tag="base")
    # it1: diagonal skipping only (global layers halve)
    run_cell("gemma3-1b", "prefill_32k", False, OUT, tag="it1_diag",
             extra_cfg={"attn_skip_diagonal": True})
    # it2: + window banding (22 local layers: 32k -> ~1.5k effective keys);
    # splits the stack into uniform-window runs (static bands)
    run_cell("gemma3-1b", "prefill_32k", False, OUT, tag="it2_banded",
             extra_cfg={"attn_skip_diagonal": True, "attn_banded": True})


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=["qwen", "xlstm", "gemma", "all"],
                    default="all")
    args = ap.parse_args()
    if args.cell in ("qwen", "all"):
        climb_qwen()
    if args.cell in ("xlstm", "all"):
        climb_xlstm()
    if args.cell in ("gemma", "all"):
        climb_gemma()


if __name__ == "__main__":
    main()
