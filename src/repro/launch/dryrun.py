import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count on first init, and the multi-pod dry-run needs 512 host devices to
# build the production mesh.  (Only the dry-run does this; tests and
# benches see the real single CPU device.)

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes and extract the roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Outputs one JSON per cell under --out (default results/dryrun/), consumed
by benchmarks/roofline.py and EXPERIMENTS.md.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import SHAPES, cell_is_applicable, get_config, list_archs
from repro.models.transformer import init_caches
from repro.launch.mesh import describe_mesh, make_production_mesh
from repro.launch.specs import input_specs
from repro.launch import analytic
from repro.launch.hlo_analysis import collective_stats, roofline
from repro.optim import OptConfig
from repro.runtime.sharding import (cache_specs, state_specs,
                                    train_batch_specs)
from repro.runtime.trainer import (init_train_state, make_rules,
                                   make_serve_steps, make_train_step,
                                   suggest_grad_accum)

ASSIGNED_ARCHS = ["xlstm-350m", "hymba-1.5b", "nemotron-4-15b",
                  "starcoder2-3b", "llama3.2-3b", "gemma3-1b",
                  "internvl2-26b", "qwen3-moe-30b-a3b",
                  "granite-moe-3b-a800m", "whisper-base"]
ASSIGNED_SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, mesh, *, extra_cfg: dict | None = None):
    """Lower + compile one cell; returns (lowered, compiled, meta)."""
    cfg = get_config(arch)
    if extra_cfg:
        import dataclasses
        cfg = dataclasses.replace(cfg, **extra_cfg)
    shape = SHAPES[shape_name]
    rules = make_rules(mesh)
    chips = int(jax.tree_util.tree_reduce(
        lambda a, b: a * b, list(mesh.shape.values()), 1))
    meta = {"arch": arch, "shape": shape_name, "mesh": dict(mesh.shape),
            "chips": chips}
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        ga = suggest_grad_accum(cfg, shape.global_batch, shape.seq_len,
                                rules.dp_size)
        meta["grad_accum"] = ga
        state_shapes = jax.eval_shape(
            lambda: init_train_state(jax.random.PRNGKey(0), cfg))
        sspecs = _named(mesh, state_specs(state_shapes["params"], cfg, rules))
        bspecs = _named(mesh, train_batch_specs(cfg, rules))
        bspecs = {k: bspecs[k] for k in specs}  # align key sets
        from repro.runtime.sharding import grad_accum_specs
        gspecs = grad_accum_specs(state_shapes["params"], cfg, rules)
        step = make_train_step(cfg, rules, OptConfig(), grad_accum=ga,
                               grad_specs=gspecs)
        jfn = jax.jit(step, in_shardings=(sspecs, bspecs),
                      out_shardings=(sspecs, None), donate_argnums=(0,))
        lowered = jfn.lower(state_shapes, specs)
    elif shape.kind == "prefill":
        params_shapes = jax.eval_shape(
            lambda: __import__("repro.models.transformer",
                               fromlist=["init_params"]).init_params(
                                   jax.random.PRNGKey(0), cfg))
        from repro.runtime.sharding import param_specs
        pspecs = _named(mesh, param_specs(params_shapes, cfg, rules))
        bspecs = _named(mesh, {k: v for k, v in
                               train_batch_specs(cfg, rules).items()
                               if k in specs})
        cspecs = _named(mesh, cache_specs(cfg, rules, shape.global_batch, shape.seq_len))
        prefill_fn, _ = make_serve_steps(cfg, rules, shape.seq_len)
        jfn = jax.jit(prefill_fn, in_shardings=(pspecs, bspecs),
                      out_shardings=(None, cspecs))
        lowered = jfn.lower(params_shapes, specs)
    else:  # decode
        from repro.models.transformer import init_params
        from repro.runtime.sharding import param_specs
        params_shapes = jax.eval_shape(
            lambda: init_params(jax.random.PRNGKey(0), cfg))
        pspecs = _named(mesh, param_specs(params_shapes, cfg, rules))
        cspecs = _named(mesh, cache_specs(cfg, rules, shape.global_batch, shape.seq_len))
        dp = rules.dp if shape.global_batch >= rules.dp_size else None
        tok_spec = NamedSharding(mesh, P(dp, None))
        pos_spec = NamedSharding(mesh, P())
        _, decode_fn = make_serve_steps(cfg, rules, shape.seq_len)
        jfn = jax.jit(decode_fn,
                      in_shardings=(pspecs, tok_spec, cspecs, pos_spec),
                      out_shardings=(None, cspecs), donate_argnums=(2,))
        lowered = jfn.lower(params_shapes, specs["tokens"], specs["caches"],
                            specs["pos"])
    return cfg, shape, lowered, meta


def analyse(cfg, shape, compiled, meta, *, analytic_kw=None) -> dict:
    chips = meta["chips"]
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = collective_stats(hlo, default_group=16)
    cost = analytic.cell_cost(cfg, shape, chips, **(analytic_kw or {}))
    rt = roofline(
        exec_flops_per_dev=cost.exec_flops_total / chips,
        hbm_bytes_per_dev=cost.hbm_bytes_per_dev,
        wire_bytes_per_dev=coll.total_wire_bytes,
        chips=chips,
        model_flops_total=cost.model_flops_total,
        cost_flops=float(ca.get("flops", 0.0)),
        cost_bytes=float(ca.get("bytes accessed", 0.0)))
    mem = {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "peak_estimate_bytes": (ma.argument_size_in_bytes
                                + ma.output_size_in_bytes
                                + ma.temp_size_in_bytes
                                - ma.alias_size_in_bytes),
    }
    out = {**meta,
           "ok": True,
           "memory": mem,
           "fits_16gb_hbm": mem["peak_estimate_bytes"] < 16e9,
           "collectives": {
               "counts": coll.counts,
               "raw_gbytes": {k: v / 1e9 for k, v in coll.raw_bytes.items()},
               "wire_gbytes": {k: v / 1e9 for k, v in coll.wire_bytes.items()},
               "total_wire_gbytes_per_dev": coll.total_wire_bytes / 1e9,
           },
           "analytic_notes": cost.notes,
           "roofline": rt.as_dict()}
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             *, extra_cfg=None, analytic_kw=None, tag: str = "",
             mesh_shape=None, mesh_axes=None) -> dict:
    """``mesh_shape``/``mesh_axes``: override the logical mesh (same chips,
    re-labeled axes — a sharding-scheme decision; the physical HyperX
    fabric is unchanged, per §5 multi-digit XOR DOR)."""
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    if mesh_shape:
        mesh_name = "x".join(str(s) for s in mesh_shape)
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    ok, reason = cell_is_applicable(arch, shape_name)
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "ok": False, "skipped": True, "reason": reason}
        _write(out_dir, cell_id, rec)
        print(f"[skip] {cell_id}: {reason}")
        return rec
    t0 = time.time()
    try:
        if mesh_shape:
            from repro._compat.jaxapi import make_auto_mesh
            mesh = make_auto_mesh(tuple(mesh_shape), tuple(mesh_axes))
        else:
            mesh = make_production_mesh(multi_pod=multi_pod)
        cfg, shape, lowered, meta = lower_cell(arch, shape_name, mesh,
                                               extra_cfg=extra_cfg)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        rec = analyse(cfg, shape, compiled, meta, analytic_kw=analytic_kw)
        rec["lower_s"] = round(t_lower, 1)
        rec["compile_s"] = round(t_compile, 1)
        _write(out_dir, cell_id, rec)
        r = rec["roofline"]
        print(f"[ok]   {cell_id}: compile={t_compile:.0f}s "
              f"dominant={r['dominant']} "
              f"compute={r['compute_s']*1e3:.2f}ms "
              f"memory={r['memory_s']*1e3:.2f}ms "
              f"collective={r['collective_s']*1e3:.2f}ms "
              f"peak={rec['memory']['peak_estimate_bytes']/1e9:.2f}GB")
        return rec
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "ok": False, "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
        _write(out_dir, cell_id, rec)
        print(f"[FAIL] {cell_id}: {type(e).__name__}: {str(e)[:300]}")
        return rec


def _write(out_dir: Path, cell_id: str, rec: dict):
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{cell_id}.json").write_text(json.dumps(rec, indent=1,
                                                        default=str))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()
    out_dir = Path(args.out)

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    if args.all:
        cells = [(a, s) for a in ASSIGNED_ARCHS for s in ASSIGNED_SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    n_fail = 0
    for multi in meshes:
        for arch, shape in cells:
            rec = run_cell(arch, shape, multi, out_dir)
            if not rec.get("ok") and not rec.get("skipped"):
                n_fail += 1
    print(f"done; failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
