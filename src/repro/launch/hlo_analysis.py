"""Roofline-term extraction from compiled XLA artifacts.

Two measurement channels, cross-checked in EXPERIMENTS.md:

1. **HLO channel** (this module): parse the post-SPMD optimized HLO.
   Shapes in that module are PER-DEVICE.  ``compiled.cost_analysis()``
   counts every computation ONCE (while-loop bodies are not multiplied by
   trip count) — so we reconstruct loop-scaled totals ourselves by walking
   the computation call graph with the ``known_trip_count`` annotations XLA
   leaves in ``backend_config``.  Collective bytes are converted to
   *semantics-adjusted wire bytes per device*:

   ================== ===========================================
   op                  wire bytes per device (group size N)
   ================== ===========================================
   all-reduce          2 (N-1)/N * size
   all-gather          (N-1)/N * out_size
   reduce-scatter      (N-1)   * out_size   (= (N-1)/N * in_size)
   all-to-all          (N-1)/N * size
   collective-permute  size
   ================== ===========================================

2. **Analytic channel** (:mod:`repro.launch.analytic`): exact matmul FLOPs
   and first-order HBM traffic from the model formulas.

Hardware constants (TPU v5e-class target): 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")
_COLL_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_CALLED_RE = re.compile(r"(?:to_apply|body|condition|branch_computations)="
                        r"\{?%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)\\?"\}')
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_PAIRS_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)*)\}")
_PAIR_RE = re.compile(r"\{(\d+),(\d+)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _result_shape_bytes(line: str) -> int:
    m = re.search(r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\]))", line)
    return _shape_bytes(m.group(1)) if m else 0


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return default


# ---------------------------------------------------------------------------
# Module parsing: computations, call edges (with trip multipliers).
# ---------------------------------------------------------------------------

@dataclass
class _Comp:
    name: str
    lines: list = field(default_factory=list)
    calls: list = field(default_factory=list)   # (callee, multiplier)


def parse_module(hlo: str) -> tuple[dict[str, _Comp], str]:
    comps: dict[str, _Comp] = {}
    entry = None
    cur: _Comp | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        # Computation headers are `[ENTRY] %name (params) -> shape {`.
        # The params list nests parentheses for tuple-typed args (while
        # bodies take one tuple arg), so the name is matched from the
        # line start and the params are not regex-consumed at all.
        head = (re.match(r"^(ENTRY\s+)?%?([\w.\-]+)", s)
                if s.endswith("{") and "->" in s else None)
        if head and not s.startswith(("ROOT", "//")) and "= " not in s:
            cur = _Comp(head.group(2))
            comps[cur.name] = cur
            if head.group(1):
                entry = cur.name
            continue
        if s == "}":
            cur = None
            continue
        if cur is None:
            continue
        cur.lines.append(s)
        if "while(" in s:
            trip = _TRIP_RE.search(s)
            mult = int(trip.group(1)) if trip else 1
            for kind in ("body", "condition"):
                m = re.search(rf"{kind}=%?([\w.\-]+)", s)
                if m:
                    cur.calls.append((m.group(1), mult if kind == "body" else 1))
        else:
            for m in re.finditer(r"(?:to_apply|called_computations=\{)%?([\w.\-]+)", s):
                cur.calls.append((m.group(1), 1))
            m = re.search(r"conditional\(", s)
            if m:
                for b in re.findall(r"branch_computations=\{([^}]*)\}", s):
                    for name in re.findall(r"%?([\w.\-]+)", b):
                        cur.calls.append((name, 1))
    if entry is None and comps:
        entry = next(iter(comps))
    return comps, entry


@dataclass
class CollectiveStats:
    counts: dict = field(default_factory=dict)
    raw_bytes: dict = field(default_factory=dict)
    wire_bytes: dict = field(default_factory=dict)
    total_wire_bytes: float = 0.0
    total_raw_bytes: float = 0.0

    def add(self, op: str, raw: float, wire: float, count: float = 1):
        self.counts[op] = self.counts.get(op, 0) + count
        self.raw_bytes[op] = self.raw_bytes.get(op, 0) + raw
        self.wire_bytes[op] = self.wire_bytes.get(op, 0) + wire
        self.total_raw_bytes += raw
        self.total_wire_bytes += wire


def collective_stats(hlo_text: str, default_group: int) -> CollectiveStats:
    """Loop-scaled, semantics-adjusted collective wire bytes per device."""
    comps, entry = parse_module(hlo_text)
    # compute multiplier per computation by DFS from entry
    mult: dict[str, float] = {}

    def visit(name: str, m: float):
        if name not in comps:
            return
        mult[name] = mult.get(name, 0.0) + m
        for callee, k in comps[name].calls:
            visit(callee, m * k)

    if entry:
        visit(entry, 1.0)

    stats = CollectiveStats()
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        for line in comp.lines:
            cm = _COLL_RE.search(line)
            if not cm or f"{cm.group(1)}-done(" in line:
                continue
            op = cm.group(1)
            raw = _result_shape_bytes(line)
            n = _group_size(line, default_group)
            if op == "all-reduce":
                wire = 2 * (n - 1) / max(n, 1) * raw
            elif op == "all-gather":
                wire = (n - 1) / max(n, 1) * raw
            elif op == "reduce-scatter":
                wire = (n - 1) * raw
            elif op == "all-to-all":
                wire = (n - 1) / max(n, 1) * raw
            else:
                wire = raw
            stats.add(op, raw * m, wire * m, count=m)
    return stats


@dataclass(frozen=True)
class CollectiveOp:
    """One collective in program order (see :func:`collective_sequence`).

    ``raw_bytes`` is the per-device result-shape size; ``count`` is the
    loop-trip multiplier (an op inside a ``known_trip_count=k`` while
    body appears once with ``count=k``); ``pairs`` holds a
    collective-permute's ``source_target_pairs`` (empty otherwise).
    """
    kind: str
    raw_bytes: int
    group_size: int
    count: int = 1
    pairs: tuple = ()


def collective_sequence(hlo_text: str, default_group: int
                        ) -> list[CollectiveOp]:
    """The module's collectives in program order, loop bodies expanded
    by multiplier rather than unrolled.

    Where :func:`collective_stats` aggregates per-op totals, this keeps
    the *sequence* — the input :mod:`repro.workload` lowers into phased
    :class:`~repro.sim.workloads.Workload`\\ s.  Each emitted op carries
    its trip-count multiplier; consecutive execution order within a
    computation follows line order, and calls (``while`` bodies,
    ``to_apply`` targets that are not the collective's own reducer)
    expand in place.
    """
    comps, entry = parse_module(hlo_text)
    out: list[CollectiveOp] = []

    def walk(name: str, m: int, stack: frozenset):
        if name not in comps or name in stack:
            return
        inner = stack | {name}
        for line in comps[name].lines:
            cm = _COLL_RE.search(line)
            if cm and f"{cm.group(1)}-done(" not in line:
                pm = _PAIRS_RE.search(line)
                pairs = (tuple((int(a), int(b))
                               for a, b in _PAIR_RE.findall(pm.group(1)))
                         if pm else ())
                out.append(CollectiveOp(
                    kind=cm.group(1), raw_bytes=_result_shape_bytes(line),
                    group_size=_group_size(line, default_group),
                    count=int(m), pairs=pairs))
                continue                # don't descend into the reducer
            if "while(" in line:
                trip = _TRIP_RE.search(line)
                mult = int(trip.group(1)) if trip else 1
                bm = re.search(r"body=%?([\w.\-]+)", line)
                if bm:
                    walk(bm.group(1), m * mult, inner)
            else:
                for mm in re.finditer(
                        r"(?:to_apply|called_computations=\{)%?([\w.\-]+)",
                        line):
                    walk(mm.group(1), m, inner)

    if entry:
        walk(entry, 1, frozenset())
    return out


# ---------------------------------------------------------------------------
# Roofline terms.
# ---------------------------------------------------------------------------

@dataclass
class RooflineTerms:
    """All *_s terms are seconds per step, per device."""
    exec_gflops_per_dev: float
    hbm_gbytes_per_dev: float
    wire_gbytes_per_dev: float
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_gflops_total: float
    useful_ratio: float
    cost_analysis_flops: float    # raw, per-device, loop-body-once (caveat)
    cost_analysis_bytes: float

    def as_dict(self) -> dict:
        return dict(self.__dict__)


def roofline(*, exec_flops_per_dev: float, hbm_bytes_per_dev: float,
             wire_bytes_per_dev: float, chips: int, model_flops_total: float,
             cost_flops: float = 0.0, cost_bytes: float = 0.0,
             links_per_chip: int = 1) -> RooflineTerms:
    compute_s = exec_flops_per_dev / PEAK_FLOPS
    memory_s = hbm_bytes_per_dev / HBM_BW
    collective_s = wire_bytes_per_dev / (ICI_BW * links_per_chip)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    total_exec = exec_flops_per_dev * chips
    useful = model_flops_total / total_exec if total_exec else 0.0
    return RooflineTerms(
        exec_gflops_per_dev=exec_flops_per_dev / 1e9,
        hbm_gbytes_per_dev=hbm_bytes_per_dev / 1e9,
        wire_gbytes_per_dev=wire_bytes_per_dev / 1e9,
        chips=chips, compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, dominant=dominant,
        model_gflops_total=model_flops_total / 1e9, useful_ratio=useful,
        cost_analysis_flops=cost_flops, cost_analysis_bytes=cost_bytes)
