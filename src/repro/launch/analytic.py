"""Analytic executed-FLOPs and HBM-traffic model for every (arch x shape).

``compiled.cost_analysis()`` on the CPU backend counts loop bodies once and
reports per-device numbers, so it cannot be used directly for module-level
FLOPs (we still record it as a cross-check).  This module derives executed
FLOPs and first-order HBM traffic from the SAME config the model code is
built from — every matmul in :mod:`repro.models` appears here, including
the deliberate inefficiencies of the baseline (full-rectangle causal
attention in the chunked path, capacity-factor padding in MoE dispatch),
so the optimization loop can watch them fall.

Conventions:
* matmul (m, k) @ (k, n) = 2 m k n FLOPs;
* backward = 2x forward matmul FLOPs; ``remat='full'`` adds one forward
  recompute (total 4x fwd for train);
* MODEL_FLOPS (the "useful" yardstick) = 6 N D for training and 2 N D for
  single-token decode, N = active params (sans embeddings), D = tokens.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.models.config import (ATTN, ATTN_CROSS, HYMBA, MLSTM, SLSTM,
                                 ModelConfig, ShapeConfig)


@dataclass(frozen=True)
class CellCost:
    exec_flops_total: float      # executed FLOPs, whole step, all devices
    model_flops_total: float     # 6*N*D (train) / 2*N*D (decode)
    hbm_bytes_per_dev: float     # first-order HBM traffic per device
    notes: str = ""


# ---------------------------------------------------------------------------
# Per-layer forward FLOPs per token.
# ---------------------------------------------------------------------------

def _attn_proj_flops(cfg) -> float:
    d, h, kv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return 2 * d * dh * (h + 2 * kv) + 2 * h * dh * d


def _attn_score_flops(cfg, s_eff: float) -> float:
    """QK^T + PV per token against s_eff keys."""
    return 2 * 2 * cfg.num_heads * cfg.head_dim * s_eff


def _mlp_flops(cfg, d_ff=None) -> float:
    f = cfg.d_ff if d_ff is None else d_ff
    n_mats = 3 if cfg.mlp in ("swiglu", "geglu") else 2
    return 2 * n_mats * cfg.d_model * f


def _moe_flops(cfg) -> float:
    """Executed expert FLOPs per token: top_k paths inflated by the
    capacity factor and expert-dim padding (empty padded buckets)."""
    n_mats = 3 if cfg.mlp in ("swiglu", "geglu") else 2
    e_pad = -(-cfg.num_experts // 16) * 16  # 16-way EP in production
    waste = cfg.capacity_factor * (e_pad / cfg.num_experts)
    router = 2 * cfg.d_model * cfg.num_experts
    expert = 2 * n_mats * cfg.d_model * cfg.d_ff * cfg.top_k
    return router + expert * waste


def _ssm_flops(cfg) -> float:
    d = cfg.d_model
    inner = cfg.ssm_expand * d
    s = cfg.ssm_state
    dtr = max(d // 16, 8)
    return (2 * d * 2 * inner              # in_proj
            + 2 * cfg.conv_kernel * inner  # conv
            + 2 * inner * (dtr + 2 * s)    # x_proj
            + 2 * dtr * inner              # dt_proj
            + 8 * inner * s                # scan update + readout
            + 2 * inner * d)               # out_proj


def _mlstm_flops(cfg, chunk: int = 256) -> float:
    d = cfg.d_model
    inner = cfg.ssm_expand * d
    h = cfg.num_heads
    dh = inner // h
    return (2 * d * 2 * inner              # up
            + 2 * cfg.conv_kernel * inner
            + 3 * 2 * inner * inner        # q, k, v
            + 2 * inner * 2 * h            # gates
            + 2 * 2 * inner * chunk        # intra-chunk scores + PV
            + 2 * 2 * inner * dh           # inter-chunk state read + update
            + 2 * inner * d)               # down


def _slstm_flops(cfg) -> float:
    d = cfg.d_model
    dh = d // cfg.num_heads
    ff = int(d * 4 / 3)
    return (2 * d * 4 * d                  # input gates
            + 2 * d * 4 * dh               # block-diag recurrence
            + 2 * 3 * d * ff)              # gated FFN


def _layer_forward_flops(cfg, kind: str, s_eff: float) -> float:
    if kind in (ATTN, ATTN_CROSS):
        fl = _attn_proj_flops(cfg) + _attn_score_flops(cfg, s_eff)
        if kind == ATTN_CROSS:
            fl += _attn_proj_flops(cfg) + _attn_score_flops(
                cfg, cfg.encoder_seq_len)
        fl += _moe_flops(cfg) if cfg.is_moe else _mlp_flops(cfg)
        return fl
    if kind == HYMBA:
        return (_attn_proj_flops(cfg) + _attn_score_flops(cfg, s_eff)
                + _ssm_flops(cfg) + _mlp_flops(cfg))
    if kind == MLSTM:
        return _mlstm_flops(cfg)
    if kind == SLSTM:
        return _slstm_flops(cfg)
    raise ValueError(kind)


def _active_params_sans_embed(cfg) -> float:
    emb = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    return cfg.active_param_count() - emb


def _s_eff(cfg, kind: str, window: int, t: int, *, mode: str = "full",
           decode_cache: int | None = None) -> float:
    """Effective keys per query.

    mode='full'  : baseline executed rectangle (no block skipping);
    mode='diag'  : diagonal skipping only -> causal average (t+1)/2;
    mode='banded': static window banding -> ~window + block granularity;
    mode='useful': the MODEL_FLOPS yardstick (min(window, causal avg)).
    """
    if decode_cache is not None:
        if window and window < decode_cache:
            return float(window)
        return float(decode_cache)
    if mode == "full":
        return float(t)
    if mode == "diag":
        return (t + 1) / 2.0
    if mode == "banded":
        if window and window < t:
            return float(window) + 512.0   # half-block granularity overhead
        return (t + 1) / 2.0
    # useful
    if window and window < t:
        return float(window)
    return (t + 1) / 2.0


# ---------------------------------------------------------------------------
# Cell-level totals.
# ---------------------------------------------------------------------------

def _exec_mode(cfg, skip_above_diagonal: bool) -> str:
    if cfg.attn_banded and cfg.sliding_window:
        return "banded"
    if skip_above_diagonal or cfg.attn_skip_diagonal:
        return "diag"
    return "full"


def train_cost(cfg: ModelConfig, shape: ShapeConfig, chips: int,
               remat: str = "full",
               skip_above_diagonal: bool = False) -> CellCost:
    b, t = shape.global_batch, shape.seq_len
    tokens = b * t
    mode = _exec_mode(cfg, skip_above_diagonal)
    fwd = 0.0
    useful_fwd = 0.0
    for kind, window in zip(cfg.block_pattern, cfg.windows):
        s_exec = _s_eff(cfg, kind, window, t, mode=mode)
        fwd += _layer_forward_flops(cfg, kind, s_exec)
        useful_fwd += _layer_forward_flops(
            cfg, kind, _s_eff(cfg, kind, window, t, mode="useful"))
    if cfg.is_encdec:
        enc_fl = cfg.encoder_layers * (
            _attn_proj_flops(cfg)
            + _attn_score_flops(cfg, cfg.encoder_seq_len)
            + _mlp_flops(cfg))
        # encoder tokens differ from decoder tokens
        fwd_enc = enc_fl * b * cfg.encoder_seq_len
    else:
        fwd_enc = 0.0
    logits = 2 * cfg.d_model * cfg.vocab_padded
    mult = 4.0 if remat == "full" else 3.0
    # logits/loss live OUTSIDE the scanned+checkpointed stack: never
    # recomputed by remat -> always 3x (fwd + 2x bwd).
    exec_total = fwd * mult * tokens + logits * 3.0 * tokens + fwd_enc * mult

    n_active = _active_params_sans_embed(cfg)
    model_total = 6.0 * n_active * tokens

    # --- HBM traffic per device (first order) ---------------------------
    # master/moments/grads are ZeRO-sharded over the whole mesh for large
    # leaves (runtime/sharding.py); the bf16 working copy is read from a
    # TP-sharded (1/16) layout on every pass (fwd, bwd, remat-recompute).
    p_total = cfg.param_count()
    opt_traffic = p_total * 28 / chips          # m r/w + v r/w + p r/w + g w
    weight_reads = (p_total * 2 / min(chips, 16)) \
        * (3 if remat == "full" else 2)
    d_bytes = 2
    acts = (cfg.num_layers * (tokens / chips) * cfg.d_model * d_bytes
            * (4 if remat == "full" else 8))
    logits_traffic = 3 * (tokens / chips) * (cfg.vocab_padded / min(chips, 16)) \
        * d_bytes * 4
    hbm = opt_traffic + weight_reads + acts + logits_traffic
    return CellCost(exec_total, model_total, hbm,
                    notes=f"mult={mult}x fwd (logits 3x); "
                          f"{'banded/diag-skip' if skip_above_diagonal else 'full-rectangle'}"
                          " attention")


def _tp_sharded(cfg) -> bool:
    return True  # all archs shard something over the model axis


def decode_cost(cfg: ModelConfig, shape: ShapeConfig, chips: int,
                swa_cache: str = "full") -> CellCost:
    b, s = shape.global_batch, shape.seq_len
    fwd = 0.0
    cache_bytes = 0.0
    d_bytes = 2
    for kind, window in zip(cfg.block_pattern, cfg.windows):
        if kind in (ATTN, ATTN_CROSS, HYMBA):
            s_att = _s_eff(cfg, kind, window, 1,
                           decode_cache=(s if (swa_cache == "full" or
                                               not window) else window))
            fwd += _layer_forward_flops(cfg, kind, s_att)
            kv_len = s if (swa_cache == "full" or not window) else window
            cache_bytes += (2 * kv_len * cfg.num_kv_heads * cfg.head_dim
                            * d_bytes)
            if kind == HYMBA:
                inner = cfg.ssm_expand * cfg.d_model
                cache_bytes += inner * cfg.ssm_state * 4
        elif kind == MLSTM:
            fwd += _mlstm_flops(cfg, chunk=1)
            inner = cfg.ssm_expand * cfg.d_model
            dh = inner // cfg.num_heads
            cache_bytes += cfg.num_heads * dh * dh * 4 * 2  # C r/w
        elif kind == SLSTM:
            fwd += _slstm_flops(cfg)
            cache_bytes += cfg.d_model * 4 * 8
    logits = 2 * cfg.d_model * cfg.vocab_size
    exec_total = (fwd + logits) * b          # one token per sequence
    n_active = _active_params_sans_embed(cfg)
    model_total = 2.0 * n_active * b
    # HBM per device: active weights once + this device's cache slice
    p_active_dev = cfg.active_param_count() / min(chips, 16)
    cache_dev = cache_bytes * b / chips
    hbm = p_active_dev * 4 + cache_dev
    return CellCost(exec_total, model_total, hbm,
                    notes=f"swa_cache={swa_cache}")


def prefill_cost(cfg: ModelConfig, shape: ShapeConfig, chips: int,
                 skip_above_diagonal: bool = False) -> CellCost:
    b, t = shape.global_batch, shape.seq_len
    tokens = b * t
    mode = _exec_mode(cfg, skip_above_diagonal)
    fwd = 0.0
    useful = 0.0
    for kind, window in zip(cfg.block_pattern, cfg.windows):
        s_exec = _s_eff(cfg, kind, window, t, mode=mode)
        fwd += _layer_forward_flops(cfg, kind, s_exec)
        useful += _layer_forward_flops(
            cfg, kind, _s_eff(cfg, kind, window, t, mode="useful"))
    logits = 2 * cfg.d_model * cfg.vocab_padded  # last position only
    exec_total = fwd * tokens + logits * b
    n_active = _active_params_sans_embed(cfg)
    model_total = 2.0 * n_active * tokens
    p_dev = cfg.param_count() * 2 / min(chips, 16)   # bf16 weights, once
    acts = cfg.num_layers * (tokens / chips) * cfg.d_model * 2 * 4
    hbm = p_dev + acts
    return CellCost(exec_total, model_total, hbm,
                    notes="prefill"
                          + ("; banded/diag-skip" if skip_above_diagonal
                             else "; full-rectangle"))


def cell_cost(cfg: ModelConfig, shape: ShapeConfig, chips: int,
              **kw) -> CellCost:
    if shape.kind in ("train", "prefill"):
        kw.setdefault("skip_above_diagonal",
                      cfg.attn_skip_diagonal or cfg.attn_banded)
    if shape.kind == "train":
        return train_cost(cfg, shape, chips, remat=cfg.remat, **kw)
    if shape.kind == "prefill":
        return prefill_cost(cfg, shape, chips, **kw)
    return decode_cost(cfg, shape, chips, swa_cache=cfg.swa_cache, **kw)
