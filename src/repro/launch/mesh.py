"""Production meshes.

The mesh mirrors the paper's §5 deployment: each axis is a radix-16 XOR
CIN (16 = 2^4, so the XOR LACIN instance applies), giving a 16x16 HyperX
single pod (256 chips) and a 2x16x16 multi-pod system (512 chips) whose
"pod" axis is the Dragonfly-style global CIN.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    from repro._compat.jaxapi import make_auto_mesh
    return make_auto_mesh(shape, axes)


def make_host_mesh(model: int = 2):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = len(jax.devices())
    model = min(model, n)
    data = n // model
    devs = np.array(jax.devices()[: data * model]).reshape(data, model)
    from jax.sharding import Mesh
    return Mesh(devs, ("data", "model"))


def describe_mesh(mesh) -> dict:
    """Report the mesh as the paper's fabric: per-axis CIN instances."""
    from repro.core.port_matrix import is_power_of_two
    out = {"axes": dict(mesh.shape), "devices": int(np.prod(list(mesh.shape.values())))}
    out["cin_instances"] = {
        name: ("xor" if is_power_of_two(size) else "circle")
        for name, size in mesh.shape.items()}
    out["schedule_steps"] = {
        name: (size - 1 if size % 2 == 0 or is_power_of_two(size) else size)
        for name, size in mesh.shape.items()}
    return out
