"""Bridge for public-API drift across jax versions.

The codebase targets the current public names (``jax.shard_map``,
``jax.set_mesh``, ``jax.sharding.AxisType``); older jaxlibs (0.4.x) ship
the same functionality under ``jax.experimental.shard_map`` with
``check_rep``/``auto`` instead of ``check_vma``/``axis_names`` and have no
mesh axis types.  Importing from here gives the new-style surface on both.
"""
from __future__ import annotations

import contextlib

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma=True):
        """New-style ``jax.shard_map`` on top of the experimental API.

        ``axis_names`` (the *manual* axes) maps to the complement ``auto``
        set; ``check_vma`` maps to ``check_rep``.
        """
        auto = frozenset()
        if axis_names is not None:
            auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        return _exp_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=bool(check_vma),
                              auto=auto)


if hasattr(jax, "set_mesh"):
    set_mesh = jax.set_mesh
else:
    @contextlib.contextmanager
    def set_mesh(mesh):
        """No-op fallback: old jax has no ambient mesh; shard_map calls in
        this codebase always pass the mesh explicitly."""
        yield mesh


def axis_size(axis_name) -> int:
    """Static size of a bound mesh axis, from inside shard_map/pmap.

    Uses ``jax.lax.axis_size`` where it exists; otherwise falls back to
    ``lax.psum(1, axis)``, which constant-folds to a Python int for
    non-traced operands.  Either way the result is static, so it can size
    schedule tables and Python loops at trace time.
    """
    if hasattr(jax.lax, "axis_size"):
        return int(jax.lax.axis_size(axis_name))
    return int(jax.lax.psum(1, axis_name))


def tpu_compiler_params():
    """The Pallas-TPU compiler-params class across the 0.4 -> 0.5 rename
    (``TPUCompilerParams`` -> ``CompilerParams``)."""
    from jax.experimental.pallas import tpu as pltpu
    return getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def make_auto_mesh(axis_shapes, axis_names, **kw):
    """``jax.make_mesh`` with ``AxisType.Auto`` axes where supported."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=(AxisType.Auto,) * len(axis_names),
                             **kw)
    except (ImportError, TypeError, AttributeError):
        pass
    try:
        return jax.make_mesh(axis_shapes, axis_names, **kw)
    except AttributeError:
        # jax < 0.4.35 has no jax.make_mesh: build the Mesh directly.
        import math
        import numpy as np
        devs = kw.get("devices") or jax.devices()
        n = math.prod(axis_shapes)
        return jax.sharding.Mesh(
            np.asarray(devs[:n]).reshape(axis_shapes), axis_names)
