"""A tiny, deterministic stand-in for ``hypothesis`` when it is not installed.

The test suite uses a small surface of hypothesis: ``@given`` with keyword
strategies, ``@settings(max_examples=..., deadline=...)``, and the
``integers`` / ``sampled_from`` / ``data`` strategies (plus ``.filter`` /
``.map``).  Real hypothesis (declared in ``pyproject.toml``) is preferred
whenever importable; this fallback keeps the property tests running as
seeded random sampling so the suite stays green in hermetic environments
where new packages cannot be installed.

Install via :func:`install`, which registers ``hypothesis`` and
``hypothesis.strategies`` modules in ``sys.modules``.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types

_DEFAULT_MAX_EXAMPLES = 25
_FILTER_TRIES = 10_000


class Unsatisfiable(Exception):
    """Raised when a .filter() predicate rejects every sampled value."""


class _Assumption(Exception):
    """Control-flow exception for assume(False): skip this example."""


class SearchStrategy:
    def __init__(self, draw, label="strategy"):
        self._draw = draw
        self._label = label

    def example_from(self, rnd: random.Random):
        return self._draw(rnd)

    def filter(self, predicate):
        def draw(rnd):
            for _ in range(_FILTER_TRIES):
                value = self._draw(rnd)
                if predicate(value):
                    return value
            raise Unsatisfiable(f"filter on {self._label} rejected all samples")
        return SearchStrategy(draw, f"{self._label}.filter(...)")

    def map(self, fn):
        return SearchStrategy(lambda rnd: fn(self._draw(rnd)),
                              f"{self._label}.map(...)")

    def __repr__(self):
        return f"<fallback {self._label}>"


def integers(min_value, max_value):
    return SearchStrategy(lambda rnd: rnd.randint(min_value, max_value),
                          f"integers({min_value}, {max_value})")


def sampled_from(elements):
    pool = list(elements)
    if not pool:
        raise ValueError("sampled_from requires a non-empty collection")
    return SearchStrategy(lambda rnd: pool[rnd.randrange(len(pool))],
                          f"sampled_from({pool!r})")


def booleans():
    return SearchStrategy(lambda rnd: bool(rnd.getrandbits(1)), "booleans()")


def floats(min_value=0.0, max_value=1.0, **_kw):
    return SearchStrategy(lambda rnd: rnd.uniform(min_value, max_value),
                          f"floats({min_value}, {max_value})")


def lists(elements, min_size=0, max_size=10, **_kw):
    def draw(rnd):
        size = rnd.randint(min_size, max_size)
        return [elements.example_from(rnd) for _ in range(size)]
    return SearchStrategy(draw, "lists(...)")


class DataObject:
    """Interactive drawing, mirroring ``st.data()``."""

    def __init__(self, rnd: random.Random):
        self._rnd = rnd

    def draw(self, strategy: SearchStrategy, label=None):
        return strategy.example_from(self._rnd)


def data():
    return SearchStrategy(lambda rnd: DataObject(rnd), "data()")


def assume(condition):
    if not condition:
        raise _Assumption()
    return True


def given(*given_args, **given_kwargs):
    if given_args:
        raise TypeError("the hypothesis fallback supports keyword strategies only")

    def decorator(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_fallback_settings", {})
            max_examples = cfg.get("max_examples", _DEFAULT_MAX_EXAMPLES)
            # Deterministic per-test seed so failures reproduce.
            rnd = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            for _ in range(max_examples):
                drawn = {name: strat.example_from(rnd)
                         for name, strat in given_kwargs.items()}
                try:
                    fn(*args, **kwargs, **drawn)
                except _Assumption:
                    continue
        wrapper.is_hypothesis_test = True
        wrapper.hypothesis_fallback = True
        # Hide the drawn parameters from pytest's fixture resolution: the
        # wrapper supplies them itself, so the visible signature must only
        # contain whatever genuine fixtures remain.
        del wrapper.__wrapped__
        sig = inspect.signature(fn)
        remaining = [p for name, p in sig.parameters.items()
                     if name not in given_kwargs]
        wrapper.__signature__ = sig.replace(parameters=remaining)
        return wrapper
    return decorator


def settings(**kwargs):
    def decorator(fn):
        fn._fallback_settings = kwargs
        return fn
    return decorator


class HealthCheck:
    too_slow = "too_slow"
    filter_too_much = "filter_too_much"
    data_too_large = "data_too_large"

    @classmethod
    def all(cls):
        return [cls.too_slow, cls.filter_too_much, cls.data_too_large]


def note(_message):
    pass


def install() -> None:
    """Register fallback ``hypothesis`` + ``hypothesis.strategies`` modules."""
    if "hypothesis" in sys.modules:
        return
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.note = note
    hyp.HealthCheck = HealthCheck
    hyp.Unsatisfiable = Unsatisfiable
    hyp.__is_fallback__ = True

    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.sampled_from = sampled_from
    st.booleans = booleans
    st.floats = floats
    st.lists = lists
    st.data = data
    st.SearchStrategy = SearchStrategy

    hyp.strategies = st
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
