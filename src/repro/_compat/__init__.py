"""Compatibility shims for optional third-party dependencies, plus the
deprecation-warning category used by ``repro.fabric`` API shims.

``LacinDeprecationWarning`` lives here (dependency-free) so both
``repro.core`` and ``repro.fabric`` can import it without cycles; the
public re-export is ``repro.fabric.LacinDeprecationWarning``.  CI runs a
``-W error::repro.fabric.LacinDeprecationWarning`` lane so no in-repo
code path keeps using a shimmed old entry point.
"""


class LacinDeprecationWarning(DeprecationWarning):
    """Raised by thin shims kept for one release after the repro.fabric
    API redesign; see the migration table in README.md."""
