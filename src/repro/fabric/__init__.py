"""``repro.fabric`` — the single entry point for every LACIN topology.

The paper's thesis is that one cabling discipline (identically indexed
ports + 1-factor schedules, §2) serves every scale: a single CIN, a
HyperX product of CINs, or a Dragonfly hierarchy of CINs (§5).  This
package is that thesis as an API:

* an **instance registry** (:func:`register_instance` /
  :func:`get_instance` / :func:`instance_names`) holding the paper's
  ``swap`` / ``circle`` / ``xor`` built-ins plus anything a caller
  registers — ``mirror`` (:mod:`repro.fabric.mirror`) is registered
  below purely through the public API as proof.  ``port_matrix``,
  ``route``, ``make_schedule``, the simulator adapters and the
  verification test suite all resolve names here;
* the **Fabric protocol** (:class:`Fabric` with :class:`CINFabric`,
  :class:`HyperXFabric`, :class:`DragonflyFabric`, built by
  :func:`make_fabric`) exposing one surface — ``neighbor_matrix()``,
  ``schedule()``, ``sim_topology()``, ``link_loads()``,
  ``deployment()``, ``verify()``, ``collectives(mesh)``;
* **mesh-aware collectives** (:class:`LacinCollectives`): axis sizes
  come from the bound mesh (or the axis environment), never from
  hand-threaded ``axis_size=`` arguments, and the hierarchical
  schedules — :func:`all_to_all_grid` (multi-axis dimension-order
  all-to-all over a HyperX-shaped mesh) and
  :func:`all_reduce_two_level` (two-level Dragonfly all-reduce) —
  compose one LACIN schedule per level.

Old entry points (``tree_all_reduce_lacin``, ``psum_or_lacin``,
``INSTANCES``) keep working for one release behind
:class:`LacinDeprecationWarning` shims; see README's migration table.
"""
from repro._compat import LacinDeprecationWarning

from .registry import (InstanceSpec, get_instance, instance_names,
                       register_instance, registered_instances,
                       unregister_instance)
from . import mirror as _mirror  # registers the 'mirror' instance (public API)
from .collectives import (LacinCollectives, all_reduce_two_level,
                          all_to_all_grid)
from .fabric import (CINFabric, DragonflyFabric, Fabric, HyperXFabric,
                     make_fabric)

__all__ = [
    "LacinDeprecationWarning",
    "InstanceSpec", "register_instance", "unregister_instance",
    "get_instance", "instance_names", "registered_instances",
    "LacinCollectives", "all_to_all_grid", "all_reduce_two_level",
    "Fabric", "CINFabric", "HyperXFabric", "DragonflyFabric", "make_fabric",
]
