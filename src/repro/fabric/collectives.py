"""Mesh-aware LACIN collectives, flat and hierarchical.

:class:`LacinCollectives` binds the paper's 1-factor step schedules to a
``jax.sharding.Mesh``: every axis size is read from the mesh (or, when no
mesh is bound, statically from the axis environment inside ``shard_map``),
so the schedule can never disagree with the mesh shape — the
``axis_size=`` threading of the old API and its silent-mismatch foot-gun
are gone.

On top of the single-axis matching chains from
:mod:`repro.core.collectives`, two *hierarchical* schedules express what
the flat API cannot:

* :func:`all_to_all_grid` — personalized all-to-all over a HyperX-shaped
  mesh (a Cartesian product of CINs, paper §5): one LACIN schedule per
  mesh dimension, composed dimension-order.  A ``(K_a, K_b, ...)`` mesh
  runs ``sum_d (K_d - 1)`` matching steps instead of ``prod_d K_d - 1``,
  and every step stays inside one dimension's CIN rows — exactly the
  traffic the per-dimension 1-factors carry on the physical HyperX.
* :func:`all_reduce_two_level` — two-level Dragonfly all-reduce: local
  reduce-scatter (inside the group's CIN) -> global all-reduce of the
  scattered shards (one flow per group pair on the global CIN) -> local
  all-gather.  Global traffic is ``1/a`` of a flat all-reduce's.

Both are validated bit-for-bit against ``lax`` references in
``tests/test_fabric_collectives.py``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro._compat.jaxapi import axis_size as _bound_axis_size
from repro.core.collectives import (all_gather_lacin, all_reduce_lacin,
                                    all_to_all_lacin, reduce_scatter_lacin)
from repro.core.schedule import LacinSchedule, make_schedule


# ---------------------------------------------------------------------------
# Hierarchical schedules (free functions; sizes explicit).
# ---------------------------------------------------------------------------

def all_to_all_grid(x: jax.Array, axis_names: Sequence[str],
                    axis_sizes: Sequence[int] | None = None, *,
                    instance: str | Sequence[str] = "auto") -> jax.Array:
    """Personalized all-to-all over the product of ``axis_names``.

    ``x`` has leading dim ``prod(axis_sizes)``; ``x[j]`` is this device's
    chunk for device ``j``, with ``j`` the row-major index over the named
    axes (the same device order ``lax.all_to_all`` uses for a tuple of
    axis names).  Composed dimension-order: one LACIN matching schedule
    per mesh axis, innermost axis first.  Each stage exchanges only along
    one axis, so on a HyperX fabric every step rides that dimension's
    1-factors.  ``instance`` may be a single name or one per axis.
    """
    names = tuple(axis_names)
    if axis_sizes is None:
        sizes = tuple(_bound_axis_size(a) for a in names)
    else:
        sizes = tuple(int(s) for s in axis_sizes)
    insts = ((instance,) * len(names) if isinstance(instance, str)
             else tuple(instance))
    if len(insts) != len(names):
        raise ValueError(f"got {len(insts)} instances for {len(names)} axes")
    total = math.prod(sizes)
    if x.shape[0] != total:
        raise ValueError(f"leading dim {x.shape[0]} != prod{sizes} = {total}")
    rest = x.shape[1:]
    x = x.reshape(sizes + rest)          # per-axis destination coordinates
    for d in reversed(range(len(names))):
        x = jnp.moveaxis(x, d, 0)
        x = all_to_all_lacin(x, names[d], axis_size=sizes[d],
                             instance=insts[d])
        x = jnp.moveaxis(x, 0, d)        # coord d now indexes the *source*
    return x.reshape((total,) + rest)


def all_reduce_two_level(x: jax.Array, local_axis: str, global_axis: str, *,
                         local_size: int | None = None,
                         global_size: int | None = None,
                         local_instance: str = "auto",
                         global_instance: str = "auto") -> jax.Array:
    """Two-level Dragonfly all-reduce (sum) over ``local_axis x global_axis``.

    Local reduce-scatter -> global all-reduce of the 1/a-sized shards ->
    local all-gather.  Equals ``lax.psum(x, (local_axis, global_axis))``;
    2(a-1) local + 2(g-1) global matching steps, with every global step
    carrying shards of ``1/a`` of the payload — the l-g-l locality the
    paper's Dragonfly composition provides.
    """
    a = local_size if local_size is not None else _bound_axis_size(local_axis)
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1)
    pad = (-flat.size) % a
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(a, -1)
    shard = reduce_scatter_lacin(chunks, local_axis, axis_size=a,
                                 instance=local_instance)
    shard = all_reduce_lacin(shard, global_axis, axis_size=global_size,
                             instance=global_instance)
    full = all_gather_lacin(shard, local_axis, axis_size=a,
                            instance=local_instance)
    flat = full.reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# The mesh-bound front-end.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LacinCollectives:
    """LACIN collectives bound to a mesh: axis sizes come from the mesh.

    ``mesh=None`` is allowed — sizes are then read statically from the
    bound axis environment inside ``shard_map``.  ``instance`` picks the
    schedule per axis (``'auto'`` = XOR for power-of-two sizes, else
    Circle); ``axis_instances`` overrides it per axis name (how
    ``DragonflyFabric`` binds its local/global instances).  ``impl='xla'``
    makes :meth:`psum` fall back to ``lax.psum`` for A/B comparisons.
    Obtain one via ``fabric.collectives(mesh, ...)`` to also get the
    fabric-vs-mesh shape check.
    """
    mesh: object | None = None
    instance: str = "auto"
    impl: str = "lacin"
    axis_instances: tuple[tuple[str, str], ...] = ()

    # -- mesh introspection --------------------------------------------------
    def axis_size(self, axis_name: str) -> int:
        if self.mesh is not None:
            if axis_name not in self.mesh.shape:
                raise ValueError(
                    f"bound mesh has no axis {axis_name!r} (axes: "
                    f"{tuple(self.mesh.axis_names)})")
            return int(self.mesh.shape[axis_name])
        return _bound_axis_size(axis_name)

    def axis_instance(self, axis_name: str) -> str:
        return dict(self.axis_instances).get(axis_name, self.instance)

    def schedule(self, axis_name: str) -> LacinSchedule:
        """The static step schedule this object uses on ``axis_name``."""
        return make_schedule(self.axis_instance(axis_name),
                             self.axis_size(axis_name))

    # -- flat (single-axis) collectives --------------------------------------
    def all_to_all(self, x, axis_name: str):
        return all_to_all_lacin(x, axis_name,
                                axis_size=self.axis_size(axis_name),
                                instance=self.axis_instance(axis_name))

    def all_gather(self, x, axis_name: str, *, tiled: bool = False):
        return all_gather_lacin(x, axis_name,
                                axis_size=self.axis_size(axis_name),
                                instance=self.axis_instance(axis_name),
                                tiled=tiled)

    def reduce_scatter(self, x, axis_name: str):
        return reduce_scatter_lacin(x, axis_name,
                                    axis_size=self.axis_size(axis_name),
                                    instance=self.axis_instance(axis_name))

    def all_reduce(self, x, axis_name: str):
        return all_reduce_lacin(x, axis_name,
                                axis_size=self.axis_size(axis_name),
                                instance=self.axis_instance(axis_name))

    def psum(self, x, axis_name: str):
        """All-reduce; ``impl='xla'`` defers to the compiler's psum."""
        if self.impl == "xla":
            return lax.psum(x, axis_name)
        return self.all_reduce(x, axis_name)

    def tree_all_reduce(self, tree, axis_name: str):
        """All-reduce every pytree leaf (DP gradient reduction)."""
        return jax.tree_util.tree_map(
            lambda g: self.all_reduce(g, axis_name), tree)

    # -- hierarchical collectives ---------------------------------------------
    def all_to_all_grid(self, x, axis_names: Sequence[str]):
        """Multi-axis dimension-order all-to-all (HyperX-shaped mesh)."""
        names = tuple(axis_names)
        return all_to_all_grid(
            x, names, tuple(self.axis_size(a) for a in names),
            instance=tuple(self.axis_instance(a) for a in names))

    def all_reduce_two_level(self, x, local_axis: str, global_axis: str):
        """Two-level Dragonfly all-reduce (local RS -> global AR -> local AG)."""
        return all_reduce_two_level(
            x, local_axis, global_axis,
            local_size=self.axis_size(local_axis),
            global_size=self.axis_size(global_axis),
            local_instance=self.axis_instance(local_axis),
            global_instance=self.axis_instance(global_axis))
