"""CIN instance registry: one extension point for every topology layer.

The paper (§2) defines a CIN *instance* as a pairing of the ``N*(N-1)``
switch ports into the ``N*(N-1)/2`` links of K_N.  Everything an instance
needs downstream — P-matrix construction (:mod:`repro.core.port_matrix`),
table-free routing (:mod:`repro.core.routing`), 1-factor step schedules
(:mod:`repro.core.schedule`), simulator adapters
(:mod:`repro.sim.topology`), and the :class:`~repro.fabric.Fabric`
implementations — is derived from four functions:

* ``neighbor(s, i, n)``   — switch reached through port ``i`` of ``s``
  (vectorized over numpy arrays; :data:`IDLE` marks an unwired port);
* ``route(a, b, n)``      — port used at ``a`` to reach ``b`` (the
  inverse of ``neighbor`` in the port argument);
* ``peer_port(s, i, n)``  — far-end port index of link ``(s, i)``.
  ``None`` declares the instance *isoport* (same index at both ends) —
  the paper's cabling discipline, and the property that makes every
  P-matrix column a 1-factor usable as a collective schedule step;
* ``route_jnp(a, b, n)``  — optional branchless ``jnp`` routing, safe
  inside jit/shard_map.

Registering an instance here makes it available to ``port_matrix()``,
``route()``, ``make_schedule()``, ``cin_topology()``, the Fabric API,
and the registry-parametrized verification suite in
``tests/test_port_matrix.py`` / ``tests/test_routing.py`` — with zero
edits to any of those modules.  The paper's ``swap`` / ``circle`` /
``xor`` instances are registered as built-ins below;
:mod:`repro.fabric.mirror` registers a fourth purely through this public
API as proof.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro.core.port_matrix import (IDLE, circle_neighbor, is_power_of_two,
                                    swap_neighbor, swap_peer_port,
                                    xor_neighbor)
from repro.core.routing import (route_circle, route_circle_jnp, route_swap,
                                route_swap_jnp, route_xor, route_xor_jnp)


def _default_num_ports(n: int) -> int:
    return n - 1


@dataclass(frozen=True)
class InstanceSpec:
    """A registered CIN instance: construction + routing + metadata."""
    name: str
    neighbor: Callable          # (s, i, n) -> neighbor switch (IDLE = unwired)
    route: Callable             # (a, b, n) -> port index at a towards b
    peer_port: Callable | None = None   # (s, i, n) -> far-end port; None = isoport
    route_jnp: Callable | None = None   # trace-safe routing, optional
    constraints: Callable | None = None  # (n) -> None, raises ValueError
    num_ports: Callable = _default_num_ports  # columns of the P matrix
    routing_ops: dict | None = None     # Table-1 style critical-path breakdown
    description: str = ""

    @property
    def isoport(self) -> bool:
        """True iff links pair same-index ports (``peer_port is None``)."""
        return self.peer_port is None

    def check(self, n: int) -> None:
        """Raise ``ValueError`` if the instance is undefined for size ``n``."""
        if n < 2:
            raise ValueError(f"CIN needs at least 2 switches, got N={n}")
        if self.constraints is not None:
            self.constraints(n)

    def supports(self, n: int) -> bool:
        try:
            self.check(n)
        except ValueError:
            return False
        return True

    def matrix(self, n: int) -> np.ndarray:
        """The (N, ports) port-pairing P matrix."""
        self.check(n)
        s = np.arange(n)[:, None]
        i = np.arange(self.num_ports(n))[None, :]
        return np.asarray(self.neighbor(s, i, n)).astype(np.int64)

    def peer_matrix(self, n: int) -> np.ndarray:
        """Far-end port index per (switch, port); ``-1`` on unwired ports."""
        P = self.matrix(n)
        ports = P.shape[1]
        if self.isoport:
            rev = np.broadcast_to(np.arange(ports, dtype=np.int64),
                                  P.shape).copy()
        else:
            s = np.arange(n)[:, None]
            i = np.arange(ports)[None, :]
            rev = np.asarray(self.peer_port(s, i, n)).astype(np.int64)
        return np.where(P == IDLE, -1, rev)


_REGISTRY: dict[str, InstanceSpec] = {}


def register_instance(name: str, *, neighbor, route, peer_port=None,
                      route_jnp=None, constraints=None, num_ports=None,
                      routing_ops=None, description: str = "",
                      overwrite: bool = False) -> InstanceSpec:
    """Register a CIN instance under ``name`` and return its spec.

    All callables take the size ``n`` as their last argument (vectorized
    numpy semantics).  ``peer_port=None`` declares the instance isoport.
    Registration makes the instance usable everywhere a built-in is.
    """
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"CIN instance {name!r} is already registered; "
                         f"pass overwrite=True to replace it")
    if name in _REGISTRY:
        _drop_schedule_cache()  # re-registration invalidates cached tables
    spec = InstanceSpec(
        name=name, neighbor=neighbor, route=route, peer_port=peer_port,
        route_jnp=route_jnp, constraints=constraints,
        num_ports=num_ports or _default_num_ports,
        routing_ops=routing_ops, description=description)
    _REGISTRY[name] = spec
    return spec


def _drop_schedule_cache() -> None:
    """Invalidate registry-derived lru caches (if their modules are loaded):
    schedule tables and Dragonfly idle-column maps both memoize on the
    instance *name*, which a re-registration rebinds."""
    import sys
    sched = sys.modules.get("repro.core.schedule")
    if sched is not None:
        sched.make_schedule.cache_clear()
    df = sys.modules.get("repro.core.dragonfly")
    if df is not None:
        df._idle_columns.cache_clear()


def unregister_instance(name: str) -> None:
    """Remove a registered instance (primarily for tests)."""
    if _REGISTRY.pop(name, None) is not None:
        _drop_schedule_cache()


def get_instance(name: str) -> InstanceSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown CIN instance {name!r}; registered: "
            f"{instance_names()}") from None


def instance_names(isoport: bool | None = None) -> tuple[str, ...]:
    """Registered instance names, optionally filtered by the isoport flag."""
    return tuple(n for n, s in _REGISTRY.items()
                 if isoport is None or s.isoport == isoport)


def registered_instances() -> dict[str, InstanceSpec]:
    return dict(_REGISTRY)


# ---------------------------------------------------------------------------
# Built-ins: the paper's three instances (Figure 2).
# ---------------------------------------------------------------------------

def _pow2_constraint(n: int) -> None:
    if not is_power_of_two(n):
        raise ValueError(
            f"XOR CIN instance requires N to be a power of two, got {n}")


def _circle_num_ports(n: int) -> int:
    # Odd N: the (N+1)-even construction keeps N ports, one idle per switch.
    return n - 1 if n % 2 == 0 else n


register_instance(
    "swap",
    neighbor=lambda s, i, n: swap_neighbor(s, i),
    route=lambda a, b, n: route_swap(a, b),
    peer_port=lambda s, i, n: swap_peer_port(s, i),
    route_jnp=lambda a, b, n: route_swap_jnp(a, b),
    routing_ops={"xor_gates": 0, "add_sub": 1, "compare": 1,
                 "total_extra_vs_xor": 1},
    description="anisoport first-available pairing (paper Fig. 2a)")

register_instance(
    "circle",
    neighbor=circle_neighbor,
    route=route_circle,
    route_jnp=route_circle_jnp,
    num_ports=_circle_num_ports,
    routing_ops={"xor_gates": 0, "add_sub": 2, "compare": 3,
                 "total_extra_vs_xor": 5},
    description="isoport round-robin 1-factorization, any N "
                "(paper Alg. 1 / Fig. 2b)")

register_instance(
    "xor",
    neighbor=lambda s, i, n: xor_neighbor(s, i),
    route=lambda a, b, n: route_xor(a, b),
    route_jnp=lambda a, b, n: route_xor_jnp(a, b),
    constraints=_pow2_constraint,
    routing_ops={"xor_gates": 1, "add_sub": 1, "compare": 0,
                 "total_extra_vs_xor": 0},
    description="isoport XOR pairing, N = 2^k (paper Fig. 2c)")
