"""The ``Fabric`` protocol: one surface for every LACIN topology.

The paper's point is that one cabling discipline serves every scale — a
single CIN, a HyperX product of CINs (§5), or a Dragonfly hierarchy of
CINs (§5/Fig. 3).  :class:`CINFabric`, :class:`HyperXFabric` and
:class:`DragonflyFabric` expose that uniformly:

======================  ====================================================
``neighbor_matrix()``   (N, P) switch graph, ``-1`` = unwired port
``peer_port_matrix()``  far-end port per (switch, port) — the cabling rule
``schedule()``          the LACIN step schedule(s) the fabric runs
``sim_topology()``      packet-simulator adapter (:mod:`repro.sim`)
``link_loads()``        closed-form uniform-traffic link loads
``deployment()``        physical arithmetic (racks / hoses / colours)
``verify()``            structural report with an ``"ok"`` verdict
``collectives(mesh)``   mesh-aware LACIN collectives, shape-checked
``replay(collective)``  packet-simulate the fabric's own schedule steps
======================  ====================================================

``make_fabric`` dispatches: a registered instance name + size -> CIN, a
:class:`~repro.core.hyperx.HyperXConfig` -> HyperX, a
:class:`~repro.core.dragonfly.DragonflyConfig` -> Dragonfly.  Anything
registered via :func:`repro.fabric.register_instance` works in all three
positions (single fabric, HyperX dimension, Dragonfly local/global).
"""
from __future__ import annotations

import abc
from dataclasses import dataclass

import numpy as np

from repro.core.dragonfly import DragonflyConfig
from repro.core.hyperx import HyperXConfig, HyperXDeployment
from repro.core.port_matrix import verify_instance
from repro.core.schedule import LacinSchedule, make_schedule
from repro.core.simulate import (cin_link_loads, dragonfly_link_loads,
                                 hyperx_link_loads, valiant_link_loads)

from .collectives import LacinCollectives
from .registry import get_instance

__all__ = ["Fabric", "CINFabric", "HyperXFabric", "DragonflyFabric",
           "make_fabric"]


class Fabric(abc.ABC):
    """Abstract fabric: a switch graph wired from CIN instances."""

    name: str

    @property
    @abc.abstractmethod
    def num_switches(self) -> int: ...

    @property
    @abc.abstractmethod
    def diameter(self) -> int: ...

    def sim_topology(self):
        """A :class:`repro.sim.topology.SimTopology` for the packet engine,
        built once and cached on the fabric (construction is O(N*ports)
        Python loops; every accessor below shares one build)."""
        topo = self.__dict__.get("_sim_topology")
        if topo is None:
            topo = self._build_sim_topology()
            # frozen dataclass: bypass __setattr__ for the cache slot
            self.__dict__["_sim_topology"] = topo
        return topo

    @abc.abstractmethod
    def _build_sim_topology(self):
        """Construct the SimTopology (uncached)."""

    def sim_sweep(self, policy, traffic_factory, loads, *,
                  seeds=(0,), backend: str = "jax",
                  terminals: int | None = None,
                  cycles: int | None = None, warmup: int | None = None,
                  **sim_kw):
        """Deprecated shim: packet-level saturation sweep of this fabric.

        Describe the sweep as a :class:`repro.studies.ExperimentSpec`
        (``FabricSpec.from_fabric(fab)`` names this fabric declaratively)
        and run it with :class:`repro.studies.Study` instead — same
        batched compiled program, plus persistence/resume/spec files.
        Returns a ``[load][seed]`` grid of RunStats.
        """
        import warnings

        from repro._compat import LacinDeprecationWarning
        from repro.studies import (ExperimentSpec, FabricSpec, RoutingSpec,
                                   Study, SweepSpec, TrafficSpec)
        warnings.warn(
            "Fabric.sim_sweep is deprecated; describe the sweep as a "
            "repro.studies.ExperimentSpec and run it with "
            "repro.studies.Study (see README 'Running studies')",
            LacinDeprecationWarning, stacklevel=2)
        spec = ExperimentSpec(
            fabric=FabricSpec.from_fabric(self),
            traffic=TrafficSpec.custom(traffic_factory),
            routing=RoutingSpec.custom(policy),
            sweep=SweepSpec(loads=tuple(loads), seeds=tuple(seeds),
                            cycles=cycles, warmup=warmup),
            terminals=terminals, engine=dict(sim_kw))
        out = Study(spec, backend=backend).run()
        return [[r.stats for r in row] for row in out.grid()]

    def replay(self, collective: str = "all_to_all", *,
               message_size: int = 1, policy="minimal",
               backend: str = "numpy", seed: int = 0, failures=None,
               **engine_kw):
        """Replay one of this fabric's own collective schedules through
        the packet simulator (:mod:`repro.sim.workloads`).

        ``collective`` is ``"all_to_all"`` or ``"all_reduce"`` — the
        step sequence is the one :meth:`schedule` /
        :mod:`repro.fabric.collectives` would execute on this fabric.
        Returns :class:`~repro.sim.metrics.RunStats` with the replay
        fields set (``phase_cycles`` / ``completion_cycles`` /
        ``ideal_cycles``), so ``stats.completion_cycles ==
        stats.ideal_cycles`` *is* the paper's contention-freedom claim,
        measured under queueing.

        ``backend`` is any :func:`repro.sim.engine.simulate` backend:
        ``"numpy"`` / ``"jax"`` measure the replay cycle-accurately;
        ``"flow"`` estimates it analytically from per-phase link
        multiplicities (:mod:`repro.flow`) — exact for contention-free
        LACIN schedules and within tolerance on serialized ones, at any
        fabric scale.

        ``failures`` (a :class:`repro.faults.FailureSpec`) measures
        collective completion on the *degraded* fabric: schedule steps
        still replay phase by phase, but traffic at dead or disconnected
        endpoints is masked out and surviving traffic reroutes over the
        fallback tables — the completion/ideal ratio then quantifies how
        much of the schedule's contention-freedom survives the failures.
        """
        from repro.sim.workloads import collective_workload
        from repro.sim.workloads import replay as replay_workload
        w = collective_workload(self, collective, message_size=message_size)
        return replay_workload(self.sim_topology(), policy, w,
                               backend=backend, seed=seed,
                               failures=failures, **engine_kw)

    @abc.abstractmethod
    def link_loads(self, traffic="uniform") -> dict:
        """Closed-form link loads under ``traffic`` (default uniform a2a)."""

    @abc.abstractmethod
    def deployment(self) -> dict:
        """Physical deployment arithmetic report."""

    @abc.abstractmethod
    def verify(self) -> dict:
        """Structural verification report; ``report['ok']`` is the verdict."""

    @abc.abstractmethod
    def collectives(self, mesh=None, **axes) -> LacinCollectives:
        """Mesh-aware collectives; checks the mesh matches the fabric."""

    def neighbor_matrix(self) -> np.ndarray:
        """(N, P) neighbour matrix (``-1`` = unwired port)."""
        return self.sim_topology().neighbor

    def peer_port_matrix(self) -> np.ndarray:
        """Far-end port index per (switch, port) (``-1`` = unwired)."""
        return self.sim_topology().rev_port

    @property
    def num_links(self) -> int:
        return self.sim_topology().num_links


def _check_axis(mesh, axis_name: str, want: int, what: str) -> None:
    if mesh is None:
        return
    if axis_name not in mesh.shape:
        raise ValueError(
            f"mesh has no axis {axis_name!r} (axes: "
            f"{tuple(mesh.axis_names)}); the {what} needs one of size {want}")
    have = int(mesh.shape[axis_name])
    if have != want:
        raise ValueError(
            f"mesh axis {axis_name!r} has size {have} but the {what} "
            f"needs {want}; bind the fabric to a matching mesh axis")


# ---------------------------------------------------------------------------
# Single CIN.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class CINFabric(Fabric):
    """A single N-switch CIN of a registered instance (paper §2-§4)."""
    instance: str
    n: int

    def __post_init__(self):
        get_instance(self.instance).check(self.n)

    @property
    def name(self) -> str:
        return f"cin-{self.instance}-{self.n}"

    @property
    def num_switches(self) -> int:
        return self.n

    @property
    def diameter(self) -> int:
        return 1

    @property
    def spec(self):
        return get_instance(self.instance)

    def port_matrix(self) -> np.ndarray:
        return self.spec.matrix(self.n)

    def neighbor(self, s, i):
        """Neighbour of switch ``s`` through port ``i``."""
        return self.spec.neighbor(s, i, self.n)

    def route(self, a, b):
        """Port used at ``a`` to reach ``b`` (table-free, §3)."""
        return self.spec.route(a, b, self.n)

    def schedule(self, instance: str | None = None) -> LacinSchedule:
        """The 1-factor step schedule.  Anisoport instances (swap) have no
        matching columns; they get the ``cyclic`` anisoport baseline."""
        if instance is None:
            instance = self.instance if self.spec.isoport else "cyclic"
        return make_schedule(instance, self.n)

    def _build_sim_topology(self):
        from repro.sim.topology import cin_topology
        return cin_topology(self.instance, self.n)

    def link_loads(self, traffic="uniform") -> dict:
        if traffic == "uniform":
            per_link = cin_link_loads(self.instance, self.n)
            return {"per_link": per_link,
                    "summary": {"max": max(per_link.values()),
                                "min": min(per_link.values()),
                                "links_used": len(per_link)}}
        if isinstance(traffic, str):
            raise NotImplementedError(
                f"CIN closed forms cover 'uniform' traffic or an explicit "
                f"list of (src, dst, demand) flows, not {traffic!r}; use "
                f"repro.sim for other patterns")
        # traffic as explicit (src, dst, demand) hot flows: Valiant spread.
        return valiant_link_loads(self.instance, self.n, list(traffic))

    def deployment(self) -> dict:
        """Linear-layout arithmetic (paper §4)."""
        from repro.core.layout import (lacin_total_wire_length,
                                       swap_total_wire_length)
        iso = self.spec.isoport
        return {
            "name": self.name,
            "switches": self.n,
            "ports_per_switch": int(self.spec.num_ports(self.n)),
            "links": (self.n * (self.n - 1)) // 2,
            "isoport": iso,
            "port_columns": int(self.spec.num_ports(self.n)) if iso else 0,
            "total_wire_length": (lacin_total_wire_length(self.n) if iso
                                  else swap_total_wire_length(self.n)),
        }

    def verify(self) -> dict:
        report = verify_instance(self.instance, self.n)
        if self.spec.isoport:
            s = self.schedule()
            report["schedule_matchings"] = s.is_matching_per_step()
            report["schedule_contention_free"] = s.is_contention_free()
            report["schedule_covers_pairs"] = s.covers_all_pairs()
            report["ok"] = bool(report["ok"] and report["schedule_matchings"]
                                and report["schedule_contention_free"]
                                and report["schedule_covers_pairs"])
        return report

    def collectives(self, mesh=None, axis_name: str | None = None,
                    **kw) -> LacinCollectives:
        if axis_name is not None:
            _check_axis(mesh, axis_name, self.n, f"{self.name} fabric")
        inst = self.instance if self.spec.isoport else "auto"
        axes = ((axis_name, inst),) if axis_name else ()
        return LacinCollectives(mesh=mesh, instance=inst,
                                axis_instances=axes, **kw)


# ---------------------------------------------------------------------------
# HyperX: Cartesian product of CINs.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class HyperXFabric(Fabric):
    """A HyperX of per-dimension CINs (paper §5, Figure 4)."""
    config: HyperXConfig

    @property
    def name(self) -> str:
        dims = "x".join(map(str, self.config.dims))
        return f"hyperx-{dims}-{self.config.instance}"

    @property
    def num_switches(self) -> int:
        return self.config.num_switches

    @property
    def diameter(self) -> int:
        return self.config.diameter

    def schedule(self) -> tuple[LacinSchedule, ...]:
        """One LACIN schedule per dimension (composed dimension-order)."""
        return tuple(make_schedule(self.config.instance, k)
                     for k in self.config.dims)

    def _build_sim_topology(self):
        from repro.sim.topology import hyperx_topology
        return hyperx_topology(self.config)

    def link_loads(self, traffic="uniform", sample_pairs=None) -> dict:
        if traffic != "uniform":
            raise NotImplementedError("HyperX closed forms cover uniform "
                                      "traffic; use repro.sim for others")
        return hyperx_link_loads(self.config, sample_pairs=sample_pairs)

    def deployment(self) -> dict:
        c = self.config
        if c.num_dims == 3:
            # Full §5/Fig. 4 rack arithmetic (Z in-rack, X/Y super-ports).
            return HyperXDeployment(c).report()
        return {
            "dims": c.dims,
            "instance": c.instance,
            "switches": c.num_switches,
            "endpoints": c.num_endpoints,
            "radix": c.radix,
            "network_ports_per_switch": c.network_ports_per_switch,
            "total_links": c.num_links,
        }

    def verify(self) -> dict:
        c = self.config
        report = {"name": self.name, "dims": c.dims}
        ok = True
        for d, k in enumerate(c.dims):
            rep = verify_instance(c.instance, k)
            report[f"dim{d}_ok"] = rep["ok"]
            ok = ok and rep["ok"]
        try:
            self.sim_topology().validate()
            report["links_pair_up"] = True
        except ValueError:
            report["links_pair_up"] = ok = False
        # DOR delivery: hop count == number of differing digits <= diameter.
        rng = np.random.default_rng(0)
        n = c.num_switches
        for _ in range(min(64, n * n)):
            a, b = map(int, rng.integers(0, n, 2))
            hops = c.dor_route(c.switch_coord(a), c.switch_coord(b))
            want = sum(x != y for x, y in
                       zip(c.switch_coord(a), c.switch_coord(b)))
            ok = ok and len(hops) == want <= c.diameter
        report["dor_delivers"] = ok
        report["ok"] = ok
        return report

    def collectives(self, mesh=None, axis_names=None, **kw) -> LacinCollectives:
        axes = ()
        if axis_names is not None:
            names = tuple(axis_names)
            if len(names) != len(self.config.dims):
                raise ValueError(
                    f"{self.name} has {len(self.config.dims)} dimensions "
                    f"but got axes {names}")
            for a, k in zip(names, self.config.dims):
                _check_axis(mesh, a, k, f"{self.name} dimension {a!r}")
            axes = tuple((a, self.config.instance) for a in names)
        return LacinCollectives(mesh=mesh, instance=self.config.instance,
                                axis_instances=axes, **kw)


# ---------------------------------------------------------------------------
# Dragonfly: local CINs under a global CIN.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DragonflyFabric(Fabric):
    """A Dragonfly of LACIN groups under a LACIN global network (§5/Fig. 3)."""
    config: DragonflyConfig

    @property
    def name(self) -> str:
        c = self.config
        return f"dragonfly-a{c.group_size}h{c.global_ports_per_switch}g{c.num_groups}"

    @property
    def num_switches(self) -> int:
        return self.config.switches

    @property
    def diameter(self) -> int:
        return 3  # l-g-l

    def schedule(self) -> dict[str, LacinSchedule]:
        """The local and global LACIN schedules of the two-level hierarchy."""
        c = self.config
        return {"local": make_schedule(c.local_instance, c.group_size),
                "global": make_schedule(c.global_instance, c.num_groups)}

    def _build_sim_topology(self):
        from repro.sim.topology import dragonfly_topology
        return dragonfly_topology(self.config)

    def link_loads(self, traffic="uniform") -> dict:
        if traffic != "uniform":
            raise NotImplementedError("Dragonfly closed forms cover uniform "
                                      "traffic; use repro.sim for others")
        return dragonfly_link_loads(self.config)

    def deployment(self) -> dict:
        c = self.config
        return {
            "name": self.name,
            "groups": c.num_groups,
            "group_size": c.group_size,
            "switches": c.switches,
            "endpoints": c.endpoints,
            "radix": c.radix,
            "local_links_per_group": c.local_links_per_group,
            "global_links": c.global_links,
            "total_links": c.total_links,
            "local_instance": c.local_instance,
            "global_instance": c.global_instance,
        }

    def verify(self) -> dict:
        c = self.config
        report = {
            "name": self.name,
            "local_ok": verify_instance(c.local_instance, c.group_size)["ok"],
            "global_ok": verify_instance(c.global_instance, c.num_groups)["ok"],
        }
        ok = report["local_ok"] and report["global_ok"]
        try:
            self.sim_topology().validate()
            report["links_pair_up"] = True
        except ValueError:
            report["links_pair_up"] = ok = False
        # minimal l-g-l delivery over sampled endpoint pairs
        rng = np.random.default_rng(0)
        for _ in range(64):
            ga, gb = map(int, rng.integers(0, c.num_groups, 2))
            sa, sb = map(int, rng.integers(0, c.group_size, 2))
            hops = c.route_packet((ga, sa, 0), (gb, sb, 0))
            kinds = [h[0] for h in hops]
            ok = ok and hops[-1] == ("eject", (gb, sb, 0))
            ok = ok and kinds.count("global") == (0 if ga == gb else 1)
            ok = ok and len(hops) <= 4
        report["lgl_delivers"] = ok
        report["ok"] = ok
        return report

    def collectives(self, mesh=None, local_axis: str | None = None,
                    global_axis: str | None = None, **kw) -> LacinCollectives:
        c = self.config
        axes = []
        if local_axis is not None:
            _check_axis(mesh, local_axis, c.group_size,
                        f"{self.name} local CIN")
            axes.append((local_axis, c.local_instance))
        if global_axis is not None:
            _check_axis(mesh, global_axis, c.num_groups,
                        f"{self.name} global CIN")
            axes.append((global_axis, c.global_instance))
        return LacinCollectives(mesh=mesh, instance="auto",
                                axis_instances=tuple(axes), **kw)


# ---------------------------------------------------------------------------
# Dispatch.
# ---------------------------------------------------------------------------

def make_fabric(spec, n: int | None = None) -> Fabric:
    """One constructor for every topology.

    * ``make_fabric("xor", 16)`` (any registered instance name) -> CIN;
    * ``make_fabric(HyperXConfig(...))``                        -> HyperX;
    * ``make_fabric(DragonflyConfig(...))``                     -> Dragonfly;
    * an existing :class:`Fabric` passes through unchanged.
    """
    if isinstance(spec, Fabric):
        return spec
    if isinstance(spec, HyperXConfig):
        return HyperXFabric(spec)
    if isinstance(spec, DragonflyConfig):
        return DragonflyFabric(spec)
    if isinstance(spec, str):
        if n is None:
            raise ValueError("make_fabric(instance_name, n) needs the size n")
        return CINFabric(spec, n)
    raise TypeError(f"cannot build a fabric from {type(spec).__name__}")
