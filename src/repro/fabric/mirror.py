"""The ``mirror`` instance: Circle with reversed port colouring.

Registered purely through :func:`repro.fabric.register_instance` — no
dispatch code anywhere in ``repro.core`` knows about it — as the proof
that the registry is a complete extension point: P-matrix construction,
table-free routing, 1-factor schedules, simulator topologies, Fabric
objects, and the registry-parametrized verification suite all pick it up
automatically.

Construction: relabel the switches of the Circle instance by the modular
reflection ``r(s) = (m - s) mod m`` (``m = N-1`` for even ``N``, ``m = N``
odd; the special switch ``N-1`` is fixed).  Conjugating every 1-factor by
``r`` preserves matchings, edge-disjointness and K_N coverage, and works
out to a pure *column reversal* of the Circle matrix: mirror port ``i``
is Circle port ``(-i) mod ports``.  The result is a genuinely different
isoport P matrix (different port colours on every wire for ``N > 3``)
whose routing function is one extra modular negation on top of
Algorithm 2.
"""
from __future__ import annotations

import numpy as np

from repro.core.port_matrix import circle_neighbor
from repro.core.routing import route_circle, route_circle_jnp

from .registry import register_instance


def _ports(n: int) -> int:
    return n - 1 if n % 2 == 0 else n


def mirror_neighbor(s, i, n):
    """Neighbour of switch ``s`` through port ``i``: Circle column ``-i``."""
    i = np.asarray(i)
    c = _ports(n)
    return circle_neighbor(s, np.mod(-i, c), n)


def mirror_route(a, b, n):
    """Port at ``a`` towards ``b``: the reflected Circle port index."""
    c = _ports(n)
    return np.mod(-np.asarray(route_circle(a, b, n)), c)


def mirror_route_jnp(a, b, n):
    import jax.numpy as jnp
    c = _ports(n)
    return jnp.mod(-route_circle_jnp(a, b, n), c)


spec = register_instance(
    "mirror",
    neighbor=mirror_neighbor,
    route=mirror_route,
    route_jnp=mirror_route_jnp,
    num_ports=_ports,
    routing_ops={"xor_gates": 0, "add_sub": 3, "compare": 3,
                 "total_extra_vs_xor": 6},
    description="isoport reflected Circle (reversed port colours), any N — "
                "registered via the public registry API")
