"""repro.workload — real workloads: extracted training steps + serving.

Two halves bridging the runtime and simulator tiers:

* **Extraction** (:mod:`~repro.workload.extract`): walk a compiled
  training step's HLO and lower its collective sequence (MoE all-to-all
  dispatch/combine, DP all-reduce, pipeline point-to-point) into
  byte-accurate, phase-barriered :class:`~repro.sim.workloads.Workload`
  objects replayable on all three backends.
* **Serving** (:mod:`~repro.workload.arrivals` /
  :mod:`~repro.workload.serving`): declarative open-loop arrival
  processes (:class:`ArrivalSpec`: Poisson / bursty MMPP /
  trace-driven) turned into timed injection schedules with per-request
  latency percentiles and SLO-attainment reporting.

``python -m repro.workload`` exposes both as a CLI (extract / replay /
slo).
"""
from .arrivals import KINDS, ArrivalSpec
from .extract import (COLLECTIVE_TO_SCHEDULE, compiled_hlo, dp_step_hlo,
                      moe_step_hlo, pipeline_step_hlo, workload_from_hlo)
from .serving import serving_demands, serving_traffic

__all__ = [
    "ArrivalSpec",
    "KINDS",
    "COLLECTIVE_TO_SCHEDULE",
    "workload_from_hlo",
    "compiled_hlo",
    "moe_step_hlo",
    "dp_step_hlo",
    "pipeline_step_hlo",
    "serving_traffic",
    "serving_demands",
]
