"""Command-line driver: ``python -m repro.workload <command>``.

Commands:

* ``extract`` — compile a training step (``--step moe | dp | pipeline``)
  for ``--devices`` host devices in a subprocess (XLA_FLAGS is set
  *before* the child imports jax), lower its collective sequence onto a
  CIN fabric of the same size, and write the resulting
  :class:`~repro.sim.workloads.Workload` as JSON.
* ``replay`` — replay an extracted workload JSON on a fabric through
  the cycle engines.  ``--backend both`` runs the numpy oracle *and*
  the compiled engine, asserts ``measured >= ideal`` (the
  contention-free bound) and exact cross-engine agreement.
* ``slo`` — run :meth:`repro.studies.Study.slo_capacity` on a serving
  study spec: the largest arrival-rate scale whose latency percentile
  still meets the SLO.

Examples::

    python -m repro.workload extract --step moe --devices 8 \\
        --bytes-per-packet 256 -o moe8.workload.json
    python -m repro.workload replay moe8.workload.json --backend both
    python -m repro.workload slo serving_slo \\
        --experiment cin-xor-16/serving-poisson-r0.05/minimal
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

_STEPS = ("moe", "dp", "pipeline")

#: Child source for ``extract``: runs in a subprocess whose XLA_FLAGS
#: already request the device count, prints the workload dict as the
#: last stdout line.
_EXTRACT_CHILD = r"""
import json, sys
args = json.loads(sys.argv[1])
from repro.workload import (dp_step_hlo, moe_step_hlo, pipeline_step_hlo,
                            workload_from_hlo)
step = {"moe": moe_step_hlo, "dp": dp_step_hlo,
        "pipeline": pipeline_step_hlo}[args["step"]]
hlo = step(args["devices"], **args["step_kw"])
w = workload_from_hlo(hlo, (args["instance"], args["n"]),
                      bytes_per_packet=args["bytes_per_packet"],
                      strict=args["strict"], name=args["name"])
print(json.dumps(w.to_dict()))
"""


def _src_path() -> str:
    import repro
    # repro is a namespace package (no __init__.py): locate it via
    # __path__, whose single entry is <src>/repro.
    return os.path.dirname(os.path.abspath(next(iter(repro.__path__))))


def cmd_extract(args) -> int:
    payload = {
        "step": args.step, "devices": args.devices,
        "instance": args.fabric, "n": args.n or args.devices,
        "bytes_per_packet": args.bytes_per_packet,
        "strict": not args.lenient, "name": args.name,
        "step_kw": ({"dp": args.dp} if args.step == "moe" and args.dp > 1
                    else {}),
    }
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " --xla_force_host_"
                        f"platform_device_count={args.devices}").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (_src_path(), env.get("PYTHONPATH")) if p)
    proc = subprocess.run(
        [sys.executable, "-c", _EXTRACT_CHILD, json.dumps(payload)],
        env=env, capture_output=True, text=True)
    if proc.returncode != 0:
        sys.stderr.write(proc.stderr)
        raise SystemExit(f"extract subprocess failed "
                         f"(exit {proc.returncode})")
    line = [l for l in proc.stdout.splitlines() if l.strip()][-1]
    wd = json.loads(line)
    out = args.out or f"{args.step}{args.devices}.workload.json"
    with open(out, "w") as f:
        json.dump(wd, f, indent=2, sort_keys=True)
        f.write("\n")
    total = sum(len(p["src"]) * p["messages"] for p in wd["phases"])
    print(f"wrote {out}: workload {wd['name']!r}, "
          f"{wd['num_switches']} switches, {len(wd['phases'])} phases, "
          f"{total} packets")
    return 0


def cmd_replay(args) -> int:
    from repro.fabric import make_fabric
    from repro.sim.workloads import Workload, replay
    with open(args.workload) as f:
        w = Workload.from_dict(json.load(f))
    fab = make_fabric(args.fabric, args.n or w.num_switches)
    topo = fab.sim_topology()
    backends = ["numpy", "jax"] if args.backend == "both" else [args.backend]
    runs = {}
    for be in backends:
        stats = replay(topo, args.routing, w, backend=be)
        runs[be] = stats
        ratio = (stats.completion_cycles / stats.ideal_cycles
                 if stats.ideal_cycles else float("nan"))
        print(f"{be}: completion={stats.completion_cycles} "
              f"ideal={stats.ideal_cycles} ratio={ratio:.3f}")
        if stats.completion_cycles < stats.ideal_cycles:
            raise SystemExit(
                f"{be}: measured completion {stats.completion_cycles} "
                f"below the contention-free bound {stats.ideal_cycles} — "
                f"the replay undercounted wire time")
    if args.backend == "both":
        a, b = runs["numpy"], runs["jax"]
        if (a.completion_cycles != b.completion_cycles
                or a.phase_cycles != b.phase_cycles):
            raise SystemExit(
                f"cross-engine replay mismatch: numpy "
                f"completion={a.completion_cycles} "
                f"phases={list(a.phase_cycles or ())} vs jax "
                f"completion={b.completion_cycles} "
                f"phases={list(b.phase_cycles or ())}")
        print("cross-engine replay agrees exactly")
    return 0


def cmd_slo(args) -> int:
    from repro.studies import Study, resolve_spec_source
    spec = resolve_spec_source(args.spec)
    study = Study(spec, backend=args.backend)
    cap = study.slo_capacity(args.experiment, percentile=args.percentile,
                             lo=args.lo, hi=args.hi, tol=args.tol)
    print(f"experiment: {cap['experiment']}")
    print(f"slo: p{cap['percentile']:g} <= {cap['slo']} cycles")
    for load, att in cap["probes"]:
        print(f"  probe load={load}: attainment={att}")
    print(f"capacity: {cap['capacity']}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.workload",
        description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    ex = sub.add_parser("extract",
                        help="compile a training step and lower it to a "
                             "replayable workload JSON")
    ex.add_argument("--step", choices=list(_STEPS), required=True)
    ex.add_argument("--devices", type=int, required=True,
                    help="host device count (XLA_FLAGS is set for you)")
    ex.add_argument("--dp", type=int, default=1,
                    help="data-parallel axis size for --step moe")
    ex.add_argument("--fabric", default="xor",
                    help="CIN instance to lower onto (default: xor)")
    ex.add_argument("--n", type=int, default=None,
                    help="fabric switch count (default: --devices)")
    ex.add_argument("--bytes-per-packet", type=int, default=8192,
                    help="simulated link payload per cycle")
    ex.add_argument("--lenient", action="store_true",
                    help="skip (rather than fail on) collectives whose "
                         "replica group size mismatches the fabric")
    ex.add_argument("--name", default=None)
    ex.add_argument("-o", "--out", default=None,
                    help="output path (default: "
                         "<step><devices>.workload.json)")
    ex.set_defaults(fn=cmd_extract)

    rp = sub.add_parser("replay",
                        help="replay an extracted workload on the cycle "
                             "engines")
    rp.add_argument("workload", help="workload JSON from extract")
    rp.add_argument("--fabric", default="xor")
    rp.add_argument("--n", type=int, default=None,
                    help="fabric switch count (default: the workload's)")
    rp.add_argument("--routing", default="minimal")
    rp.add_argument("--backend", default="both",
                    choices=["numpy", "jax", "both"])
    rp.set_defaults(fn=cmd_replay)

    sl = sub.add_parser("slo", help="SLO capacity search on a serving spec")
    sl.add_argument("spec", help="spec file path or bundled spec name")
    sl.add_argument("--experiment", default=None,
                    help="experiment name (required unless the spec holds "
                         "exactly one)")
    sl.add_argument("--backend", default=None,
                    help="auto | jax | numpy | flow")
    sl.add_argument("--percentile", type=float, default=99.0)
    sl.add_argument("--lo", type=float, default=0.05)
    sl.add_argument("--hi", type=float, default=2.0)
    sl.add_argument("--tol", type=float, default=0.01)
    sl.set_defaults(fn=cmd_slo)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
