"""Serving traffic: request streams -> timed injection schedules.

:func:`serving_traffic` turns an :class:`~repro.workload.ArrivalSpec`
into engine-ready :class:`~repro.sim.traffic.Traffic`: each arriving
request becomes ``packets_per_request`` packets from its serving switch
to one uniformly drawn peer (the KV/activation fan a disaggregated
serving tier pushes per request), stamped with a shared request id so
the engines report per-request latency percentiles and SLO attainment
(:func:`repro.sim.metrics.attach_serving`) on top of the per-packet
statistics.

A request's latency is the delivery cycle of its *last* packet minus
its arrival cycle (+1).  Because the per-terminal source FIFOs inject
at most one packet per terminal per cycle, a request's packets serialize
through its switch's injectors exactly as a real NIC would — the service
time is simulated, not modeled.

The same request stream feeds the flow model as a demand matrix
(:func:`serving_demands`), giving the 10k-switch capacity-planning tier
the identical offered pattern at flow fidelity.
"""
from __future__ import annotations

import numpy as np

from repro.sim.traffic import Traffic, _random_dst_excluding_src

from .arrivals import ArrivalSpec

__all__ = ["serving_traffic", "serving_demands"]


def serving_traffic(arrival, n: int, *, cycles: int, load: float = 1.0,
                    terminals: int = 1, packets_per_request: int = 4,
                    slo: float | None = None, seed: int = 0) -> Traffic:
    """Engine-ready serving traffic for ``n`` switches over ``cycles``.

    ``load`` scales the spec's arrival rate (the study sweep axis;
    refused by trace kinds), ``packets_per_request`` is the per-request
    packet fan, ``slo`` the per-request latency target in cycles
    (carried on the traffic for the engines' attainment metric).
    ``offered`` is the *realized* packet rate of the sampled stream —
    per terminal per cycle, like every open-loop generator — so
    saturation accounting stays exact under burstiness.
    """
    spec = ArrivalSpec.coerce(arrival)
    if spec is None:
        raise ValueError("serving_traffic needs an ArrivalSpec")
    if packets_per_request < 1:
        raise ValueError(f"packets_per_request must be >= 1, "
                         f"got {packets_per_request}")
    src_req, gen_req = spec.arrivals(n=n, horizon=cycles, seed=seed,
                                     scale=load)
    rng = np.random.default_rng(
        (spec.seed if spec.seed is not None else int(seed)) + 0x5EED)
    if n > 1:
        dst_req = _random_dst_excluding_src(rng, src_req, n)
    else:
        dst_req = src_req.copy()
    p = int(packets_per_request)
    requests = src_req.size
    src = np.repeat(src_req, p)
    dst = np.repeat(dst_req, p)
    gen = np.repeat(gen_req, p)
    request = np.repeat(np.arange(requests, dtype=np.int64), p)
    offered = (src.size / (n * max(terminals, 1) * cycles)
               if cycles else 0.0)
    return Traffic(f"serving-{spec.label}", src, dst, gen,
                   offered=float(offered), horizon=max(cycles, 1),
                   terminals=terminals, request=request,
                   slo=float(slo) if slo is not None else None)


def serving_demands(traffic: Traffic, n: int
                    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The flow-model demand view of a serving stream: unique
    ``(src, dst)`` pairs with per-pair packet rates (packets per cycle
    over the traffic's horizon)."""
    if traffic.num_packets == 0:
        z = np.zeros(0, dtype=np.int64)
        return z, z.copy(), np.zeros(0)
    pair = traffic.src.astype(np.int64) * n + traffic.dst.astype(np.int64)
    uniq, counts = np.unique(pair, return_counts=True)
    rate = counts / max(traffic.horizon, 1)
    return (uniq // n).astype(np.int64), (uniq % n).astype(np.int64), rate
