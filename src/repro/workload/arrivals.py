"""Declarative open-loop arrival processes: the :class:`ArrivalSpec`.

An ``ArrivalSpec`` names *when* serving requests arrive and *where* —
a seeded stochastic process (Poisson, bursty MMPP) or a recorded trace
— decoupled from what each request costs the fabric (packet fan-out,
destinations: :func:`repro.workload.serving.serving_traffic`).  It is a
:class:`repro.studies.spec._SpecBase` like
:class:`~repro.faults.FailureSpec`, so it JSON-round-trips exactly and
nests inside an :class:`~repro.studies.spec.ExperimentSpec`'s traffic
params, keeping arrival sweeps as declarative as every other study axis.

Processes
---------
* ``"poisson"`` — independent Poisson(``rate``) arrivals per switch per
  cycle; the memoryless baseline of the serving literature.
* ``"mmpp"`` — a two-state Markov-modulated Poisson process per switch:
  a *low* state arriving at ``rate`` and a *high* (burst) state arriving
  at ``rate * burst``, with per-cycle transition probabilities ``p_on``
  (low -> high) and ``p_off`` (high -> low).  The stationary high-state
  fraction is ``p_on / (p_on + p_off)``, making the long-run mean rate
  :attr:`mean_rate` — so a Poisson and an MMPP spec with equal
  ``mean_rate`` offer the same load and differ only in burstiness.
* ``"trace"`` — explicit ``(times, sources)`` arrays, e.g. recorded from
  :meth:`repro.serving.engine.ServingEngine.arrival_trace`.  Deterministic:
  replaying a trace ignores the seed, and rate scaling is refused (a
  trace is evidence, not a distribution — resample the fitted process
  to scale).

Determinism: given the same ``(spec, n, horizon, seed)``, ``arrivals``
returns bit-identical arrays on every backend and host — the same
contract :class:`~repro.faults.FailureSpec` gives failure sampling.
The spec's own ``seed`` field, when set, *pins* the stream (a study
sweep's per-point seed is ignored), mirroring ``TrafficSpec`` fixed
seeds.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping

import numpy as np

from repro.studies.spec import _SpecBase

__all__ = ["ArrivalSpec", "KINDS"]

#: Arrival-process kinds, in documentation order.
KINDS = ("poisson", "mmpp", "trace")


@dataclass(frozen=True, eq=True)
class ArrivalSpec(_SpecBase):
    """When and where serving requests arrive.

    All fields are JSON-serializable; ``ArrivalSpec.from_json(
    spec.to_json()) == spec`` exactly (the ``_SpecBase`` contract).

    ``rate`` is requests per switch per cycle (the *low*-state rate for
    ``"mmpp"``); ``times``/``sources`` are the trace arrays for
    ``kind="trace"`` (ignored otherwise); ``seed=None`` defers to the
    caller's seed, an integer pins the stream.
    """
    kind: str = "poisson"
    rate: float = 0.01
    burst: float = 4.0
    p_on: float = 0.05
    p_off: float = 0.2
    times: tuple = ()
    sources: tuple = ()
    seed: int | None = None

    def __post_init__(self):
        super().__post_init__()
        if self.kind not in KINDS:
            raise ValueError(f"unknown arrival kind {self.kind!r}; "
                             f"expected one of {KINDS}")
        rate = float(self.rate)
        if rate < 0:
            raise ValueError(f"rate must be >= 0, got {rate}")
        burst = float(self.burst)
        if burst < 1.0:
            raise ValueError(f"burst is the high-state rate multiplier and "
                             f"must be >= 1, got {burst}")
        p_on, p_off = float(self.p_on), float(self.p_off)
        if self.kind == "mmpp" and not (0.0 < p_on <= 1.0
                                        and 0.0 < p_off <= 1.0):
            raise ValueError(f"mmpp transition probabilities must lie in "
                             f"(0, 1]; got p_on={p_on}, p_off={p_off}")
        times = tuple(int(t) for t in self.times)
        sources = tuple(int(s) for s in self.sources)
        if self.kind == "trace":
            if not times:
                raise ValueError("a trace spec needs at least one arrival "
                                 "in times")
            if any(t < 0 for t in times):
                raise ValueError("trace times must be >= 0")
            if sources and len(sources) != len(times):
                raise ValueError(
                    f"trace sources must be empty (uniform-random) or match "
                    f"times: {len(sources)} != {len(times)}")
            if any(s < 0 for s in sources):
                raise ValueError("trace sources must be >= 0")
            # Canonical order: arrivals sorted by (time, source) so two
            # specs recording the same arrivals compare equal.
            if sources:
                order = sorted(range(len(times)),
                               key=lambda i: (times[i], sources[i]))
                times = tuple(times[i] for i in order)
                sources = tuple(sources[i] for i in order)
            else:
                times = tuple(sorted(times))
        object.__setattr__(self, "rate", rate)
        object.__setattr__(self, "burst", burst)
        object.__setattr__(self, "p_on", p_on)
        object.__setattr__(self, "p_off", p_off)
        object.__setattr__(self, "times", times)
        object.__setattr__(self, "sources", sources)
        object.__setattr__(
            self, "seed", int(self.seed) if self.seed is not None else None)

    @property
    def mean_rate(self) -> float:
        """Long-run arrivals per switch per cycle for the stochastic
        kinds (``"trace"`` has no intrinsic rate — it depends on the
        window and switch count it is replayed over)."""
        if self.kind == "poisson":
            return self.rate
        if self.kind == "mmpp":
            pi_hi = self.p_on / (self.p_on + self.p_off)
            return self.rate * (1.0 - pi_hi) + self.rate * self.burst * pi_hi
        raise ValueError("a trace spec has no intrinsic mean rate; divide "
                         "len(times) by the replay window x switch count")

    @property
    def label(self) -> str:
        """Compact human tag (experiment names, stores)."""
        if self.kind == "trace":
            return f"trace{len(self.times)}"
        tag = f"{self.kind}-r{self.rate:g}"
        if self.kind == "mmpp":
            tag += f"-b{self.burst:g}"
        if self.seed is not None:
            tag += f"-s{self.seed}"
        return tag

    # -- sampling -----------------------------------------------------------

    def arrivals(self, *, n: int, horizon: int, seed: int = 0,
                 scale: float = 1.0) -> tuple[np.ndarray, np.ndarray]:
        """Sample the request stream: ``(src, gen)`` int64 arrays, sorted
        by ``(src, gen)``, all ``gen`` in ``[0, horizon)``.

        ``n`` is the switch count, ``horizon`` the arrival window in
        cycles, ``scale`` a rate multiplier (the study load axis; the
        ``slo_capacity`` search drives it).  ``seed`` is the stream key
        unless the spec pins its own.  Trace kinds refuse ``scale != 1``
        and replay their recorded arrivals verbatim (sources drawn
        uniformly, seeded, when the trace carries none).
        """
        if n < 1 or horizon < 0:
            raise ValueError(f"need n >= 1 and horizon >= 0; "
                             f"got n={n}, horizon={horizon}")
        if scale < 0:
            raise ValueError(f"scale must be >= 0, got {scale}")
        use_seed = self.seed if self.seed is not None else int(seed)
        rng = np.random.default_rng(use_seed)
        if self.kind == "trace":
            if scale != 1.0:
                raise ValueError(
                    f"a trace replays recorded arrivals and cannot be "
                    f"rate-scaled (scale={scale}); fit a poisson/mmpp spec "
                    f"to the trace to sweep its rate")
            gen = np.asarray(self.times, dtype=np.int64)
            keep = gen < horizon
            gen = gen[keep]
            if self.sources:
                src = np.asarray(self.sources, dtype=np.int64)[keep]
                if src.size and src.max(initial=0) >= n:
                    raise ValueError(
                        f"trace source {int(src.max())} outside [0, {n})")
            else:
                src = rng.integers(0, n, size=gen.size)
            order = np.lexsort((gen, src))
            return src[order].astype(np.int64), gen[order]
        if horizon == 0:
            empty = np.zeros(0, dtype=np.int64)
            return empty, empty.copy()
        if self.kind == "poisson":
            counts = rng.poisson(self.rate * scale, size=(n, horizon))
        else:                                   # mmpp
            # Per-switch two-state chain, started from the stationary
            # distribution so the window mean matches mean_rate without
            # a warm-up transient.
            pi_hi = self.p_on / (self.p_on + self.p_off)
            state = rng.random(n) < pi_hi       # True = high (burst) state
            rates = np.empty((n, horizon))
            flips = rng.random((n, horizon))
            for c in range(horizon):
                rates[:, c] = np.where(state, self.rate * self.burst,
                                       self.rate)
                state = np.where(state, flips[:, c] >= self.p_off,
                                 flips[:, c] < self.p_on)
            counts = rng.poisson(rates * scale)
        src = np.repeat(np.arange(n), counts.sum(axis=1))
        gen = np.repeat(np.tile(np.arange(horizon), n), counts.reshape(-1))
        return src.astype(np.int64), gen.astype(np.int64)

    @classmethod
    def coerce(cls, obj) -> "ArrivalSpec | None":
        """``None`` | ArrivalSpec | its dict form -> ArrivalSpec | None."""
        if obj is None or isinstance(obj, cls):
            return obj
        if isinstance(obj, Mapping):
            return cls.from_dict(obj)
        raise TypeError(f"arrival must be an ArrivalSpec (or its dict "
                        f"form), got {type(obj).__name__}")
