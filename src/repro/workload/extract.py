"""Training-step extraction: compiled HLO -> replayable ``Workload``.

The bridge from the repo's *runtime* half (jitted training/serving steps
on a device mesh) to its *simulator* half: walk a compiled program's
collective sequence in program order
(:func:`repro.launch.hlo_analysis.collective_sequence`) and lower each
op onto a :class:`~repro.fabric.Fabric`'s own step schedules as
barrier-phased :class:`~repro.sim.workloads.Workload` phases, with
byte-accurate message sizes (``bytes_per_packet`` = the simulated link's
per-cycle payload).

Lowering table (per op of group size N = the fabric's switch count,
``raw`` = the op's per-device result bytes, ``ceil`` division
throughout):

================== ======================== ==========================
HLO op             Workload phases          messages per (src, dst)
================== ======================== ==========================
all-to-all         ``all_to_all`` schedule  ``raw / (N * bpp)``
all-reduce         ``all_reduce`` sequence  ``raw / (N * bpp)``
reduce-scatter     ``reduce_scatter`` half  ``raw / bpp``
all-gather         ``all_gather`` half      ``raw / (N * bpp)``
collective-permute one phase from its       ``raw / bpp``
                   ``source_target_pairs``
================== ======================== ==========================

(The reduce-scatter row uses ``raw / bpp`` because XLA's result shape is
the *scattered output* shard, of which each schedule step moves one full
copy; the other rows split an unsharded payload N ways.)

An op whose ``replica_groups`` size differs from the fabric's switch
count cannot be laid onto that fabric's schedules one-to-one:
``strict=True`` (default) raises, ``strict=False`` skips the op and
records it in the returned workload's name no further — the caller
decides whether a partial replay is meaningful.

Ops inside ``known_trip_count`` while loops repeat their phases
``count`` times (a ``grad_accum``-microbatch scan replays its DP
all-reduce per trip, exactly as the wire would see it).
"""
from __future__ import annotations

import math

from repro.launch.hlo_analysis import CollectiveOp, collective_sequence
from repro.sim.workloads import Phase, Workload, collective_workload

__all__ = ["workload_from_hlo", "compiled_hlo", "moe_step_hlo",
           "dp_step_hlo", "pipeline_step_hlo", "COLLECTIVE_TO_SCHEDULE"]

#: HLO op -> (collective_workload name, payload divisor is N).
COLLECTIVE_TO_SCHEDULE = {
    "all-to-all": ("all_to_all", True),
    "all-reduce": ("all_reduce", True),
    "reduce-scatter": ("reduce_scatter", False),
    "all-gather": ("all_gather", True),
}


def _permute_phases(op: CollectiveOp, n: int, messages: int) -> list[Phase]:
    """A collective-permute is already a single explicit matching."""
    src = tuple(a for a, b in op.pairs if a != b)
    dst = tuple(b for a, b in op.pairs if a != b)
    if not src:
        return []
    bad = [v for v in src + dst if not 0 <= v < n]
    if bad:
        raise ValueError(
            f"collective-permute references device {bad[0]} outside the "
            f"fabric's [0, {n}) switch range")
    return [Phase(src, dst, messages=messages)]


def workload_from_hlo(hlo_text: str, fabric, *, bytes_per_packet: int = 8192,
                      strict: bool = True, name: str | None = None
                      ) -> Workload:
    """Lower a compiled module's collective sequence onto ``fabric``.

    ``fabric`` is anything :func:`repro.fabric.make_fabric` accepts;
    ``bytes_per_packet`` sets the simulated link's per-cycle payload
    (message sizes round *up*, so the replayed bound never undercounts
    wire time).  Returns a phased :class:`Workload` replayable on all
    three backends; raises if the module carries no lowerable
    collective.
    """
    from repro.fabric import Fabric, make_fabric
    if isinstance(fabric, Fabric):
        fab = fabric
    elif isinstance(fabric, tuple):
        fab = make_fabric(*fabric)
    else:
        fab = make_fabric(fabric)
    n = int(fab.num_switches)
    if bytes_per_packet < 1:
        raise ValueError(f"bytes_per_packet must be >= 1, "
                         f"got {bytes_per_packet}")
    seq = collective_sequence(hlo_text, default_group=n)
    phases: list[Phase] = []
    skipped = 0
    for op in seq:
        if op.kind != "collective-permute" and op.group_size != n:
            if strict:
                raise ValueError(
                    f"{op.kind} has replica group size {op.group_size} but "
                    f"fabric {fab.name!r} has {n} switches; extract with a "
                    f"matching fabric, or pass strict=False to skip "
                    f"mismatched ops")
            skipped += op.count
            continue
        if op.kind == "collective-permute":
            messages = max(1, math.ceil(op.raw_bytes / bytes_per_packet))
            per_op = _permute_phases(op, n, messages)
        else:
            sched_name, split_n = COLLECTIVE_TO_SCHEDULE[op.kind]
            div = bytes_per_packet * (n if split_n else 1)
            messages = max(1, math.ceil(op.raw_bytes / div))
            per_op = list(collective_workload(
                fab, sched_name, message_size=messages).phases)
        for _ in range(max(op.count, 1)):
            phases.extend(per_op)
    if not phases:
        raise ValueError(
            f"no lowerable collectives found for fabric {fab.name!r} "
            f"({len(seq)} parsed, {skipped} skipped on group-size "
            f"mismatch); was the program compiled for {n} devices?")
    return Workload(name or f"{fab.name}-hlo", n, tuple(phases))


# ---------------------------------------------------------------------------
# Compiled-program helpers.  These touch jax and must run in a process
# whose XLA_FLAGS requested enough host devices *before* the first jax
# import (see repro.launch.dryrun and ``python -m repro.workload
# extract``, which spawns such a process for you).
# ---------------------------------------------------------------------------

def compiled_hlo(fn, *args, static_argnums=(), **jit_kw) -> str:
    """``jit(fn).lower(*args).compile()`` -> optimized HLO text."""
    import jax
    jitted = jax.jit(fn, static_argnums=static_argnums, **jit_kw)
    return jitted.lower(*args).compile().as_text()


def moe_step_hlo(num_devices: int, *, dp: int = 1, d_model: int = 32,
                 d_ff: int = 16, num_experts: int | None = None,
                 batch: int = 4, seq: int = 8) -> str:
    """Compiled HLO of one expert-parallel MoE forward step.

    The EP axis spans ``num_devices // dp`` shards (the ``"model"`` mesh
    axis the LACIN dispatch/combine all-to-alls ride); requires the
    process to expose ``num_devices`` jax devices.
    """
    import jax
    import jax.numpy as jnp
    from repro._compat.jaxapi import make_auto_mesh, set_mesh
    from repro.models.config import ModelConfig
    from repro.models.layers import AxisRules
    from repro.models.moe import apply_moe, init_moe
    ep = num_devices // dp
    if ep * dp != num_devices:
        raise ValueError(f"dp={dp} must divide num_devices={num_devices}")
    cfg = ModelConfig(
        name="extract-moe", family="moe", num_layers=1, d_model=d_model,
        num_heads=4, num_kv_heads=2, d_ff=d_ff, vocab_size=64,
        num_experts=num_experts if num_experts is not None else ep,
        top_k=2, expert_pad_to=1, capacity_factor=2.0)
    mesh = make_auto_mesh((dp, ep), ("data", "model"))
    rules = AxisRules(dp=("data",), tp="model", mesh=mesh)
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, seq, d_model))
    with set_mesh(mesh):
        return compiled_hlo(lambda p_, x_: apply_moe(p_, x_, cfg, rules)[0],
                            p, x)


def _tiny_dense_cfg(name: str, *, num_layers: int, d_model: int) -> "object":
    from repro.models.config import ModelConfig
    return ModelConfig(name=name, family="dense", num_layers=num_layers,
                       d_model=d_model, num_heads=4, num_kv_heads=2,
                       d_ff=2 * d_model, vocab_size=64)


def dp_step_hlo(num_devices: int, *, d_model: int = 32, num_layers: int = 1,
                batch: int = 8, seq: int = 8, compress: bool = False) -> str:
    """Compiled HLO of one explicit-DP train step
    (:func:`repro.runtime.manual_dp.make_manual_dp_train_step`) — the
    LACIN reduce-scatter + all-gather gradient reduction appears as
    ``collective-permute`` chains in the sequence."""
    import jax
    import jax.numpy as jnp
    from repro._compat.jaxapi import make_auto_mesh
    from repro.optim import OptConfig
    from repro.runtime.manual_dp import make_manual_dp_train_step
    from repro.runtime.trainer import init_train_state
    if batch % num_devices:
        raise ValueError(f"batch={batch} must divide over "
                         f"num_devices={num_devices}")
    cfg = _tiny_dense_cfg("extract-dp", num_layers=num_layers,
                          d_model=d_model)
    mesh = make_auto_mesh((num_devices,), ("data",))
    step = make_manual_dp_train_step(cfg, mesh, OptConfig(),
                                     compress=compress)
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    batch_d = {"tokens": jnp.zeros((batch, seq), jnp.int32),
               "labels": jnp.zeros((batch, seq), jnp.int32)}
    return step.lower(state, batch_d).compile().as_text()


def pipeline_step_hlo(num_devices: int, *, d_model: int = 32,
                      layers_per_stage: int = 1, n_micro: int = 2,
                      batch: int = 4, seq: int = 8) -> str:
    """Compiled HLO of one GPipe-style pipeline loss
    (:func:`repro.runtime.pipeline.make_pipeline_loss_fn`) — the
    stage-to-stage shifts appear as ``collective-permute`` ops with
    neighbour ``source_target_pairs``."""
    import jax
    import jax.numpy as jnp
    from repro._compat.jaxapi import make_auto_mesh
    from repro.models.transformer import init_params
    from repro.runtime.pipeline import make_pipeline_loss_fn
    cfg = _tiny_dense_cfg("extract-pipe",
                          num_layers=num_devices * layers_per_stage,
                          d_model=d_model)
    mesh = make_auto_mesh((num_devices,), ("pipe",))
    loss_fn = make_pipeline_loss_fn(cfg, mesh, n_micro=n_micro)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch_d = {"tokens": jnp.zeros((batch, seq), jnp.int32),
               "labels": jnp.zeros((batch, seq), jnp.int32)}
    return compiled_hlo(loss_fn, params, batch_d)
