"""Explicit-DP trainer: the paper's 1-factor schedule on the gradient
all-reduce, with optional int8 gradient compression.

Unlike the pjit trainer (where GSPMD inserts the DP reduction), this
variant runs the whole step inside a manual ``shard_map`` over the dp
axes, so per-device gradients exist as values and the LACIN schedule is
applied *explicitly*: reduce-scatter + all-gather chains of
``ppermute`` matchings (wire-optimal 2(N-1)/N bytes, one hop per datum on
the CIN).  Used on host-device meshes in tests/benchmarks and as the
reference implementation of the paper's technique on the DP axis.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro._compat.jaxapi import shard_map
from repro.fabric import LacinCollectives
from repro.models import ModelConfig
from repro.models.layers import AxisRules
from repro.models.transformer import forward_train
from repro.optim import OptConfig, adamw_update


def _quantize_int8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def lacin_grad_allreduce(grads, axis_name: str, coll: LacinCollectives,
                         compress: bool = False):
    """All-reduce a gradient pytree over one manual axis with the LACIN
    schedule.  ``coll`` is the mesh-bound collective set — the axis size
    comes from its mesh (or the bound axis environment), never from a
    hand-threaded count.  ``compress=True`` quantizes the *scattered*
    shards to int8 before the all-gather phase (error <= 1/254 of max |g|
    per tensor), halving...quartering the AG wire bytes."""
    axis_size = coll.axis_size(axis_name)

    def reduce_leaf(g):
        shape, dtype = g.shape, g.dtype
        flat = g.reshape(-1).astype(jnp.float32)
        pad = (-flat.size) % axis_size
        if pad:
            flat = jnp.pad(flat, (0, pad))
        chunks = flat.reshape(axis_size, -1)
        shard = coll.reduce_scatter(chunks, axis_name)
        if compress:
            q, scale = _quantize_int8(shard)
            qs = coll.all_gather(q, axis_name)
            ss = coll.all_gather(scale[None], axis_name)
            full = _dequantize(qs, ss[:, 0][:, None])
        else:
            full = coll.all_gather(shard, axis_name)
        flat = full.reshape(-1)
        if pad:
            flat = flat[:-pad]
        return (flat / axis_size).reshape(shape).astype(dtype)

    return jax.tree_util.tree_map(reduce_leaf, grads)


def make_manual_dp_train_step(cfg: ModelConfig, mesh, opt: OptConfig,
                              *, axis_name: str = "data",
                              compress: bool = False,
                              instance: str = "auto"):
    """Whole-step shard_map over one dp axis; params replicated."""
    coll = LacinCollectives(mesh=mesh, instance=instance)
    inner_rules = AxisRules()  # single-device math inside the manual region

    def body(state, batch):
        params = state["params"]
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: forward_train(p, batch, cfg, inner_rules),
            has_aux=True)(params)
        grads = lacin_grad_allreduce(grads, axis_name, coll,
                                     compress=compress)
        loss = jax.lax.pmean(loss, axis_name)
        new_params, new_opt, om = adamw_update(params, grads, state["opt"],
                                               opt)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, {"loss": loss, **om}

    state_specs = jax.tree_util.tree_map(lambda _: P(), {"params": 0,
                                                         "opt": 0,
                                                         "step": 0})
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(), {"tokens": P(axis_name), "labels": P(axis_name)}),
        out_specs=(P(), P()),
        axis_names={axis_name}, check_vma=False)
    return jax.jit(fn, donate_argnums=(0,))
