"""Train / serve step factories used by the launcher, the dry-run, and the
fault-tolerant training loop.

``make_train_step`` returns a pjit-able pure function
``(state, batch) -> (state, metrics)``; ``make_serve_steps`` returns the
prefill and decode step functions for serving shapes.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import ModelConfig
from repro.models.layers import AxisRules
from repro.models.transformer import (decode_step, forward_train, init_caches,
                                      init_params, prefill)
from repro.optim import OptConfig, adamw_update, init_opt_state


def make_rules(mesh) -> AxisRules:
    """AxisRules for a production mesh (("pod",)?, "data", "model")."""
    if mesh is None:
        return AxisRules()
    names = mesh.axis_names
    dp = tuple(n for n in names if n in ("pod", "data"))
    tp = "model" if "model" in names else None
    return AxisRules(dp=dp, tp=tp, mesh=mesh)


def init_train_state(key, cfg: ModelConfig) -> dict:
    params = init_params(key, cfg)
    return {"params": params, "opt": init_opt_state(params),
            "step": jnp.zeros((), jnp.int32)}


def make_train_step(cfg: ModelConfig, rules: AxisRules, opt: OptConfig,
                    *, grad_accum: int = 1, dp_allreduce: str = "xla",
                    grad_specs=None):
    """Build the train step.

    ``grad_accum > 1`` splits the batch into microbatches scanned
    sequentially (grads averaged) — the standard memory lever.
    ``grad_specs``: optional PartitionSpec tree for the gradient
    accumulator — constraining it dp-sharded turns the accumulation into a
    ZeRO-2-style reduce-scatter instead of replicated all-reduce.
    ``dp_allreduce='lacin'`` reduces gradients with the explicit LACIN
    1-factor schedule over the dp axes inside a shard_map (paper technique
    on the DP axis); 'xla' leaves the reduction to GSPMD.
    """
    def loss_fn(params, batch):
        return forward_train(params, batch, cfg, rules)

    def constrain_grads(grads):
        if grad_specs is None or rules.mesh is None:
            return grads
        return jax.tree_util.tree_map(
            lambda g, s: jax.lax.with_sharding_constraint(
                g, NamedSharding(rules.mesh, s)), grads, grad_specs)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, constrain_grads(grads)

    def train_step(state, batch):
        params = state["params"]
        if grad_accum > 1:
            def micro(carry, mb):
                loss, metrics, grads = grads_of(params, mb)
                acc = jax.tree_util.tree_map(jnp.add, carry[0], grads)
                acc = constrain_grads(acc)
                return (acc, carry[1] + loss), metrics
            zero = constrain_grads(jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)
            (gacc, loss), metrics = jax.lax.scan(micro, (zero, 0.0), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / grad_accum, gacc)
            loss = loss / grad_accum
            metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
        else:
            loss, metrics, grads = grads_of(params, batch)

        # NOTE: under pjit/GSPMD the DP gradient reduction is inserted by
        # the partitioner.  The explicit LACIN 1-factor gradient all-reduce
        # (dp_allreduce='lacin') is implemented in runtime/manual_dp.py,
        # where per-device gradients exist (whole-step shard_map).
        new_params, new_opt, opt_metrics = adamw_update(
            params, grads, state["opt"], opt)
        metrics = {**metrics, **opt_metrics, "loss": loss}
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, metrics

    return train_step


def suggest_grad_accum(cfg: ModelConfig, global_batch: int, seq_len: int,
                       dp_size: int, budget_bytes: float = 5e9,
                       tp_size: int = 16) -> int:
    """Microbatch count keeping per-microbatch live bytes under budget.

    Two dominant terms with scan-over-layers + full remat:
    * saved residual stream:  L * B_loc * T * d * 2 bytes;
    * CE logits (fp32 value + grad + recompute ~ 3 copies):
      B_loc * T * (V / tp) * 4 * 3 bytes.
    """
    b_loc = max(global_batch // max(dp_size, 1), 1)
    acts = cfg.num_layers * b_loc * seq_len * cfg.d_model * 2
    logits = b_loc * seq_len * (cfg.vocab_padded / max(tp_size, 1)) * 4 * 3
    moe = 0.0
    if cfg.is_moe:
        # dispatch buffer + backward cotangents: T*k*cf*d; measured ~5 live
        # fp32 copies in the dispatch backward (see EXPERIMENTS.md §Perf)
        moe = (b_loc * seq_len * cfg.top_k * cfg.capacity_factor
               * cfg.d_model * 4 * 5)
    per_mb = acts + logits + moe
    ga = 1
    while per_mb / ga > budget_bytes and ga < b_loc:
        ga *= 2
    return min(ga, b_loc)


def make_serve_steps(cfg: ModelConfig, rules: AxisRules, seq_len: int):
    """(prefill_fn, decode_fn) for serving shapes."""
    def prefill_fn(params, batch):
        return prefill(params, batch, cfg, rules, seq_len)

    def decode_fn(params, tokens, caches, pos, cross_src=None):
        return decode_step(params, tokens, caches, pos, cfg, rules, seq_len,
                           cross_src=cross_src)

    return prefill_fn, decode_fn
