"""Pipeline parallelism (GPipe-style) over a mesh axis.

Each device on the ``pipe`` axis owns a contiguous stage of layers;
microbatches stream through ``n_micro + n_stages - 1`` ticks of a
``lax.scan`` whose carry is the activation entering the local stage, and
stage-to-stage transfer is a single ``ppermute`` shift per tick.  Because
``ppermute``/``scan``/``where`` are all linearizable, **the backward
pipeline falls out of autodiff**: the transpose of the forward shift is
the reverse shift, so the 1F1B-ish reverse schedule needs no hand-written
machinery.

On the paper's fabric the shift permutation is a subset of a 1-factor
(neighbour exchanges), i.e. contention-free by construction.

Scope: uniform single-run stacks (all-ATTN architectures).  Stage
parameters are taken as layer-slices of the replicated stacked params —
a real deployment would shard the stack along the pipe axis; the schedule
and its gradients are what this module demonstrates (tests assert
loss/grad equality with the sequential forward).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro._compat.jaxapi import shard_map
from repro.models import ModelConfig
from repro.models.layers import AxisRules
from repro.models import layers as L
from repro.models.transformer import (_run_body, build_runs, cross_entropy)


def make_pipeline_loss_fn(cfg: ModelConfig, mesh, *, axis_name: str = "pipe",
                          n_micro: int = 2):
    """Returns ``loss_fn(params, batch) -> loss`` running the layer stack
    as a pipeline over ``axis_name`` (params replicated, batch replicated;
    output loss replicated)."""
    runs = build_runs(cfg)
    if len(runs) != 1:
        raise ValueError("pipeline demo supports uniform single-run stacks")
    run = runs[0]
    n_stages = mesh.shape[axis_name]
    if run.count % n_stages:
        raise ValueError(f"{run.count} layers must divide {n_stages} stages")
    per_stage = run.count // n_stages
    rules = AxisRules()   # single-device math inside the manual region

    def local(params, batch):
        s = lax.axis_index(axis_name)
        tokens, labels = batch["tokens"], batch["labels"]
        b, t = tokens.shape
        assert b % n_micro == 0
        x = L.embed_tokens(params["embed"], tokens, cfg, rules)
        micro = x.reshape(n_micro, b // n_micro, t, cfg.d_model)
        pos = jnp.arange(t, dtype=jnp.int32)

        # this stage's layer slice of the stacked run params
        stage_p = jax.tree_util.tree_map(
            lambda a: lax.dynamic_slice_in_dim(a, s * per_stage, per_stage,
                                               axis=0),
            params["stack"][0])
        windows = lax.dynamic_slice_in_dim(
            jnp.asarray(run.windows, jnp.int32), s * per_stage, per_stage)
        thetas = lax.dynamic_slice_in_dim(
            jnp.asarray(run.thetas, jnp.float32), s * per_stage, per_stage)
        body = _run_body(run, cfg, rules, q_pos=pos, kv_pos=pos,
                         causal=True, cross_src=None, mode="train")

        def stage_fn(xb):
            dummy_cache = jnp.zeros((per_stage,), jnp.float32)
            y, _ = lax.scan(body, xb, (stage_p, windows, thetas, dummy_cache))
            return y

        shift = [(i, i + 1) for i in range(n_stages - 1)]
        n_ticks = n_micro + n_stages - 1

        def tick(buf, tk):
            y = stage_fn(buf)
            nxt = lax.ppermute(y, axis_name, shift)
            feed = micro[jnp.clip(tk + 1, 0, n_micro - 1)]
            newbuf = jnp.where(s == 0, feed, nxt)
            return newbuf, y

        buf0 = jnp.where(s == 0, micro[0], jnp.zeros_like(micro[0]))
        _, ys = lax.scan(tick, buf0, jnp.arange(n_ticks))
        # last stage: outputs for microbatch m are at tick m + S - 1
        outs = lax.dynamic_slice_in_dim(ys, n_stages - 1, n_micro, axis=0)
        h = outs.reshape(b, t, cfg.d_model)
        h = L.apply_norm(params["final_norm"], h)
        logits = L.logits_from_hidden(h, params["embed"],
                                      params.get("lm_head"), cfg, rules)
        loss, _ = cross_entropy(logits, labels)
        # only the last stage's loss is real; replicate it across the axis
        loss = lax.psum(jnp.where(s == n_stages - 1, loss, 0.0), axis_name)
        return loss

    fn = shard_map(local, mesh=mesh,
                       in_specs=(P(), {"tokens": P(), "labels": P()}),
                       out_specs=P(), axis_names={axis_name},
                       check_vma=False)
    return fn
