"""Fault-tolerant training loop: checkpoint/restart, failure injection,
straggler-deterministic data, elastic re-mesh.

The loop is deliberately structured as crash-only software: *any* failure
path (injected or real) is handled by the same mechanism — restart from the
latest atomic checkpoint.  Because the data pipeline is a pure function of
(seed, step), a restarted (or re-sized) job replays the exact token stream
with no data-state handoff.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, host_batch
from repro.models import ModelConfig
from repro.optim import OptConfig
from repro.runtime.trainer import init_train_state, make_rules, make_train_step


class InjectedFailure(RuntimeError):
    """Raised by the failure-injection hook to simulate a node crash."""


@dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "results/ckpt"
    keep: int = 3
    log_every: int = 10
    fail_at_steps: tuple[int, ...] = ()       # failure injection (tests)
    max_restarts: int = 8


@dataclass
class LoopReport:
    steps_run: int = 0
    restarts: int = 0
    losses: list = field(default_factory=list)
    restored_from: list = field(default_factory=list)


def _attempt(cfg: ModelConfig, opt: OptConfig, loop: LoopConfig,
             data: DataConfig, mesh, report: LoopReport,
             fail_once: set, mgr: CheckpointManager) -> bool:
    """One run attempt; returns True when training completed."""
    rules = make_rules(mesh)
    step_fn = make_train_step(cfg, rules, opt)
    if mesh is not None:
        step_fn = jax.jit(step_fn, donate_argnums=(0,))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0,))

    start = mgr.latest_step()
    state_like = jax.eval_shape(
        lambda: init_train_state(jax.random.PRNGKey(data.seed), cfg))
    if start is not None:
        state = mgr.restore(start, state_like)
        state = jax.tree_util.tree_map(jnp.asarray, state)
        report.restored_from.append(start)
        first = start
    else:
        state = init_train_state(jax.random.PRNGKey(data.seed), cfg)
        first = 0

    for step in range(first, loop.total_steps):
        if step in fail_once:
            fail_once.discard(step)
            raise InjectedFailure(f"injected failure at step {step}")
        batch = {k: jnp.asarray(v) for k, v in
                 host_batch(data, step).items()}
        state, metrics = step_fn(state, batch)
        report.steps_run += 1
        if step % loop.log_every == 0 or step == loop.total_steps - 1:
            loss = float(metrics["loss"])
            report.losses.append((step, loss))
        if (step + 1) % loop.ckpt_every == 0:
            mgr.save(step + 1, state)
    mgr.save(loop.total_steps, state, blocking=True)
    return True


def run_training(cfg: ModelConfig, opt: OptConfig, loop: LoopConfig,
                 data: DataConfig, mesh=None) -> LoopReport:
    """Crash-only training: restart from the latest checkpoint on failure."""
    report = LoopReport()
    fail_once = set(loop.fail_at_steps)
    # One manager across attempts: its wait() must cover writes that were
    # still in flight when the failure hit (async-save / crash race).
    mgr = CheckpointManager(loop.ckpt_dir, keep=loop.keep)
    for attempt in range(loop.max_restarts + 1):
        try:
            _attempt(cfg, opt, loop, data, mesh, report, fail_once, mgr)
            return report
        except InjectedFailure:
            report.restarts += 1
            mgr.wait()
            continue
    raise RuntimeError(f"exceeded {loop.max_restarts} restarts")
