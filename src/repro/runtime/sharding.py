"""Parameter / state / batch partition specs for the production mesh.

Name-pattern based: every parameter leaf gets a PartitionSpec from its path
(the leading stacked-layer dim is always unsharded).  GSPMD supports uneven
shards (e.g. granite's 40 experts over 16) by implicit padding.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.layers import AxisRules


def _leaf_spec(path, leaf, cfg: ModelConfig, rules: AxisRules) -> P:
    tp = rules.tp
    names = [p.key for p in path if hasattr(p, "key")]
    name = names[-1] if names else ""
    in_moe = "moe" in names
    in_mlstm_ish = any(n in ("ssm",) for n in names)
    nd = leaf.ndim
    stacked = 1 if (names and names[0] == "stack") or "encoder" in names else 0

    def spec(*tail):
        return P(*([None] * stacked + list(tail)))

    heads_shardable = cfg.num_heads % max(rules.tp_size, 1) == 0
    kv_shardable = cfg.num_kv_heads % max(rules.tp_size, 1) == 0
    ff_shardable = cfg.d_ff % max(rules.tp_size, 1) == 0 if cfg.d_ff else False

    if name == "table":                       # embedding (V, d)
        return P(tp, None)
    if name == "w" and "lm_head" in names:    # (d, V)
        return P(None, tp)
    if name == "router":
        return spec(None, None)
    if in_moe and name in ("wi", "wg"):       # (E, d, f)
        return spec(tp, None, None)
    if in_moe and name == "wo":               # (E, f, d)
        return spec(tp, None, None)
    if name == "wq" and nd - stacked == 3:    # attn (d, h, dh)
        return spec(None, tp, None) if heads_shardable else spec(tp, None, None)
    if name in ("wk", "wv") and nd - stacked == 3:  # attn (d, kv, dh)
        return spec(None, tp, None) if kv_shardable else spec(None, None, None)
    if name in ("wq", "wk", "wv") and nd - stacked == 2:  # mLSTM (inner, inner)
        return spec(None, tp)
    if name == "wo" and nd - stacked == 3:    # attn out (h, dh, d)
        return spec(tp, None, None) if heads_shardable else spec(None, None, tp)
    if name in ("bq",):                       # (h, dh)
        return spec(tp, None) if heads_shardable else spec(None, None)
    if name in ("bk", "bv"):
        return spec(tp, None) if kv_shardable else spec(None, None)
    if name == "wi" or name == "wg":          # mlp (d, f)
        return spec(None, tp) if ff_shardable else spec(None, None)
    if name == "wo":                          # mlp (f, d)
        return spec(tp, None) if ff_shardable else spec(None, None)
    if name == "bi":                          # (f,)
        return spec(tp) if ff_shardable else spec(None)
    # --- xLSTM / SSM inner-dim sharded leaves -----------------------------
    if name == "up":                          # (d, 2*inner)
        return spec(None, tp)
    if name == "down" or name == "out_proj":  # (inner, d)
        return spec(tp, None)
    if name in ("in_proj", "w_gates", "ffn_wi", "ffn_wg", "dt_proj"):
        return spec(None, tp)
    if name in ("ffn_wo", "x_proj"):          # (inner/ff, ...)
        return spec(tp, None)
    if name in ("A_log",):                    # (inner, S)
        return spec(tp, None)
    if name in ("D", "dt_bias"):              # (inner,)
        return spec(tp)
    if name == "conv_w":                      # (K, inner)
        return spec(None, tp)
    if name in ("wq_m", "wk_m", "wv_m"):
        return spec(None, tp)
    if names and "stack" in names and name in ("wq", "wk", "wv") \
            and nd - stacked == 2:            # mLSTM (inner, inner)
        return spec(None, tp)
    # everything else (norm scales, small biases, meta tokens, gates)
    return P(*([None] * nd))


def _fit_spec(spec: P, shape: tuple, mesh) -> P:
    """Drop sharded axes whose mesh extent does not divide the dim size
    (jit rejects uneven in_shardings; e.g. whisper's 51865 vocab)."""
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        extent = 1
        for a in axes:
            extent *= mesh.shape[a]
        out.append(ax if dim % extent == 0 else None)
    return P(*out)


#: Leaves at least this many elements get ZeRO-extended (fsdp-style 2-D)
#: sharding on the master store: tp on the model dim, dp on the largest
#: remaining dim.  GSPMD gathers the bf16 working copy once per step (the
#: stacked scan input is resharded before the loop), so the wire cost is a
#: single parameter gather while fp32 master/moments/grads stay 2-D-sharded.
FSDP_MIN_ELEMS = 1 << 22    # 4M elements (16 MB fp32)


def param_specs(params, cfg: ModelConfig, rules: AxisRules):
    """PartitionSpec pytree matching ``params`` (works on ShapeDtypeStructs)."""
    def go(path, leaf):
        spec = _leaf_spec(path, leaf, cfg, rules)
        if rules.mesh is None:
            return spec
        if int(np.prod(leaf.shape)) >= FSDP_MIN_ELEMS:
            spec = zero_extend_spec(spec, leaf.shape, rules)
        return _fit_spec(spec, leaf.shape, rules.mesh)
    return jax.tree_util.tree_map_with_path(go, params)


def zero_extend_spec(spec: P, shape: tuple, rules: AxisRules) -> P:
    """ZeRO-style extension: additionally shard the largest unsharded dim
    over the dp axes (if it divides).  Used for optimizer moments and the
    gradient accumulator — they are only touched once per step, so the
    extra gather cost is one parameter-delta all-gather."""
    if not rules.dp or rules.mesh is None:
        return spec
    used = {a for ax in spec if ax is not None
            for a in (ax if isinstance(ax, tuple) else (ax,))}
    if used & set(rules.dp):
        return spec    # dp axes already placed (idempotent)
    extent = rules.dp_size
    tail = tuple(spec) + (None,) * (len(shape) - len(spec))
    cands = [(d, i) for i, (d, ax) in enumerate(zip(shape, tail))
             if ax is None and d % extent == 0 and d >= extent]
    if not cands:
        return spec
    _, idx = max(cands)
    out = list(tail)
    out[idx] = rules.dp if len(rules.dp) > 1 else rules.dp[0]
    return P(*out)


def opt_state_specs(params, pspecs, rules: AxisRules):
    mom = jax.tree_util.tree_map(
        lambda leaf, spec: zero_extend_spec(spec, leaf.shape, rules),
        params, pspecs)
    return {"m": mom, "v": mom, "step": P()}


def grad_accum_specs(params, cfg, rules: AxisRules):
    """Sharding for the microbatch gradient accumulator (ZeRO-2-ish)."""
    ps = param_specs(params, cfg, rules)
    return jax.tree_util.tree_map(
        lambda leaf, spec: zero_extend_spec(spec, leaf.shape, rules),
        params, ps)


def state_specs(params, cfg, rules):
    ps = param_specs(params, cfg, rules)
    return {"params": ps, "opt": opt_state_specs(params, ps, rules),
            "step": P()}


def train_batch_specs(cfg: ModelConfig, rules: AxisRules) -> dict:
    dp = rules.dp if rules.dp else None
    out = {"tokens": P(dp, None), "labels": P(dp, None)}
    if cfg.num_patch_tokens:
        out["patch_embeds"] = P(dp, None, None)
    if cfg.is_encdec:
        out["frames"] = P(dp, None, None)
    return out


def cache_specs(cfg: ModelConfig, rules: AxisRules, batch: int,
                seq_len: int = 8):
    """Decode-cache specs.  Batch over dp when it divides; otherwise
    sequence-parallel over every axis (long_500k, batch 1)."""
    dp = rules.dp if rules.dp else ()
    tp = rules.tp
    big_batch = batch >= max(rules.dp_size, 1) and rules.dp_size > 1
    bspec = dp if big_batch else None
    # sequence axis: tp normally; everything when batch is unshardable
    sspec = tp if big_batch else (tuple(dp) + (tp,) if tp else dp) or None

    def leaf(path, a):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        if name in ("k", "v"):          # (L, B, S, kv, dh)
            return P(None, bspec, sspec, None, None)
        if name in ("ck", "cv"):        # (L, B, S_enc, kv, dh)
            return P(None, bspec, None, None, None)
        if name == "conv":              # (L, B, K-1, inner)
            return P(None, bspec, None, tp)
        if name == "state":             # (L, B, inner, S)
            return P(None, bspec, tp, None)
        if name == "C":                 # mLSTM (L, B, H, dh, dh)
            return P(None, bspec, None, tp, None)
        if name == "n":                 # mLSTM (L,B,H,dh) / sLSTM (L,B,d)
            return P(None, bspec, None, tp) if a.ndim == 4 \
                else P(None, bspec, tp)
        if name == "m":                 # mLSTM (L,B,H) / sLSTM (L,B,d)
            if a.ndim == 3 and a.shape[-1] != cfg.num_heads:
                return P(None, bspec, tp)
            return P(None, bspec, None)
        if name in ("h", "c"):          # sLSTM (L, B, d)
            return P(None, bspec, tp)
        return P(*([None] * a.ndim))

    from repro.models.transformer import init_caches
    shapes = jax.eval_shape(lambda: init_caches(cfg, batch, seq_len))
    def go(path, a):
        spec = leaf(path, a)
        return _fit_spec(spec, a.shape, rules.mesh) if rules.mesh is not None \
            else spec
    return jax.tree_util.tree_map_with_path(go, shapes)
