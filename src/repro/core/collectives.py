"""LACIN-scheduled collectives: ppermute step chains over mesh axes.

These are the paper's 1-factor step schedules (§2, refs [8,9]) realized as
JAX collectives inside ``shard_map``.  Step ``i`` moves exactly the traffic
the port-``i`` 1-factor would carry on the physical CIN, so every step is a
perfect matching: contention-free by construction, with both endpoints of
every exchange using the same step index (the isoport property).

Wire-byte optimality (per device, shard bytes ``b = B/N``):

==================  ==========  =================
collective           steps       bytes on wire
==================  ==========  =================
all_to_all_lacin     N-1         (N-1) * b   (optimal)
all_gather_lacin     N-1         (N-1) * b   (optimal)
reduce_scatter       N-1         (N-1) * b   (optimal)
all_reduce           2(N-1)      2(N-1) * b  (optimal, RS+AG)
==================  ==========  =================

Unlike ring algorithms (same byte counts), every datum crosses exactly ONE
link — single-hop minimal routing on the CIN, the paper's diameter-1
advantage.  All functions must be called inside ``shard_map`` with
``axis_name`` bound.

``axis_size`` is optional: when omitted it is read statically from the
bound axis, so the schedule always matches the mesh.  The mesh-aware
front-end (``repro.fabric.LacinCollectives`` and the hierarchical
multi-axis / two-level schedules) builds on these single-axis chains.
"""
from __future__ import annotations

import warnings
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro._compat import LacinDeprecationWarning
from repro._compat.jaxapi import axis_size as _bound_axis_size

from .schedule import LacinSchedule, make_schedule


def _resolve_axis_size(axis_name: str, axis_size: int | None) -> int:
    """``axis_size`` if given, else the static size of the bound axis."""
    if axis_size is None:
        return _bound_axis_size(axis_name)
    return int(axis_size)


def _partners_for(sched: LacinSchedule) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(steps, n) send-target and recv-source tables as device constants."""
    return (jnp.asarray(np.asarray(sched.table, dtype=np.int32)),
            jnp.asarray(np.asarray(sched.inv_table, dtype=np.int32)))


# ---------------------------------------------------------------------------
# all-to-all
# ---------------------------------------------------------------------------

def all_to_all_lacin(x: jax.Array, axis_name: str, *, axis_size: int | None = None,
                     instance: str = "auto") -> jax.Array:
    """Personalized all-to-all over ``axis_name``.

    ``x`` has leading dim ``axis_size``; ``x[j]`` is this device's chunk for
    device ``j``.  Returns ``out`` with ``out[j]`` = chunk from device ``j``
    for this device.  N-1 matching steps; step ``i`` exchanges with the
    1-factor-``i`` partner.
    """
    axis_size = _resolve_axis_size(axis_name, axis_size)
    sched = make_schedule(instance, axis_size)
    send_to, recv_from = _partners_for(sched)
    me = lax.axis_index(axis_name)
    out = jnp.zeros_like(x)
    own = jnp.take(x, me, axis=0)
    out = lax.dynamic_update_index_in_dim(out, own, me, axis=0)
    for step in range(sched.num_steps):
        perm = sched.perm(step)
        if not perm:
            continue
        target = send_to[step][me]
        source = recv_from[step][me]
        send = jnp.take(x, target, axis=0)           # my chunk for target
        recv = lax.ppermute(send, axis_name, perm)   # source's chunk for me
        # Idle device (odd-N circle): target == source == me; keep own chunk.
        recv = jnp.where(source == me, own, recv)
        out = lax.dynamic_update_index_in_dim(out, recv, source, axis=0)
    return out


# ---------------------------------------------------------------------------
# all-gather
# ---------------------------------------------------------------------------

def all_gather_lacin(x: jax.Array, axis_name: str, *, axis_size: int | None = None,
                     instance: str = "auto", tiled: bool = False) -> jax.Array:
    """All-gather this device's shard across ``axis_name``.

    Every step sends the *original* shard to the step partner — on a CIN
    each shard travels exactly one hop to each consumer.  Returns shape
    ``(axis_size, *x.shape)`` or concatenated along axis 0 if ``tiled``.
    """
    axis_size = _resolve_axis_size(axis_name, axis_size)
    sched = make_schedule(instance, axis_size)
    _, recv_from = _partners_for(sched)
    me = lax.axis_index(axis_name)
    out = jnp.zeros((axis_size,) + x.shape, x.dtype)
    out = lax.dynamic_update_index_in_dim(out, x, me, axis=0)
    for step in range(sched.num_steps):
        perm = sched.perm(step)
        if not perm:
            continue
        source = recv_from[step][me]
        recv = lax.ppermute(x, axis_name, perm)      # source's original shard
        recv = jnp.where(source == me, x, recv)
        out = lax.dynamic_update_index_in_dim(out, recv, source, axis=0)
    if tiled:
        out = out.reshape((axis_size * x.shape[0],) + x.shape[1:])
    return out


# ---------------------------------------------------------------------------
# reduce-scatter
# ---------------------------------------------------------------------------

def reduce_scatter_lacin(x: jax.Array, axis_name: str, *, axis_size: int | None = None,
                         instance: str = "auto") -> jax.Array:
    """Reduce-scatter over ``axis_name``.

    ``x`` has leading dim ``axis_size``; ``x[j]`` is this device's
    contribution to device ``j``'s output shard.  Each step sends the
    partner its addend directly (one hop) and accumulates the received one.
    Returns the reduced shard ``sum_s x_s[me]`` of shape ``x.shape[1:]``.
    """
    axis_size = _resolve_axis_size(axis_name, axis_size)
    sched = make_schedule(instance, axis_size)
    send_to, recv_from = _partners_for(sched)
    me = lax.axis_index(axis_name)
    acc = jnp.take(x, me, axis=0)
    for step in range(sched.num_steps):
        perm = sched.perm(step)
        if not perm:
            continue
        target = send_to[step][me]
        source = recv_from[step][me]
        send = jnp.take(x, target, axis=0)           # my addend for target
        recv = lax.ppermute(send, axis_name, perm)   # source's addend for me
        recv = jnp.where(source == me, jnp.zeros_like(recv), recv)
        acc = acc + recv
    return acc


# ---------------------------------------------------------------------------
# all-reduce = reduce-scatter + all-gather
# ---------------------------------------------------------------------------

def all_reduce_lacin(x: jax.Array, axis_name: str, *, axis_size: int | None = None,
                     instance: str = "auto") -> jax.Array:
    """All-reduce (sum) of an arbitrary-shaped array over ``axis_name``.

    RS+AG decomposition over a flattened, padded view: 2(N-1) matching
    steps, wire-optimal 2(N-1)/N * bytes.
    """
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1)
    n = _resolve_axis_size(axis_name, axis_size)
    pad = (-flat.size) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(n, -1)
    shard = reduce_scatter_lacin(chunks, axis_name, axis_size=n, instance=instance)
    full = all_gather_lacin(shard, axis_name, axis_size=n, instance=instance)
    flat = full.reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# Deprecated shims (one release): superseded by the mesh-aware
# repro.fabric.LacinCollectives front-end.
# ---------------------------------------------------------------------------

def tree_all_reduce_lacin(tree, axis_name: str, *, axis_size: int | None = None,
                          instance: str = "auto"):
    """Deprecated: use ``repro.fabric.LacinCollectives(mesh).tree_all_reduce``."""
    warnings.warn(
        "tree_all_reduce_lacin is deprecated; use "
        "repro.fabric.LacinCollectives(mesh, instance=...).tree_all_reduce(tree, axis)",
        LacinDeprecationWarning, stacklevel=2)
    return jax.tree_util.tree_map(
        partial(all_reduce_lacin, axis_name=axis_name, axis_size=axis_size,
                instance=instance), tree)


def psum_or_lacin(x, axis_name: str, *, axis_size: int | None = None,
                  impl: str = "xla", instance: str = "auto"):
    """Deprecated: use ``repro.fabric.LacinCollectives(mesh, impl=...).psum``."""
    warnings.warn(
        "psum_or_lacin is deprecated; use "
        "repro.fabric.LacinCollectives(mesh, instance=..., impl=...).psum(x, axis)",
        LacinDeprecationWarning, stacklevel=2)
    if impl == "xla":
        return lax.psum(x, axis_name)
    return all_reduce_lacin(x, axis_name, axis_size=axis_size, instance=instance)
