"""The paper's primary contribution: LACIN — isoport Complete
Interconnection Network instances, their table-free routing, linear
layouts, large-scale compositions (HyperX / Dragonfly), and the 1-factor
step schedules that drive LACIN-scheduled JAX collectives.

Instance dispatch (``port_matrix`` / ``route`` / ``make_schedule`` / ...)
resolves names through the :mod:`repro.fabric` registry; the unified
topology surface (``Fabric`` objects, mesh-aware collectives) lives in
:mod:`repro.fabric`.
"""
from .port_matrix import (IDLE, circle_matrix, circle_neighbor,
                          is_complete, is_isoport, is_power_of_two,
                          port_matrix, swap_matrix, swap_neighbor,
                          swap_peer_port, verify_instance, xor_matrix,
                          xor_neighbor)
from .factorization import (column_contention, factor, factorization,
                            factors, is_one_factorization,
                            is_perfect_matching)
from .routing import (ROUTING_COST, route, route_circle,
                      route_circle_closed, route_jnp, route_packet,
                      route_swap, route_xor, routing_ops)
from .layout import (circle_layout_crossings_with_rule,
                     circle_predicted_crossings, column_report,
                     factor_crossings, instance_crossings,
                     lacin_total_wire_length,
                     lacin_total_wire_length_enumerated, swap_to_lacin_ratio,
                     swap_total_wire_length, table1, wire_length_histogram)
from .hyperx import (HyperXConfig, HyperXDeployment, all_pairs_max_hops,
                     fig4_4cubed, paper_16cubed)
from .dragonfly import (DragonflyConfig, PartitionedCIN, fig3_16,
                        frontier_like, hpe_dragonfly_group)
from .schedule import LacinSchedule, make_schedule, partner_table, schedule_for_axis
from .collectives import (all_gather_lacin, all_reduce_lacin,
                          all_to_all_lacin, psum_or_lacin,
                          reduce_scatter_lacin, tree_all_reduce_lacin)
from .simulate import (all_to_all_steps, cin_link_loads,
                       dragonfly_link_loads, hyperx_link_loads,
                       schedule_hop_counts, schedule_step_report,
                       valiant_link_loads)


def __getattr__(name: str):
    if name == "INSTANCES":  # deprecated: forwards to port_matrix.__getattr__
        import importlib
        return importlib.import_module(".port_matrix", __name__).INSTANCES
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
