"""Port-pairing matrices for Complete Interconnection Networks (paper §2).

A CIN of ``N`` switches is modeled by a port-pairing matrix ``P`` with ``N``
rows (switches) and ``N-1`` columns (network ports).  ``P[S, i]`` records the
*neighbour switch* reached through port ``i`` of switch ``S``.  The
``N*(N-1)`` ports are paired by ``N*(N-1)/2`` links forming the complete
graph K_N; different pairings are different *CIN instances*.

This module holds the *primitive* neighbour functions of the paper's
three instances (Figure 2):

* ``swap``   — anisoport baseline: successively connect each switch to all
  the others using the first available ports.  ``P[S, i]`` pairs with
  ``P[i+1, S]`` when ``S <= i`` and with ``P[i, S-1]`` when ``S > i``.
* ``circle`` — isoport, any ``N``.  Round-robin-tournament 1-factorization
  (paper Algorithm 1).  Odd ``N`` is obtained from the even ``N+1`` matrix
  by deleting the last row (one idle port per switch remains).
* ``xor``    — isoport, ``N = 2**n``.  Port index ``i = A ^ B - 1``; since
  XOR is self-inverse, ``P[S, i]`` pairs with ``P[S ^ (i+1), i]``.

Instance *dispatch* lives in the :mod:`repro.fabric.registry`: the
primitives below are registered there as built-ins, and
:func:`port_matrix` / :func:`verify_instance` resolve names through the
registry — so ``repro.fabric.register_instance`` extends them (and every
downstream consumer) without edits here.

Everything here is plain ``numpy`` — these are construction/verification
tools, not traced code.  The jnp-vectorized routing used inside jitted
programs lives in :mod:`repro.core.routing`.
"""
from __future__ import annotations

import numpy as np

# Sentinel for an idle (unconnected) port.  Only appears for odd-N Circle.
IDLE = -1


def _require_positive(n: int) -> None:
    if n < 2:
        raise ValueError(f"CIN needs at least 2 switches, got N={n}")


def is_power_of_two(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


# ---------------------------------------------------------------------------
# Neighbour functions (scalar semantics, vectorized over numpy arrays).
# ---------------------------------------------------------------------------

def swap_neighbor(s, i):
    """Neighbour of switch ``s`` through port ``i`` in the Swap instance."""
    s = np.asarray(s)
    i = np.asarray(i)
    return np.where(s <= i, i + 1, i)


def swap_peer_port(s, i):
    """Port index used on the *other* end of Swap link (s, i) — anisoport."""
    s = np.asarray(s)
    i = np.asarray(i)
    return np.where(s <= i, s, s - 1)


def circle_neighbor(s, i, n):
    """Neighbour of switch ``s`` through port ``i`` in the Circle instance.

    Implements paper Algorithm 1 for even ``n``.  For odd ``n`` the matrix
    is the even ``n+1`` construction with the last row removed; port ``i``
    of switch ``i`` becomes IDLE.
    """
    s = np.asarray(s)
    i = np.asarray(i)
    if n % 2 == 0:
        m = n - 1  # modulus
        parallel = np.mod(2 * i - s, m)
        out = np.where(s == n - 1, i, np.where(s == i, n - 1, parallel))
        return out
    # Odd n: even construction on n+1 switches, last switch removed.
    m = n  # (n+1) - 1
    parallel = np.mod(2 * i - s, m)
    return np.where(s == i, IDLE, parallel)


def xor_neighbor(s, i):
    """Neighbour of switch ``s`` through port ``i`` in the XOR instance."""
    s = np.asarray(s)
    i = np.asarray(i)
    return s ^ (i + 1)


# ---------------------------------------------------------------------------
# P-matrix builders.
# ---------------------------------------------------------------------------

def swap_matrix(n: int) -> np.ndarray:
    """Swap (anisoport) P matrix, any ``N >= 2`` (paper Fig. 2a)."""
    _require_positive(n)
    s = np.arange(n)[:, None]
    i = np.arange(n - 1)[None, :]
    return swap_neighbor(s, i).astype(np.int64)


def circle_matrix(n: int) -> np.ndarray:
    """Circle (isoport) P matrix, any ``N >= 2`` (paper Alg. 1 / Fig. 2b)."""
    _require_positive(n)
    s = np.arange(n)[:, None]
    if n % 2 == 0:
        i = np.arange(n - 1)[None, :]
        return circle_neighbor(s, i, n).astype(np.int64)
    # Odd N: ports 0..n-1 exist (from the (n+1)-even construction) but we
    # keep the canonical n-1+1 = n columns?  The even construction on n+1
    # switches has n ports per switch; after deleting the last switch every
    # remaining switch keeps n ports, one of which is idle.
    i = np.arange(n)[None, :]
    return circle_neighbor(s, i, n).astype(np.int64)


def xor_matrix(n: int) -> np.ndarray:
    """XOR (isoport) P matrix, ``N = 2**n`` only (paper Fig. 2c)."""
    _require_positive(n)
    if not is_power_of_two(n):
        raise ValueError(f"XOR CIN instance requires N to be a power of two, got {n}")
    s = np.arange(n)[:, None]
    i = np.arange(n - 1)[None, :]
    return xor_neighbor(s, i).astype(np.int64)


def port_matrix(instance: str, n: int) -> np.ndarray:
    """P matrix of any registered CIN instance (resolved via the
    :mod:`repro.fabric` registry).

    ``P[s, i]`` is the switch that port ``i`` of switch ``s`` links to;
    for isoport instances the far end uses the *same* port index — the
    paper's cabling discipline:

    >>> port_matrix("xor", 4)
    array([[1, 2, 3],
           [0, 3, 2],
           [3, 0, 1],
           [2, 1, 0]])
    >>> int(port_matrix("xor", 4)[port_matrix("xor", 4)[1, 2], 2])
    1
    """
    from repro.fabric.registry import get_instance
    return get_instance(instance).matrix(n)


def __getattr__(name: str):
    if name == "INSTANCES":
        import warnings

        from repro._compat import LacinDeprecationWarning
        warnings.warn(
            "repro.core.port_matrix.INSTANCES is deprecated; use "
            "repro.fabric.instance_names() — the registry also lists "
            "instances registered after import", LacinDeprecationWarning,
            stacklevel=2)
        return ("swap", "circle", "xor")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


# ---------------------------------------------------------------------------
# Structural checks (used by tests and by the simulator).
# ---------------------------------------------------------------------------

def is_complete(P: np.ndarray) -> bool:
    """Every switch sees every other switch exactly once across its ports."""
    n = P.shape[0]
    for s in range(n):
        row = P[s]
        row = row[row != IDLE]
        expect = sorted(set(range(n)) - {s})
        if sorted(row.tolist()) != expect:
            return False
    return True


def is_isoport(P: np.ndarray) -> bool:
    """True iff every link pairs ports with the same index.

    Port ``i`` of ``S`` reaches ``T = P[S, i]``; the instance is isoport iff
    ``P[T, i] == S`` for every non-idle entry — i.e. each column is an
    involution (a perfect matching = 1-factor).
    """
    n, p = P.shape
    for i in range(p):
        col = P[:, i]
        for s in range(n):
            t = col[s]
            if t == IDLE:
                continue
            if not (0 <= t < n) or col[t] != s:
                return False
    return True


def links(P: np.ndarray, peer_port=None) -> set[tuple[tuple[int, int], tuple[int, int]]]:
    """The set of links as ((switch, port), (switch, port)) endpoint pairs.

    ``peer_port(s, i)`` gives the far-end port index; defaults to the
    isoport rule (same index).  Each link appears once (endpoints sorted).
    """
    n, p = P.shape
    out = set()
    for s in range(n):
        for i in range(p):
            t = int(P[s, i])
            if t == IDLE:
                continue
            j = int(peer_port(s, i)) if peer_port is not None else i
            a, b = (s, i), (t, j)
            out.add((a, b) if a <= b else (b, a))
    return out


def edge_set(P: np.ndarray) -> set[tuple[int, int]]:
    """The set of undirected switch pairs covered by the instance."""
    return {tuple(sorted((s, int(t)))) for s in range(P.shape[0])
            for t in P[s] if t != IDLE}


def verify_instance(instance: str, n: int) -> dict:
    """Full structural verification of a registered CIN instance.

    The far-end port rule comes from the registry spec: isoport instances
    pair same-index ports; anisoport ones supply ``peer_port``.
    """
    from repro.fabric.registry import get_instance
    spec = get_instance(instance)
    P = spec.matrix(n)
    peer = None if spec.isoport else (lambda s, i: spec.peer_port(s, i, n))
    L = links(P, peer_port=peer)
    n_idle = int(np.sum(P == IDLE))
    expected_links = (n * (n - 1)) // 2
    report = {
        "instance": instance,
        "n": n,
        "complete": is_complete(P),
        "isoport": is_isoport(P),
        "num_links": len(L),
        "expected_links": expected_links,
        "num_idle_ports": n_idle,
        "covers_K_N": edge_set(P) == {(a, b) for a in range(n) for b in range(a + 1, n)},
    }
    report["ok"] = (report["complete"] and report["covers_K_N"]
                    and report["num_links"] == report["expected_links"])
    return report
