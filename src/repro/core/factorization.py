"""1-factorizations of K_N extracted from isoport P matrices (paper §2).

A *1-factor* of an even-order graph is a perfect matching; a
*1-factorization* of K_N (N even) partitions its N(N-1)/2 edges into N-1
1-factors.  Isoport CIN instances use the N ports of index ``i`` to build
1-factor ``i`` — this is the structural property behind both the cabling
discipline (§4) and the step-wise all-to-all schedules (§2, refs [8,9]).
"""
from __future__ import annotations

import numpy as np

from .port_matrix import IDLE, port_matrix


def factor(P: np.ndarray, i: int) -> list[tuple[int, int]]:
    """Edge list of 1-factor ``i`` (column ``i``) of an isoport P matrix."""
    col = P[:, i]
    edges = set()
    for s, t in enumerate(col):
        t = int(t)
        if t == IDLE:
            continue
        edges.add((min(s, t), max(s, t)))
    return sorted(edges)


def factors(P: np.ndarray) -> list[list[tuple[int, int]]]:
    """All 1-factors of an isoport P matrix."""
    return [factor(P, i) for i in range(P.shape[1])]


def is_perfect_matching(edges: list[tuple[int, int]], n: int) -> bool:
    """Every vertex covered exactly once (n even) or exactly one idle (odd)."""
    seen: set[int] = set()
    for a, b in edges:
        if a == b or a in seen or b in seen:
            return False
        seen.update((a, b))
    if n % 2 == 0:
        return len(seen) == n
    return len(seen) == n - 1  # one idle switch per factor for odd N


def is_one_factorization(P: np.ndarray) -> bool:
    """Columns are disjoint perfect matchings that cover K_N."""
    n = P.shape[0]
    all_edges: set[tuple[int, int]] = set()
    for i in range(P.shape[1]):
        f = factor(P, i)
        if not is_perfect_matching(f, n):
            return False
        fs = set(f)
        if all_edges & fs:
            return False  # factors must be edge-disjoint
        all_edges |= fs
    return all_edges == {(a, b) for a in range(n) for b in range(a + 1, n)}


def factorization(instance: str, n: int) -> list[list[tuple[int, int]]]:
    """The 1-factorization induced by an isoport instance."""
    if instance == "swap":
        raise ValueError("swap is anisoport: its columns are not 1-factors")
    return factors(port_matrix(instance, n))


def column_contention(P: np.ndarray) -> np.ndarray:
    """Per-column max endpoint multiplicity.

    1.0 for isoport instances (each column is a matching).  For Swap this
    quantifies why the 'port i' step is NOT contention-free: column ``i``
    concentrates endpoints on switches ``i`` and ``i+1``.
    """
    n, p = P.shape
    out = np.zeros(p, dtype=np.int64)
    for i in range(p):
        col = P[:, i]
        counts = np.zeros(n, dtype=np.int64)
        for s, t in enumerate(col):
            if int(t) == IDLE:
                continue
            counts[int(t)] += 1
        out[i] = counts.max()
    return out
