"""Minimal table-free routing for CIN instances (paper §3, Algorithm 2).

A computer has a two-digit global address ``C = (C1, C0)``: switch and
edge-port.  Intra-switch (``A1 == B1``) or after the single network hop the
packet ejects through port ``B0``.  For ``A1 != B1`` the network port is a
pure function of ``(A1, B1)`` — no routing tables:

* **XOR**:    ``i = A ^ B - 1``                       (logic gates + decrementer)
* **Swap**:   ``i = B - 1 if A < B else B``           (comparator + decrementer)
* **Circle**: paper Algorithm 2 (a handful of adds/compares), equivalent to
  the closed form ``i = (A + B) * inv2 mod (N-1)`` with ``inv2 = N/2``
  (since ``2 * N/2 = N ≡ 1 (mod N-1)``), plus the two ``N-1`` special cases.

NOTE (erratum): §3's prose states Swap routing as ``i = B if A <= B else
B + 1``, which contradicts §2's pairing rule ``P[S,i] ~ P[i+1,S] (S<=i)``;
routing consistent with the §2 construction is ``i = B-1 if A < B else B``.
We implement the §2-consistent form and verify ``route∘neighbor == id``
exhaustively in tests.

Two implementation tiers:
* ``route_*``      — scalar/numpy, faithful branch structure, used by the
                     simulator, benchmarks, and the hardware cost model.
* ``route_*_jnp``  — branchless ``jnp`` versions, safe inside jit/shard_map
                     (e.g., to build ppermute partner tables at trace time).

Name-based dispatch (:func:`route` / :func:`route_jnp` /
:func:`routing_ops`) resolves through the :mod:`repro.fabric` registry,
so instances added via ``register_instance`` route here too.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Scalar / numpy routing (vectorized over arrays, faithful semantics).
# ---------------------------------------------------------------------------

def route_swap(a, b):
    """Port used at switch ``a`` to reach switch ``b`` (Swap instance)."""
    a = np.asarray(a)
    b = np.asarray(b)
    return np.where(a < b, b - 1, b)


def route_xor(a, b):
    """Port used at switch ``a`` to reach switch ``b`` (XOR instance)."""
    a = np.asarray(a)
    b = np.asarray(b)
    return (a ^ b) - 1


def route_circle(a, b, n):
    """Port used at switch ``a`` to reach ``b`` (Circle; paper Algorithm 2).

    Faithful to the published branch structure for even ``n``; odd ``n``
    uses the (n+1)-even construction (no ``n-1`` special cases, modulus n).
    """
    a = np.asarray(a)
    b = np.asarray(b)
    if n % 2 == 0:
        m = n - 1
        t = a + b
        parallel_even = t // 2
        parallel_odd_lo = (t + m) // 2          # T odd, T < N-1
        parallel_odd_hi = (t - m) // 2          # T odd, T > N-1
        parallel = np.where(
            t == m, 0,
            np.where(t % 2 == 0, parallel_even,
                     np.where(t < m, parallel_odd_lo, parallel_odd_hi)))
        return np.where(a == n - 1, b, np.where(b == n - 1, a, parallel))
    # Odd n: modulus n, inverse of 2 is (n+1)//2.
    inv2 = (n + 1) // 2
    return np.mod((a + b) * inv2, n)


def route_circle_closed(a, b, n):
    """Closed form of Algorithm 2: ``i = (A+B) * inv2 mod (N-1)`` (+ specials).

    Used to cross-check the faithful branch structure.
    """
    a = np.asarray(a)
    b = np.asarray(b)
    if n % 2 == 0:
        m = n - 1
        inv2 = n // 2  # 2 * (n/2) = n ≡ 1 (mod n-1)
        parallel = np.mod((a + b) * inv2, m)
        return np.where(a == n - 1, b, np.where(b == n - 1, a, parallel))
    inv2 = (n + 1) // 2
    return np.mod((a + b) * inv2, n)


def route(instance: str, a, b, n: int):
    """Routing for any registered CIN instance (via :mod:`repro.fabric`):
    the port used at ``a`` to reach ``b``, computed table-free (§3).

    Routing is the inverse of the P matrix in the port argument:

    >>> int(route("xor", 5, 3, 8))        # 5 ^ 3 = 6 -> port 6 - 1
    5
    >>> from repro.core.port_matrix import port_matrix
    >>> int(port_matrix("xor", 8)[5, route("xor", 5, 3, 8)])
    3
    """
    from repro.fabric.registry import get_instance
    return get_instance(instance).route(a, b, n)


# ---------------------------------------------------------------------------
# Branchless jnp routing (trace-safe).
# ---------------------------------------------------------------------------

def route_swap_jnp(a, b):
    return jnp.where(a < b, b - 1, b)


def route_xor_jnp(a, b):
    return jnp.bitwise_xor(a, b) - 1


def route_circle_jnp(a, b, n: int):
    if n % 2 == 0:
        m = n - 1
        inv2 = n // 2
        parallel = jnp.mod((a + b) * inv2, m)
        return jnp.where(a == n - 1, b, jnp.where(b == n - 1, a, parallel))
    inv2 = (n + 1) // 2
    return jnp.mod((a + b) * inv2, n)


def route_jnp(instance: str, a, b, n: int):
    """Trace-safe routing for any registered instance providing one."""
    from repro.fabric.registry import get_instance
    spec = get_instance(instance)
    if spec.route_jnp is None:
        raise ValueError(
            f"CIN instance {instance!r} registered no trace-safe "
            f"route_jnp; pass one to register_instance")
    return spec.route_jnp(a, b, n)


# ---------------------------------------------------------------------------
# Hardware cost model (paper Table 1, 'Routing cost' column).
# ---------------------------------------------------------------------------

#: Number of adder/comparator-class operations on the routing critical path,
#: *additional to XOR* (whose cost is gates + one decrementer).  Matches the
#: paper's Table 1: Swap = 1 (one comparator), Circle = 5.
ROUTING_COST = {"xor": 0, "swap": 1, "circle": 5}


def routing_ops(instance: str) -> dict:
    """Arithmetic on the routing critical path, from the registry spec.

    For the paper's instances (Table 1): XOR is gates + one decrementer;
    Swap adds one comparator; Circle (Algorithm 2) adds T = A+B, compares
    against N-1 and a parity test, then one of T/2, (T+N-1)/2, (T-N+1)/2.
    """
    from repro.fabric.registry import get_instance
    spec = get_instance(instance)
    if spec.routing_ops is None:
        raise ValueError(f"CIN instance {instance!r} registered no "
                         f"routing-cost breakdown")
    return dict(spec.routing_ops)


# ---------------------------------------------------------------------------
# End-to-end address routing (two-digit addresses, §3).
# ---------------------------------------------------------------------------

def route_packet(instance: str, n: int, src: tuple[int, int],
                 dst: tuple[int, int]) -> list[tuple[int, int]]:
    """Full minimal path as a list of (switch, port) hops.

    ``src``/``dst`` are (switch, edge_port) computer addresses.  Returns the
    sequence of (switch, output-port) decisions: at most one network hop
    followed by the ejection port ``B0``.
    """
    a1, _ = src
    b1, b0 = dst
    hops: list[tuple[int, int]] = []
    if a1 != b1:
        hops.append((a1, int(route(instance, a1, b1, n))))
    hops.append((b1, int(b0)))  # ejection through edge port B0
    return hops
