"""Flow-level network simulation for CIN / HyperX fabrics.

Validates the paper's structural claims without packet-level machinery:

* **Delivery**: every (src, dst) pair routed by the instance's table-free
  function arrives (diameter 1 for a CIN; <= D for HyperX DOR).
* **Contention-freedom of isoport step schedules**: in step ``i`` of a
  1-factor schedule every link of factor ``i`` carries exactly one flow in
  each direction; an anisoport (Swap-column) "schedule" concentrates
  endpoints and serializes.
* **Uniform-traffic link loads**: on a CIN every network link carries
  exactly ``2 / N``-normalized load under all-to-all switch traffic (each
  unordered pair exchanges two directed flows over its dedicated link).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .port_matrix import IDLE, port_matrix
from .routing import route
from .dragonfly import DragonflyConfig
from .hyperx import HyperXConfig


# ---------------------------------------------------------------------------
# CIN all-to-all (directed) link loads.
# ---------------------------------------------------------------------------

def cin_link_loads(instance: str, n: int) -> dict[tuple[int, int], int]:
    """Directed flow counts per (src_switch, dst_switch) link under
    all-to-all: every ordered pair (a, b), a != b, sends one unit flow.

    In a CIN the minimal path is the direct link, so every directed link
    carries exactly one flow — the ideal load balance the paper leverages.
    """
    P = port_matrix(instance, n)
    loads: dict[tuple[int, int], int] = {}
    for a in range(n):
        for b in range(n):
            if a == b:
                continue
            i = int(route(instance, a, b, n))
            t = int(P[a, i])
            assert t == b, f"{instance} N={n}: route({a},{b})={i} lands on {t}"
            loads[(a, b)] = loads.get((a, b), 0) + 1
    return loads


# ---------------------------------------------------------------------------
# Step schedules: 1-factor (isoport) vs column-of-Swap (anisoport).
# ---------------------------------------------------------------------------

@dataclass
class StepReport:
    step: int
    flows: int
    max_link_load: int      # flows sharing one directed link
    max_endpoint_in: int    # flows terminating at one switch
    idle_switches: int


def schedule_step_report(instance: str, n: int) -> list[StepReport]:
    """Simulate the step-wise exchange in which, at step ``i``, every switch
    sends through its port ``i`` (paper refs [8, 9]).

    Isoport instances: step ``i`` is 1-factor ``i`` — a perfect matching —
    so ``max_link_load == 1`` and ``max_endpoint_in == 1``.
    Swap: column ``i`` concentrates endpoints on switches ``i`` / ``i+1``.
    """
    P = port_matrix(instance, n)
    reports = []
    for i in range(P.shape[1]):
        col = P[:, i]
        in_counts = np.zeros(n, dtype=np.int64)
        link_counts: dict[tuple[int, int], int] = {}
        flows = idle = 0
        for s in range(n):
            t = int(col[s])
            if t == IDLE:
                idle += 1
                continue
            flows += 1
            in_counts[t] += 1
            link_counts[(s, t)] = link_counts.get((s, t), 0) + 1
        reports.append(StepReport(
            step=i, flows=flows,
            # default=0: a step can be all-idle (odd-N Circle columns).
            max_link_load=max(link_counts.values(), default=0),
            max_endpoint_in=int(in_counts.max()),
            idle_switches=idle))
    return reports


def all_to_all_steps(instance: str, n: int) -> int:
    """Steps for a full personalized all-to-all using the step schedule.

    Isoport: N-1 steps (N even) or N (odd; one idle per step).  Swap's
    column schedule is not a matching, so the serialized step count is the
    sum over columns of the max endpoint multiplicity.
    """
    reports = schedule_step_report(instance, n)
    if instance == "swap":
        return int(sum(r.max_endpoint_in for r in reports))
    return len(reports)


# ---------------------------------------------------------------------------
# Non-minimal (Valiant) routing — the paper's §3 adaptive sketch.
# ---------------------------------------------------------------------------

def valiant_link_loads(instance: str, n: int, flows: list[tuple[int, int, float]],
                       seed: int = 0, spread: bool = True) -> dict:
    """Two-hop Valiant routing on a CIN for a *hot-flow* traffic pattern.

    ``flows``: (src, dst, demand).  Minimal routing puts each flow on its
    single dedicated link (max link load = demand); Valiant splits the
    demand over all N-2 two-hop paths via random intermediates — the §3
    observation that non-minimal adaptivity needs either restricted routes
    or 2 VCs for deadlock freedom, traded for hot-link relief.

    Returns {max_min, max_valiant, vc_required}.
    """
    loads_min: dict[tuple[int, int], float] = {}
    loads_val: dict[tuple[int, int], float] = {}
    for a, b, demand in flows:
        if a == b:
            continue
        loads_min[(a, b)] = loads_min.get((a, b), 0.0) + demand
        mids = [m for m in range(n) if m not in (a, b)]
        if not spread or not mids:
            loads_val[(a, b)] = loads_val.get((a, b), 0.0) + demand
            continue
        share = demand / len(mids)
        for m in mids:
            loads_val[(a, m)] = loads_val.get((a, m), 0.0) + share
            loads_val[(m, b)] = loads_val.get((m, b), 0.0) + share
    return {
        "max_min": max(loads_min.values(), default=0.0),
        "max_valiant": max(loads_val.values(), default=0.0),
        "vc_required": 2,   # one VC per hop class (paper §3)
    }


# ---------------------------------------------------------------------------
# Hop-count accounting: the CIN diameter-1 advantage vs ring schedules.
# ---------------------------------------------------------------------------

def schedule_hop_counts(n: int) -> dict:
    """Datum-hops for an all-to-all: LACIN 1-factor schedules deliver every
    chunk in ONE hop (dedicated link); a ring schedule forwards chunk k
    through k intermediate devices."""
    lacin_total = n * (n - 1) * 1
    ring_total = n * sum(range(1, n))         # chunk to distance-k: k hops
    return {
        "lacin_hops_total": lacin_total,
        "ring_hops_total": ring_total,
        "lacin_max_hops": 1,
        "ring_max_hops": n - 1,
        "ratio": ring_total / lacin_total,
    }


# ---------------------------------------------------------------------------
# HyperX DOR link loads (uniform endpoint traffic).
# ---------------------------------------------------------------------------

def hyperx_link_loads(cfg: HyperXConfig, sample_pairs: int | None = None,
                      seed: int = 0) -> dict:
    """Directed network-link loads under uniform switch-to-switch traffic
    routed with DOR.  Returns summary stats; exact for small configs.
    """
    rng = np.random.default_rng(seed)
    n = cfg.num_switches
    coords = [cfg.switch_coord(s) for s in range(n)]
    loads: dict[tuple[tuple, tuple], int] = {}

    def add(a: tuple, b: tuple):
        loads[(a, b)] = loads.get((a, b), 0) + 1

    if sample_pairs is None:
        pairs = [(a, b) for a in range(n) for b in range(n) if a != b]
    else:
        pairs = []
        while len(pairs) < sample_pairs:
            a, b = rng.integers(0, n, 2)
            if a != b:
                pairs.append((int(a), int(b)))

    total_hops = 0
    for a, b in pairs:
        cur = list(coords[a])
        for d in range(cfg.num_dims):
            if cur[d] == coords[b][d]:
                continue
            nxt = cur.copy()
            nxt[d] = coords[b][d]
            add(tuple(cur), tuple(nxt))
            cur = nxt
            total_hops += 1
        assert tuple(cur) == coords[b]

    vals = np.array(list(loads.values()))
    return {
        "pairs": len(pairs),
        "total_hops": total_hops,
        "avg_hops": total_hops / len(pairs),
        "links_used": len(loads),
        "max_link_load": int(vals.max()),
        "min_link_load": int(vals.min()),
        "mean_link_load": float(vals.mean()),
        "load_cv": float(vals.std() / vals.mean()),
    }


# ---------------------------------------------------------------------------
# Dragonfly closed-form link loads (local/global split).
# ---------------------------------------------------------------------------

def dragonfly_link_loads(cfg: DragonflyConfig) -> dict:
    """Closed-form directed link loads under uniform switch-to-switch
    all-to-all (one unit per ordered switch pair), minimal l-g-l routing.

    Every directed *global* link carries exactly ``a**2`` units (all
    ordered switch pairs between its two groups) — the perfect balance of
    one dedicated link per group pair.  A directed *local* link
    ``(g, s) -> (g, t)`` carries::

        1  +  a * cnt_g[t]  +  a * cnt_g[s]

    where ``cnt_g[x]`` counts the peer groups whose global colour (the
    global CIN's port index ``route(g, peer)``) lives on switch ``x`` of
    group ``g``: the direct intra-group flow, plus source-side transit
    (``s`` sending to the ``a`` switches of each peer group exiting at
    ``t``), plus destination-side transit (flows from each peer group
    entering at ``s``, fanning out to ``t``).

    Returns ``{"local": {(g, s, t): load}, "global": {(g, h): a*a},
    "summary": {...}}``; cross-checked link-for-link against the packet
    simulator's :func:`repro.sim.topology.dragonfly_topology` in tests.
    """
    a, g = cfg.group_size, cfg.num_groups
    local: dict[tuple[int, int, int], int] = {}
    glob: dict[tuple[int, int], int] = {}
    owner_counts = np.zeros((g, a), dtype=np.int64)
    for grp in range(g):
        for peer in range(g):
            if peer == grp:
                continue
            sw, _ = cfg.global_port_owner(grp, peer)
            owner_counts[grp, sw] += 1
            glob[(grp, peer)] = a * a
    for grp in range(g):
        cnt = owner_counts[grp]
        for s in range(a):
            for t in range(a):
                if s == t:
                    continue
                local[(grp, s, t)] = int(1 + a * cnt[t] + a * cnt[s])
    lvals = np.array(list(local.values())) if local else np.zeros(1)
    return {
        "local": local,
        "global": glob,
        "summary": {
            "global_link_load": a * a,
            "global_links_used": len(glob),
            "local_links_used": len(local),
            "local_max": int(lvals.max()),
            "local_min": int(lvals.min()),
            "local_mean": float(lvals.mean()),
            "total_units": int(sum(local.values()) + sum(glob.values())),
        },
    }
