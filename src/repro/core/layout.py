"""LACIN linear layouts: wire length and crossing analysis (paper §4).

Switches sit on a line at integer positions ``0..N-1``.  In an isoport
instance every link joins two ports with the same index, so links run
straight inside per-port-index "columns": link (a, b) has length ``|a-b|``.
The paper's claims reproduced here:

* K_N on a line needs ``w`` wires of length ``N-w`` (``1 <= w <= N-1``) and
  total wire length ``(N^3 - N) / 6`` — the minimum of any 1-D layout.
* Anisoport Swap needs oblique wires: a link with vertical span ``k`` has a
  horizontal run ``k-1`` (port offset), length ``sqrt(k^2 + (k-1)^2)``;
  asymptotically ``sqrt(2)`` times LACIN's total.
* Circle admits a crossing-free layout: each 1-factor ``i`` has >= N/2 - 1
  parallel links plus the single link (i, N-1) which crosses ``i`` of them
  for ``0 <= i <= N/2-1`` and ``N-2-i`` for ``N/2 <= i <= N-2``; routing the
  parallel wires right of the port column and the crossing wire left of it
  removes all crossings.
* XOR layouts keep in-factor crossings that grow with N.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from .factorization import factors
from .port_matrix import IDLE, port_matrix, swap_peer_port


# ---------------------------------------------------------------------------
# Wire length.
# ---------------------------------------------------------------------------

def wire_length_histogram(n: int) -> dict[int, int]:
    """#wires at each length for any complete graph on a line.

    Length ``d`` occurs ``N - d`` times; equivalently ``w`` wires of length
    ``N - w``.
    """
    return {d: n - d for d in range(1, n)}


def lacin_total_wire_length(n: int) -> int:
    """Exact total wire length of a LACIN: sum_d d*(N-d) = (N^3 - N)/6."""
    return (n ** 3 - n) // 6


def lacin_total_wire_length_enumerated(n: int) -> int:
    """Same total, by explicit enumeration (cross-check for tests)."""
    return sum(d * c for d, c in wire_length_histogram(n).items())


def swap_total_wire_length(n: int) -> float:
    """Exact oblique total for the linear Swap layout.

    Every K_N edge appears once; a Swap link between switches at vertical
    distance ``k`` connects ports whose indices differ by ``k - 1``
    (``P[S,i] ~ P[i+1,S]``: |i - S| = k-1 for S <= i), hence length
    ``sqrt(k^2 + (k-1)^2)`` under the paper's similar-spacing assumption.
    """
    P = port_matrix("swap", n)
    total = 0.0
    seen = set()
    for s in range(n):
        for i in range(n - 1):
            t = int(P[s, i])
            j = int(swap_peer_port(s, i))
            key = tuple(sorted(((s, i), (t, j))))
            if key in seen:
                continue
            seen.add(key)
            k = abs(t - s)
            h = abs(j - i)
            total += math.hypot(k, h)
    return total


def swap_to_lacin_ratio(n: int) -> float:
    """Swap oblique total / LACIN straight total — approaches sqrt(2)."""
    return swap_total_wire_length(n) / lacin_total_wire_length(n)


# ---------------------------------------------------------------------------
# Crossing analysis.
# ---------------------------------------------------------------------------

def _pairs_cross(e1: tuple[int, int], e2: tuple[int, int]) -> bool:
    """Two links drawn as arcs in the same column cross iff they interleave."""
    (a1, b1), (a2, b2) = sorted(e1), sorted(e2)
    if (a1, b1) == (a2, b2):
        return False
    return (a1 < a2 < b1 < b2) or (a2 < a1 < b2 < b1)


def factor_crossings(edges: list[tuple[int, int]]) -> int:
    """Number of crossing pairs among same-column (same 1-factor) links."""
    c = 0
    for x in range(len(edges)):
        for y in range(x + 1, len(edges)):
            if _pairs_cross(edges[x], edges[y]):
                c += 1
    return c


def instance_crossings(instance: str, n: int) -> list[int]:
    """Per-1-factor crossing counts for a naive single-track-per-column layout."""
    P = port_matrix(instance, n)
    return [factor_crossings(f) for f in factors(P)]


def circle_predicted_crossings(n: int) -> list[int]:
    """Paper §4 closed form: 1-factor ``i``'s crossing link (i, N-1) crosses
    ``i`` parallel links for i < N/2 and ``N-2-i`` for i >= N/2."""
    assert n % 2 == 0
    return [i if i <= n // 2 - 1 else n - 2 - i for i in range(n - 1)]


def circle_layout_crossings_with_rule(n: int) -> int:
    """Crossings after the paper's left/right rule — always zero.

    Parallel wires of factor ``i`` run on the right sub-track of column
    ``i``; the single potentially-crossing wire (i, N-1) runs on the left
    sub-track.  Two wires on different sub-tracks cannot cross; parallel
    wires of the same factor are nested/disjoint (never interleave).
    """
    P = port_matrix("circle", n)
    total = 0
    for i, f in enumerate(factors(P)):
        special = tuple(sorted((i, n - 1))) if n % 2 == 0 else None
        parallels = [e for e in f if e != special]
        # left sub-track: the special wire alone -> 0 crossings there.
        # right sub-track: parallel wires only.
        total += factor_crossings(parallels)
    return total


@dataclass(frozen=True)
class LayoutRow:
    """One row of the paper's Table 1."""
    instance: str
    isoport: bool
    sizes: str
    wire_length_norm: float  # total wire length / LACIN minimum (asymptotic)
    routing_cost: int | None  # extra adders/comparators vs XOR


def table1(n: int = 64) -> list[LayoutRow]:
    """Reproduce Table 1 (normalized wire length evaluated at ``n``)."""
    from .routing import ROUTING_COST
    return [
        LayoutRow("swap", False, "Any", swap_to_lacin_ratio(n), ROUTING_COST["swap"]),
        LayoutRow("circle", True, "Any", 1.0, ROUTING_COST["circle"]),
        LayoutRow("xor", True, "N=2^n", 1.0, ROUTING_COST["xor"]),
    ]


# ---------------------------------------------------------------------------
# Deployment report: per-column track usage (cable organisation, §2 end).
# ---------------------------------------------------------------------------

def column_report(instance: str, n: int) -> list[dict]:
    """Per port-index 'colour': #links, total length, crossings — the
    cable-organisation view the paper argues isoport instances enable."""
    P = port_matrix(instance, n)
    out = []
    if instance == "swap":
        # Anisoport: columns are not matchings; report endpoint concentration.
        from .factorization import column_contention
        cont = column_contention(P)
        for i in range(P.shape[1]):
            out.append({"column": i, "matching": False,
                        "max_endpoint_multiplicity": int(cont[i])})
        return out
    for i, f in enumerate(factors(P)):
        out.append({
            "column": i,
            "matching": True,
            "num_links": len(f),
            "total_length": sum(b - a for a, b in f),
            "naive_crossings": factor_crossings(f),
        })
    return out
