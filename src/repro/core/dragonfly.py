"""Dragonfly networks with LACIN wiring (paper §5, Figure 3).

A Dragonfly connects ``num_groups`` switch groups via a *global* CIN; each
group of ``group_size`` switches is itself wired as a *local* CIN.  The
paper observes that:

* one-rack groups can use a vertical LACIN along the rack (local CIN);
* the global network applied as a LACIN induces a linear rack organisation;
  with co-packaged photonics, larger groups become rack *rows* with a
  horizontal local LACIN and column-wise global LACIN wiring;
* the 2-level partitioned layout of Fig. 3 (and HPE's 2x4-partition racks)
  is an alternative 2-D arrangement whose bundles our arithmetic below
  reproduces: 4 partitions of 4 switches = 24 intra + 96 inter links in
  6 hoses of 16 wires; 8 partitions = 28 bundles of 16.

Minimal routing is hierarchical: local hop to the switch owning the right
global port, global hop, local hop (l-g-l), each hop resolved by the CIN
instance's table-free routing.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache

import numpy as np

from .routing import route
from .port_matrix import IDLE, is_power_of_two


@lru_cache(maxsize=None)
def _idle_columns(instance: str, n: int) -> tuple[int, ...] | None:
    """Per-switch idle-port column of an odd-size isoport construction.

    Odd-``n`` instances built from the even ``n+1`` matrix keep ``n`` port
    columns with exactly one idle per switch (Circle: column ``s``;
    mirror: column ``-s mod n``).  Returns ``None`` when every column is
    wired (even sizes / ``n-1``-column instances).
    """
    from repro.fabric.registry import get_instance
    spec = get_instance(instance)
    if spec.num_ports(n) != n:
        return None
    P = spec.matrix(n)
    return tuple(int(np.argmax(P[s] == IDLE)) for s in range(n))


@dataclass(frozen=True)
class DragonflyConfig:
    """Balanced dragonfly: ``a`` switches/group, ``p`` terminals/switch,
    ``h`` global ports/switch; canonical balance a = 2p = 2h,
    num_groups <= a*h + 1."""
    group_size: int                     # a
    terminals_per_switch: int           # p
    global_ports_per_switch: int        # h
    num_groups: int                     # g
    local_instance: str = "circle"
    global_instance: str = "circle"

    def __post_init__(self):
        if self.num_groups > self.group_size * self.global_ports_per_switch + 1:
            raise ValueError("too many groups: need g <= a*h + 1 for a global CIN")
        for inst, n in ((self.local_instance, self.group_size),
                        (self.global_instance, self.num_groups)):
            if inst == "xor" and not is_power_of_two(n):
                raise ValueError(f"xor instance needs power-of-two size, got {n}")

    # -- arithmetic -----------------------------------------------------------
    @property
    def switches(self) -> int:
        return self.group_size * self.num_groups

    @property
    def endpoints(self) -> int:
        return self.switches * self.terminals_per_switch

    @property
    def radix(self) -> int:
        return (self.terminals_per_switch + (self.group_size - 1)
                + self.global_ports_per_switch)

    @property
    def local_links_per_group(self) -> int:
        a = self.group_size
        return a * (a - 1) // 2

    @property
    def global_links(self) -> int:
        g = self.num_groups
        return g * (g - 1) // 2  # one (logical) global link per group pair

    @property
    def total_links(self) -> int:
        return self.num_groups * self.local_links_per_group + self.global_links

    # -- global-port ownership --------------------------------------------------
    def global_port_owner(self, group: int, peer_group: int) -> tuple[int, int]:
        """(switch within group, global-port slot) that carries the link from
        ``group`` to ``peer_group``.

        The g-1 global 'colours' of the group are distributed round-robin
        over the a*h global ports: colour c lives on switch c // h, slot
        c % h.  The colour is the global CIN's port index route(group,
        peer_group) — an isoport global instance gives the same colour at
        both ends (the cabling discipline of §5).

        Odd-g instances with g port columns (Circle/mirror) leave one
        colour per group idle; the used colours are compacted around it
        so all g-1 fit on the a*h ports even at num_groups == a*h + 1
        (mirrors :func:`repro.sim.topology.dragonfly_topology`).
        """
        colour = int(route(self.global_instance, group, peer_group, self.num_groups))
        idle = _idle_columns(self.global_instance, self.num_groups)
        if idle is not None:
            colour -= colour > idle[group]
        return colour // self.global_ports_per_switch, colour % self.global_ports_per_switch

    # -- minimal routing ----------------------------------------------------------
    def route_packet(self, src: tuple[int, int, int], dst: tuple[int, int, int]
                     ) -> list[tuple[str, tuple]]:
        """Minimal l-g-l path between (group, switch, terminal) addresses.

        Returns a list of hops: ('local', (group, src_sw, port)) /
        ('global', (group, sw, slot)) / ('eject', (group, sw, terminal)).
        """
        (ga, sa, _), (gb, sb, tb) = src, dst
        hops: list[tuple[str, tuple]] = []
        cur_sw = sa
        if ga != gb:
            exit_sw, slot = self.global_port_owner(ga, gb)
            if cur_sw != exit_sw:
                port = int(route(self.local_instance, cur_sw, exit_sw, self.group_size))
                hops.append(("local", (ga, cur_sw, port)))
                cur_sw = exit_sw
            hops.append(("global", (ga, cur_sw, slot)))
            # arrive at the peer group's owner of the same colour (isoport!)
            cur_sw, _ = self.global_port_owner(gb, ga)
        if cur_sw != sb:
            port = int(route(self.local_instance, cur_sw, sb, self.group_size))
            hops.append(("local", (gb, cur_sw, port)))
            cur_sw = sb
        hops.append(("eject", (gb, cur_sw, tb)))
        return hops

    def max_hops(self) -> int:
        return 3  # l-g-l (plus ejection)


# ---------------------------------------------------------------------------
# Figure 3 / HPE partitioned-rack arithmetic.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PartitionedCIN:
    """A CIN of ``partitions * partition_size`` switches arranged as a
    2-level hierarchy (paper Fig. 3): full CINs inside partitions, and a
    partition-level CIN whose 'links' are bundles of
    ``partition_size**2`` wires."""
    partitions: int
    partition_size: int

    @property
    def switches(self) -> int:
        return self.partitions * self.partition_size

    @property
    def intra_links(self) -> int:
        m = self.partition_size
        return self.partitions * (m * (m - 1) // 2)

    @property
    def inter_links(self) -> int:
        p, m = self.partitions, self.partition_size
        return (p * (p - 1) // 2) * m * m

    @property
    def bundles(self) -> int:
        p = self.partitions
        return p * (p - 1) // 2

    @property
    def wires_per_bundle(self) -> int:
        return self.partition_size ** 2

    @property
    def total_links(self) -> int:
        n = self.switches
        return n * (n - 1) // 2

    def report(self) -> dict:
        assert self.intra_links + self.inter_links == self.total_links
        return {
            "switches": self.switches,
            "partitions": self.partitions,
            "partition_size": self.partition_size,
            "total_links": self.total_links,
            "intra_links": self.intra_links,
            "inter_links": self.inter_links,
            "bundles": self.bundles,
            "wires_per_bundle": self.wires_per_bundle,
        }


def fig3_16() -> PartitionedCIN:
    """Fig. 3: CIN-16 as 4 partitions of 4 — 120 links = 24 intra + 96
    inter, the 96 grouped in 6 hoses of 16 wires."""
    return PartitionedCIN(partitions=4, partition_size=4)


def hpe_dragonfly_group() -> PartitionedCIN:
    """HPE dragonfly group: 32 switches as 2x4 partition columns — 28
    bundles of 16 wires (paper §4)."""
    return PartitionedCIN(partitions=8, partition_size=4)


def frontier_like() -> DragonflyConfig:
    """A Frontier-scale-ish dragonfly for deployment reports (74 groups is
    Frontier's shape; we use a CIN-sized example with LACIN wiring)."""
    return DragonflyConfig(group_size=32, terminals_per_switch=16,
                           global_ports_per_switch=3, num_groups=64,
                           local_instance="circle", global_instance="circle")
