"""Step schedules for collectives, derived from CIN 1-factorizations (§2).

The paper's isoport instances are 1-factorizations of K_N: the N ports of
index ``i`` form 1-factor ``i``.  Read as a *communication schedule*, step
``i`` exchanges data along a perfect matching — every device talks to
exactly one partner, no link is shared, and both endpoints use the same
"port"/step index.  This is precisely the step-wise all-to-all discipline
of the paper's refs [8, 9], and it is what LACIN-scheduled collectives
(:mod:`repro.core.collectives`) execute with ``jax.lax.ppermute``.

A :class:`LacinSchedule` is static (built from numpy at trace time): a
``(steps, n)`` partner table plus the per-step ppermute permutation lists.
``partner[step, s] == s`` marks an idle device (odd-N Circle only).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache

import numpy as np

from .port_matrix import IDLE, is_power_of_two


def partner_table(instance: str, n: int) -> np.ndarray:
    """(steps, n) table: device ``s``'s exchange partner at each step.

    Any *isoport* instance in the :mod:`repro.fabric` registry yields a
    matching schedule: step ``i`` is 1-factor ``i`` (P-matrix column
    ``i``), with idle ports mapped to self.  For the paper's built-ins:

    * ``xor``    — steps = n-1, partner = s ^ (step+1); requires n = 2^k.
    * ``circle`` — steps = n-1 (even n) or n (odd n; one idle per step).

    ``cyclic`` is a schedule-only anisoport baseline (not a CIN pairing):
    partner = (s + step + 1) mod n.  Each step is a permutation but NOT a
    matching (send/recv partners differ) — the paper's anisoport case,
    kept for comparison.  Registered anisoport instances (``swap``) are
    rejected: their columns concentrate endpoints and serialize.
    """
    s = np.arange(n)
    if instance == "cyclic":
        steps = [np.mod(s + i + 1, n) for i in range(n - 1)]
        return np.stack(steps).astype(np.int64)
    from repro.fabric.registry import get_instance
    try:
        spec = get_instance(instance)
    except ValueError:
        raise ValueError(f"unknown schedule instance {instance!r}") from None
    if not spec.isoport:
        raise ValueError(
            f"{instance!r} is anisoport: its P-matrix columns are not "
            f"matchings, so they cannot serve as schedule steps")
    P = spec.matrix(n)
    table = np.where(P == IDLE, s[:, None], P)  # idle -> self
    return table.T.astype(np.int64)


@dataclass(frozen=True)
class LacinSchedule:
    """A static step schedule over one mesh axis.

    ``table[step][s]`` is the device ``s`` *sends to*; ``inv_table[step][s]``
    is the device ``s`` *receives from* (the inverse permutation).  For
    isoport (matching) schedules the two coincide — every step is an
    involution; they differ only for the anisoport ``cyclic`` baseline.
    """
    instance: str
    n: int
    table: tuple[tuple[int, ...], ...]       # (steps, n) send-partner table
    inv_table: tuple[tuple[int, ...], ...]   # (steps, n) recv-source table
    perms: tuple[tuple[tuple[int, int], ...], ...]  # per-step ppermute pairs

    @property
    def num_steps(self) -> int:
        return len(self.table)

    def partners(self, step: int) -> np.ndarray:
        return np.asarray(self.table[step])

    def perm(self, step: int) -> list[tuple[int, int]]:
        return list(self.perms[step])

    # -- structural properties (the paper's guarantees) ---------------------
    def is_matching_per_step(self) -> bool:
        """Isoport property: each step's partner map is an involution."""
        for row in self.table:
            row = np.asarray(row)
            if not np.array_equal(row[row], np.arange(self.n)):
                return False
        return True

    def is_contention_free(self) -> bool:
        """No directed link carries two flows within a step, and no device
        sends or receives twice (permutation per step)."""
        for perm in self.perms:
            srcs = [a for a, _ in perm]
            dsts = [b for _, b in perm]
            if len(set(srcs)) != len(srcs) or len(set(dsts)) != len(dsts):
                return False
        return True

    def covers_all_pairs(self) -> bool:
        """Across steps, every device meets every other exactly once (as a
        send target)."""
        met = {s: set() for s in range(self.n)}
        for row in self.table:
            for s, t in enumerate(row):
                if t == s:
                    continue
                if t in met[s]:
                    return False
                met[s].add(int(t))
        return all(met[s] == set(range(self.n)) - {s} for s in range(self.n))


@lru_cache(maxsize=None)
def make_schedule(instance: str, n: int) -> LacinSchedule:
    """Build (and cache) the schedule for a mesh axis of size ``n``.

    ``instance='auto'`` picks XOR when n is a power of two (simplest
    routing, Table 1) else Circle (defined for any n).

    Every isoport schedule is a 1-factorization read as steps — N-1
    matchings covering all pairs, each step contention-free:

    >>> s = make_schedule("auto", 8)
    >>> s.instance, s.num_steps
    ('xor', 7)
    >>> s.is_matching_per_step() and s.is_contention_free()
    True
    >>> s.covers_all_pairs()
    True
    >>> s.partners(0).tolist()            # step 0 = 1-factor 0: s ^ 1
    [1, 0, 3, 2, 5, 4, 7, 6]
    """
    if instance == "auto":
        instance = "xor" if is_power_of_two(n) else "circle"
    table = partner_table(instance, n)
    inv = np.empty_like(table)
    for k, row in enumerate(table):
        inv[k, row] = np.arange(n)  # row is a permutation; invert it
    perms = tuple(
        tuple((s, int(t)) for s, t in enumerate(row) if int(t) != s)
        for row in table)
    return LacinSchedule(
        instance=instance, n=n,
        table=tuple(tuple(int(v) for v in row) for row in table),
        inv_table=tuple(tuple(int(v) for v in row) for row in inv),
        perms=perms)


def schedule_for_axis(mesh, axis_name: str, instance: str = "auto") -> LacinSchedule:
    """Schedule for a named mesh axis."""
    return make_schedule(instance, mesh.shape[axis_name])
