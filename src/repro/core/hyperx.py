"""HyperX networks wired with LACINs (paper §5, Figure 4).

A HyperX is the Cartesian product of complete graphs: switches carry a
coordinate vector ``(c_{D-1}, ..., c_0)`` with ``c_d in [0, K_d)``; switches
that differ in exactly one coordinate are connected — each "row" along a
dimension is a CIN of size ``K_d``.  The paper's flagship example is the
16x16x16 HyperX with 16 terminals per switch: 65,536 end-points, 4,096
radix-61 switches, wired with XOR LACINs (16 = 2^4).

This module provides addressing, per-dimension LACIN port selection,
dimension-order routing (DOR), and the physical deployment arithmetic
(racks, super-ports, hoses, colour classes) that §5 and Fig. 4 describe.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from itertools import product

import numpy as np

from .routing import route
from .port_matrix import port_matrix, is_power_of_two


@dataclass(frozen=True)
class HyperXConfig:
    """A HyperX: ``dims[d]`` switches along dimension ``d``; ``terminals``
    end-points per switch; per-dimension CIN instance."""
    dims: tuple[int, ...]
    terminals: int
    instance: str = "xor"

    def __post_init__(self):
        if self.instance == "xor":
            for k in self.dims:
                if not is_power_of_two(k):
                    raise ValueError(
                        f"XOR LACIN needs power-of-two dimension sizes, got {self.dims}")

    # -- basic arithmetic ---------------------------------------------------
    @property
    def num_dims(self) -> int:
        return len(self.dims)

    @property
    def num_switches(self) -> int:
        return int(np.prod(self.dims))

    @property
    def num_endpoints(self) -> int:
        return self.num_switches * self.terminals

    @property
    def network_ports_per_switch(self) -> int:
        return sum(k - 1 for k in self.dims)

    @property
    def radix(self) -> int:
        return self.terminals + self.network_ports_per_switch

    @property
    def num_links(self) -> int:
        """Total network links: each dimension contributes
        (switches / K_d) rows * K_d(K_d-1)/2 links."""
        n = self.num_switches
        return sum((n // k) * (k * (k - 1) // 2) for k in self.dims)

    @property
    def diameter(self) -> int:
        return self.num_dims

    # -- addressing ----------------------------------------------------------
    def switch_coord(self, s: int) -> tuple[int, ...]:
        """Mixed-radix decode, dimension D-1 most significant."""
        c = []
        for k in reversed(self.dims):
            c.append(s % k)
            s //= k
        return tuple(reversed(c))

    def switch_index(self, coord: tuple[int, ...]) -> int:
        s = 0
        for c, k in zip(coord, self.dims):
            s = s * k + c
        return s

    def endpoint_address(self, e: int) -> tuple[tuple[int, ...], int]:
        """(switch coordinate vector, edge port C0)."""
        return self.switch_coord(e // self.terminals), e % self.terminals

    # -- port numbering ------------------------------------------------------
    # Global port layout on a switch: [terminals] + [dim D-1 ports] + ... +
    # [dim 0 ports]; dimension d's CIN uses K_d - 1 ports.
    def dim_port_base(self, d: int) -> int:
        return self.terminals + sum(self.dims[dd] - 1 for dd in range(d))

    def port_for(self, src: tuple[int, ...], d: int, dst_digit: int) -> int:
        """Global output port at ``src`` to move dimension ``d`` to
        ``dst_digit`` — the per-dimension LACIN routing function."""
        i = int(route(self.instance, src[d], dst_digit, self.dims[d]))
        return self.dim_port_base(d) + i

    # -- routing ---------------------------------------------------------------
    def dor_route(self, src: tuple[int, ...], dst: tuple[int, ...],
                  order: tuple[int, ...] | None = None) -> list[tuple[tuple[int, ...], int]]:
        """Dimension-order minimal route.

        Returns [(switch_coord, global output port), ...]; dimensions whose
        source/destination digits match are skipped (XOR of digits == 0 in
        the paper's formulation).  Deadlock-free with a single buffer class
        (paper §5: DOR in HyperX needs no virtual channels).
        """
        order = order if order is not None else tuple(range(self.num_dims))
        hops = []
        cur = list(src)
        for d in order:
            if cur[d] == dst[d]:
                continue  # dimension skipped
            hops.append((tuple(cur), self.port_for(tuple(cur), d, dst[d])))
            cur[d] = dst[d]
        assert tuple(cur) == tuple(dst)
        return hops

    def route_endpoint(self, a: int, b: int) -> list[tuple[tuple[int, ...], int]]:
        """End-point to end-point minimal path incl. final ejection port."""
        (asw, _), (bsw, b0) = self.endpoint_address(a), self.endpoint_address(b)
        hops = self.dor_route(asw, bsw) if asw != bsw else []
        hops.append((bsw, b0))
        return hops


# ---------------------------------------------------------------------------
# Physical deployment (paper §5 and Figure 4).
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class HyperXDeployment:
    """Rack/hose arithmetic for a 3-D HyperX whose Z dimension lives inside
    racks (one chassis per switch) and whose X/Y dimensions connect racks
    through super-ports and hoses."""
    config: HyperXConfig

    @property
    def chassis_per_rack(self) -> int:
        return self.config.dims[0]  # Z dimension (most-significant digit C3)

    @property
    def num_racks(self) -> int:
        return self.config.num_switches // self.chassis_per_rack

    @property
    def rack_grid(self) -> tuple[int, int]:
        return (self.config.dims[1], self.config.dims[2])  # Y x X

    # Z links live inside a rack: one LACIN of size K_z per rack.
    @property
    def z_links_per_rack(self) -> int:
        k = self.config.dims[0]
        return k * (k - 1) // 2

    @property
    def z_columns_per_rack(self) -> int:
        """LACIN port colours along the rack's vertical dimension."""
        return self.config.dims[0] - 1

    @property
    def z_wires_per_column(self) -> int:
        """Links per 1-factor: K_z / 2 (even K_z)."""
        return self.config.dims[0] // 2

    # X/Y super-ports: per rack, one super-port per port colour per dim.
    def super_ports_per_rack(self, dim: int) -> int:
        return self.config.dims[dim] - 1

    @property
    def wires_per_super_port(self) -> int:
        return self.chassis_per_rack  # one wire per chassis

    def hoses_per_line(self, dim: int) -> int:
        """Hoses (bundled cables) along one row/column of racks: the rack-
        level CIN of size K_dim has K(K-1)/2 hoses."""
        k = self.config.dims[dim]
        return k * (k - 1) // 2

    def hose_colour_classes(self, dim: int) -> tuple[int, int]:
        """(#colours, hoses per colour) along one rack line: K-1 colours of
        K/2 hoses each — the 1-factors of the rack-level LACIN."""
        k = self.config.dims[dim]
        return (k - 1, k // 2)

    def report(self) -> dict:
        c = self.config
        return {
            "dims": c.dims,
            "instance": c.instance,
            "switches": c.num_switches,
            "endpoints": c.num_endpoints,
            "radix": c.radix,
            "network_ports_per_switch": c.network_ports_per_switch,
            "total_links": c.num_links,
            "racks": self.num_racks,
            "rack_grid": self.rack_grid,
            "chassis_per_rack": self.chassis_per_rack,
            "z_links_per_rack": self.z_links_per_rack,
            "z_columns_per_rack": self.z_columns_per_rack,
            "z_wires_per_column": self.z_wires_per_column,
            "super_ports_per_rack_x": self.super_ports_per_rack(2),
            "super_ports_per_rack_y": self.super_ports_per_rack(1),
            "wires_per_super_port": self.wires_per_super_port,
            "hoses_per_rack_row": self.hoses_per_line(2),
            "hose_colours_x": self.hose_colour_classes(2),
        }


def paper_16cubed() -> HyperXDeployment:
    """The paper's flagship: 16x16x16 XOR HyperX, 16 terminals/switch."""
    return HyperXDeployment(HyperXConfig(dims=(16, 16, 16), terminals=16,
                                         instance="xor"))


def fig4_4cubed() -> HyperXDeployment:
    """Figure 4's illustrative 4x4x4 XOR HyperX."""
    return HyperXDeployment(HyperXConfig(dims=(4, 4, 4), terminals=4,
                                         instance="xor"))


def all_pairs_max_hops(cfg: HyperXConfig, sample: int | None = None,
                       seed: int = 0) -> int:
    """Max DOR hop count over (sampled) endpoint pairs — equals the number
    of differing digits, bounded by the diameter."""
    rng = np.random.default_rng(seed)
    n = cfg.num_switches
    coords = [cfg.switch_coord(s) for s in range(n)]
    if sample is None and n <= 256:
        pairs = [(a, b) for a in range(n) for b in range(n) if a != b]
    else:
        k = sample or 4096
        pairs = [tuple(rng.integers(0, n, 2)) for _ in range(k)]
        pairs = [(a, b) for a, b in pairs if a != b]
    best = 0
    for a, b in pairs:
        hops = cfg.dor_route(coords[a], coords[b])
        best = max(best, len(hops))
    return best
