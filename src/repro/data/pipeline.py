"""Deterministic synthetic token pipeline with sharded, restartable loading.

Design goals (the ones that matter at 1000+ nodes):

* **Determinism keyed on (seed, step)** — any host can regenerate any
  microbatch, so a restarted or replacement worker needs no data-state
  handoff (straggler mitigation: work stealing is trivial when data is a
  pure function of the step).
* **Host-sharded**: each host materializes only its slice of the global
  batch (``host_index`` / ``num_hosts``).
* **Double-buffered prefetch** via a background thread.

The generator is a mixture of Zipf-distributed unigrams and a Markov-ish
repeated-ngram process — enough structure that a model's loss decreases,
while remaining fully synthetic and offline.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np

import jax.numpy as jnp


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    repeat_p: float = 0.3      # probability of copying an earlier token
    ignore_index: int = -100


def _batch_rng(cfg: DataConfig, step: int, host_index: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, host_index]))


def host_batch(cfg: DataConfig, step: int, host_index: int = 0,
               num_hosts: int = 1) -> dict:
    """This host's slice of the global batch for ``step`` (pure function)."""
    b = cfg.global_batch // num_hosts
    rng = _batch_rng(cfg, step, host_index)
    # Zipf unigrams, clipped to vocab.
    toks = rng.zipf(cfg.zipf_a, size=(b, cfg.seq_len + 1)).astype(np.int64)
    toks = (toks - 1) % cfg.vocab_size
    # repeated-ngram structure: with prob repeat_p, copy token from lag.
    lag = rng.integers(1, 64, size=(b, 1))
    idx = np.arange(cfg.seq_len + 1)[None, :]
    src = np.maximum(idx - lag, 0)
    copy = rng.random((b, cfg.seq_len + 1)) < cfg.repeat_p
    toks = np.where(copy, np.take_along_axis(toks, src, axis=1), toks)
    tokens = toks[:, :-1].astype(np.int32)
    labels = toks[:, 1:].astype(np.int32)
    return {"tokens": tokens, "labels": labels}


class Prefetcher:
    """Background-thread double buffering over ``host_batch``."""

    def __init__(self, cfg: DataConfig, start_step: int = 0,
                 host_index: int = 0, num_hosts: int = 1, depth: int = 2):
        self.cfg = cfg
        self.host_index = host_index
        self.num_hosts = num_hosts
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = host_batch(self.cfg, step, self.host_index,
                               self.num_hosts)
            try:
                self._q.put((step, batch), timeout=1.0)
                step += 1
            except queue.Full:
                if self._stop.is_set():
                    return
                self._q.put((step, batch))
                step += 1

    def next(self) -> tuple[int, dict]:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
