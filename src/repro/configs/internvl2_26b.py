"""internvl2-26b [vlm]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553 — InternViT + InternLM2 [arXiv:2404.16821].

Assignment specifies the TRANSFORMER BACKBONE only; the InternViT frontend
is a stub — ``input_specs()`` provides 256 precomputed patch embeddings at
d_model, prepended to the token stream (loss masked over the prefix).
"""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    num_patch_tokens=256,
))
