"""lacin-demo: the paper's own 'architecture' — a small dense LM whose
every communicating axis is driven by LACIN-scheduled collectives
(DP all-reduce and, in the MoE variant, EP all-to-all).  Used by the
examples and collective benchmarks; not part of the assigned 40 cells.
"""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="lacin-demo",
    family="dense",
    num_layers=8,
    d_model=512,
    num_heads=8,
    num_kv_heads=4,
    head_dim=64,
    d_ff=2048,
    vocab_size=32768,
    mlp="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
))
