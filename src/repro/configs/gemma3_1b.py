"""gemma3-1b [dense]: 26L d_model=1152 4H (GQA kv=1) d_ff=6912
vocab=262144 — 5:1 local:global [hf:google/gemma-3-1b-pt].

Sliding-window 512 on local layers, full attention every 6th layer
(indices 5, 11, 17, 23) with RoPE theta 1M; locals use theta 10k.
head_dim=256 (decoupled from d_model/num_heads), qk-norm, geglu, tied
embeddings.
"""
from repro.models.config import ModelConfig, register

WINDOWS = tuple(0 if i % 6 == 5 else 512 for i in range(26))

CONFIG = register(ModelConfig(
    name="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    windows=WINDOWS,
    sliding_window=512,
    mlp="geglu",
    norm="rmsnorm",
    qk_norm=True,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    tie_embeddings=True,
))
