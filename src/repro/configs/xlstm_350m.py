"""xlstm-350m [ssm]: 24L d_model=1024 4H d_ff=0 vocab=50304.

sLSTM + mLSTM blocks [arXiv:2405.04517]; xLSTM[7:1]-style ratio — one sLSTM
per 8 layers (positions 4, 12, 20), mLSTM elsewhere.  d_ff=0: no separate
transformer FFN; mLSTM blocks carry a 2x up-projection, sLSTM blocks a 4/3
gated post-FFN (paper's block design).
"""
from repro.models.config import MLSTM, SLSTM, ModelConfig, register

_SLSTM_AT = {4, 12, 20}
PATTERN = tuple(SLSTM if i in _SLSTM_AT else MLSTM for i in range(24))

CONFIG = register(ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50304,
    block_pattern=PATTERN,
    mlp="swiglu",
    norm="rmsnorm",
    ssm_expand=2,
    conv_kernel=4,
    tie_embeddings=True,
))
