"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) d_ff=768
vocab=151936, MoE 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].

Every layer is MoE (no shared expert); qk-norm per Qwen3.  Expert
parallelism over the "model" mesh axis uses the paper's XOR 1-factor
all-to-all schedule (``moe_impl='lacin_ep'``).
"""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    mlp="swiglu",
    norm="rmsnorm",
    qk_norm=True,
    rope_theta=1_000_000.0,
    num_experts=128,
    top_k=8,
    capacity_factor=1.25,
    moe_impl="lacin_ep",
))
