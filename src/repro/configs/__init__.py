"""Assigned-architecture configs.  Importing this package registers every
architecture in :mod:`repro.models.config`'s registry (used by
``--arch <id>`` in the launchers).
"""
from . import (xlstm_350m, hymba_1p5b, nemotron4_15b, starcoder2_3b,
               llama32_3b, gemma3_1b, internvl2_26b, qwen3_moe_30b_a3b,
               granite_moe_3b_a800m, whisper_base, lacin_demo)

__all__ = ["xlstm_350m", "hymba_1p5b", "nemotron4_15b", "starcoder2_3b",
           "llama32_3b", "gemma3_1b", "internvl2_26b", "qwen3_moe_30b_a3b",
           "granite_moe_3b_a800m", "whisper_base", "lacin_demo"]
