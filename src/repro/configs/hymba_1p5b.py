"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attn+mamba heads [arXiv:2411.13676].

Full (global) attention at layers {0, 15, 31}; sliding-window 1024
elsewhere (Hymba's 3-global pattern).  128 learnable meta tokens prepended.
"""
from repro.models.config import HYMBA, ModelConfig, register

_GLOBAL_AT = {0, 15, 31}
WINDOWS = tuple(0 if i in _GLOBAL_AT else 1024 for i in range(32))

CONFIG = register(ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    block_pattern=(HYMBA,) * 32,
    windows=WINDOWS,
    sliding_window=1024,
    mlp="swiglu",
    norm="rmsnorm",
    ssm_state=16,
    ssm_expand=2,
    conv_kernel=4,
    num_meta_tokens=128,
))
