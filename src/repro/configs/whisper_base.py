"""whisper-base [audio]: 6L enc + 6L dec, d_model=512 8H (kv=8) d_ff=2048
vocab=51865 — enc-dec, conv frontend STUB [arXiv:2212.04356].

``input_specs()`` provides precomputed frame embeddings (1500, d_model) —
the two-conv frontend is stubbed per the assignment.  Decoder blocks carry
cross-attention over the encoder output; decode shapes run with a 32k
self-attention KV cache (beyond Whisper's trained 448 positions — noted in
DESIGN.md as a systems exercise).  RoPE replaces learned positions so the
decoder is length-agnostic.
"""
from repro.models.config import ATTN_CROSS, ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    block_pattern=(ATTN_CROSS,) * 6,
    mlp="gelu",
    norm="layernorm",
    attn_bias=True,
    mlp_bias=True,
    encoder_layers=6,
    encoder_seq_len=1500,
))
