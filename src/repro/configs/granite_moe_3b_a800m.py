"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8 [hf:ibm-granite].

NOTE: the assignment line reads "MoE 40e top-8 — 32 experts top-8"; we
follow the config field literally (40 experts) and record the discrepancy
here and in DESIGN.md §6.  40 experts over a 16-way model axis do not
divide evenly, so the EP path pads the expert dim to 48 (3 per shard);
``num_experts`` below stays 40 (router never selects padding experts).
"""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    num_experts=40,
    top_k=8,
    capacity_factor=1.25,
    moe_impl="lacin_ep",
    tie_embeddings=True,
))
