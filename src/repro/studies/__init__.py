"""``repro.studies`` — the declarative experiment surface.

One API describes and runs every simulation experiment in the repo: a
serializable :class:`ExperimentSpec` (fabric x traffic x routing x sweep
grid) executed by a :class:`Study`, which auto-selects the simulator
backend (batching each grid into a single compiled
:mod:`repro.sim.xengine` program when JAX is available, looping the
numpy oracle otherwise, and escalating to the :mod:`repro.flow`
fair-share model for fabrics of :data:`FLOW_AUTO_SWITCHES` = 1024+
switches), streams unified :class:`Result` records to a JSONL store,
and resumes interrupted grids by skipping the keys already persisted.

Quickstart::

    from repro import studies

    spec = studies.ExperimentSpec(
        fabric=studies.FabricSpec("cin", {"instance": "xor", "n": 16}),
        traffic=studies.TrafficSpec("uniform"),
        routing=studies.RoutingSpec("minimal"),
        sweep=studies.SweepSpec(loads=(0.3, 0.6, 0.9), seeds=(0, 1),
                                cycles=1000),
        terminals=8)
    out = studies.Study(spec, store="sweep.jsonl").run()
    print(out.table())
    print(out.saturation_points())

The same experiment as a file::

    python -m repro.studies run sweep_spec.json

Bundled specs under ``repro/studies/specs/`` reproduce the paper's
CIN-16 / HyperX-256 / Dragonfly-72 sweeps and the ``collective_replay``
schedule-vs-bound comparison; ``python -m repro.studies specs`` lists
them.  The legacy entry points
(``repro.sim.report.saturation_sweep`` / ``compare_policies`` /
``Fabric.sim_sweep``) are thin deprecated shims over this package.
"""
from __future__ import annotations

import os

from .spec import (ExperimentSpec, FabricSpec, RoutingSpec, SweepSpec,
                   TrafficSpec, dump_specs, load_specs)
from .store import JsonlStore, Result
from .runner import (BACKENDS, FLOW_AUTO_SWITCHES, Study, StudyResult,
                     jax_available)

__all__ = [
    "ExperimentSpec", "FabricSpec", "TrafficSpec", "RoutingSpec",
    "SweepSpec", "load_specs", "dump_specs",
    "Result", "JsonlStore", "Study", "StudyResult", "jax_available",
    "BACKENDS", "FLOW_AUTO_SWITCHES",
    "bundled_specs", "bundled_spec_path", "resolve_spec_source",
]

_SPEC_DIR = os.path.join(os.path.dirname(__file__), "specs")


def bundled_specs() -> dict[str, str]:
    """Name -> path of the spec files shipped inside the package."""
    out = {}
    if os.path.isdir(_SPEC_DIR):
        for fn in sorted(os.listdir(_SPEC_DIR)):
            if fn.endswith(".json"):
                out[fn[:-len(".json")]] = os.path.join(_SPEC_DIR, fn)
    return out


def bundled_spec_path(name: str) -> str:
    """Path of a bundled spec by name (``'cin16_saturation'``, ...)."""
    specs = bundled_specs()
    try:
        return specs[name]
    except KeyError:
        raise ValueError(f"no bundled study spec named {name!r}; "
                         f"available: {sorted(specs)}") from None


def resolve_spec_source(spec: str) -> str:
    """A spec argument as every CLI/example accepts it: an existing file
    path wins, otherwise a bundled spec name.  Raises ``ValueError``
    naming the bundled specs when neither matches."""
    if os.path.exists(spec):
        return spec
    try:
        return bundled_spec_path(spec)
    except ValueError:
        raise ValueError(
            f"spec {spec!r} is neither a file nor a bundled spec name "
            f"(bundled: {', '.join(sorted(bundled_specs()))})") from None
