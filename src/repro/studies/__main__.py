"""Command-line driver: ``python -m repro.studies <command>``.

Commands:

* ``run SPEC``   — execute a study spec (a path, or a bundled spec name)
  and stream results to a JSONL store (default: ``<spec>.results.jsonl``
  next to the current directory).  Re-running resumes: grid points whose
  keys are already in the store are skipped.
* ``show SPEC``  — print the experiments, grid sizes, and store keys a
  spec expands to, without running anything.
* ``specs``      — list the bundled spec files.

Examples::

    python -m repro.studies specs
    python -m repro.studies run studies_smoke --backend numpy --table
    python -m repro.studies run cin16_saturation --store knees.jsonl
    python -m repro.studies show my_experiment.json
"""
from __future__ import annotations

import argparse
import os
import sys
import time

from . import (JsonlStore, Study, bundled_specs, load_specs,
               resolve_spec_source)


def _resolve_spec_arg(spec: str) -> str:
    try:
        return resolve_spec_source(spec)
    except ValueError as e:
        raise SystemExit(str(e)) from None


def _default_store(spec_path: str) -> str:
    stem = os.path.splitext(os.path.basename(spec_path))[0]
    return f"{stem}.results.jsonl"


def cmd_run(args) -> int:
    spec_path = _resolve_spec_arg(args.spec)
    store = args.store if args.store is not None else _default_store(spec_path)
    study = Study(spec_path, store=JsonlStore(store),
                  backend=args.backend)
    print(f"study: {spec_path}")
    print(f"store: {store}")
    for exp in study.experiments:
        print(f"  - {exp.describe()}")
    t0 = time.time()
    out = study.run(resume=not args.no_resume)
    dt = time.time() - t0
    print(f"ran {out.executed} grid points "
          f"({out.restored} restored from the store) "
          f"on backend={out.backend} in {dt:.1f}s")
    if args.table:
        print()
        print(out.table())
    replays = out.replay_points()
    if replays:
        print("collective replay (measured vs contention-free bound):")
        for name, rp in replays.items():
            print(f"  {name}: measured={rp['measured']} "
                  f"ideal={rp['ideal']} ratio={rp['ratio']}")
    if len(replays) < len(out.experiments):
        print("saturation points:")
        for name, knee in out.saturation_points().items():
            if name in replays:
                continue
            print(f"  {name}: {knee if knee is not None else '> max load'}")
    return 0


def cmd_show(args) -> int:
    spec_path = _resolve_spec_arg(args.spec)
    specs = load_specs(spec_path)
    total = 0
    for exp in specs:
        pts = exp.points()
        total += len(pts)
        print(exp.describe())
        print(f"    loads={list(exp.sweep.loads)} seeds={list(exp.sweep.seeds)}"
              f" warmup={exp.sweep.warmup}")
        print(f"    first key: {exp.key(*pts[0])}")
    print(f"{len(specs)} experiments, {total} grid points")
    return 0


def cmd_specs(_args) -> int:
    for name, path in bundled_specs().items():
        n_exp = len(load_specs(path))
        print(f"{name:<24} {n_exp:>2} experiments   {path}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.studies",
        description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    run = sub.add_parser("run", help="execute a study spec")
    run.add_argument("spec", help="spec file path or bundled spec name")
    run.add_argument("--store", default=None,
                     help="JSONL result store (default: <spec>.results.jsonl"
                          " in the current directory)")
    run.add_argument("--backend", default="auto",
                     choices=["auto", "jax", "numpy"])
    run.add_argument("--no-resume", action="store_true",
                     help="re-run every grid point even if already stored")
    run.add_argument("--table", action="store_true",
                     help="print the full result table")
    run.set_defaults(fn=cmd_run)

    show = sub.add_parser("show", help="expand a spec without running")
    show.add_argument("spec", help="spec file path or bundled spec name")
    show.set_defaults(fn=cmd_show)

    specs = sub.add_parser("specs", help="list bundled spec files")
    specs.set_defaults(fn=cmd_specs)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
