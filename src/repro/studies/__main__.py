"""Command-line driver: ``python -m repro.studies <command>``.

Commands:

* ``run SPEC``   — execute a study spec (a path, or a bundled spec name)
  and stream results to a JSONL store (default: ``<spec>.results.jsonl``
  next to the current directory).  Re-running resumes: grid points whose
  keys are already in the store are skipped.
* ``show SPEC``  — print the experiments, grid sizes, and store keys a
  spec expands to, without running anything.  ``--results`` additionally
  prints each stored record's fidelity tier, latency percentiles, and
  serving SLO fields (including fields written by a newer version —
  nothing is silently dropped).  ``--trace`` additionally
  reads the spec's result store and prints each record's provenance
  (host, backend, compile-vs-execute timings) plus the per-experiment
  compile-tax summary.
* ``trace export SPEC`` — run one experiment of a spec with time-series
  tracing and write a Perfetto/Chrome-loadable trace JSON
  (``ui.perfetto.dev``).  ``--backend both`` runs the numpy oracle *and*
  the compiled engine and fails unless their traces agree exactly.
* ``cache``      — inspect the persistent compile cache (directory,
  entries, hit/miss counters); ``--clear`` evicts the disk entries.
  See ``docs/compile_cache.md``.
* ``specs``      — list the bundled spec files.

Examples::

    python -m repro.studies specs
    python -m repro.studies run studies_smoke --backend numpy --table
    python -m repro.studies run cin16_saturation --store knees.jsonl
    python -m repro.studies show my_experiment.json
    python -m repro.studies show collective_replay --trace
    python -m repro.studies cache
    python -m repro.studies cache --clear
    python -m repro.studies trace export collective_replay \\
        --experiment cin-xor-16/replay-all_to_all/minimal \\
        --backend both --packets 8 --out trace-cin16.json
"""
from __future__ import annotations

import argparse
import os
import sys
import time

from . import (BACKENDS, JsonlStore, Study, bundled_specs, load_specs,
               resolve_spec_source)


def _resolve_spec_arg(spec: str) -> str:
    try:
        return resolve_spec_source(spec)
    except ValueError as e:
        raise SystemExit(str(e)) from None


def _default_store(spec_path: str) -> str:
    stem = os.path.splitext(os.path.basename(spec_path))[0]
    return f"{stem}.results.jsonl"


def cmd_run(args) -> int:
    spec_path = _resolve_spec_arg(args.spec)
    store = args.store if args.store is not None else _default_store(spec_path)
    study = Study(spec_path, store=JsonlStore(store),
                  backend=args.backend)
    print(f"study: {spec_path}")
    print(f"store: {store}")
    for exp in study.experiments:
        print(f"  - {exp.describe()}")
    t0 = time.time()
    out = study.run(resume=not args.no_resume)
    dt = time.time() - t0
    print(f"ran {out.executed} grid points "
          f"({out.restored} restored from the store) "
          f"on backend={out.backend} in {dt:.1f}s")
    if args.table:
        print()
        print(out.table())
    replays = out.replay_points()
    if replays:
        print("collective replay (measured vs contention-free bound):")
        for name, rp in replays.items():
            print(f"  {name}: measured={rp['measured']} "
                  f"ideal={rp['ideal']} ratio={rp['ratio']}")
    serving = out.serving_points()
    if serving:
        print("serving SLO (worst grid point):")
        for name, sp in serving.items():
            att = (f"{sp['attainment']:.4f}"
                   if sp['attainment'] is not None else "n/a")
            print(f"  {name}: requests={sp['requests']} p50={sp['p50']} "
                  f"p95={sp['p95']} p99={sp['p99']} "
                  f"slo={sp['slo']} attainment={att}")
    if len(replays) + len(serving) < len(out.experiments):
        print("saturation points:")
        try:
            knees = [("", out.saturation_points())]
        except ValueError:
            # A resumed store mixing fidelity tiers: one knee per tier.
            knees = [(f" [{tier}]", out.saturation_points(fidelity=tier))
                     for tier in ("cycle", "flow")]
        for suffix, tier_knees in knees:
            for name, knee in tier_knees.items():
                if name in replays or name in serving:
                    continue
                print(f"  {name}{suffix}: "
                      f"{knee if knee is not None else '> max load'}")
    return 0


def cmd_show(args) -> int:
    spec_path = _resolve_spec_arg(args.spec)
    specs = load_specs(spec_path)
    total = 0
    for exp in specs:
        pts = exp.points()
        total += len(pts)
        print(exp.describe())
        print(f"    loads={list(exp.sweep.loads)} seeds={list(exp.sweep.seeds)}"
              f" warmup={exp.sweep.warmup}")
        if exp.failures is not None:
            print(f"    failures: {exp.failures.label} "
                  f"(policy={exp.failures.policy})")
        print(f"    first key: {exp.key(*pts[0])}")
    print(f"{len(specs)} experiments, {total} grid points")
    if getattr(args, "results", False):
        _show_results(spec_path, args.store)
    if getattr(args, "trace", False):
        _show_trace(spec_path, specs, args.store)
    return 0


def _show_results(spec_path: str, store_arg: str | None) -> None:
    """The ``show --results`` tail: one line per stored record, with the
    fidelity tier, serving latency percentiles, and any fields written
    by a newer Result version (``extra``) — nothing silently dropped."""
    store_path = store_arg if store_arg is not None \
        else _default_store(spec_path)
    store = JsonlStore(store_path)
    if not store.exists():
        print(f"no result store at {store_path} — run the study first "
              f"(or pass --store)")
        return
    records = store.load()
    print(f"\nstore: {store_path} ({len(records)} records)")
    for key in sorted(records):
        r = records[key]
        line = (f"  {key}: fidelity={r.fidelity} "
                f"accepted={r.accepted} lat_p99={r.latency_p99}")
        if r.completion_cycles is not None:
            line += (f" completion={r.completion_cycles}"
                     f" ideal={r.ideal_cycles}")
        if r.request_count is not None:
            line += (f" requests={r.request_count}"
                     f" req_p50={r.request_latency_p50}"
                     f" req_p95={r.request_latency_p95}"
                     f" req_p99={r.request_latency_p99}")
            if r.slo_target is not None:
                line += (f" slo={r.slo_target}"
                         f" attainment={r.slo_attainment}")
        if r.extra:
            line += " " + " ".join(f"{k}={v}" for k, v in
                                   sorted(r.extra.items()))
        print(line)


def _show_trace(spec_path: str, specs, store_arg: str | None) -> None:
    """The ``show --trace`` tail: stored provenance + compile-tax totals."""
    store_path = store_arg if store_arg is not None \
        else _default_store(spec_path)
    store = JsonlStore(store_path)
    if not store.exists():
        print(f"no result store at {store_path} — run the study first "
              f"(or pass --store)")
        return
    records = store.load()
    print(f"\nstore: {store_path} ({len(records)} records)")
    timed = 0
    for key in sorted(records):
        prov = records[key].provenance or {}
        timings = prov.get("timings")
        if timings is None:
            continue
        timed += 1
        amortized = (timings.get("total_s", 0.0)
                     / max(timings.get("grid_points", 1), 1))
        kind = timings.get("compile_cached")
        cached = f" (cached: {kind})" if kind else ""
        print(f"  {key}")
        print(f"    backend={timings.get('backend')} host={prov.get('host')}"
              f" jax={prov.get('jax')}")
        print(f"    compile={timings.get('compile_s')}s{cached}"
              f" execute={timings.get('execute_s')}s"
              f" amortized={amortized:.6f}s/point")
    if not timed:
        print("  no records carry timings (store predates telemetry); "
              "re-run with --no-resume to refresh")
        return
    # Per-experiment compile tax, each batched program counted once.
    from .runner import StudyResult
    by_name = {e.name: e for e in specs}
    summary = StudyResult(
        experiments=[by_name[r.experiment] for r in records.values()
                     if r.experiment in by_name],
        results=list(records.values()), executed=0, restored=len(records),
        backend="").telemetry()
    if summary:
        print("compile tax per experiment (batched programs counted once):")
        for name, t in summary.items():
            print(f"  {name}: {t['programs']} program(s), {t['points']} "
                  f"point(s), compile={t['compile_s']}s "
                  f"execute={t['execute_s']}s")


def cmd_trace(args) -> int:
    if args.action != "export":
        raise SystemExit(f"unknown trace action {args.action!r}")
    from repro.obs import (TraceConfig, export_perfetto,
                           replay_trace_events)
    spec_path = _resolve_spec_arg(args.spec)
    study = Study(spec_path)
    by_name = {e.name: e for e in study.experiments}
    if args.experiment is not None:
        if args.experiment not in by_name:
            raise SystemExit(
                f"no experiment named {args.experiment!r} in {spec_path}; "
                f"have: {', '.join(sorted(by_name))}")
        exp = by_name[args.experiment]
    elif len(by_name) == 1:
        exp = study.experiments[0]
    else:
        raise SystemExit(
            f"{spec_path} holds {len(by_name)} experiments; pick one with "
            f"--experiment: {', '.join(sorted(by_name))}")

    from repro.sim.engine import simulate
    topo, tf = study._resolve(exp)
    load, seed = exp.points()[0]
    cfg = TraceConfig(stride=args.stride, max_samples=args.max_samples,
                      packets=args.packets)
    engine_kw = dict(exp.engine)
    engine_kw["trace"] = cfg

    def run(backend: str):
        traffic = tf(load, seed)
        cycles = (exp.sweep.cycles if exp.sweep.cycles is not None
                  else max(traffic.horizon, 1))
        warmup = (exp.sweep.warmup if exp.sweep.warmup is not None
                  else 0 if traffic.workload is not None else cycles // 4)
        t0 = time.time()
        stats = simulate(topo, exp.routing.make(), traffic,
                         terminals=exp.terminals, cycles=cycles,
                         warmup=warmup, seed=seed, backend=backend,
                         **engine_kw)
        print(f"{backend}: {stats.trace.num_samples} samples in "
              f"{time.time() - t0:.2f}s "
              f"(timing: {stats.timing})")
        return stats

    backends = (["numpy", "jax"] if args.backend == "both"
                else [args.backend])
    runs = {be: run(be) for be in backends}
    if args.backend == "both":
        a, b = runs["numpy"].trace, runs["jax"].trace
        if not a.equals(b):
            raise SystemExit(
                f"cross-engine trace mismatch on {exp.name!r}: "
                f"{a.diff_summary(b)}")
        print("cross-engine traces agree exactly")
    # The numpy run carries packet spans; prefer it for the export.
    stats = runs.get("numpy") or runs[backends[0]]
    out_path = args.out if args.out is not None else \
        f"trace-{exp.name.replace('/', '-')}.json"
    payload = export_perfetto(out_path,
                              replay_trace_events(stats, topo=topo))
    print(f"wrote {out_path} ({len(payload['traceEvents'])} events) — "
          f"load it in ui.perfetto.dev")
    if stats.completion_cycles is not None and stats.ideal_cycles:
        print(f"completion={stats.completion_cycles} "
              f"ideal={stats.ideal_cycles} "
              f"ratio={stats.completion_cycles / stats.ideal_cycles:.3f}")
    return 0


def cmd_cache(args) -> int:
    """Inspect (or clear) the persistent compile cache.

    The disk layer makes the compiled engine pay its compile once per
    machine instead of once per process; this command is the operator's
    view of it — where it lives, what it holds, and how this process's
    acquisitions split across memory/disk/recompile.
    """
    from repro.obs.telemetry import (cache_dir, cache_stats, clear_caches,
                                     disk_cache_entries)
    cdir = cache_dir()
    if cdir is None:
        print("compile cache: disabled (LACIN_CACHE_DIR is set but empty)")
        return 0
    entries = disk_cache_entries()
    if args.clear:
        clear_caches(memory=True, disk=True)
        print(f"cleared {len(entries)} entries from {cdir}")
        return 0
    total = sum(p.stat().st_size for p in entries)
    print(f"dir:     {cdir}")
    print(f"entries: {len(entries)} ({total / 1e6:.1f} MB)")
    for p in entries:
        print(f"  {p.name}  {p.stat().st_size / 1e6:.2f} MB")
    stats = cache_stats()
    print("this-process counters: " +
          " ".join(f"{k}={v}" for k, v in sorted(stats.items())))
    return 0


def cmd_specs(_args) -> int:
    for name, path in bundled_specs().items():
        n_exp = len(load_specs(path))
        print(f"{name:<24} {n_exp:>2} experiments   {path}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.studies",
        description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    run = sub.add_parser("run", help="execute a study spec")
    run.add_argument("spec", help="spec file path or bundled spec name")
    run.add_argument("--store", default=None,
                     help="JSONL result store (default: <spec>.results.jsonl"
                          " in the current directory)")
    run.add_argument("--backend", default="auto", choices=list(BACKENDS))
    run.add_argument("--no-resume", action="store_true",
                     help="re-run every grid point even if already stored")
    run.add_argument("--table", action="store_true",
                     help="print the full result table")
    run.set_defaults(fn=cmd_run)

    show = sub.add_parser("show", help="expand a spec without running")
    show.add_argument("spec", help="spec file path or bundled spec name")
    show.add_argument("--results", action="store_true",
                      help="also print each stored record's fidelity, "
                           "latency percentiles, and serving SLO fields")
    show.add_argument("--trace", action="store_true",
                      help="also print stored provenance/timing records "
                           "and the per-experiment compile tax")
    show.add_argument("--store", default=None,
                      help="result store to read with --trace "
                           "(default: <spec>.results.jsonl)")
    show.set_defaults(fn=cmd_show)

    trace = sub.add_parser(
        "trace", help="run one experiment with tracing and export it")
    trace.add_argument("action", choices=["export"])
    trace.add_argument("spec", help="spec file path or bundled spec name")
    trace.add_argument("--experiment", default=None,
                       help="experiment name within the spec (required "
                            "unless the spec holds exactly one)")
    trace.add_argument("--backend", default="numpy",
                       choices=["numpy", "jax", "both"],
                       help="'both' runs both engines and fails unless "
                            "their traces agree exactly")
    trace.add_argument("--stride", type=int, default=1,
                       help="sample every k-th cycle")
    trace.add_argument("--max-samples", type=int, default=4096)
    trace.add_argument("--packets", type=int, default=0,
                       help="follow K sampled packets hop-by-hop "
                            "(numpy engine only)")
    trace.add_argument("--out", default=None,
                       help="output path (default: trace-<experiment>.json)")
    trace.set_defaults(fn=cmd_trace)

    cache = sub.add_parser(
        "cache", help="inspect the persistent compile cache")
    cache.add_argument("--clear", action="store_true",
                       help="evict every disk entry (and the in-process "
                            "LRU) instead of listing them")
    cache.set_defaults(fn=cmd_cache)

    specs = sub.add_parser("specs", help="list bundled spec files")
    specs.set_defaults(fn=cmd_specs)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
