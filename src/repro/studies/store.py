"""Unified result records + the append-only JSONL store.

Every grid point a :class:`~repro.studies.runner.Study` executes becomes
one :class:`Result` — the serializable summary of a simulator
:class:`~repro.sim.metrics.RunStats` plus its grid identity (experiment
name, offered load, sweep seed, backend).  A :class:`JsonlStore` streams
Results one JSON line at a time, so an interrupted study leaves a valid
prefix behind and a re-run resumes by skipping the keys already present
(:meth:`JsonlStore.load` tolerates a torn trailing line).
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field, fields
from typing import Iterable, Mapping

from repro.obs.telemetry import provenance
from repro.sim.metrics import RunStats

__all__ = ["Result", "JsonlStore"]


@dataclass
class Result:
    """One executed grid point: identity + the RunStats summary."""
    key: str
    experiment: str
    load: float
    seed: int
    backend: str
    # -- RunStats summary (same fields as repro.sim.report.to_record) -------
    topology: str
    policy: str
    traffic: str
    offered: float
    accepted: float
    cycles: int
    warmup: int
    num_switches: int
    terminals: int
    packets_generated: int
    packets_delivered: int
    latency_mean: float
    latency_p50: float
    latency_p99: float
    latency_max: int
    link_util_max: float
    link_util_mean: float
    link_util_cv: float
    saturated: bool
    #: Packets still in fabric queues when the run stopped (0 on a
    #: drained run); defaulted so records from older stores load.
    in_flight_at_end: int = 0
    #: Hash of the experiment spec that produced this record (see
    #: :meth:`repro.studies.spec.ExperimentSpec.digest`); ``""`` for
    #: inline specs and records from older stores.
    spec_digest: str = ""
    #: Simulation fidelity tier: ``"cycle"`` for the packet-level
    #: engines (jax/numpy), ``"flow"`` for the analytical fair-share
    #: model (:mod:`repro.flow`).  Stores may mix tiers; analyses that
    #: compare knees must filter on this marker (see
    #: :meth:`repro.studies.runner.StudyResult.saturation_points`).
    #: Defaulted so records from older stores load as cycle-fidelity.
    fidelity: str = "cycle"
    # -- collective-replay summary (None for open-loop experiments) ---------
    #: Cycle the workload's last packet delivered.
    completion_cycles: int | None = None
    #: Contention-free lower bound (num_steps x message_size).
    ideal_cycles: int | None = None
    #: Per-phase durations in cycles.
    phase_cycles: list | None = None
    # -- serving summary (None for non-serving experiments) ------------------
    #: Distinct request ids in the serving stream.
    request_count: int | None = None
    #: Per-request latency percentiles, cycles (last packet delivered
    #: minus arrival, +1); computed over completed requests.
    request_latency_p50: float | None = None
    request_latency_p95: float | None = None
    request_latency_p99: float | None = None
    #: The per-request latency SLO carried by the traffic, and the
    #: fraction of requests that completed within it (requests that
    #: never completed count as misses).
    slo_target: float | None = None
    slo_attainment: float | None = None
    #: Environment + timing block (:func:`repro.obs.telemetry.provenance`):
    #: host, library versions, and the point's compile-vs-execute split.
    #: ``None`` for records from older stores.
    provenance: dict | None = None
    #: Fields a *newer* version of this class wrote that this one does
    #: not know.  Carried verbatim so loading and re-appending a store
    #: never silently drops data, and ``show`` can still print them.
    extra: dict = field(default_factory=dict)
    #: The full in-memory stats of a freshly executed point (histograms,
    #: raw link loads).  ``None`` for points restored from a store.
    stats: RunStats | None = field(default=None, compare=False, repr=False)

    @classmethod
    def from_stats(cls, stats: RunStats, *, key: str, experiment: str,
                   load: float, seed: int, backend: str,
                   spec_digest: str = "", fidelity: str = "cycle"
                   ) -> "Result":
        return cls(
            key=key, experiment=experiment, load=float(load), seed=int(seed),
            backend=backend,
            topology=stats.topology, policy=stats.policy,
            traffic=stats.traffic, offered=float(stats.offered),
            accepted=round(float(stats.accepted), 6),
            cycles=int(stats.cycles), warmup=int(stats.warmup),
            num_switches=int(stats.num_switches),
            terminals=int(stats.terminals),
            packets_generated=int(stats.packets_generated),
            packets_delivered=int(stats.packets_delivered),
            latency_mean=round(float(stats.latency_mean), 3),
            latency_p50=float(stats.latency_p50),
            latency_p99=float(stats.latency_p99),
            latency_max=int(stats.latency_max),
            link_util_max=round(float(stats.link_util_max), 4),
            link_util_mean=round(float(stats.link_util_mean), 4),
            link_util_cv=round(float(stats.link_util_cv), 4),
            saturated=bool(stats.saturated),
            in_flight_at_end=int(stats.in_flight_at_end),
            spec_digest=spec_digest, fidelity=fidelity,
            completion_cycles=stats.completion_cycles,
            ideal_cycles=stats.ideal_cycles,
            phase_cycles=(list(stats.phase_cycles)
                          if stats.phase_cycles is not None else None),
            request_count=stats.request_count,
            request_latency_p50=stats.request_latency_p50,
            request_latency_p95=stats.request_latency_p95,
            request_latency_p99=stats.request_latency_p99,
            slo_target=stats.slo_target,
            slo_attainment=stats.slo_attainment,
            provenance=provenance(stats.timing, backend=backend,
                                  spec_digest=spec_digest),
            stats=stats)

    def record(self) -> dict:
        """The JSON-object form (everything except the in-memory stats).

        Unknown fields restored into ``extra`` are merged back at the
        top level, so load -> append round-trips a newer store's records
        byte-compatibly."""
        out = {f.name: getattr(self, f.name) for f in fields(self)
               if f.name not in ("stats", "extra")}
        out.update(self.extra)
        return out

    def to_line(self) -> str:
        return json.dumps(self.record(), sort_keys=True)

    @classmethod
    def from_record(cls, d: Mapping) -> "Result":
        want = {f.name for f in fields(cls)} - {"stats", "extra"}
        extra = {k: v for k, v in d.items() if k not in want}
        return cls(**{k: v for k, v in d.items() if k in want}, extra=extra)


class JsonlStore:
    """Append-only JSONL persistence for :class:`Result` records.

    ``flush_interval`` amortizes durability for large sweeps: records
    are always *written* (and flushed to the OS) per :meth:`append`
    call, but the store only ``fsync``\\ s once every ``flush_interval``
    appended records.  The default of 1 keeps the historical
    every-record durability; a crash between fsyncs can cost at most
    the last ``flush_interval - 1`` records plus a torn tail — which
    :meth:`load` skips and :meth:`append` repairs in place, so a
    resumed study re-runs exactly the lost points.
    """

    def __init__(self, path: str | os.PathLike, *, flush_interval: int = 1):
        self.path = os.fspath(path)
        if int(flush_interval) < 1:
            raise ValueError(
                f"flush_interval must be >= 1, got {flush_interval!r}")
        self.flush_interval = int(flush_interval)
        self._unsynced = 0

    def exists(self) -> bool:
        return os.path.exists(self.path)

    def clear(self) -> None:
        """Drop every stored record (a ``resume=False`` run starts clean —
        appending duplicates would shadow older records on load)."""
        if self.exists():
            os.remove(self.path)

    def load(self) -> dict[str, Result]:
        """Stored results keyed by grid-point key.

        A torn trailing line (the study was killed mid-write) is skipped;
        a corrupt line anywhere else raises, since silently dropping it
        would silently re-run (and duplicate) its grid point.
        """
        out: dict[str, Result] = {}
        if not self.exists():
            return out
        with open(self.path) as f:
            text = f.read()
        lines = text.split("\n")
        # A torn tail can only be the final fragment of a file that was
        # killed mid-write, i.e. one missing its trailing newline; a
        # newline-terminated corrupt record is a real error.
        torn = len(lines) - 1 if text and not text.endswith("\n") else None
        for i, line in enumerate(lines):
            if not line.strip():
                continue
            try:
                rec = Result.from_record(json.loads(line))
            except (json.JSONDecodeError, TypeError) as e:
                if i == torn:
                    break
                raise ValueError(
                    f"{self.path}:{i + 1}: corrupt result line ({e}); "
                    f"remove or repair the store to resume") from e
            out[rec.key] = rec
        return out

    def append(self, results: Iterable[Result] | Result) -> None:
        """Append records and flush; fsync per ``flush_interval`` records
        (every append with the default of 1 — each line durable on its
        own)."""
        if isinstance(results, Result):
            results = [results]
        parent = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(parent, exist_ok=True)
        # An unterminated tail (killed mid-write) must not swallow the
        # next record.  Mirror load()'s tolerance exactly: a tail that
        # parses as a complete record was *restored*, so terminate it in
        # place; an unparseable fragment was ignored, so truncate it.
        if self.exists() and os.path.getsize(self.path) > 0:
            with open(self.path, "rb+") as f:
                f.seek(-1, os.SEEK_END)
                if f.read(1) != b"\n":
                    f.seek(0)
                    data = f.read()
                    keep = data.rfind(b"\n") + 1
                    try:
                        Result.from_record(json.loads(data[keep:]))
                    except (json.JSONDecodeError, TypeError,
                            UnicodeDecodeError):
                        f.truncate(keep)
                    else:
                        f.write(b"\n")
        with open(self.path, "a") as f:
            for r in results:
                f.write(r.to_line() + "\n")
                self._unsynced += 1
            f.flush()
            if self._unsynced >= self.flush_interval:
                os.fsync(f.fileno())
                self._unsynced = 0

    def sync(self) -> None:
        """Force an fsync of everything appended so far (a no-op when
        nothing is pending) — call at study end when running with a
        ``flush_interval`` above 1."""
        if self._unsynced and self.exists():
            with open(self.path, "rb") as f:
                os.fsync(f.fileno())
        self._unsynced = 0
