"""The ``Study`` runner: expand a spec grid, batch it, persist, resume.

One :class:`Study` executes the (load x seed) grid of one or more
:class:`~repro.studies.spec.ExperimentSpec`\\ s:

* **Backend auto-selection.**  ``backend=None``/"auto" compiles each
  experiment's grid into a single batched :func:`repro.sim.xengine.sweep`
  program when JAX is importable, and falls back to looping the numpy
  oracle (:func:`repro.sim.engine.simulate`) otherwise.  Same-shape
  programs across experiments (same topology size, policy, horizon,
  grid size) additionally share one compilation through the jit cache.
* **Streaming persistence.**  Each finished grid point becomes a
  :class:`~repro.studies.store.Result` appended to a JSONL store as soon
  as it exists, so a killed study leaves a valid prefix.
* **Resume.**  A re-run loads the store first and executes only the
  grid points whose keys are missing; a partially-done experiment is
  batched over just its missing points (packed by index into one
  compiled program).  On the numpy backend resumed points are
  bit-identical to an uninterrupted run (same per-point engine seeds);
  on the jax backend they are statistically equivalent (the smaller
  batch draws a different arbitration stream — the same contract the
  compiled engine already has against the oracle).
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Sequence

from .spec import ExperimentSpec, load_specs
from .store import JsonlStore, Result

__all__ = ["BACKENDS", "FLOW_AUTO_SWITCHES", "Study", "StudyResult",
           "jax_available"]

#: The valid ``backend=`` values, in the order the CLI offers them —
#: the single source of truth shared by :func:`_select_backend` and
#: ``python -m repro.studies run --backend``.
BACKENDS = ("auto", "jax", "numpy", "flow")

#: ``backend="auto"`` escalates to the flow model at or above this many
#: switches: the cycle engines' per-point cost grows with N x cycles
#: and tops out around a few hundred switches, while the flow model
#: holds single-digit seconds past 10k (see benchmarks/bench_flow.py).
FLOW_AUTO_SWITCHES = 1024


def jax_available() -> bool:
    try:
        import jax  # noqa: F401
        return True
    except Exception:  # pragma: no cover - jax is a hard dep in-repo
        return False


def _select_backend(backend: str | None, *,
                    num_switches: int | None = None,
                    experiment: "ExperimentSpec | None" = None) -> str:
    if backend in (None, "auto"):
        if num_switches is not None and num_switches >= FLOW_AUTO_SWITCHES:
            choice = "flow"
        else:
            choice = "jax" if jax_available() else "numpy"
    elif backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; "
                         f"expected one of {BACKENDS}")
    else:
        choice = backend
    if (choice == "flow" and experiment is not None
            and experiment.failures is not None
            and experiment.traffic.pattern == "workload"
            and experiment.failures.policy == "strict"):
        # A collective replay on the flow backend traces every phase's
        # routes through the degraded table; a disconnected residual
        # fabric would only surface deep inside trace_routes as an
        # unwired-port walk.  Check connectivity here, while the error
        # can still name the experiment and the fix.
        from repro.faults import residual_report
        report = residual_report(experiment.fabric.resolve_topology(),
                                 experiment.failures)
        if not report["connected"]:
            raise ValueError(
                f"experiment {experiment.name!r} replays a collective on "
                f"the flow backend, but failures "
                f"{experiment.failures.label!r} leave the fabric in "
                f"{report['num_components']} components and "
                f"policy='strict' forbids dropping the stranded traffic; "
                f"use policy='drop' to mask unreachable pairs, or pick a "
                f"FailureSpec that keeps the fabric connected")
    return choice


@dataclass
class StudyResult:
    """Everything a finished :meth:`Study.run` produced.

    ``results`` follows grid order (experiments in spec order, loads
    major, seeds minor) and mixes freshly executed points with points
    restored from the store (whose ``.stats`` is ``None``).
    """
    experiments: list[ExperimentSpec]
    results: list[Result]
    executed: int
    restored: int
    backend: str
    store_path: str | None = None

    def stats(self):
        """In-memory RunStats per grid point (None for restored points)."""
        return [r.stats for r in self.results]

    def by_experiment(self) -> dict[str, list[Result]]:
        out: dict[str, list[Result]] = {e.name: [] for e in self.experiments}
        for r in self.results:
            out.setdefault(r.experiment, []).append(r)
        return out

    def grid(self, name: str | None = None) -> list[list[Result]]:
        """One experiment's results as the legacy ``[load][seed]`` grid."""
        exps = {e.name: e for e in self.experiments}
        if name is None:
            if len(exps) != 1:
                raise ValueError(f"study has {len(exps)} experiments; "
                                 f"pass the name of one of {sorted(exps)}")
            name = next(iter(exps))
        exp = exps[name]
        by_key = {r.key: r for r in self.results if r.experiment == name}
        return [[by_key[exp.key(load, seed)] for seed in exp.sweep.seeds]
                for load in exp.sweep.loads]

    def fidelities(self) -> dict[str, str]:
        """Per experiment: the fidelity tier of its records — ``"cycle"``
        (packet-level engines), ``"flow"`` (the analytical model), or
        ``"mixed"`` when a resumed store holds both."""
        out: dict[str, str] = {}
        for exp in self.experiments:
            tiers = {getattr(r, "fidelity", "cycle") or "cycle"
                     for r in self.results if r.experiment == exp.name}
            if tiers:
                out[exp.name] = tiers.pop() if len(tiers) == 1 else "mixed"
        return out

    def saturation_points(self, threshold: float = 0.95, *,
                          fidelity: str | None = None
                          ) -> dict[str, float | None]:
        """Per experiment: the smallest offered load whose accepted
        throughput (seed-averaged) falls below ``threshold * offered``.

        ``threshold`` is the tolerated shortfall fraction before a load
        point counts as saturated — 0.95 (the literature's convention)
        flags the knee where the fabric stops accepting ~all offered
        traffic, while tolerating sub-5% sampling noise on uncongested
        points.  Returns ``None`` for experiments that never cross it
        (including collective replays, whose offered load is 0 — see
        :meth:`replay_points` for their headline numbers).

        A knee averaged across fidelity tiers would belong to neither
        model, so mixed-fidelity experiments refuse to produce one:
        pass ``fidelity="cycle"``/``"flow"`` to pick the tier (records
        of other tiers are ignored; experiments with no record of the
        requested tier are omitted), or leave it ``None`` for
        single-tier stores."""
        out = {}
        for exp in self.experiments:
            rows = [r for r in self.results if r.experiment == exp.name]
            if fidelity is not None:
                rows = [r for r in rows
                        if (getattr(r, "fidelity", "cycle") or "cycle")
                        == fidelity]
                if not rows:
                    continue
            else:
                tiers = {getattr(r, "fidelity", "cycle") or "cycle"
                         for r in rows}
                if len(tiers) > 1:
                    raise ValueError(
                        f"experiment {exp.name!r} holds records of mixed "
                        f"fidelities {sorted(tiers)}; their knees are not "
                        f"comparable — pass fidelity='cycle' or "
                        f"fidelity='flow' to saturation_points()")
            by_key = {r.key: r for r in rows}
            knee = None
            for load in exp.sweep.loads:
                row = [by_key[exp.key(load, seed)]
                       for seed in exp.sweep.seeds
                       if exp.key(load, seed) in by_key]
                if not row:
                    continue
                acc = sum(r.accepted for r in row) / len(row)
                if load > 0 and acc < threshold * load:
                    knee = load
                    break
            out[exp.name] = knee
        return out

    def replay_points(self) -> dict[str, dict]:
        """Per collective-replay experiment: measured completion cycles
        vs the schedule algebra's contention-free bound.

        ``measured`` is the worst completion over the experiment's grid
        points; ``ratio`` is ``measured / ideal`` — 1.0 certifies the
        schedule ran contention-free under queueing, anything above it
        quantifies the serialization the replay uncovered.  Experiments
        without replay records are omitted.
        """
        out: dict[str, dict] = {}
        for exp in self.experiments:
            rows = [r for r in self.results
                    if r.experiment == exp.name
                    and r.completion_cycles is not None]
            if not rows:
                continue
            measured = max(r.completion_cycles for r in rows)
            ideal = rows[0].ideal_cycles
            out[exp.name] = {
                "measured": measured,
                "ideal": ideal,
                "ratio": round(measured / ideal, 3) if ideal else None,
            }
        return out

    def serving_points(self) -> dict[str, dict]:
        """Per serving experiment: the grid's worst request-latency
        percentiles and lowest SLO attainment (the headline numbers a
        serving study exists to measure).  Experiments without request
        records are omitted."""
        out: dict[str, dict] = {}
        for exp in self.experiments:
            rows = [r for r in self.results
                    if r.experiment == exp.name
                    and getattr(r, "request_count", None)]
            if not rows:
                continue

            def worst(field_name, rows=rows):
                vals = [getattr(r, field_name) for r in rows
                        if getattr(r, field_name, None) is not None]
                return max(vals) if vals else None

            atts = [r.slo_attainment for r in rows
                    if getattr(r, "slo_attainment", None) is not None]
            out[exp.name] = {
                "requests": sum(r.request_count for r in rows),
                "p50": worst("request_latency_p50"),
                "p95": worst("request_latency_p95"),
                "p99": worst("request_latency_p99"),
                "slo": rows[0].slo_target,
                "attainment": min(atts) if atts else None,
            }
        return out

    def telemetry(self) -> dict[str, dict]:
        """Compile-vs-execute telemetry per experiment, deduplicated.

        A batched compiled experiment shares one timing dict across its
        grid points, so the sum here counts each program once, not once
        per point.  ``compile_s``/``execute_s`` are program totals;
        ``points`` is the grid points they covered (restored points
        contribute their stored provenance timings, if any).
        """
        out: dict[str, dict] = {}
        for exp in self.experiments:
            seen: list[dict] = []
            points = 0
            for r in self.results:
                if r.experiment != exp.name:
                    continue
                timing = (r.provenance or {}).get("timings")
                if timing is None and r.stats is not None:
                    timing = r.stats.timing
                if timing is None:
                    continue
                points += 1
                # A batched program's dict is one shared object across
                # its fresh points; restored points get value-equal
                # copies from JSON (wall-clock values to 6 decimals make
                # distinct programs with equal dicts improbable).
                if not any(t is timing or t == timing for t in seen):
                    seen.append(timing)
            if seen:
                out[exp.name] = {
                    "backend": seen[0].get("backend"),
                    "programs": len(seen),
                    "points": points,
                    "compile_s": round(sum(t.get("compile_s", 0.0)
                                           for t in seen), 6),
                    "execute_s": round(sum(t.get("execute_s", 0.0)
                                           for t in seen), 6),
                }
        return out

    def table(self) -> str:
        from repro.sim.report import format_table
        return format_table(self.results)


class Study:
    """Run the grid of one spec file / one or more experiment specs.

    ``store`` (a path or :class:`JsonlStore`) turns on persistence and
    resume; ``backend`` picks the engine:

    * ``"auto"`` / ``None`` (default) — resolved per experiment: fabrics
      with at least :data:`FLOW_AUTO_SWITCHES` switches escalate to the
      flow model (the cycle engines cannot reach them), smaller ones use
      the compiled :mod:`repro.sim.xengine` whenever ``import jax``
      succeeds, else the numpy oracle.  Between the cycle engines there
      is no result-shape difference, only speed: the compiled path
      batches each experiment's entire (load x seed) grid into one jit
      program (and same-shape grids across experiments share the
      compilation via the jit cache), while numpy loops
      :func:`repro.sim.engine.simulate` per point.
    * ``"jax"`` — force the compiled engine (raises if jax is absent).
    * ``"numpy"`` — force the oracle; per-point results are bit-stable
      across resumes (the compiled path re-draws arbitration streams
      when a resumed batch has different geometry, so its resumed points
      are statistically — not bitwise — equivalent).
    * ``"flow"`` — force the analytical fair-share model
      (:mod:`repro.flow`): a different *fidelity tier* whose records
      carry ``fidelity="flow"`` so stores stay mixable with cycle
      results without their knees being conflated.
    """

    def __init__(self, experiments, *, store=None, backend: str | None = None):
        self.experiments: list[ExperimentSpec] = load_specs(experiments)
        if not self.experiments:
            raise ValueError("a Study needs at least one experiment")
        names = [e.name for e in self.experiments]
        if len(set(names)) != len(names):
            dup = sorted({n for n in names if names.count(n) > 1})
            raise ValueError(
                f"experiment names must be unique within a study (they key "
                f"the result store); duplicated: {dup}")
        self.store = (store if isinstance(store, JsonlStore)
                      else JsonlStore(store) if store is not None else None)
        self.backend = backend
        # Experiments naming the same fabric share one resolved topology
        # (one SimTopology build, one memoized LinkTable family).
        self._topo_cache: dict[str, object] = {}

    @staticmethod
    def _fabric_key(fs) -> str | None:
        if fs.is_inline:
            return None
        return json.dumps({"kind": fs.kind, "params": fs.params},
                          sort_keys=True, default=str)

    @property
    def grid_size(self) -> int:
        return sum(len(e.points()) for e in self.experiments)

    # -- execution -----------------------------------------------------------

    def run(self, *, resume: bool = True) -> StudyResult:
        # Backend resolution is per experiment: "auto" escalates to the
        # flow model above FLOW_AUTO_SWITCHES switches, so one study can
        # mix a cycle-accurate CIN-16 grid with a 10k-switch flow grid.
        resolved = {exp.name: _select_backend(
            self.backend, num_switches=exp.fabric.num_switches,
            experiment=exp)
            for exp in self.experiments}
        label = (next(iter(set(resolved.values())))
                 if len(set(resolved.values())) == 1 else "mixed")
        if self.store is not None and not resume:
            self.store.clear()
        existing = (self.store.load()
                    if self.store is not None and resume else {})
        results: list[Result] = []
        executed = restored = 0
        for exp in self.experiments:
            backend = resolved[exp.name]
            digest = exp.digest()
            exp_results: dict[str, Result] = {}
            missing: list[tuple[float, int]] = []
            for load, seed in exp.points():
                key = exp.key(load, seed)
                if key in existing:
                    stored = existing[key]
                    # The key names the grid point but not the spec's
                    # cycles/warmup/traffic/engine parameters — restoring
                    # a record written by a *different* version of the
                    # spec would silently mislabel its results.
                    if digest and stored.spec_digest and \
                            stored.spec_digest != digest:
                        raise ValueError(
                            f"store {self.store.path!r} holds results for "
                            f"{key!r} produced by a different version of "
                            f"the experiment spec (digest "
                            f"{stored.spec_digest} != {digest}); re-run "
                            f"with resume=False (CLI: --no-resume) or "
                            f"point the study at a fresh store")
                    exp_results[key] = stored
                    restored += 1
                else:
                    missing.append((load, seed))
            if missing:
                if backend == "jax":
                    fresh = self._run_jax(exp, missing)
                    if self.store is not None:
                        self.store.append(fresh)
                elif backend == "flow":
                    fresh = self._run_flow(exp, missing)
                    if self.store is not None:
                        self.store.append(fresh)
                else:           # numpy streams per point inside the loop
                    fresh = self._run_numpy(exp, missing)
                executed += len(fresh)
                exp_results.update((r.key, r) for r in fresh)
            results.extend(exp_results[exp.key(load, seed)]
                           for load, seed in exp.points())
        if self.store is not None:
            # Settle any fsyncs a flush_interval > 1 store deferred.
            self.store.sync()
        return StudyResult(
            experiments=self.experiments, results=results,
            executed=executed, restored=restored, backend=label,
            store_path=self.store.path if self.store is not None else None)

    def _resolve(self, exp: ExperimentSpec):
        fs = exp.fabric
        key = self._fabric_key(fs)
        topo = self._topo_cache.get(key) if key is not None else None
        if topo is None:
            topo = fs.resolve_topology()
            if key is not None:
                self._topo_cache[key] = topo
        if exp.failures is not None:
            # Degrade once per (fabric, FailureSpec) and cache alongside
            # the pristine topology: a failure-rate x seed sweep shares
            # each degraded table across its experiments' grid points.
            from repro.faults import FabricDisconnectedError, degrade
            fkey = (f"{key}|faults={exp.failures.to_json()}"
                    if key is not None else None)
            degraded = (self._topo_cache.get(fkey)
                        if fkey is not None else None)
            if degraded is None:
                try:
                    degraded = degrade(topo, exp.failures)
                except FabricDisconnectedError as e:
                    raise FabricDisconnectedError(
                        f"experiment {exp.name!r}: {e}") from e
                if fkey is not None:
                    self._topo_cache[fkey] = degraded
            topo = degraded
        tf = exp.traffic.factory(topo, cycles=exp.sweep.cycles,
                                 terminals=exp.terminals
                                 if exp.terminals is not None else 1)
        if exp.failures is not None:
            from repro.faults import mask_traffic as _mask
            inner, masked_topo = tf, topo

            def tf(load, seed):
                return _mask(inner(load, seed), masked_topo)
        return topo, tf

    # -- serving capacity ----------------------------------------------------

    def slo_capacity(self, experiment: str | None = None, *,
                     percentile: float = 99.0, lo: float = 0.05,
                     hi: float = 2.0, tol: float = 0.01,
                     seed: int = 0) -> dict:
        """Largest load scale at which a serving experiment still meets
        its SLO, by bisection on the load axis.

        A load is *feasible* when the probed point's SLO attainment is
        at least ``percentile / 100`` — i.e. the latency ``percentile``
        sits at or under the traffic's ``slo`` target, with requests
        that never completed counting as misses.  Probes run outside
        the study's store (warmup 0, the experiment's own seed policy)
        on the numpy oracle, or on the flow model when the experiment
        resolves to the flow tier.  Returns ``{"capacity", "percentile",
        "slo", "probes": [(load, attainment), ...]}``; ``capacity`` is
        0.0 when even ``lo`` misses and ``hi`` when the search never
        found the knee (raise ``hi`` to chase it).
        """
        exps = {e.name: e for e in self.experiments}
        if experiment is None:
            if len(exps) != 1:
                raise ValueError(
                    f"study has {len(exps)} experiments; pass one of "
                    f"{sorted(exps)}")
            experiment = next(iter(exps))
        exp = exps[experiment]
        if exp.traffic.pattern != "serving":
            raise ValueError(
                f"slo_capacity needs a 'serving' traffic pattern; "
                f"experiment {exp.name!r} uses {exp.traffic.pattern!r}")
        slo = exp.traffic.params.get("slo")
        if slo is None:
            raise ValueError(
                f"experiment {exp.name!r} sets no params['slo'] target to "
                f"search against")
        if not (0.0 < lo <= hi) or tol <= 0:
            raise ValueError(f"need 0 < lo <= hi and tol > 0; "
                             f"got lo={lo}, hi={hi}, tol={tol}")
        backend = _select_backend(self.backend,
                                  num_switches=exp.fabric.num_switches,
                                  experiment=exp)
        topo, tf = self._resolve(exp)
        target = float(percentile) / 100.0
        probes: list[tuple[float, float]] = []

        def attainment(load: float) -> float:
            if backend == "flow":
                from repro.flow import study_point_stats
                stats = study_point_stats(exp, topo, tf, load, seed)
            else:
                from repro.sim.engine import simulate
                cycles = exp.sweep.cycles or 1
                stats = simulate(topo, exp.routing.make(), tf(load, seed),
                                 terminals=exp.terminals, cycles=cycles,
                                 warmup=0, seed=seed, backend="numpy",
                                 **dict(exp.engine))
            att = stats.slo_attainment
            att = 0.0 if att is None else float(att)
            probes.append((round(float(load), 6), att))
            return att

        out = {"experiment": exp.name, "percentile": float(percentile),
               "slo": float(slo), "probes": probes}
        if attainment(lo) < target:
            out["capacity"] = 0.0
            return out
        if attainment(hi) >= target:
            out["capacity"] = float(hi)
            return out
        good, bad = float(lo), float(hi)
        while bad - good > tol:
            mid = (good + bad) / 2.0
            if attainment(mid) >= target:
                good = mid
            else:
                bad = mid
        out["capacity"] = round(good, 6)
        return out

    def _run_jax(self, exp: ExperimentSpec,
                 missing: Sequence[tuple[float, int]]) -> list[Result]:
        from repro.sim import xengine
        topo, tf = self._resolve(exp)
        sweep = exp.sweep
        kw = dict(terminals=exp.terminals, cycles=sweep.cycles,
                  warmup=sweep.warmup, **dict(exp.engine))
        if list(missing) == exp.points():
            # Full grid: one compiled program over loads x seeds, with the
            # per-point arbitration streams keyed off the real seed tuple
            # (bit-identical to the legacy xengine.sweep entry point).
            grid = xengine.sweep(topo, exp.routing.make(), tf,
                                 list(sweep.loads), seeds=tuple(sweep.seeds),
                                 **kw)
            flat = [(load, seed, grid[li][si])
                    for li, load in enumerate(sweep.loads)
                    for si, seed in enumerate(sweep.seeds)]
        else:
            # Resume: batch just the missing points into one program by
            # packing them along the load axis (the traffic objects carry
            # the real offered loads and seeds; the index is only a
            # routing key).  The batch geometry differs from the full
            # grid's, so the re-executed points draw a fresh arbitration
            # stream — statistically equivalent, same contract as the
            # compiled engine vs the oracle (numpy resume, by contrast,
            # is bit-identical).  The pseudo-seed keys that stream off
            # the actual missing points, so distinct resumes decorrelate.
            pts = list(missing)
            pseudo_seed = hash(tuple(pts)) & 0x7FFFFFFF
            grid = xengine.sweep(
                topo, exp.routing.make(),
                lambda i, _seed: tf(*pts[int(i)]),
                list(range(len(pts))), seeds=(pseudo_seed,), **kw)
            flat = [(load, seed, grid[i][0])
                    for i, (load, seed) in enumerate(pts)]
        return [Result.from_stats(stats, key=exp.key(load, seed),
                                  experiment=exp.name, load=load, seed=seed,
                                  backend="jax", spec_digest=exp.digest())
                for load, seed, stats in flat]

    def _run_flow(self, exp: ExperimentSpec,
                  missing: Sequence[tuple[float, int]]) -> list[Result]:
        import time
        from repro.flow import study_point_stats
        from repro.obs.telemetry import timing_dict
        topo, tf = self._resolve(exp)
        t0 = time.perf_counter()
        batch = [(load, seed,
                  study_point_stats(exp, topo, tf, load, seed))
                 for load, seed in missing]
        # One timing dict shared across the batch, like the compiled
        # path: the flow model has no compile step, only execute.
        timing = timing_dict("flow",
                             execute_s=time.perf_counter() - t0,
                             grid_points=len(batch))
        out = []
        for load, seed, stats in batch:
            stats.timing = timing
            out.append(Result.from_stats(
                stats, key=exp.key(load, seed), experiment=exp.name,
                load=load, seed=seed, backend="flow",
                spec_digest=exp.digest(), fidelity="flow"))
        return out

    def _run_numpy(self, exp: ExperimentSpec,
                   missing: Sequence[tuple[float, int]]) -> list[Result]:
        from repro.sim.engine import simulate
        topo, tf = self._resolve(exp)
        sweep = exp.sweep
        out = []
        for load, seed in missing:
            traffic = tf(load, seed)
            cycles = (sweep.cycles if sweep.cycles is not None
                      else max(traffic.horizon, 1))
            # Collective replays measure completion from cycle 0 — a
            # warmup window would carve latency/throughput out of the
            # very phases being measured (the jax path does the same
            # inside xengine.sweep).
            warmup = (sweep.warmup if sweep.warmup is not None
                      else 0 if traffic.workload is not None
                      else cycles // 4)
            stats = simulate(topo, exp.routing.make(), traffic,
                             terminals=exp.terminals, cycles=cycles,
                             warmup=warmup, seed=seed, backend="numpy",
                             **dict(exp.engine))
            res = Result.from_stats(stats, key=exp.key(load, seed),
                                    experiment=exp.name, load=load,
                                    seed=seed, backend="numpy",
                                    spec_digest=exp.digest())
            # Stream per point: a killed numpy study resumes mid-experiment.
            if self.store is not None:
                self.store.append(res)
            out.append(res)
        return out
