"""Declarative, serializable experiment specs.

The paper's claims are all *experiment grids* — topology family x
routing policy x traffic pattern x offered load x seed.  This module is
the grid as data: four small spec dataclasses compose into an
:class:`ExperimentSpec` whose JSON form round-trips exactly
(``ExperimentSpec.from_json(spec.to_json()) == spec``), so a study can
be named, persisted, diffed, resumed, and shipped to CI as a file.

======================  =====================================================
:class:`FabricSpec`     which topology (resolved via ``repro.fabric``)
:class:`TrafficSpec`    which synthetic pattern (``repro.sim.traffic``)
:class:`RoutingSpec`    which policy (``repro.sim.policies``)
:class:`SweepSpec`      the grid: offered loads x seeds x cycles
======================  =====================================================

Specs are *declarative*: they hold names and parameters, never objects.
The escape hatch for the legacy shims (``report.saturation_sweep``,
``Fabric.sim_sweep``) is the ``.custom(...)`` constructors, which carry a
caller-supplied object/callable; such inline specs run fine but refuse
to serialize.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Mapping, Sequence

import numpy as np

__all__ = ["FabricSpec", "TrafficSpec", "RoutingSpec", "SweepSpec",
           "ExperimentSpec", "load_specs", "dump_specs"]

_INLINE = "custom"      # kind/pattern/policy marker for non-serializable specs


def _canon(v):
    """Canonical in-memory form: JSON arrays (and tuples) become tuples,
    object keys become strings — so equality between a constructed spec
    and its JSON round-trip is exact."""
    if isinstance(v, (list, tuple)):
        return tuple(_canon(x) for x in v)
    if isinstance(v, Mapping):
        return {str(k): _canon(x) for k, x in v.items()}
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, np.ndarray):
        return tuple(_canon(x) for x in v.tolist())
    return v


def _jsonable(v):
    """The JSON form of a canonical value (tuples back to lists)."""
    if isinstance(v, tuple):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {k: _jsonable(x) for k, x in v.items()}
    return v


class _SpecBase:
    """Shared (de)serialization: dataclass fields <-> a JSON object.

    Fields whose name starts with ``_`` are excluded from serialization:
    they carry inline objects (``.custom(...)`` constructors) or lazily
    cached resolutions.  Whether a spec *is* inline is decided by its
    declarative marker (``kind``/``pattern``/``policy`` == "custom"),
    never by the caches — resolving a declarative spec must not stop it
    serializing.
    """

    def __post_init__(self):
        for name, val in list(self.__dict__.items()):
            if not name.startswith("_"):
                object.__setattr__(self, name, _canon(val))

    @property
    def is_inline(self) -> bool:
        return False

    def to_dict(self) -> dict:
        if self.is_inline:
            raise ValueError(
                f"{type(self).__name__} carries an inline (non-declarative) "
                f"object and cannot be serialized; build it from "
                f"names/parameters instead")
        out = {}
        for k, v in self.__dict__.items():
            if k.startswith("_"):
                continue
            v = _jsonable(v)
            if isinstance(v, _SpecBase):
                v = v.to_dict()
            out[k] = v
        return out

    @classmethod
    def from_dict(cls, d: Mapping) -> "_SpecBase":
        return cls(**{str(k): v for k, v in d.items()})

    def to_json(self, **kw) -> str:
        kw.setdefault("sort_keys", True)
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_json(cls, s: str) -> "_SpecBase":
        return cls.from_dict(json.loads(s))


# ---------------------------------------------------------------------------
# Fabric.
# ---------------------------------------------------------------------------

@dataclass(frozen=True, eq=True)
class FabricSpec(_SpecBase):
    """A topology by name: ``kind`` picks the family, ``params`` the size.

    * ``kind="cin"``       — ``params={"instance": ..., "n": ...}``;
    * ``kind="hyperx"``    — :class:`repro.core.hyperx.HyperXConfig` kwargs;
    * ``kind="dragonfly"`` — :class:`repro.core.dragonfly.DragonflyConfig`
      kwargs.

    ``resolve()`` builds the :class:`repro.fabric.Fabric` through
    ``make_fabric``, so any instance registered with
    :func:`repro.fabric.register_instance` works in every position.
    """
    kind: str
    params: dict = field(default_factory=dict)
    _fabric: Any = field(default=None, compare=False, repr=False)
    _topology: Any = field(default=None, compare=False, repr=False)

    @property
    def is_inline(self) -> bool:
        return self.kind == _INLINE

    def resolve(self):
        """The :class:`repro.fabric.Fabric` this spec names."""
        if self._fabric is not None:
            return self._fabric
        from repro.core.dragonfly import DragonflyConfig
        from repro.core.hyperx import HyperXConfig
        from repro.fabric import make_fabric
        p = dict(self.params)
        if self.kind == "cin":
            fab = make_fabric(p["instance"], int(p["n"]))
        elif self.kind == "hyperx":
            fab = make_fabric(HyperXConfig(**p))
        elif self.kind == "dragonfly":
            fab = make_fabric(DragonflyConfig(**p))
        elif self.kind == _INLINE:
            raise ValueError("inline FabricSpec lost its carried object")
        else:
            raise ValueError(
                f"unknown fabric kind {self.kind!r}; expected "
                f"'cin' | 'hyperx' | 'dragonfly'")
        object.__setattr__(self, "_fabric", fab)
        return fab

    def resolve_topology(self):
        """The simulator :class:`~repro.sim.topology.SimTopology`."""
        if self._topology is not None:
            return self._topology
        topo = self.resolve().sim_topology()
        object.__setattr__(self, "_topology", topo)
        return topo

    @property
    def num_switches(self) -> int | None:
        """Fabric size without resolving the topology — the cheap input
        to backend auto-selection (``None`` for unresolved inline
        specs, whose size is unknowable declaratively)."""
        if self._topology is not None:
            return int(self._topology.num_switches)
        if self.kind == "cin":
            return int(self.params["n"])
        if self.kind == "hyperx":
            out = 1
            for d in self.params.get("dims", ()):
                out *= int(d)
            return out
        if self.kind == "dragonfly":
            return (int(self.params["group_size"])
                    * int(self.params["num_groups"]))
        return None

    @property
    def label(self) -> str:
        if self._topology is not None:
            return self._topology.name
        if self.kind == "cin":
            return f"cin-{self.params.get('instance')}-{self.params.get('n')}"
        if self.kind == "hyperx":
            dims = "x".join(map(str, self.params.get("dims", ())))
            return f"hyperx-{dims}-{self.params.get('instance', 'xor')}"
        if self.kind == "dragonfly":
            p = self.params
            return (f"dragonfly-a{p.get('group_size')}"
                    f"h{p.get('global_ports_per_switch')}"
                    f"g{p.get('num_groups')}")
        return self.kind

    # -- constructors from live objects (shims / convenience) ---------------
    @classmethod
    def from_fabric(cls, fab) -> "FabricSpec":
        """A spec naming an existing :class:`repro.fabric.Fabric` — fully
        declarative for the three in-repo families, and reusing the live
        object (and its cached SimTopology) on resolve."""
        from dataclasses import asdict as dc_asdict
        from repro.fabric import (CINFabric, DragonflyFabric, HyperXFabric)
        if isinstance(fab, CINFabric):
            spec = cls("cin", {"instance": fab.instance, "n": fab.n})
        elif isinstance(fab, HyperXFabric):
            spec = cls("hyperx", dc_asdict(fab.config))
        elif isinstance(fab, DragonflyFabric):
            spec = cls("dragonfly", dc_asdict(fab.config))
        else:
            spec = cls(_INLINE, {"name": getattr(fab, "name", "fabric")},
                       _fabric=fab)
            return spec
        object.__setattr__(spec, "_fabric", fab)
        return spec

    @classmethod
    def from_topology(cls, topo) -> "FabricSpec":
        """A spec naming an existing SimTopology.  The in-repo adapters
        record their construction in ``topo.meta``, so the result is
        declarative for them; unknown topologies become inline specs."""
        from repro.core.dragonfly import DragonflyConfig
        from repro.core.hyperx import HyperXConfig
        from dataclasses import asdict as dc_asdict
        meta = getattr(topo, "meta", {}) or {}
        cfg = meta.get("config")
        if "instance" in meta and "n" in meta:
            spec = cls("cin", {"instance": meta["instance"],
                               "n": int(meta["n"])})
        elif isinstance(cfg, HyperXConfig):
            spec = cls("hyperx", dc_asdict(cfg))
        elif isinstance(cfg, DragonflyConfig):
            spec = cls("dragonfly", dc_asdict(cfg))
        else:
            spec = cls(_INLINE, {"name": topo.name}, _topology=topo)
            return spec
        object.__setattr__(spec, "_topology", topo)
        return spec


# ---------------------------------------------------------------------------
# Traffic.
# ---------------------------------------------------------------------------

#: Declarative pattern names: the open-loop generators of
#: :mod:`repro.sim.traffic`, the closed collective-replay kind, and the
#: request-level serving kind (:mod:`repro.workload`).
_PATTERNS = ("uniform", "permutation", "hotspot", "adversarial", "workload",
             "serving")


@dataclass(frozen=True, eq=True)
class TrafficSpec(_SpecBase):
    """A traffic pattern by name.

    **Open-loop patterns** (``uniform`` / ``permutation`` / ``hotspot`` /
    ``adversarial``): ``params`` forwards generator kwargs
    (``hot_fraction``, ``hot_dst``, ``partner_shift``, ``perm``) plus an
    optional fixed ``seed`` — without one, each grid point's packet set
    draws from its own sweep seed, so multi-seed grids measure traffic
    variance; with one, every point replays the identical packet set and
    the seeds axis varies only arbitration.  These patterns need
    ``sweep.cycles`` to size their generation window, and the sweep's
    ``loads`` are their offered load in packets/terminal/cycle.

    **Collective replay** (``workload``): a closed, phase-barriered
    workload from :mod:`repro.sim.workloads` — the sweep's ``loads`` and
    ``seeds`` are ignored by generation (keys only) and ``cycles`` may
    be ``None`` (the run completes when the workload drains).  ``params``
    is either

    * ``{"collective": "all_to_all" | "all_reduce", "message_size": m}``
      — the workload is derived from the experiment's *own fabric*
      (its LACIN schedules), so the spec stays fully declarative; or
    * ``{"workload": {...}}`` — an explicit
      :meth:`repro.sim.workloads.Workload.to_dict` payload, replayed
      verbatim (still serializable).

    **Serving streams** (``serving``): open-loop *request* arrivals from
    an :class:`repro.workload.ArrivalSpec` — ``params`` is
    ``{"arrival": {...spec dict...}, "packets_per_request": p,
    "slo": cycles}`` and the sweep's ``loads`` scale the arrival rate
    (:func:`repro.workload.serving_traffic`), so the engines report
    per-request latency percentiles and SLO attainment per grid point.
    """
    pattern: str
    params: dict = field(default_factory=dict)
    _factory: Callable | None = field(default=None, compare=False, repr=False)

    @property
    def is_inline(self) -> bool:
        return self.pattern == _INLINE

    @classmethod
    def custom(cls, factory: Callable) -> "TrafficSpec":
        """Inline spec around a legacy ``factory(load[, seed]) -> Traffic``
        callable (not serializable)."""
        return cls(_INLINE, {}, _factory=factory)

    def factory(self, topo, *, cycles: int | None,
                terminals: int) -> Callable:
        """A ``(load, seed) -> Traffic`` generator bound to ``topo``."""
        from repro import sim
        from repro.core.dragonfly import DragonflyConfig
        from repro.sim.xengine import _accepts_seed
        if self._factory is not None:
            inner = self._factory
            if _accepts_seed(inner):
                return inner
            return lambda load, seed: inner(load)
        if self.pattern == "workload":
            tr = self._resolve_workload(topo).traffic()
            return lambda load, seed: tr
        if self.pattern == "serving":
            from repro.workload import ArrivalSpec, serving_traffic
            if cycles is None:
                raise ValueError("serving traffic needs sweep.cycles to "
                                 "size its arrival window")
            kw = dict(self.params)
            spec = ArrivalSpec.coerce(kw.pop("arrival", None))
            if spec is None:
                raise ValueError("serving traffic needs params['arrival'] "
                                 "(an ArrivalSpec dict)")
            ppr = int(kw.pop("packets_per_request", 4))
            slo = kw.pop("slo", None)
            if kw:
                raise ValueError(f"unknown serving traffic params: "
                                 f"{sorted(kw)}")
            n = topo.num_switches
            return lambda load, seed: serving_traffic(
                spec, n, cycles=cycles, load=load, terminals=terminals,
                packets_per_request=ppr, slo=slo, seed=seed)
        if self.pattern not in _PATTERNS:
            raise ValueError(
                f"unknown traffic pattern {self.pattern!r}; expected one "
                f"of {_PATTERNS}")
        if cycles is None:
            raise ValueError(
                f"traffic pattern {self.pattern!r} needs sweep.cycles to "
                f"size its generation window")
        kw = dict(self.params)
        fixed_seed = kw.pop("seed", None)
        if self.pattern == "adversarial":
            cfg = (topo.meta or {}).get("config")
            if not isinstance(cfg, DragonflyConfig):
                raise ValueError(
                    "adversarial traffic is the Dragonfly same-group "
                    f"pattern; topology {topo.name!r} is not a Dragonfly")
            gen, first = sim.adversarial_same_group, cfg
        else:
            gen = {"uniform": sim.uniform, "permutation": sim.permutation,
                   "hotspot": sim.hotspot}[self.pattern]
            first = topo.num_switches
        if self.pattern == "permutation" and "perm" in kw:
            kw["perm"] = np.asarray(kw["perm"], dtype=np.int64)

        def make(load, seed):
            return gen(first, offered=load, cycles=cycles,
                       terminals=terminals,
                       seed=fixed_seed if fixed_seed is not None else seed,
                       **kw)
        return make

    def _resolve_workload(self, topo):
        """The :class:`repro.sim.workloads.Workload` this spec replays on
        ``topo`` — explicit phases if given, else the named collective's
        step sequence on the fabric the topology was built from."""
        from repro.sim.workloads import Workload, collective_workload
        kw = dict(self.params)
        if "workload" in kw:
            w = Workload.from_dict(kw["workload"])
            if w.num_switches != topo.num_switches:
                # Packets sourced past the topology's switch count would
                # never inject; fail here instead of spinning the drain
                # cutoff into a misleading "deadlock" error.
                raise ValueError(
                    f"explicit workload {w.name!r} spans {w.num_switches} "
                    f"switches but the experiment's fabric "
                    f"{topo.name!r} has {topo.num_switches}")
            return w
        meta = getattr(topo, "meta", {}) or {}
        if "instance" in meta and "n" in meta:
            from repro.fabric import make_fabric
            fab = make_fabric(meta["instance"], int(meta["n"]))
        elif meta.get("config") is not None:
            from repro.fabric import make_fabric
            fab = make_fabric(meta["config"])
        else:
            raise ValueError(
                f"workload traffic needs a fabric to derive the "
                f"{kw.get('collective', 'all_to_all')!r} schedule from, "
                f"but topology {topo.name!r} records no construction "
                f"metadata; pass explicit phases via params['workload']")
        return collective_workload(
            fab, str(kw.get("collective", "all_to_all")),
            message_size=int(kw.get("message_size", 1)))

    @property
    def label(self) -> str:
        if self.pattern == "workload":
            wl = self.params.get("workload")
            if isinstance(wl, Mapping):
                return f"replay-{wl.get('name', 'workload')}"
            return f"replay-{self.params.get('collective', 'all_to_all')}"
        if self.pattern == "serving":
            arrival = self.params.get("arrival")
            if isinstance(arrival, Mapping):
                from repro.workload import ArrivalSpec
                try:
                    return f"serving-{ArrivalSpec.from_dict(arrival).label}"
                except (TypeError, ValueError):
                    pass
            return "serving"
        return self.pattern


# ---------------------------------------------------------------------------
# Routing.
# ---------------------------------------------------------------------------

@dataclass(frozen=True, eq=True)
class RoutingSpec(_SpecBase):
    """A routing policy by name (+ kwargs, e.g. adaptive's threshold)."""
    policy: str
    params: dict = field(default_factory=dict)
    _make: Any = field(default=None, compare=False, repr=False)

    @property
    def is_inline(self) -> bool:
        return self.policy == _INLINE

    @classmethod
    def custom(cls, policy) -> "RoutingSpec":
        """Inline spec around a policy object / factory / name."""
        if isinstance(policy, str):
            return cls(policy)
        name = getattr(policy, "name", None) or getattr(
            policy, "__name__", _INLINE)
        return cls(_INLINE, {"name": str(name)}, _make=policy)

    def make(self):
        """A fresh policy object (one per run, like the legacy sweeps)."""
        from repro.sim.policies import make_policy
        from repro.sim.xengine import _resolve_policy
        if self._make is not None:
            return _resolve_policy(self._make)
        return make_policy(self.policy, **dict(self.params))

    @property
    def label(self) -> str:
        if self._make is not None:
            return str(self.params.get("name", _INLINE))
        return self.policy


# ---------------------------------------------------------------------------
# Sweep grid.
# ---------------------------------------------------------------------------

@dataclass(frozen=True, eq=True)
class SweepSpec(_SpecBase):
    """The grid: offered loads x seeds, over a shared cycle horizon.

    ``cycles=None`` lets the engines derive the horizon from the traffic
    objects (only meaningful with inline traffic specs — declarative
    patterns need ``cycles`` to size their generation window); ``warmup``
    defaults to a quarter of the horizon.
    """
    loads: tuple = (1.0,)
    seeds: tuple = (0,)
    cycles: int | None = None
    warmup: int | None = None

    def __post_init__(self):
        super().__post_init__()
        if not self.loads or not self.seeds:
            raise ValueError("a sweep grid needs at least one load and "
                             "one seed")

    def points(self) -> list[tuple[float, int]]:
        """Grid points in canonical (load-major) order."""
        return [(load, seed) for load in self.loads for seed in self.seeds]


# ---------------------------------------------------------------------------
# The composed experiment.
# ---------------------------------------------------------------------------

@dataclass(frozen=True, eq=True)
class ExperimentSpec(_SpecBase):
    """One experiment: fabric x traffic x routing, swept over a grid.

    ``name`` keys result records (and resume); it defaults to
    ``<fabric>/<traffic>/<routing>``.  ``terminals`` is the injector
    count per switch; ``None`` means 1 for declarative traffic and
    "whatever the traffic objects record" for inline factories (traffic
    generation and engine agree by construction either way — see
    :func:`repro.sim.traffic.resolve_terminals`).  ``engine`` forwards
    extra engine kwargs (``queue_capacity``, ``num_vcs``, ``eject_bw``,
    ``max_cycles``, ``drain``).

    ``failures`` is an optional :class:`repro.faults.FailureSpec` (or its
    dict form): the experiment then runs on the *degraded* fabric — the
    topology passes through :func:`repro.faults.degrade` once per study
    and traffic to/from dead or disconnected switches is masked before
    injection.  ``failures=None`` (or a null spec) is byte-identical to
    the pre-faults behaviour: the key is omitted from ``to_dict``, so
    old spec files load unchanged and stored digests keep resuming.
    """
    fabric: FabricSpec = None
    traffic: TrafficSpec = None
    routing: RoutingSpec = None
    sweep: SweepSpec = field(default_factory=SweepSpec)
    name: str = ""
    terminals: int | None = None
    engine: dict = field(default_factory=dict)
    failures: Any = None

    def __post_init__(self):
        for fld, typ in (("fabric", FabricSpec), ("traffic", TrafficSpec),
                         ("routing", RoutingSpec), ("sweep", SweepSpec)):
            v = getattr(self, fld)
            if isinstance(v, Mapping):
                object.__setattr__(self, fld, typ.from_dict(v))
            elif not isinstance(v, typ):
                raise TypeError(f"ExperimentSpec.{fld} must be a {typ.__name__}"
                                f" (or its dict form), got {type(v).__name__}")
        if self.failures is not None:
            from repro.faults import FailureSpec
            spec = FailureSpec.coerce(self.failures)
            object.__setattr__(self, "failures",
                               None if spec is not None and spec.is_null
                               else spec)
        super().__post_init__()
        if not self.name:
            object.__setattr__(self, "name", "/".join(
                (self.fabric.label, self.traffic.label, self.routing.label)))

    def to_dict(self) -> dict:
        out = super().to_dict()
        if out.get("failures") is None:
            # Absent and None are the same spec; omitting the key keeps
            # old JSON loading exactly and leaves pre-faults digests (and
            # thus resumable stores) untouched.
            out.pop("failures", None)
        return out

    @property
    def is_inline(self) -> bool:
        return (self.fabric.is_inline or self.traffic.is_inline
                or self.routing.is_inline)

    def key(self, load: float, seed: int) -> str:
        """The stable identity of one grid point in a result store."""
        return f"{self.name}|load={load!r}|seed={seed}"

    def digest(self) -> str:
        """A short hash of the declarative spec *minus the grid axes*,
        carried by every stored :class:`~repro.studies.store.Result` so a
        resume can detect that the spec behind a key changed (cycles,
        warmup, traffic or engine params — none of which the key itself
        encodes).  ``loads``/``seeds`` are excluded: the key already
        names the grid point, and growing a grid must resume cleanly,
        executing only the new points.  Inline specs are unhashable and
        return ``""`` (resume skips the check)."""
        if self.is_inline:
            return ""
        import hashlib
        d = self.to_dict()
        d["sweep"] = {k: v for k, v in d["sweep"].items()
                      if k not in ("loads", "seeds")}
        return hashlib.sha1(json.dumps(d, sort_keys=True).encode()
                            ).hexdigest()[:12]

    def points(self):
        return self.sweep.points()

    def describe(self) -> str:
        s = self.sweep
        out = (f"{self.name}: {len(s.loads)} loads x {len(s.seeds)} seeds"
               f" x {s.cycles} cycles (terminals={self.terminals})")
        if self.failures is not None:
            out += f" failures={self.failures.label}"
        return out

    def with_sweep(self, **kw) -> "ExperimentSpec":
        """A copy with sweep fields replaced (loads, seeds, cycles, warmup)
        — the knob benchmarks use to shrink bundled specs in quick mode."""
        return replace(self, sweep=replace(self.sweep, **kw))


# ---------------------------------------------------------------------------
# Spec files: one experiment, or {"experiments": [...]}.
# ---------------------------------------------------------------------------

def load_specs(source) -> list[ExperimentSpec]:
    """Experiments from a spec file path, JSON string, or parsed object.

    Accepts a single experiment object or ``{"experiments": [...]}``
    (extra top-level keys like ``"study"``/``"description"`` are
    ignored, so spec files can self-document).
    """
    if isinstance(source, (list, tuple)):
        return [e if isinstance(e, ExperimentSpec)
                else ExperimentSpec.from_dict(e) for e in source]
    if isinstance(source, ExperimentSpec):
        return [source]
    if isinstance(source, Mapping):
        obj = source
    else:
        text = str(source)
        if text.lstrip().startswith(("{", "[")):
            obj = json.loads(text)
        else:
            with open(text) as f:
                obj = json.load(f)
    if isinstance(obj, list):
        return [ExperimentSpec.from_dict(e) for e in obj]
    if "experiments" in obj:
        return [ExperimentSpec.from_dict(e) for e in obj["experiments"]]
    return [ExperimentSpec.from_dict(obj)]


def dump_specs(specs: Sequence[ExperimentSpec], path: str | None = None, *,
               study: str | None = None, description: str | None = None
               ) -> str:
    """Serialize experiments to a spec-file JSON string (and ``path``)."""
    specs = [specs] if isinstance(specs, ExperimentSpec) else list(specs)
    payload: dict = {}
    if study:
        payload["study"] = study
    if description:
        payload["description"] = description
    payload["experiments"] = [e.to_dict() for e in specs]
    text = json.dumps(payload, indent=2, sort_keys=True) + "\n"
    if path is not None:
        with open(path, "w") as f:
            f.write(text)
    return text
