"""Batched serving engine: continuous prefill + decode over a KV cache.

A deliberately small but real engine: requests queue up, get prefetched
into per-slot caches (prefill), and decode proceeds in lockstep over the
active batch with greedy or temperature sampling.  Slot management keeps
the batch full (continuous batching at step granularity).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import ModelConfig
from repro.models.layers import AxisRules
from repro.models.transformer import decode_step, init_caches, prefill


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (T,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: list = field(default_factory=list)
    done: bool = False
    arrived: int | None = None         # decode step at submit time


class ServingEngine:
    """Fixed-slot continuous batching engine (one model, one mesh)."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_seq: int = 256, rules: AxisRules = AxisRules(),
                 seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.rules = rules
        self.slots = slots
        self.max_seq = max_seq
        self.caches = init_caches(cfg, slots, max_seq)
        self.pos = 0                      # lockstep fill position
        self.active: list[Request | None] = [None] * slots
        self.queue: list[Request] = []
        self.rng = np.random.default_rng(seed)
        self.steps_total = 0              # decode steps across all runs
        self._decode = jax.jit(
            lambda p, t, c, q: decode_step(p, t, c, q, cfg, rules, max_seq))
        self._last_tok = jnp.zeros((slots, 1), jnp.int32)

    # -- request management ---------------------------------------------------
    def submit(self, req: Request, *, at: int | None = None):
        """Queue a request.  ``at`` overrides the recorded arrival step
        (defaults to the engine's decode-step clock) so replayed logs
        keep their original timestamps."""
        req.arrived = self.steps_total if at is None else int(at)
        self.queue.append(req)

    def arrival_trace(self, requests=None):
        """The submitted requests' arrival times as a replayable
        ``kind="trace"`` :class:`repro.workload.ArrivalSpec` — feed it to
        :func:`repro.workload.serving_traffic` (or a ``"serving"``
        study spec) to drive a fabric simulation with this engine's real
        admission timing.  Sources are left empty: the fabric draws them
        uniformly at replay, since engine slots are not switch ids.

        ``requests`` defaults to everything queued or active now; pass
        the list :meth:`run` returned to trace a completed batch.
        """
        from repro.workload import ArrivalSpec
        if requests is None:
            requests = [r for r in self.active if r is not None] + self.queue
        times = tuple(int(r.arrived) for r in requests
                      if r.arrived is not None)
        if not times:
            raise ValueError("no requests with recorded arrival steps; "
                             "submit() some first")
        return ArrivalSpec(kind="trace", times=times)

    def _admit(self):
        """Lockstep admission: fill empty slots at a batch boundary by
        replaying prompts through the shared-position decode stream."""
        for i in range(self.slots):
            if self.active[i] is None and self.queue:
                self.active[i] = self.queue.pop(0)

    # -- stepping ---------------------------------------------------------------
    def _prefill_all(self):
        """Prefill all admitted prompts (padded to a common length)."""
        reqs = [r for r in self.active if r is not None]
        if not reqs:
            return
        tlen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((self.slots, tlen), np.int32)
        for i, r in enumerate(self.active):
            if r is not None:
                toks[i, -len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.is_encdec:
            batch["frames"] = jnp.zeros(
                (self.slots, self.cfg.encoder_seq_len, self.cfg.d_model),
                jnp.float32)
        logits, caches = jax.jit(
            lambda p, b: prefill(p, b, self.cfg, self.rules, self.max_seq))(
            self.params, batch)
        self.caches = caches
        self.pos = tlen
        self._last_tok = self._sample(logits[:, -1])

    def _sample(self, logits):
        logits = np.asarray(logits, np.float32)
        out = np.zeros((self.slots, 1), np.int32)
        for i, r in enumerate(self.active):
            if r is None:
                continue
            row = logits[i]
            if r.temperature > 0:
                p = np.exp((row - row.max()) / r.temperature)
                p = p / p.sum()
                out[i, 0] = self.rng.choice(len(row), p=p)
            else:
                out[i, 0] = int(row.argmax())
        return jnp.asarray(out)

    def step(self):
        """One decode step for the whole batch."""
        logits, self.caches = self._decode(
            self.params, self._last_tok, self.caches,
            jnp.asarray(self.pos, jnp.int32))
        self.pos += 1
        self.steps_total += 1
        tok = self._sample(logits[:, 0])
        self._last_tok = tok
        for i, r in enumerate(self.active):
            if r is None:
                continue
            r.out_tokens.append(int(tok[i, 0]))
            if len(r.out_tokens) >= r.max_new_tokens \
                    or self.pos >= self.max_seq - 1:
                r.done = True
                self.active[i] = None

    def run(self, max_steps: int = 512) -> list[Request]:
        """Run until every queued request completes; returns them."""
        finished: list[Request] = []
        self._admit()
        self._prefill_all()
        steps = 0
        all_reqs = [r for r in self.active if r is not None] + self.queue
        while any(not r.done for r in all_reqs) and steps < max_steps:
            self.step()
            steps += 1
            # NOTE: lockstep engine admits new requests only between runs
            # (prefill replays would desync positions); production engines
            # use per-slot position tracking — see DESIGN.md §serving.
        return [r for r in all_reqs if r.done]
