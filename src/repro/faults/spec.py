"""Declarative failure injection: the :class:`FailureSpec`.

A ``FailureSpec`` names *which* links and switches are dead — seeded
random fractions plus explicit lists — and *what disconnection means*
(``policy``).  It is a :class:`repro.studies.spec._SpecBase`, so it
JSON-round-trips exactly and nests inside an
:class:`~repro.studies.spec.ExperimentSpec` (the optional ``failures``
field), keeping failure sweeps as declarative as every other study axis.

Sampling is deterministic given ``seed``: switch failures draw first
(``round(switch_fraction * N)`` switches from one permutation), then
link failures (``round(link_fraction * L)`` of the pristine fabric's
``L`` undirected links, in canonical ``(switch, port)`` order) — so the
same spec kills the same hardware on every backend and every run.
Explicit ``dead_links`` are undirected ``(switch_a, switch_b)`` endpoint
pairs (unique per pair in all three in-repo families); explicit
``dead_switches`` are switch indices.  A dead switch takes every
incident link down with it.

``policy`` decides what happens to traffic between *surviving* switches
that the failures disconnected:

* ``"strict"`` (default) — a disconnected residual fabric is an error:
  :func:`repro.faults.degrade` raises
  :class:`~repro.faults.degrade.FabricDisconnectedError`.
* ``"drop"`` — unreachable surviving pairs are dropped from traffic,
  workloads, and flow demands (their packets simply never exist).

Traffic sourced at or destined to a *dead* switch is dropped under
either policy — those endpoints are gone, not merely unreachable.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping

from repro.studies.spec import _SpecBase

__all__ = ["FailureSpec", "POLICIES"]

#: Disconnection policies, in documentation order.
POLICIES = ("strict", "drop")


@dataclass(frozen=True, eq=True)
class FailureSpec(_SpecBase):
    """Which hardware is dead, and what disconnection means.

    All fields are JSON-serializable; ``FailureSpec.from_json(
    spec.to_json()) == spec`` exactly (the ``_SpecBase`` contract).
    """
    link_fraction: float = 0.0
    switch_fraction: float = 0.0
    seed: int = 0
    dead_links: tuple = ()
    dead_switches: tuple = ()
    policy: str = "strict"

    def __post_init__(self):
        super().__post_init__()
        lf, sf = float(self.link_fraction), float(self.switch_fraction)
        if not 0.0 <= lf < 1.0 or not 0.0 <= sf < 1.0:
            raise ValueError(
                f"failure fractions must lie in [0, 1); got "
                f"link_fraction={lf}, switch_fraction={sf}")
        if self.policy not in POLICIES:
            raise ValueError(f"unknown disconnection policy "
                             f"{self.policy!r}; expected one of {POLICIES}")
        pairs = set()
        for pair in self.dead_links:
            if len(pair) != 2:
                raise ValueError(f"dead_links entries are (switch_a, "
                                 f"switch_b) pairs; got {pair!r}")
            a, b = int(pair[0]), int(pair[1])
            if a == b:
                raise ValueError(f"dead link ({a}, {b}) is a self-loop; "
                                 f"links join distinct switches")
            pairs.add((min(a, b), max(a, b)))
        object.__setattr__(self, "link_fraction", lf)
        object.__setattr__(self, "switch_fraction", sf)
        object.__setattr__(self, "seed", int(self.seed))
        object.__setattr__(self, "dead_links", tuple(sorted(pairs)))
        object.__setattr__(
            self, "dead_switches",
            tuple(sorted({int(s) for s in self.dead_switches})))

    @property
    def is_null(self) -> bool:
        """True when the spec kills nothing — :func:`~repro.faults.degrade`
        returns the pristine topology unchanged (bit-identical results
        by construction)."""
        return (self.link_fraction == 0.0 and self.switch_fraction == 0.0
                and not self.dead_links and not self.dead_switches)

    @property
    def label(self) -> str:
        """Compact human tag (experiment names, degraded topology names)."""
        if self.is_null:
            return "f0"
        bits = []
        if self.link_fraction:
            bits.append(f"L{self.link_fraction:g}")
        if self.switch_fraction:
            bits.append(f"S{self.switch_fraction:g}")
        if self.dead_links:
            bits.append(f"dl{len(self.dead_links)}")
        if self.dead_switches:
            bits.append(f"ds{len(self.dead_switches)}")
        if self.link_fraction or self.switch_fraction:
            bits.append(f"s{self.seed}")
        if self.policy != "strict":
            bits.append(self.policy)
        return "-".join(bits)

    @classmethod
    def coerce(cls, obj) -> "FailureSpec | None":
        """``None`` | FailureSpec | its dict form -> FailureSpec | None."""
        if obj is None or isinstance(obj, cls):
            return obj
        if isinstance(obj, Mapping):
            return cls.from_dict(obj)
        raise TypeError(f"failures must be a FailureSpec (or its dict "
                        f"form), got {type(obj).__name__}")
