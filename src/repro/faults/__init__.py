"""repro.faults — degraded-fabric simulation.

Failure injection (:class:`FailureSpec`), table-based fallback routing
over the surviving graph (:func:`degrade`), and the traffic/demand
masking that keeps all three backends — numpy :class:`~repro.sim.engine.
Engine`, the compiled ``xengine``, and the :mod:`repro.flow` model —
consistent on the same degraded fabric.  See ``docs/failure_model.md``
for the full model.

Quick start::

    from repro.fabric import make_fabric
    from repro.faults import FailureSpec

    fab = make_fabric("xor", 16)
    spec = FailureSpec(link_fraction=0.05, seed=7)
    stats = fab.replay("all_to_all", failures=spec)   # degraded replay

    topo = fab.sim_topology().degrade(spec)           # or by hand
    topo.minimal_port_table()                         # fallback routes

Study sweeps use :func:`failure_grid` to expand one experiment into a
failure-rate x seed grid, or set ``failures`` directly in spec JSON
(see the bundled ``failure_sweep`` spec).
"""
from __future__ import annotations

from dataclasses import replace

from .degrade import (FabricDisconnectedError, bfs_distances,
                      build_fallback_table, degrade, filter_pairs,
                      mask_traffic, mask_workload, packet_keep,
                      residual_report)
from .spec import POLICIES, FailureSpec

__all__ = [
    "FailureSpec", "POLICIES", "FabricDisconnectedError",
    "degrade", "residual_report", "bfs_distances", "build_fallback_table",
    "packet_keep", "mask_traffic", "mask_workload", "filter_pairs",
    "failure_grid",
]


def failure_grid(exp, link_fractions, seeds=(0,), *, policy="strict",
                 switch_fractions=(0.0,)):
    """Expand one base :class:`~repro.studies.spec.ExperimentSpec` into a
    failure-rate x seed grid: one experiment per (link fraction, switch
    fraction, seed), named ``<base>/<label>``.

    The zero-failure point is emitted exactly once (per-seed copies
    would be identical) with ``failures=None``, so its digest, store
    keys, and results are bit-identical to the pristine experiment's.
    """
    out = []
    for fl in link_fractions:
        for fs in switch_fractions:
            fl, fs = float(fl), float(fs)
            if fl == 0.0 and fs == 0.0:
                out.append(replace(exp, name=f"{exp.name}/f0",
                                   failures=None))
                continue
            for seed in seeds:
                spec = FailureSpec(link_fraction=fl, switch_fraction=fs,
                                   seed=int(seed), policy=policy)
                tag = spec.label if len(seeds) > 1 else \
                    spec.label.replace(f"-s{int(seed)}", "")
                out.append(replace(exp, name=f"{exp.name}/{tag}",
                                   failures=spec))
    return out
