"""Degraded topologies: failure masking + table-based fallback routing.

:func:`degrade` turns a pristine :class:`~repro.sim.topology.SimTopology`
plus a :class:`~repro.faults.spec.FailureSpec` into a degraded
``SimTopology`` that every backend consumes through the seams it already
has:

* the neighbor/port structure is masked (dead slots -> ``-1``), so the
  numpy engine's feasibility checks, ``xengine``'s credit accounting
  (unwired queues are credit-starved), and the flow model's wired-link
  capacities all see the surviving fabric automatically;
* residual connectivity is verified by a BFS component sweep
  (``policy="strict"`` raises :class:`FabricDisconnectedError` when the
  survivors split);
* fallback routing is precomputed as a dense ``(N, N)`` next-hop table
  and installed through the existing ``minimal_port`` /
  ``minimal_port_table`` seam.  Pairs whose *entire* pristine route
  survives keep their pristine next hop (minimal routing semantics —
  and load balance — are untouched for unaffected traffic; with nothing
  failed the table is therefore bit-identical to the pristine
  ``minimal_port_table``).  Broken pairs fall back to shortest paths
  over the surviving graph, computed by vectorized multi-source BFS and
  tie-broken deterministically (prefer the pristine port when it still
  lies on a shortest path, else the smallest valid port).  The pristine
  route is *not* always graph-shortest (Dragonfly's canonical l-g-l
  route may skip a shorter global detour), which is exactly why the
  intact-path check — not a shortest-path membership test — guards the
  pristine collapse.  Mixed routes terminate: shortest-path hops
  strictly shrink the distance to the target, and once a packet reaches
  a switch whose pristine route to the target is intact, every suffix
  of that route is intact too.

The degraded topology carries a ``meta["faults"]`` block (spec, alive
mask, component labels, dead/rerouted link masks, pristine diameter)
that downstream layers key off: engines collapse Valiant mids that fall
outside the source's component, traffic/workload masking drops packets
whose endpoints died, and ``repro.obs`` classes rerouted link
utilization separately.
"""
from __future__ import annotations

import numpy as np

from repro.sim.topology import SimTopology

from .spec import FailureSpec

__all__ = [
    "FabricDisconnectedError", "degrade", "residual_report",
    "bfs_distances", "build_fallback_table",
    "packet_keep", "mask_traffic", "mask_workload", "filter_pairs",
]


class FabricDisconnectedError(ValueError):
    """Raised when ``policy='strict'`` failures disconnect the surviving
    fabric.  Subclasses :class:`ValueError` so callers that only know
    "bad spec" still catch it."""


def _dead_mask(topo: SimTopology, spec: FailureSpec):
    """Sample/collect failures: ``(alive switches, dead (N, P) slots)``.

    Draw order is part of the spec contract (see ``FailureSpec``):
    switches first, then links, from one ``default_rng(seed)`` stream.
    Random link failures sample the *pristine* undirected link pool in
    canonical ``(switch, port)`` order; overlap with dead switches is
    coincidental and harmless (the slot is dead either way).
    """
    n, p = topo.num_switches, topo.num_ports
    nbr, rev = topo.neighbor, topo.rev_port
    flat = nbr.reshape(-1)
    rflat = rev.reshape(-1)
    rng = np.random.default_rng(spec.seed)

    alive = np.ones(n, dtype=bool)
    k_s = int(round(spec.switch_fraction * n))
    if k_s:
        alive[rng.permutation(n)[:k_s]] = False
    for s in spec.dead_switches:
        if not 0 <= s < n:
            raise ValueError(f"dead switch {s} outside [0, {n}) "
                             f"on {topo.name}")
        alive[s] = False

    slot = np.arange(n * p)
    canonical = np.flatnonzero((flat >= 0) & (flat > slot // p))
    kill = []
    k_l = int(round(spec.link_fraction * canonical.size))
    if k_l:
        kill.append(canonical[rng.permutation(canonical.size)[:k_l]])
    for a, b in spec.dead_links:
        hits = np.flatnonzero(nbr[a] == b) if 0 <= a < n else \
            np.empty(0, dtype=np.int64)
        if hits.size == 0:
            raise ValueError(f"dead link ({a}, {b}) does not exist "
                             f"on {topo.name}")
        kill.append(a * p + hits)

    dead = np.zeros(n * p, dtype=bool)
    if kill:
        ids = np.concatenate(kill)
        dead[ids] = True
        dead[flat[ids] * p + rflat[ids]] = True  # far side of each wire
    if not alive.all():
        down = ~alive[slot // p] & (flat >= 0)
        dead |= down
        ids = np.flatnonzero(down)
        dead[flat[ids] * p + rflat[ids]] = True
    dead &= flat >= 0
    return alive, dead.reshape(n, p)


def _components(neighbor: np.ndarray, alive: np.ndarray):
    """Flood-fill component labels over the masked graph.

    Returns ``(comp, count)``: ``comp[s]`` is the component id of alive
    switch ``s`` (ids are dense, assigned in ascending switch order) and
    ``-1`` for dead switches.
    """
    n = alive.size
    comp = np.full(n, -1, dtype=np.int64)
    cid = 0
    todo = np.flatnonzero(alive)
    while todo.size:
        frontier = todo[:1]
        comp[frontier] = cid
        while frontier.size:
            nxt = neighbor[frontier].reshape(-1)
            nxt = nxt[nxt >= 0]
            nxt = np.unique(nxt)
            nxt = nxt[comp[nxt] < 0]
            comp[nxt] = cid
            frontier = nxt
        cid += 1
        todo = np.flatnonzero(alive & (comp < 0))
    return comp, cid


def bfs_distances(neighbor: np.ndarray) -> np.ndarray:
    """All-pairs hop distances over a masked ``(N, P)`` neighbor matrix.

    Multi-source BFS on ``(N, W)`` uint64 reachability bitsets: each
    round ORs every port column's neighbor rows into the running set and
    stamps newly-set bits with the round number.  ``O(diameter)`` rounds
    of ``N * N/64 * P`` word operations — dense but vectorized, which is
    the regime the dense fallback table needs anyway.  Returns int32;
    ``-1`` marks unreachable pairs (and every pair touching a dead
    switch).
    """
    n, p = neighbor.shape
    words = (n + 63) // 64
    reach = np.zeros((n, words), dtype=np.uint64)
    idx = np.arange(n)
    reach[idx, idx >> 6] = np.uint64(1) << np.uint64(idx & 63)
    dist = np.full((n, n), -1, dtype=np.int32)
    dist[idx, idx] = 0
    cols = [q for q in range(p) if (neighbor[:, q] >= 0).any()]
    rounds = 0
    while True:
        rounds += 1
        new = reach.copy()
        for q in cols:
            nb = neighbor[:, q]
            m = nb >= 0
            new[m] |= reach[nb[m]]
        diff = new & ~reach
        if not diff.any():
            break
        bits = np.unpackbits(diff.view(np.uint8), axis=1,
                             bitorder="little")[:, :n]
        dist[bits.astype(bool)] = rounds
        reach = new
    return dist


def _shortest_table(nbr: np.ndarray, dist: np.ndarray,
                    pristine: np.ndarray) -> np.ndarray:
    """Shortest-path next hops over the masked graph, tie-broken
    deterministically: the pristine port when it still lies on a
    shortest path, else the smallest valid port.  Unreachable pairs and
    the diagonal get port 0 (masked traffic never asks for them)."""
    n, p = nbr.shape
    table = np.zeros((n, n), dtype=np.int64)
    # Chunk source rows so the (C, P, N) neighbor-distance gather stays
    # ~32 MB even at the 4k-switch benchmark tier.
    chunk = max(1, (1 << 23) // max(p * n, 1))
    for lo in range(0, n, chunk):
        hi = min(lo + chunk, n)
        nb = nbr[lo:hi]
        du = dist[lo:hi]
        dn = dist[np.where(nb >= 0, nb, 0)]          # (C, P, N)
        valid = (nb >= 0)[:, :, None] & (dn >= 0) \
            & (dn == du[:, None, :] - 1)
        pp = pristine[lo:hi]
        pref = np.take_along_axis(valid, pp[:, None, :], axis=1)[:, 0, :]
        first = np.argmax(valid, axis=1)
        rows = np.where(pref, pp, first)
        table[lo:hi] = np.where(du > 0, rows, 0)
    return table


def _intact_pristine(topo: SimTopology, pristine: np.ndarray,
                     dead: np.ndarray) -> np.ndarray:
    """Bool ``(N, N)``: pairs whose *entire* pristine route survives.

    Fixpoint over route suffixes: after ``k`` rounds, pairs whose
    pristine route has length <= ``k`` and crosses no dead slot are
    marked; pristine routes are at most ``topo.diameter`` hops, so the
    iteration converges in ``diameter`` rounds.
    """
    n = topo.num_switches
    rows = np.arange(n)[:, None]
    cols = np.arange(n)[None, :]
    nxt = topo.neighbor[rows, pristine]
    link_ok = ~dead[rows, pristine]
    intact = np.zeros((n, n), dtype=bool)
    np.fill_diagonal(intact, True)
    for _ in range(max(topo.diameter, 1)):
        new = link_ok & intact[nxt, cols]
        np.fill_diagonal(new, True)
        if np.array_equal(new, intact):
            break
        intact = new
    return intact


def _route_lengths(nbr: np.ndarray, table: np.ndarray, dist: np.ndarray,
                   cap: int) -> np.ndarray:
    """Exact per-pair hop counts induced by walking ``table`` — validates
    that the composed (intact-pristine + shortest-fallback) table is
    loop-free and yields the degraded diameter the engines size their
    VC ladders by.  ``-1`` for unreachable pairs."""
    n = nbr.shape[0]
    cols = np.arange(n)[None, :]
    nxt = nbr[np.arange(n)[:, None], table]
    nxt_safe = np.where(nxt >= 0, nxt, 0)
    lengths = np.full((n, n), -1, dtype=np.int32)
    np.fill_diagonal(lengths, 0)
    reachable = dist >= 0
    for _ in range(cap):
        if (lengths[reachable] >= 0).all():
            return lengths
        hop = lengths[nxt_safe, cols]
        lengths = np.where((lengths < 0) & reachable & (hop >= 0),
                           hop + 1, lengths)
    if not (lengths[reachable] >= 0).all():
        raise AssertionError("fallback routing table walked into a loop "
                             "— this is a repro.faults bug")
    return lengths


def build_fallback_table(topo: SimTopology, *, dead=None, neighbor=None,
                         dist=None, pristine=None) -> np.ndarray:
    """Dense ``(N, N)`` next-hop fallback table for ``topo`` with the
    ``dead`` directed-slot mask applied (default: nothing dead).

    Pairs whose entire pristine route survives keep the pristine
    ``minimal_port_table`` entry — so with ``dead`` all-False the result
    is bit-identical to ``minimal_port_table``, the closed-form collapse
    the pristine baseline needs.  Broken pairs take shortest paths over
    the surviving graph (see :func:`_shortest_table` for the
    deterministic tie-break).
    """
    n = topo.num_switches
    if pristine is None:
        pristine = topo.minimal_port_table()
    if dead is None:
        dead = (np.zeros_like(topo.neighbor, dtype=bool) if neighbor is None
                else (neighbor != topo.neighbor))
    if neighbor is None:
        neighbor = np.where(dead, -1, topo.neighbor)
    if dist is None:
        dist = bfs_distances(neighbor)
    intact = _intact_pristine(topo, pristine, dead)
    short = _shortest_table(neighbor, dist, pristine)
    offdiag = ~np.eye(n, dtype=bool)
    return np.where(intact & offdiag, pristine, short)


def residual_report(topo: SimTopology, failures) -> dict:
    """Cheap connectivity check — no distance/table build.

    Returns ``{"alive", "comp", "num_components", "connected"}`` for the
    surviving graph under ``failures``.  This is the early check
    ``repro.studies`` runs before committing to a backend, and what the
    ``strict`` policy enforces inside :func:`degrade`.
    """
    spec = FailureSpec.coerce(failures)
    n = topo.num_switches
    if spec is None or spec.is_null:
        return {"alive": np.ones(n, dtype=bool),
                "comp": np.zeros(n, dtype=np.int64),
                "num_components": 1 if n else 0, "connected": True}
    alive, dead = _dead_mask(topo, spec)
    comp, count = _components(np.where(dead, -1, topo.neighbor), alive)
    return {"alive": alive, "comp": comp, "num_components": count,
            "connected": count <= 1}


#: Degraded builds memoized per pristine topology (see :func:`degrade`).
#: Bounded: a failure-rate x seed sweep touches a handful of specs per
#: fabric; an unbounded map would pin every 4k-switch table a long-lived
#: process ever built.
_DEGRADE_CACHE_MAX = 16


def degrade(topo: SimTopology, failures) -> SimTopology:
    """Pristine topology + failures -> degraded ``SimTopology``.

    A null spec (or ``None``) returns ``topo`` itself — same object,
    same caches, trivially bit-identical results.  Otherwise the
    degraded topology is fully built eagerly: masked neighbor/rev_port,
    component labels, all-pairs distances, the fallback next-hop table
    (pre-seeded into the ``minimal_port_table`` cache), the surviving
    graph's diameter, and the ``meta["faults"]`` block described in the
    module docstring.

    Builds are memoized on the pristine topology object, keyed by the
    spec's canonical JSON: experiments that degrade the same fabric with
    the same ``FailureSpec`` (a :class:`repro.studies.runner.Study`
    sweeping loads x seeds, a flow-model saturation bisection, repeated
    ``simulate(failures=...)`` calls) pay the table build — ~40 s at the
    4k-switch benchmark tier — once.  The build itself is deterministic
    (seeded draws, deterministic tie-breaks), so the cached object is
    exactly what a fresh build would return.
    """
    spec = FailureSpec.coerce(failures)
    if spec is None or spec.is_null:
        return topo
    meta = topo.meta or {}
    if "faults" in meta:
        raise ValueError(f"{topo.name} is already degraded; apply the "
                         f"FailureSpec to the pristine topology instead")
    cache = topo.__dict__.setdefault("_degrade_cache", {})
    ckey = spec.to_json()
    hit = cache.get(ckey)
    if hit is not None:
        return hit
    n, p = topo.num_switches, topo.num_ports
    alive, dead = _dead_mask(topo, spec)
    new_nbr = np.where(dead, -1, topo.neighbor)
    new_rev = np.where(dead, -1, topo.rev_port)
    comp, count = _components(new_nbr, alive)
    if spec.policy == "strict" and count > 1:
        sizes = np.bincount(comp[comp >= 0], minlength=count)
        raise FabricDisconnectedError(
            f"{topo.name}: failures {spec.label!r} leave the surviving "
            f"fabric in {count} components (sizes "
            f"{sorted(sizes.tolist(), reverse=True)}); policy='strict' "
            f"requires a connected residual fabric — use policy='drop' "
            f"to drop unreachable pairs, or lower the failure fraction "
            f"/ change the seed")

    pristine = topo.minimal_port_table()
    dist = bfs_distances(new_nbr)
    intact = _intact_pristine(topo, pristine, dead)
    short = _shortest_table(new_nbr, dist, pristine)
    table = np.where(intact & ~np.eye(n, dtype=bool), pristine, short)
    lengths = _route_lengths(new_nbr, table, dist,
                             cap=int(dist.max()) + topo.diameter + 2)
    diameter = max(int(lengths.max()), 1)

    # Directed link slots carrying rerouted traffic: the degraded first
    # hop of every reachable pair whose pristine route broke.
    changed = ~intact & (dist > 0)
    rerouted = np.zeros(n * p, dtype=bool)
    u, t = np.nonzero(changed)
    rerouted[u * p + table[u, t]] = True
    unreachable = int(np.sum((dist < 0) & alive[:, None] & alive[None, :]))

    def minimal_port(cur, tgt):
        return table[np.asarray(cur, dtype=np.int64),
                     np.asarray(tgt, dtype=np.int64)]

    new_meta = dict(meta)
    new_meta["faults"] = {
        "spec": spec,
        "alive": alive,
        "comp": comp,
        "num_components": count,
        "dead_links": dead,                  # (N, P) directed slot mask
        "rerouted": rerouted,                # (N*P,) flat directed mask
        "unreachable_pairs": unreachable,
        "pristine_diameter": int(topo.diameter),
        "pristine_name": topo.name,
    }
    out = SimTopology(
        name=f"{topo.name}+{spec.label}", num_switches=n, num_ports=p,
        neighbor=new_nbr, rev_port=new_rev, minimal_port=minimal_port,
        diameter=diameter, meta=new_meta)
    out.__dict__["_minimal_port_table"] = table
    out.validate()
    if len(cache) >= _DEGRADE_CACHE_MAX:
        cache.pop(next(iter(cache)))        # evict oldest (insertion order)
    cache[ckey] = out
    return out


def _faults_of(topo) -> dict | None:
    meta = getattr(topo, "meta", None) or {}
    return meta.get("faults")


def packet_keep(topo, src, dst) -> np.ndarray:
    """Bool mask over ``(src, dst)`` pairs that still exist on ``topo``:
    both endpoints alive and mutually reachable.  All-True on pristine
    topologies."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    faults = _faults_of(topo)
    if faults is None:
        return np.ones(src.size, dtype=bool)
    alive, comp = faults["alive"], faults["comp"]
    return alive[src] & alive[dst] & (comp[src] == comp[dst])


def filter_pairs(topo, src, dst, rate):
    """Drop demand entries whose endpoints died or were disconnected —
    the flow-model counterpart of :func:`mask_traffic`."""
    faults = _faults_of(topo)
    if faults is None:
        return src, dst, rate
    keep = packet_keep(topo, src, dst)
    if keep.all():
        return src, dst, rate
    return (np.asarray(src)[keep], np.asarray(dst)[keep],
            np.asarray(rate)[keep])


def mask_workload(workload, topo):
    """Rebuild a :class:`~repro.sim.workloads.Workload` for a degraded
    topology: per-phase, drop pairs whose endpoints died or were
    disconnected; drop phases masked empty entirely (so the engines'
    delivered-count phase barrier tracks the surviving traffic).
    Returns ``workload`` unchanged on pristine topologies or when
    nothing is masked."""
    faults = _faults_of(topo)
    if faults is None:
        return workload
    from repro.sim.workloads import Phase, Workload
    phases = []
    dirty = False
    for ph in workload.phases:
        src = np.asarray(ph.src, dtype=np.int64)
        dst = np.asarray(ph.dst, dtype=np.int64)
        keep = packet_keep(topo, src, dst)
        if keep.all():
            phases.append(ph)
            continue
        dirty = True
        if keep.any():
            phases.append(Phase(tuple(int(v) for v in src[keep]),
                                tuple(int(v) for v in dst[keep]),
                                ph.messages))
    if not dirty:
        return workload
    return Workload(f"{workload.name}+degraded", workload.num_switches,
                    tuple(phases))


def mask_traffic(traffic, topo):
    """Drop packets whose endpoints died or were disconnected.

    Open-loop traffic is filtered in place (src/dst/gen rows); workload
    replays rebuild the workload via :func:`mask_workload` and re-emit
    its traffic so phase boundaries stay consistent with the surviving
    packet counts.  No-op on pristine topologies.
    """
    faults = _faults_of(topo)
    if faults is None:
        return traffic
    if traffic.workload is not None:
        masked = mask_workload(traffic.workload, topo)
        return traffic if masked is traffic.workload else masked.traffic()
    keep = packet_keep(topo, traffic.src, traffic.dst)
    if keep.all():
        return traffic
    from dataclasses import replace
    return replace(traffic,
                   src=np.asarray(traffic.src)[keep],
                   dst=np.asarray(traffic.dst)[keep],
                   gen=np.asarray(traffic.gen)[keep],
                   request=(np.asarray(traffic.request)[keep]
                            if traffic.request is not None else None))
