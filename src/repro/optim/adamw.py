"""AdamW + cosine schedule + global-norm clipping (no external deps).

The optimizer state mirrors the parameter pytree (same sharding), so ZeRO-1
style sharding falls out of the parameter partition specs.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 200
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(opt: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(opt.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - opt.warmup_steps)
                    / jnp.maximum(opt.total_steps - opt.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return opt.lr * warm * (opt.min_lr_ratio + (1 - opt.min_lr_ratio) * cos)


def init_opt_state(params) -> dict:
    zeros = lambda p: jax.tree_util.tree_map(
        lambda a: jnp.zeros(a.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def _decay_mask(path) -> bool:
    """Decay weights only for >=2-D matrices (not norms/biases/gates)."""
    name = path[-1].key if hasattr(path[-1], "key") else ""
    return name not in ("scale", "bias", "b_i", "b_f", "b_gates", "dt_bias",
                        "A_log", "D", "norm_scale", "hnorm_scale",
                        "ffn_norm_scale", "q_scale", "k_scale",
                        "attn_out_scale", "ssm_out_scale")


def adamw_update(params, grads, state, opt: OptConfig):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, opt.clip_norm)
    step = state["step"] + 1
    lr = schedule(opt, step)
    b1, b2 = opt.beta1, opt.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(path, p, g, m, v):
        g = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + opt.eps)
        if _decay_mask(path) and p.ndim >= 2:
            delta = delta + opt.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat = jax.tree_util.tree_map_with_path(
        lambda path, p, g, m, v: upd(path, p, g, m, v),
        params, grads, state["m"], state["v"])
    new_params = jax.tree_util.tree_map(lambda t: t[0], flat,
                                        is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], flat,
                                   is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], flat,
                                   is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
