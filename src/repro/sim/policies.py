"""Routing policies: minimal, Valiant, and congestion-threshold adaptive.

A policy decides, *per packet at injection time*, the two-phase itinerary
``src -> mid -> dst`` (``mid == dst`` collapses to minimal).  In-network
forwarding is always the topology's table-free minimal route towards the
current phase's target, so every policy inherits the paper's §3 machinery;
non-minimal policies add the one extra decision the §3 sketch calls for.

Deadlock freedom uses distance-class virtual channels (the engine's VC
ladder: hop ``k`` travels in class ``min(k, V-1)``).  On a CIN this is
precisely the §3 argument: minimal routing needs 1 VC, any two-phase
non-minimal route needs 2 (``vc_required``); hierarchical compositions
scale the ladder with their diameter.
"""
from __future__ import annotations

import numpy as np


class RoutingPolicy:
    """Base: pure minimal routing (``mid = dst``)."""
    name = "minimal"
    vc_required = 1

    def on_inject(self, state, pids: np.ndarray) -> None:
        """Choose ``state.mid``/``state.phase`` for injection candidates.

        Called every cycle for every not-yet-injected candidate, so
        adaptive policies re-evaluate congestion until the packet wins
        injection arbitration.
        """
        state.mid[pids] = state.dst[pids]
        state.phase[pids] = 1


class MinimalPolicy(RoutingPolicy):
    """Table-free minimal routing (paper §3, Algorithm 2)."""


def _sample_mid(state, pids: np.ndarray) -> np.ndarray:
    """Uniform intermediate switch avoiding {src, dst} (shift-remap).

    On a degraded topology (``meta["faults"]``), mids that died or fell
    outside the source's component collapse to the destination — the
    packet routes minimally instead of detouring into a black hole.  The
    RNG draw happens unconditionally, so pristine runs consume the exact
    same stream (bit-identical behavior with no failures).
    """
    n = state.topo.num_switches
    s = state.src[pids]
    d = state.dst[pids]
    lo = np.minimum(s, d)
    hi = np.maximum(s, d)
    r = state.rng.integers(0, n - 2, size=pids.size)
    r = r + (r >= lo)
    r = r + (r >= hi)
    faults = (state.topo.meta or {}).get("faults")
    if faults is not None:
        comp = faults["comp"]
        r = np.where(comp[r] == comp[s], r, d)
    return r


class ValiantPolicy(RoutingPolicy):
    """Two-phase Valiant: minimal to a random intermediate, then minimal to
    the destination.  Doubles the expected path length but randomizes any
    adversarial pattern into (two superimposed) uniform ones."""
    name = "valiant"
    vc_required = 2

    def on_inject(self, state, pids: np.ndarray) -> None:
        if state.topo.num_switches < 3 or pids.size == 0:
            super().on_inject(state, pids)
            return
        mid = _sample_mid(state, pids)
        state.mid[pids] = mid
        # A collapsed mid (degraded fabric) is already the destination:
        # skip phase 0 so the packet ejects on arrival.  Pristine mids
        # never equal the destination (shift-remap), so this is the
        # unconditional ``phase = 0`` of the pristine engine.
        state.phase[pids] = np.where(mid == state.dst[pids], 1, 0)


class AdaptivePolicy(RoutingPolicy):
    """Congestion-threshold adaptive (UGAL-style, local information).

    At injection, compare the congestion of the minimal first hop against
    a randomly sampled Valiant alternative, weighting the non-minimal side
    by its extra hop count: go non-minimal iff

        congestion_minimal > weight * congestion_valiant + threshold.

    Congestion is the engine's smoothed per-link *requested demand* plus
    the downstream credit occupancy: demand pressure exposes source-side
    contention (many heads wanting one hot link), credit occupancy exposes
    fabric-side backpressure.  With idle links everywhere this reduces to
    minimal routing; on a concentrated hot pair the minimal signal grows
    past the threshold and the policy detours — the §3 trade of hot-link
    relief for doubled hops.
    """
    name = "adaptive"
    vc_required = 2

    def __init__(self, threshold: float = 1.0, weight: float = 2.0):
        self.threshold = threshold
        self.weight = weight

    def _congestion(self, state, sw, port):
        return state.link_pressure(sw, port) + state.port_backlog(sw, port)

    def on_inject(self, state, pids: np.ndarray) -> None:
        if state.topo.num_switches < 3 or pids.size == 0:
            RoutingPolicy.on_inject(self, state, pids)
            return
        s = state.src[pids]
        d = state.dst[pids]
        c_min = self._congestion(state, s, state.topo.minimal_port(s, d))
        mid = _sample_mid(state, pids)
        c_val = self._congestion(state, s, state.topo.minimal_port(s, mid))
        # On degraded fabrics _sample_mid collapses unreachable mids to
        # the destination; treating that as "no detour" keeps the phase
        # bookkeeping exact.  Pristine mids never equal the destination.
        detour = (c_min > self.weight * c_val + self.threshold) & (mid != d)
        state.mid[pids] = np.where(detour, mid, d)
        state.phase[pids] = np.where(detour, 0, 1)


def make_policy(name: str, **kw) -> RoutingPolicy:
    if name == "minimal":
        return MinimalPolicy()
    if name == "valiant":
        return ValiantPolicy()
    if name == "adaptive":
        return AdaptivePolicy(**kw)
    raise ValueError(f"unknown routing policy {name!r}")
