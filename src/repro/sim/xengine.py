"""Compiled simulation engine: the cycle pipeline as one JAX program.

The numpy :class:`~repro.sim.engine.Engine` is the semantic oracle: one
Python iteration per simulated cycle, with dynamic-shape ``np.nonzero``
gathers selecting the active queues and feasible requests.  That costs
O(points x seeds x cycles) interpreter round-trips per saturation sweep —
the hottest path in the repo.  This module re-expresses the same pipeline
(eject -> route -> inject -> credit-checked link arbitration -> move) as a
*fixed-shape, functionally pure* step over a state pytree, compiled with
``jax.lax`` loops under ``jit``, so an entire sweep — every offered-load
point x every seed — runs as a single compiled program.

Masked-dense design
-------------------
Dynamic selections become dense lanes with validity masks, and — because
XLA's scatter is a serial per-update loop on CPU — every per-cycle update
except the delivery-timestamp record is formulated as a gather, select,
or axis reduction:

* **Queues.** Every (switch, input-port, VC) FIFO is a lane; ``occ > 0``
  masks the active ones.  The packet attributes that evolve in flight
  (itinerary ``mid``, routing ``phase``, ``hops``) ride *inside* the
  ring buffers as one packed word per slot, pushed and popped with the
  packet id; a packet's location is implicit in the queue holding it.
* **Routing.** The table-free minimal route is evaluated once per
  topology into a dense ``(N, N)`` next-port table
  (:meth:`SimTopology.minimal_port_table`); in-step routing is a gather.
* **Arbitration.** All contenders for a switch's output links — its
  ``ports x VCs`` queue heads plus its ``terminals`` injection lanes —
  form one dense block, and the oracle's lexsort-based
  :func:`arbitrate` becomes an argmin over a (contender, port) key
  tensor: transit-beats-injection rides in the key's class bit, random
  tie-breaks in its low bits.  Ejection (k winners per switch) is a
  pairwise rank inside the same block.
* **Movement as gathers.** One winner per directed link means the
  downstream queue of link (s, i) receives from exactly one place, so
  pushes invert into a *gather* through the wire's feeder table
  (``nbr[s,i]*P + rev[s,i]``), and ring-buffer writes are one-hot
  selects over the ``capacity`` axis.  Link-load counters increment
  elementwise (loads are link-indexed).  The only scatter left is the
  per-ejection delivery-cycle record.
* **Batching by fabric replication, not vmap.** A sweep's (load, seed)
  grid is laid out as B disjoint copies of the topology inside one flat
  state: queue lane ``b*Q + q``, link slot ``b*L + l``, packet id
  ``b*M + p`` belong to grid point ``b``.  Every op above stays flat
  and vectorized (a vmapped scatter is not), the loop predicate stays
  scalar, and per-op dispatch overhead is amortized over the grid.
* **Traffic.** Packet descriptors concatenate at exact sizes with
  cumulative id offsets — the flat layout needs no per-point padding,
  only that every point shares the compiled horizon.

Equivalence is statistical, not bitwise: both engines simulate the same
queueing system over the same packet sets, but arbitration tie-breaks
draw from different RNG streams.  ``tests/test_xengine.py`` pins the
invariants that *must* agree exactly (delivered packet counts under
drain, minimal-route link loads) and bounds the rest (accepted
throughput, latency) within seed-matched tolerances.
"""
from __future__ import annotations

import hashlib
import inspect
from functools import lru_cache, partial
from typing import Callable, NamedTuple, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from .._compat import jaxapi
from ..obs.telemetry import timed_compiled
from ..obs.trace import Trace, TraceConfig, derive_backlog
from .engine import _DRAIN_SLACK
from .link import LinkLoadCounter, LinkTable
from .metrics import (RunStats, attach_replay, attach_serving, build_stats,
                      replay_timeline)
from .policies import RoutingPolicy, make_policy
from .topology import SimTopology
from .traffic import Traffic, resolve_terminals

_I32 = jnp.int32
_I16 = jnp.int16
_INT32_MAX = np.iinfo(np.int32).max
#: Sentinel generation cycle for padded packet slots: larger than any
#: simulated cycle, so a padded slot never becomes an injection candidate.
_PAD_GEN = _INT32_MAX
#: Hop counts saturate at this value inside the packed attribute word
#: (mid << 8 | phase << 7 | hops); hops only feed the VC-class clamp
#: ``min(hops, num_vcs - 1)``, so saturation is lossless for V <= 128.
_MAX_HOPS = 127


#: Above this many (horizon x queue-lane) entries the per-cycle ejection
#: log (see _step) falls back to a per-packet scatter to bound memory.
_LOG_ENTRY_BUDGET = 48_000_000


def _bucket_count(x: int) -> int:
    """The shape-bucketing boundary at or above ``x``.

    Grid sizes, packet counts, and cycle horizons are rounded up to one
    of these boundaries so that sweeps over many nearby sizes reuse a
    handful of compiled programs instead of compiling one each (the
    padding is fully masked — see :func:`sweep`).  The ladder bounds the
    padding waste: exact powers of two below 8, multiples of 8 up to 64
    (<= ~30% waste where programs are cheap anyway), then the
    {2^k, 1.5 * 2^k} ladder (<= 33% waste) beyond."""
    x = max(int(x), 1)
    if x <= 8:
        return 1 << (x - 1).bit_length()
    if x <= 64:
        return (x + 7) // 8 * 8
    p = 1 << (x - 1).bit_length()          # next pow2 >= x
    if 3 * p // 4 >= x:
        return 3 * p // 4                  # the 1.5 * 2^(k-1) rung
    return p


class XSpec(NamedTuple):
    """Static (hashable) engine configuration — the jit cache key.

    ``horizon``/``cutoff`` are static so the loop can be a fixed-trip
    ``fori_loop`` and the ejection log can be allocated ``(horizon, Q)``;
    :func:`sweep` buckets them (with the grid width and packet count) to
    shared boundaries and measures to the *runtime* bounds riding in the
    packet dict, so nearby sweep sizes reuse one compiled program.
    """
    n: int
    ports: int
    vcs: int
    cap: int
    terminals: int
    eject_bw: int
    policy: str
    threshold: float
    weight: float
    alpha: float
    drain: bool
    horizon: int
    cutoff: int
    log_deliveries: bool
    #: Collective-replay mode: > 0 enables the phase barrier — packet
    #: ``gen`` is a phase ordinal, injection gates on completed phases,
    #: and ``phase_done`` windows (one static (B, num_phases) record)
    #: capture each phase's completion cycle.  0 = open-loop traffic.
    num_phases: int = 0
    #: Time-series tracing (repro.obs): sample the trace ring buffers
    #: every ``trace_stride`` cycles into ``trace_samples`` statically
    #: allocated rows.  0 = off — the defaults keep the compiled program
    #: (and its jit cache key) identical to an untraced build.
    trace_stride: int = 0
    trace_samples: int = 0


class _Tables(NamedTuple):
    """Constants of one compiled run: topology tables plus precomputed
    index vectors (everything an iota/div/mod chain would otherwise
    recompute inside the loop body every cycle).

    Topology tables use *local* (per-copy) ids; index vectors span the
    flat replicated state (Q = B*N*P*V lanes, L = B*N*P links,
    NT = B*N*T terminal lanes).
    """
    port_table: jax.Array        # (N, N) next-hop output port
    comp_of_switch: jax.Array    # (N,) component label on degraded
    #                              fabrics (-1 = dead switch); all zeros
    #                              pristine, so the Valiant-mid collapse
    #                              below is the identity there
    feeder_local: jax.Array      # (N*P,) local link feeding port (s,i); -1.
    #                              Read both ways: the queue behind input
    #                              port (s,i) receives from link
    #                              feeder_local[s*p+i], and the downstream
    #                              port of link (s,i) IS feeder_local[s*p+i]
    #                              (inverse-wire identity).
    sw_local: jax.Array          # (Q,) local switch of each queue lane
    x_of_lane: jax.Array         # (Q,) contender slot within the block
    vc_of_lane: jax.Array        # (Q,) VC of each queue lane
    linkbase_of_lane: jax.Array  # (Q,) flat link id of the block's port 0
    feeder_flat: jax.Array       # (Q,) flat link feeding the lane's port
    feeder_xbase: jax.Array      # (Q,) feeder's block * x (contender base)
    wired_q: jax.Array           # (Q,) lane's input port is wired
    blk_idx: jax.Array           # (NT,) flat (copy, switch) index
    slot_of_term: jax.Array      # (NT,) terminal slot within the switch
    linkbase_of_term: jax.Array  # (NT,) flat link id of the switch's port 0
    copybase_of_term: jax.Array  # (NT,) copy * N*P (adaptive congestion)
    copybase_of_block: jax.Array  # (B*N,) copy * N*P per switch block
    copy_of_link: jax.Array      # (L,) copy owning each flat link


class _State(NamedTuple):
    """Flat state of all B fabric copies: the loop carry.

    Shapes use Q = B*N*P*V queue lanes, L = B*N*P link slots, and
    M = B*pad packet slots.  Queue ring buffers interleave the packet id
    and its packed attribute word along a trailing axis of 2, so head
    reads and winner gathers move one (pid, attr) pair per row.

    ``deliver`` and ``ej_log`` are the two delivery-record modes: with
    ``spec.log_deliveries`` each cycle writes its ejected pids as one
    contiguous ``(Q,)`` row of ``ej_log`` (a ``dynamic_update_slice`` —
    cheap), and per-packet times are reconstructed on the host after the
    run; otherwise ``deliver`` is scattered per ejection (XLA's CPU
    scatter is a serial per-row loop, but drain-mode runs are small).
    Exactly one of the two is non-trivial per compile.
    """
    buf: jax.Array               # (Q, cap, 2) ring buffers: pid, attr word
    head: jax.Array              # (Q,)
    occ: jax.Array               # (Q,)
    deliver: jax.Array           # (M,) delivery cycle, -1 = in flight
    ej_log: jax.Array            # (horizon, Q) ejected pid per lane, -1
    term_next: jax.Array         # (B*N*T,) injected count per terminal lane
    pressure: jax.Array          # (L,) EWMA requested link demand
    load_total: jax.Array        # (L,) lifetime link traversals
    load_window: jax.Array       # (L,) traversals inside [warmup, horizon)
    delivered_total: jax.Array   # (B,)
    delivered_win: jax.Array     # (B,)
    phase_done: jax.Array        # (B, num_phases) completion cycle, -1
    cycle: jax.Array             # scalar, shared by every copy
    # Trace ring buffers (repro.obs): S = spec.trace_samples rows, one
    # contiguous dynamic_update_slice row write per sampled cycle — the
    # same zero-scatter pattern as ej_log.  (1,)/(1, 1) dummies when off.
    tr_cycle: jax.Array          # (S,) sampled cycle index, -1 = unwritten
    tr_link: jax.Array           # (S, L) cumulative link traversals
    tr_occ: jax.Array            # (S, B*N) per-switch queue occupancy
    tr_inj: jax.Array            # (S, B*N) cumulative injections per switch
    tr_del: jax.Array            # (S, B) cumulative deliveries per copy


def _pack_attr(mid, phase, hops):
    return (mid << 8) | (phase << 7) | jnp.minimum(hops, _MAX_HOPS)


def _resolve_policy(policy) -> RoutingPolicy:
    if isinstance(policy, RoutingPolicy):
        return policy
    if isinstance(policy, str):
        return make_policy(policy)
    if callable(policy):
        return policy()
    raise TypeError(f"cannot resolve a routing policy from {policy!r}")


def _accepts_seed(traffic_factory: Callable) -> bool:
    """True when the factory takes ``(load, seed)`` rather than ``(load)``."""
    try:
        pos = [q for q in
               inspect.signature(traffic_factory).parameters.values()
               if q.kind in (q.POSITIONAL_ONLY, q.POSITIONAL_OR_KEYWORD,
                             q.VAR_POSITIONAL)]
        return len(pos) >= 2
    except (TypeError, ValueError):
        return False


def _pack_traffic(traffic: Traffic, n: int, pid_base: int
                  ) -> dict[str, np.ndarray]:
    """The oracle Engine's packet layout — sorted by (src, gen), with
    per-switch source-FIFO block bounds — offset into the flat packet-id
    space at ``pid_base``.  Grid points keep their exact sizes (no
    padding); the flat layout only needs cumulative offsets."""
    src = traffic.src.astype(np.int64)
    gen = traffic.gen.astype(np.int64)
    # All in-repo generators emit (src, gen)-sorted packets already; the
    # stable lexsort is then the identity, so skip it (it is one of the
    # priciest host-side steps of a batched sweep).
    key = src * (gen.max(initial=0) + 1) + gen
    if np.all(key[1:] >= key[:-1]):
        dst = traffic.dst
    else:
        order = np.lexsort((traffic.gen, traffic.src))
        src = src[order]
        gen = gen[order]
        dst = traffic.dst[order]
    m = src.size
    counts = np.bincount(src, minlength=n) if m else np.zeros(n, np.int64)
    blk_start = np.concatenate([[0], np.cumsum(counts)[:-1]])
    blk_end = blk_start + counts
    return {
        "src": src.astype(np.int32),
        "dst": np.asarray(dst, dtype=np.int32),
        "gen": np.clip(gen, 0, _PAD_GEN).astype(np.int32),
        "blk_start": (blk_start + pid_base).astype(np.int32),
        "blk_end": (blk_end + pid_base).astype(np.int32),
        "m_real": np.int32(m),
    }


# ---------------------------------------------------------------------------
# The compiled cycle step (all B fabric copies at once).
# ---------------------------------------------------------------------------

def _step(spec: XSpec, tables: _Tables, pkt: dict, base_key: jax.Array,
          warmup: jax.Array, state: _State) -> _State:
    n, p, v = spec.n, spec.ports, spec.vcs
    cap, t = spec.cap, spec.terminals
    pv = p * v
    blocks = state.head.shape[0] // pv          # B * N switch blocks
    b = blocks // n                             # fabric copies in the batch
    q_flat = blocks * pv
    nt_flat = b * n * t
    n_links = blocks * p
    m_flat = pkt["src"].shape[0]
    x = pv + t                                  # contenders per switch block
    # Packed arbitration key: [cls | rand | contender index], low bits the
    # index so one min-reduction yields both the winning key and who won.
    # Index bits cover x strictly (2^x_bits > x), so the sentinel's index
    # field can never alias a real contender.  Small blocks fit the key
    # in int16 (halving the hot tensor); the random field keeps >= 8 bits
    # either way, so tie-break bias stays negligible.
    x_bits = int(x).bit_length()
    x_mask = (1 << x_bits) - 1
    if x_bits <= 6:
        key_dtype, sent, rand_bits = jnp.int16, 32767, 14 - x_bits
    else:
        key_dtype, sent = _I32, _INT32_MAX
        rand_bits = min(30 - x_bits, 16)
    src, dst, gen = pkt["src"], pkt["dst"], pkt["gen"]
    c = state.cycle
    if spec.num_phases:
        # Replays measure the whole run (the horizon is only the phase
        # count); the window upper bound applies to open-loop drains.
        in_window = c >= warmup                      # (B,) per-copy mask
    else:
        # The measurement horizon is the *runtime* ``h_eff``, not the
        # (possibly bucket-padded) static ``spec.horizon``: a padded
        # program measures exactly what the exact-shape program would.
        in_window = (c >= warmup) & (c < pkt["h_eff"])
    # One random word per queue lane and per terminal lane; mechanisms
    # consume disjoint bit ranges of a word (threefry bits are
    # independent), halving the per-cycle threefry work.  The stream is
    # drawn *per fabric copy* from a key folded over the copy's global
    # id: copy b's bits depend only on (base key, cycle, copy_id[b]) —
    # never on how many copies share the program — so bucket-padding
    # the batch or sharding it across devices is bit-identical to the
    # exact-shape single-device program.  Copy 0 keeps the unfolded
    # per-cycle key: a single-copy program then draws the stream this
    # engine has always drawn, preserving every seed-era single-run
    # result bit for bit.
    ck = jax.random.fold_in(base_key, c)
    per_copy = n * pv + n * t
    folded = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        ck, pkt["copy_id"])
    keys = jnp.where((pkt["copy_id"] == 0)[:, None], ck, folded)
    bits = jax.vmap(lambda k: jax.random.bits(k, (per_copy,)))(keys)
    lane_bits = bits[:, :n * pv].reshape(q_flat)
    #                                  ^ high 16: ejection; low 16: arb
    term_bits = bits[:, n * pv:].reshape(nt_flat)
    #                                  ^ high bits: arb; low: Valiant mid

    # -- queue heads --------------------------------------------------------
    lanes = jnp.arange(q_flat, dtype=_I32)
    valid = state.occ > 0
    head_slot = state.head % cap
    h_pair = state.buf[lanes, head_slot]        # (Q, 2): pid, attr
    pid = jnp.where(valid, h_pair[:, 0], 0)
    h_attr = h_pair[:, 1]
    h_mid = h_attr >> 8
    h_phase = (h_attr >> 7) & 1
    h_hops = h_attr & _MAX_HOPS
    done = valid & (tables.sw_local == dst[pid]) & (h_phase == 1)

    # 1. ejection: up to eject_bw random winners per switch ----------------
    # Winners are the eject_bw smallest unique (randbits, lane) keys among
    # the done heads of each switch's (ports * VCs) lane block.  Small
    # blocks use a pairwise rank (fewest dispatches); large blocks a
    # sorted k-th-key threshold (O(pv log pv) beats O(pv^2)).  Both pick
    # the same winners.
    done2 = done.reshape(blocks, pv)
    if spec.eject_bw <= 0:
        # A stalled ejection port: nothing leaves (matches the oracle's
        # arbitrate(..., k=0)); without this guard the sort-threshold
        # branch below would index the k-th key at -1 and eject everything.
        ej_win = jnp.zeros(q_flat, bool)
    elif pv <= 32:
        r2 = (lane_bits >> np.uint32(16)).astype(jnp.uint16
                                                 ).reshape(blocks, pv)
        idx = jnp.arange(pv)
        before = (r2[:, None, :] < r2[:, :, None]) | (
            (r2[:, None, :] == r2[:, :, None])
            & (idx[None, :] < idx[:, None]))
        rank = jnp.sum(before & done2[:, None, :], axis=2)
        ej_win = (done2 & (rank < spec.eject_bw)).reshape(q_flat)
    else:
        e_bits = int(pv).bit_length()
        ekey = (((lane_bits >> np.uint32(16)).astype(_I32)
                 << e_bits) | tables.x_of_lane)
        ekey = jnp.where(done, ekey, _INT32_MAX)
        kth = jnp.sort(ekey.reshape(blocks, pv), axis=1)[
            :, min(spec.eject_bw, pv) - 1]
        ej_win = done & (ekey <= jnp.repeat(kth, pv))

    ej_cnt = ej_win.reshape(b, n * pv).sum(axis=1, dtype=_I32)
    if spec.log_deliveries:
        # One contiguous row write per cycle; per-packet times are
        # reconstructed on the host.  Orders of magnitude cheaper than a
        # per-row scatter on XLA:CPU.
        deliver = state.deliver
        ej_log = lax.dynamic_update_slice(
            state.ej_log, jnp.where(ej_win, pid, -1)[None, :], (c, 0))
    else:
        deliver = state.deliver.at[
            jnp.where(ej_win, pid, m_flat)].set(c, mode="drop")
        ej_log = state.ej_log
    occ = state.occ - ej_win.astype(_I16)
    head = state.head + ej_win.astype(_I16)
    delivered_total = state.delivered_total + ej_cnt
    delivered_win = state.delivered_win + jnp.where(in_window, ej_cnt, 0)

    # -- phase barrier (collective replay) ---------------------------------
    # cur_phase[b] = completed phases of copy b, derived from the
    # post-ejection delivered count against the per-copy cumulative phase
    # sizes — the same-cycle release discipline of the oracle engine
    # (a phase's closing delivery unblocks the next phase's injection in
    # this very cycle).  phase_done records each phase's closing cycle.
    if spec.num_phases:
        cum = pkt["phase_cum"]                      # (B, num_phases)
        done_p = delivered_total[:, None] >= cum
        phase_done = jnp.where((state.phase_done < 0) & done_p, c,
                               state.phase_done)
        cur_phase = jnp.sum(done_p, axis=1).astype(_I32)   # (B,)
    else:
        phase_done = state.phase_done

    # 2. transit requests --------------------------------------------------
    transit = valid & ~done
    sw_q = tables.sw_local
    tgt = jnp.where(h_phase == 1, dst[pid], h_mid)
    safe_tgt = jnp.where(transit & (tgt != sw_q), tgt, (sw_q + 1) % n)
    t_port = tables.port_table[sw_q, safe_tgt]

    # 3. injection candidates + policy itinerary ---------------------------
    cand = (pkt["blk_start"][tables.blk_idx] + tables.slot_of_term
            + state.term_next * t)
    inj_valid = cand < pkt["blk_end"][tables.blk_idx]
    ip = jnp.where(inj_valid, cand, 0)
    if spec.num_phases:
        # Replay: gen is the packet's phase ordinal; it may inject once
        # its copy has completed that many phases.
        inj_valid &= gen[ip] <= cur_phase[tables.copybase_of_term // (n * p)]
    else:
        inj_valid &= gen[ip] <= c

    i_mid, i_phase = dst[ip], jnp.ones(nt_flat, _I32)
    if spec.policy != "minimal" and n >= 3:
        # Uniform intermediate avoiding {src, dst} (shift-remap).
        s_i, d_i = src[ip], dst[ip]
        lo = jnp.minimum(s_i, d_i)
        hi = jnp.maximum(s_i, d_i)
        r = ((term_bits & np.uint32(0x3FFF)) % np.uint32(n - 2)
             ).astype(_I32)
        r = r + (r >= lo)
        r = r + (r >= hi)
        # Degraded fabrics: a mid that died or fell outside the source's
        # component collapses to the destination (route minimally rather
        # than detour into a black hole).  comp_of_switch is all zeros
        # pristine, so ``ok`` is constant-True there and the collapse is
        # the identity — same sample bits, same results.
        ok = (tables.comp_of_switch[r] == tables.comp_of_switch[s_i])
        if spec.policy == "valiant":
            i_mid = jnp.where(ok, r, d_i)
            i_phase = jnp.where(ok, 0, 1).astype(_I32)
        else:  # adaptive: congestion-threshold detour (UGAL-style)
            per_port_occ = occ.reshape(n_links, v).sum(axis=1)
            base = tables.copybase_of_term

            def congestion(port_local):
                link_local = s_i * p + port_local
                backlog = per_port_occ[
                    base + tables.feeder_local[link_local]]
                return state.pressure[base + link_local] + backlog

            safe_d = jnp.where(d_i != s_i, d_i, (s_i + 1) % n)
            c_min = congestion(tables.port_table[s_i, safe_d])
            c_val = congestion(tables.port_table[s_i, r])
            detour = (c_min > spec.weight * c_val + spec.threshold) & ok
            i_mid = jnp.where(detour, r, d_i)
            i_phase = jnp.where(detour, 0, 1).astype(_I32)

    i_tgt = jnp.where(i_phase == 1, dst[ip], i_mid)
    i_src = src[ip]
    i_tgt = jnp.where(i_tgt != i_src, i_tgt, (i_src + 1) % n)
    i_port = tables.port_table[i_src, i_tgt]

    # 4. link arbitration with credit check --------------------------------
    # Contender block per switch: its pv queue heads then its t terminals.
    # The attribute word carries (mid, phase, hops-after-this-hop), so the
    # requested VC class is derived from it: min(hops - 1, V-1).
    act = jnp.concatenate([transit.reshape(blocks, pv),
                           inj_valid.reshape(blocks, t)], axis=1)
    port_x = jnp.concatenate([t_port.reshape(blocks, pv),
                              i_port.reshape(blocks, t)], axis=1)
    pid_x = jnp.concatenate([pid.reshape(blocks, pv),
                             ip.reshape(blocks, t)], axis=1)
    attr_x = jnp.concatenate([
        _pack_attr(h_mid, h_phase, h_hops + 1).reshape(blocks, pv),
        _pack_attr(i_mid, i_phase, jnp.ones(nt_flat, _I32)
                   ).reshape(blocks, t)], axis=1)
    vc_x = jnp.minimum((attr_x & _MAX_HOPS) - 1, v - 1)

    # Credit check against the downstream (port, VC) queue of each
    # contender's requested link.  The downstream (switch, input-port) of
    # link (s, i) is ``feeder_local[s*p + i]`` — the same inverse-wire
    # table that routes pushes, read in the other direction.
    link_local_x = jnp.concatenate(
        [(sw_q * p + t_port).reshape(blocks, pv),
         (i_src * p + i_port).reshape(blocks, t)], axis=1)
    dq = ((tables.copybase_of_block[:, None]
           + tables.feeder_local[link_local_x]) * v + vc_x)
    # Unwired slots (feeder_local == -1), including links a FailureSpec
    # killed, are permanently credit-starved: well-formed routing never
    # requests them, and this mask keeps any stray request from reading
    # a garbage queue's occupancy and winning arbitration on it.
    feas = act & (tables.feeder_local[link_local_x] >= 0) & (occ[dq] < cap)

    # Arbitration randomness: transit lanes use the low half of their
    # lane word (the high half fed ejection); terminal lanes use the top
    # of their word (the bottom 14 bits fed the Valiant-mid sample).
    rand = jnp.concatenate(
        [((lane_bits & np.uint32(0xFFFF))
          >> np.uint32(16 - rand_bits)).astype(_I32).reshape(blocks, pv),
         (term_bits >> np.uint32(32 - rand_bits)).astype(_I32
                                                         ).reshape(blocks, t)],
        axis=1)
    cls = (jnp.arange(x, dtype=_I32) >= pv).astype(_I32)[None, :]
    packed = ((((cls << rand_bits) | rand) << x_bits) | jnp.arange(
        x, dtype=_I32)[None, :]).astype(key_dtype)
    # (blocks, x, p) one-hot expansion; one min-reduction per port gives
    # the winning key and the winner's contender index in its low bits.
    key_m = jnp.where(
        feas[:, :, None] & (port_x[:, :, None] == jnp.arange(p)),
        packed[:, :, None], key_dtype(sent))
    minval_flat = jnp.min(key_m, axis=1).reshape(n_links).astype(_I32)

    if spec.policy == "adaptive":
        # EWMA of requested (pre-credit) demand — only adaptive reads it.
        req = act[:, :, None] & (port_x[:, :, None] == jnp.arange(p))
        demand = jnp.sum(req, axis=1).reshape(n_links)
        pressure = (state.pressure
                    + spec.alpha * (demand - state.pressure))
    else:
        pressure = state.pressure

    # 5. movement ----------------------------------------------------------
    # Transit pop: queue lane q wins iff the winner of its requested link
    # is contender q itself (sentinel's index field cannot match).
    win_t = transit & ((minval_flat[tables.linkbase_of_lane + t_port]
                        & x_mask) == tables.x_of_lane)
    occ = occ - win_t.astype(_I16)
    head = head + win_t.astype(_I16)

    # Injection advance: terminal lane wins iff the winner of its link is
    # contender pv + (lane's slot within the switch).
    i_win = inj_valid & ((minval_flat[tables.linkbase_of_term + i_port]
                          & x_mask) == pv + tables.slot_of_term)
    term_next = state.term_next + i_win.astype(_I32)

    # Push as a gather: queue (sw', p', vc') receives the winner of its
    # feeder link (the wire into input port p') when the VC matches.
    mv = minval_flat[tables.feeder_flat]
    recv_x = tables.feeder_xbase + (mv & x_mask)
    pair_x = jnp.stack([pid_x, attr_x], axis=-1).reshape(blocks * x, 2)
    pair_w = pair_x[recv_x]                     # (Q, 2): pid, attr
    pid_w, attr_w = pair_w[:, 0], pair_w[:, 1]
    vc_w = jnp.minimum((attr_w & _MAX_HOPS) - 1, v - 1)
    recv = tables.wired_q & (mv != sent) & (vc_w == tables.vc_of_lane)
    # Phase flips on arrival at the Valiant intermediate — which, seen
    # from the receiving queue, is simply its own switch.
    attr_w = jnp.where(((attr_w & (1 << 7)) == 0)
                       & ((attr_w >> 8) == tables.sw_local),
                       attr_w | (1 << 7), attr_w)

    slot = (head + occ) % cap
    onehot = (jnp.arange(cap, dtype=_I32)[None, :] == slot[:, None]
              ) & recv[:, None]
    buf = jnp.where(
        onehot[:, :, None],
        jnp.stack([pid_w, attr_w], axis=-1)[:, None, :], state.buf)
    occ = occ + recv.astype(_I16)
    # Ring-buffer heads live in int16 (the dtype diet halves the hot
    # state); stored mod capacity so they never overflow over long runs.
    head = head % cap

    has_w = minval_flat != sent
    load_total = state.load_total + has_w.astype(_I32)
    load_window = state.load_window + (
        has_w & in_window[tables.copy_of_link]).astype(_I32)

    # -- trace sampling (end of cycle c, after movement) -------------------
    # Gated at Python trace time on the static spec, so an untraced
    # program is byte-for-byte the pre-trace program.  Row writes are
    # read-modify-write: an out-of-range dynamic_update_slice start
    # clamps (it would silently overwrite the last row), so the row is
    # first read and only replaced when this cycle really samples.
    if spec.trace_stride:
        row = jnp.minimum(c // spec.trace_stride, spec.trace_samples - 1)
        write = ((c % spec.trace_stride) == 0) & (
            c // spec.trace_stride < spec.trace_samples)

        def _row_write(rbuf, vec):
            cur = lax.dynamic_slice_in_dim(rbuf, row, 1, axis=0)
            new = jnp.where(write, vec[None, :].astype(rbuf.dtype), cur)
            return lax.dynamic_update_slice_in_dim(rbuf, new, row, axis=0)

        cur_c = lax.dynamic_slice_in_dim(state.tr_cycle, row, 1, axis=0)
        tr_cycle = lax.dynamic_update_slice_in_dim(
            state.tr_cycle, jnp.where(write, c.astype(_I32), cur_c),
            row, axis=0)
        tr_link = _row_write(state.tr_link, load_total)
        tr_occ = _row_write(state.tr_occ,
                            occ.reshape(blocks, pv).sum(axis=1))
        tr_inj = _row_write(state.tr_inj,
                            term_next.reshape(blocks, t).sum(axis=1))
        tr_del = _row_write(state.tr_del, delivered_total)
    else:
        tr_cycle, tr_link = state.tr_cycle, state.tr_link
        tr_occ, tr_inj, tr_del = state.tr_occ, state.tr_inj, state.tr_del

    return _State(buf=buf, head=head, occ=occ, deliver=deliver,
                  ej_log=ej_log, term_next=term_next, pressure=pressure,
                  load_total=load_total, load_window=load_window,
                  delivered_total=delivered_total,
                  delivered_win=delivered_win, phase_done=phase_done,
                  cycle=c + 1, tr_cycle=tr_cycle, tr_link=tr_link,
                  tr_occ=tr_occ, tr_inj=tr_inj, tr_del=tr_del)


def _run_loop(spec: XSpec, tables: _Tables, pkt: dict, key: jax.Array,
              warmup: jax.Array) -> dict:
    """One device's whole run: state init, the cycle loop, output dict.

    Shapes derive from the *local* packet/block arrays, so the same body
    serves the single-device jit (:data:`_run_flat`, all copies in one
    flat state) and each shard of :func:`_sharded_runner` (a contiguous
    block of copies per device).  The static ``spec.horizon``/``cutoff``
    only size allocations and trip counts; the *measured* bounds are the
    runtime ``pkt["h_eff"]``/``pkt["cutoff_eff"]`` scalars, so a
    bucket-padded program computes exactly what the exact-shape program
    would (see :func:`sweep`).
    """
    n, p, v = spec.n, spec.ports, spec.vcs
    b = pkt["blk_start"].shape[0] // n
    bq = b * n * p * v
    m_flat = pkt["src"].shape[0]
    state = _State(
        buf=jnp.full((bq, spec.cap, 2), -1, _I32),
        head=jnp.zeros(bq, _I16),
        occ=jnp.zeros(bq, _I16),
        deliver=jnp.full(m_flat if not spec.log_deliveries else 1, -1, _I32),
        ej_log=jnp.full((spec.horizon if spec.log_deliveries else 1, bq),
                        -1, _I32),
        term_next=jnp.zeros(b * n * spec.terminals, _I32),
        pressure=jnp.zeros(b * n * p, jnp.float32),
        load_total=jnp.zeros(b * n * p, _I32),
        load_window=jnp.zeros(b * n * p, _I32),
        delivered_total=jnp.zeros(b, _I32),
        delivered_win=jnp.zeros(b, _I32),
        phase_done=jnp.full((b, spec.num_phases), -1, _I32),
        cycle=jnp.zeros((), _I32),
        tr_cycle=jnp.full(spec.trace_samples if spec.trace_stride else 1,
                          -1, _I32),
        tr_link=jnp.zeros((spec.trace_samples, b * n * p)
                          if spec.trace_stride else (1, 1), _I32),
        tr_occ=jnp.zeros((spec.trace_samples, b * n)
                         if spec.trace_stride else (1, 1), _I32),
        tr_inj=jnp.zeros((spec.trace_samples, b * n)
                         if spec.trace_stride else (1, 1), _I32),
        tr_del=jnp.zeros((spec.trace_samples, b)
                         if spec.trace_stride else (1, 1), _I32),
    )

    def body(st: _State):
        return _step(spec, tables, pkt, key, warmup, st)

    # All copies of one program share a horizon by construction, so the
    # per-copy runtime bounds collapse to scalars.
    h_eff = pkt["h_eff"][0]
    if spec.drain:
        total_m = jnp.sum(pkt["m_real"])
        cutoff_eff = pkt["cutoff_eff"][0]

        def cond(st: _State):
            return (st.cycle < h_eff) | (
                (jnp.sum(st.delivered_total) < total_m)
                & (st.cycle < cutoff_eff))

        final = lax.while_loop(cond, body, state)
    else:
        # Static trip count: unrolling folds several cycles into each XLA
        # loop iteration, amortizing per-op dispatch overhead.  Bucket
        # padding runs the loop to the padded horizon; the cond skips the
        # padded tail cycles, leaving the state untouched past h_eff.
        def step_or_skip(_i, st: _State):
            return lax.cond(st.cycle < h_eff, body, lambda s: s, st)

        final = lax.fori_loop(0, spec.horizon, step_or_skip, state,
                              unroll=8)
    out = {
        "deliver": final.deliver,
        "ej_log": final.ej_log,
        "load_total": final.load_total,
        "load_window": final.load_window,
        "delivered_total": final.delivered_total,
        "delivered_in_window": final.delivered_win,
        "phase_done": final.phase_done,
        "cycle": final.cycle,
        "in_flight": final.occ.reshape(b, n * p * v).sum(axis=1,
                                                         dtype=_I32),
    }
    if spec.trace_stride:
        out.update(tr_cycle=final.tr_cycle, tr_link=final.tr_link,
                   tr_occ=final.tr_occ, tr_inj=final.tr_inj,
                   tr_del=final.tr_del)
    return out


_run_flat = partial(jax.jit, static_argnums=0)(_run_loop)


@lru_cache(maxsize=None)
def _sharded_runner(spec: XSpec, ndev: int, pkt_keys: tuple):
    """A jitted ``shard_map`` over a ``copies`` mesh axis: each of the
    ``ndev`` devices runs :func:`_run_loop` on its contiguous block of
    fabric copies.  Packet descriptors (``src``/``dst``/``gen``) are
    *replicated* so packet ids stay global — per-shard block bounds,
    delivery records, and ejection logs line up without any remapping —
    while every per-copy array shards along its leading axis.  The copies
    are disjoint fabrics, so the program is SPMD with zero collectives;
    shard outputs gain a leading device axis and reassemble on the host
    (see :func:`sweep`).  Donating the packet/warmup operands lets XLA
    reuse their buffers for the (much larger) state."""
    from jax.sharding import PartitionSpec

    mesh = jaxapi.make_auto_mesh((ndev,), ("copies",))
    rep, shard = PartitionSpec(), PartitionSpec("copies")
    pkt_specs = {k: (rep if k in ("src", "dst", "gen") else shard)
                 for k in pkt_keys}

    def run(tables, pkt, key, warmup):
        out = _run_loop(spec, tables, pkt, key, warmup)
        return jax.tree_util.tree_map(lambda a: a[None], out)

    return jax.jit(jaxapi.shard_map(
        run, mesh=mesh, in_specs=(rep, pkt_specs, rep, shard),
        out_specs=shard, check_vma=False), donate_argnums=(1, 3))


def _resolve_devices(devices) -> int:
    """Number of devices to shard the fabric copies across.

    ``None``/``1`` = the classic single-program path; ``"auto"`` = every
    visible JAX device; an int is validated against availability (on CPU,
    ``XLA_FLAGS=--xla_force_host_platform_device_count=<n>`` exposes n
    host devices)."""
    if devices is None:
        return 1
    avail = jax.local_device_count()
    if devices == "auto":
        return max(avail, 1)
    ndev = int(devices)
    if ndev < 1:
        raise ValueError(f"devices={devices!r} must be >= 1")
    if ndev > avail:
        raise ValueError(
            f"devices={ndev} but only {avail} JAX device(s) are visible; "
            f"on CPU set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{ndev} before importing jax")
    return ndev


# ---------------------------------------------------------------------------
# Host-side API.
# ---------------------------------------------------------------------------

def _default_num_vcs(topo: SimTopology, policy: RoutingPolicy) -> int:
    return topo.diameter * (2 if policy.vc_required > 1 else 1)


def _build_tables(topo: SimTopology, links: LinkTable, b: int,
                  terminals: int, num_vcs: int) -> _Tables:
    """Topology tables + flat index vectors for ``b`` fabric copies."""
    n, p, v, t = topo.num_switches, topo.num_ports, num_vcs, terminals
    pv, x = p * v, p * v + terminals
    nbr = links.neighbor_flat.astype(np.int64)
    rev = links.rev_flat.astype(np.int64)
    feeder_local = np.where(nbr >= 0, nbr * p + rev, -1)

    lanes = np.arange(b * n * pv, dtype=np.int64)
    copy_of_lane = lanes // (n * pv)
    block_of_lane = lanes // pv
    qport_local = (lanes % (n * pv)) // v
    f_local = feeder_local[qport_local]
    feeder_flat = np.clip(copy_of_lane * (n * p) + f_local, 0,
                          b * n * p - 1)
    ti = np.arange(b * n * t, dtype=np.int64)
    term_block = ti // t
    blk_idx = term_block                         # flat (copy, switch)
    link_ids = np.arange(b * n * p, dtype=np.int64)
    faults = (topo.meta or {}).get("faults")
    comp = (faults["comp"] if faults is not None
            else np.zeros(n, dtype=np.int64))
    as_i32 = lambda a: jnp.asarray(a, _I32)  # noqa: E731
    return _Tables(
        port_table=as_i32(topo.minimal_port_table()),
        comp_of_switch=as_i32(comp),
        feeder_local=as_i32(feeder_local),
        sw_local=as_i32((lanes % (n * pv)) // pv),
        x_of_lane=as_i32(lanes % pv),
        vc_of_lane=as_i32(lanes % v),
        linkbase_of_lane=as_i32(block_of_lane * p),
        feeder_flat=as_i32(feeder_flat),
        feeder_xbase=as_i32((feeder_flat // p) * x),
        wired_q=jnp.asarray(f_local >= 0),
        blk_idx=as_i32(blk_idx),
        slot_of_term=as_i32(ti % t),
        linkbase_of_term=as_i32(term_block * p),
        copybase_of_term=as_i32((ti // (n * t)) * (n * p)),
        copybase_of_block=as_i32((np.arange(b * n) // n) * (n * p)),
        copy_of_link=as_i32(link_ids // (n * p)))


def sweep(topo: SimTopology, policy, traffic_factory: Callable,
          loads: Sequence[float], *, seeds: Sequence[int] = (0,),
          terminals: int | None = None, eject_bw: int | None = None,
          num_vcs: int | None = None, queue_capacity: int = 4,
          cycles: int | None = None, warmup: int | None = None,
          drain: bool | None = None, max_cycles: int | None = None,
          trace=None, bucket: bool | None = None,
          devices=None) -> list[list[RunStats]]:
    """An entire saturation sweep as one compiled program.

    Every (offered load, seed) point becomes one replicated fabric copy
    inside a single jit-compiled run (see the module docstring), so the
    whole grid costs one compile + one device program.  Returns a
    ``[load][seed]`` grid of :class:`RunStats` built by the same metrics
    pipeline as the oracle engine.

    ``traffic_factory`` is called as ``factory(load, seed)`` when it
    accepts two positional arguments, else ``factory(load)`` (the oracle
    sweep's convention, reusing one packet set across seeds).  All grid
    points share one simulated horizon (they are one program): ``cycles=``
    pins it, otherwise it is derived from the traffic objects as the max
    generation window over the grid.  ``terminals`` defaults to the
    traffic objects' own record.  Per-point arbitration streams derive
    from a key over the full seed tuple.

    Every point's stats carry a shared ``timing`` record splitting the
    program's compile time from its execution
    (:func:`repro.obs.telemetry.timed_compiled`).  ``trace`` (anything
    :meth:`repro.obs.TraceConfig.coerce` accepts) compiles statically
    shaped time-series ring buffers into the loop — per-point
    :class:`~repro.obs.Trace` objects land on ``stats.trace``.  Packet
    spans (``TraceConfig.packets``) are a numpy-engine feature and are
    ignored here.

    ``bucket`` (default on) rounds the program's *static* shapes — grid
    width, packet count, horizon, drain cutoff — up to
    :func:`_bucket_count` boundaries, so nearby sweep sizes share one
    compiled program (and one persistent-cache entry) instead of
    compiling each.  The padding is fully masked: padded copies carry no
    packets, padded packet slots never become eligible, padded cycles
    are skipped by the runtime ``h_eff`` bound, and the per-copy RNG
    streams are keyed on global copy ids — so a bucketed run is
    *bit-identical* to the exact-shape run (``tests/test_conformance.py``
    pins this).  ``bucket=False`` restores exact shapes.

    ``devices`` shards the fabric copies across JAX devices with
    ``shard_map`` (``None`` = single device, ``"auto"`` = all visible,
    or an int).  Copies are independent fabrics, so sharding is SPMD
    with zero collectives and also bit-identical to the single-device
    program.  Tracing forces the single-device path.
    """
    policy = _resolve_policy(policy)
    seeded_factory = _accepts_seed(traffic_factory)
    n = topo.num_switches
    grid: list[tuple[float, int, Traffic]] = []
    for load in loads:
        for seed in seeds:
            tr = (traffic_factory(load, seed) if seeded_factory
                  else traffic_factory(load))
            grid.append((load, seed, tr))
    if not grid:
        return []

    resolved_t = {resolve_terminals(tr, terminals) for _, _, tr in grid}
    if len(resolved_t) > 1:
        raise ValueError(
            f"a batched sweep shares one injector count across the grid "
            f"but the traffic objects record terminals="
            f"{sorted(resolved_t)}; use one terminals value per sweep")
    terminals = resolved_t.pop()

    # Collective replays (traffic.workload set) compile the phase barrier
    # into the program: all-or-none across the grid (the barrier changes
    # the injection gate's meaning), one static phase-window count.
    wls = [tr.workload for _, _, tr in grid]
    replaying = any(w is not None for w in wls)
    if replaying and not all(w is not None for w in wls):
        raise ValueError("a batched sweep cannot mix collective-replay "
                         "workloads with open-loop traffic")
    num_phases = max((w.num_phases for w in wls), default=0) if replaying \
        else 0
    replaying = num_phases > 0

    if drain is None:
        drain = all(tr.offered == 0 for _, _, tr in grid)
    if num_vcs is None:
        num_vcs = _default_num_vcs(topo, policy)
    if num_vcs > _MAX_HOPS + 1:
        raise ValueError(f"compiled engine packs hop counts into 7 bits; "
                         f"num_vcs={num_vcs} is out of range")

    sizes = [tr.num_packets for _, _, tr in grid]
    bases = np.concatenate([[0], np.cumsum(sizes)[:-1]]).astype(np.int64)
    packed = [_pack_traffic(tr, n, int(bases[i]))
              for i, (_, _, tr) in enumerate(grid)]
    # One program = one horizon.  cycles= pins it; otherwise take the max
    # generation window over the grid so no point's traffic is truncated
    # (points with shorter windows simply stop generating early).
    if cycles is not None:
        horizon = int(cycles)
    else:
        windows = {max(tr.horizon, 1) for _, _, tr in grid}
        horizon = int(max(windows))
        if len(windows) > 1:
            import warnings
            warnings.warn(
                f"batched sweep derived a shared horizon of {horizon} "
                f"cycles from traffic windows {sorted(windows)}; points "
                f"with shorter generation windows are still measured over "
                f"the shared horizon, which dilutes their accepted "
                f"throughput — pass cycles= to pin one window",
                stacklevel=2)
    default_warmup = 0 if replaying else horizon // 4
    warmups = [default_warmup if warmup is None else warmup] * len(grid)
    cutoff = int(max_cycles if max_cycles is not None
                 else horizon + _DRAIN_SLACK)
    bucket = True if bucket is None else bool(bucket)
    trace_cfg = TraceConfig.coerce(trace)
    # Trace ring buffers slice per-copy columns host-side; the (rare,
    # small) traced runs stay on the classic single-device path.
    ndev = 1 if trace_cfg is not None else _resolve_devices(devices)
    b_real = len(grid)
    b_pad = _bucket_count(b_real) if bucket else b_real
    b_pad = -(-b_pad // ndev) * ndev          # whole copy blocks per device
    h_static = _bucket_count(horizon) if bucket else horizon
    c_static = max(_bucket_count(cutoff) if bucket else cutoff, h_static)
    q_flat = b_pad * n * topo.num_ports * num_vcs
    log_deliveries = (not drain
                      and h_static * q_flat <= _LOG_ENTRY_BUDGET)
    if trace_cfg is not None:
        # Static row budget: a drain run can stop anywhere below the
        # cutoff, so allocate for the worst case (capped by max_samples);
        # unwritten rows stay at the -1 sentinel and are dropped below.
        # Budgets derive from the *exact* span — padded cycles never run.
        span = cutoff if drain else horizon
        trace_samples = min(trace_cfg.max_samples,
                            (max(span, 1) - 1) // trace_cfg.stride + 1)
    spec = XSpec(
        n=n, ports=topo.num_ports, vcs=num_vcs, cap=queue_capacity,
        terminals=terminals,
        eject_bw=terminals if eject_bw is None else eject_bw,
        policy=policy.name,
        threshold=float(getattr(policy, "threshold", 0.0)),
        weight=float(getattr(policy, "weight", 0.0)),
        alpha=0.05, drain=bool(drain), horizon=h_static, cutoff=c_static,
        log_deliveries=log_deliveries, num_phases=num_phases,
        trace_stride=0 if trace_cfg is None else trace_cfg.stride,
        trace_samples=0 if trace_cfg is None else trace_samples)

    links = LinkTable.for_topology(topo, num_vcs)
    tables = _build_tables(topo, links, b_pad // ndev, terminals, num_vcs)

    flat_np = {k: (np.concatenate([pk[k] for pk in packed])
                   if packed[0][k].ndim else
                   np.asarray([pk[k] for pk in packed]))
               for k in packed[0]}
    # Bucket the flat packet axis too, with inert padding slots: their
    # generation time is past any horizon, so a padded slot never becomes
    # an injection candidate (this also covers the all-empty grid, whose
    # gathers need at least one in-range slot).  Padded *copies* carry
    # empty source blocks, zero real packets, and warmup 0.
    m_total = int(flat_np["src"].size)
    m_pad = _bucket_count(max(m_total, 1)) if bucket else max(m_total, 1)
    flat_np["src"] = np.concatenate(
        [flat_np["src"], np.zeros(m_pad - m_total, np.int32)])
    flat_np["dst"] = np.concatenate(
        [flat_np["dst"], np.full(m_pad - m_total, min(1, n - 1), np.int32)])
    flat_np["gen"] = np.concatenate(
        [flat_np["gen"], np.full(m_pad - m_total, _PAD_GEN, np.int32)])
    pad_b = b_pad - b_real
    flat_np["blk_start"] = np.concatenate(
        [flat_np["blk_start"], np.zeros(pad_b * n, np.int32)])
    flat_np["blk_end"] = np.concatenate(
        [flat_np["blk_end"], np.zeros(pad_b * n, np.int32)])
    flat_np["m_real"] = np.concatenate(
        [flat_np["m_real"], np.zeros(pad_b, np.int32)])
    if replaying:
        # Per-copy cumulative phase sizes, padded to the shared static
        # phase count (padding phases are empty and complete instantly).
        flat_np["phase_cum"] = np.concatenate(
            [np.stack([w.phase_cum(num_phases) for w in wls]),
             np.zeros((pad_b, num_phases))]).astype(np.int32)
    # Global copy ids (the per-copy RNG fold keys) plus the runtime
    # measurement bounds — per-copy so they shard with the batch.
    flat_np["copy_id"] = np.arange(b_pad, dtype=np.int32)
    flat_np["h_eff"] = np.full(b_pad, horizon, np.int32)
    flat_np["cutoff_eff"] = np.full(b_pad, cutoff, np.int32)

    # The persistent compile cache keys on content, not object identity:
    # fold the (replicated) topology tables into the entry digest so two
    # fabrics that merely share shapes never alias an entry.
    dig = hashlib.sha256()
    for a in tables:
        dig.update(np.asarray(a).tobytes())
    tab_digest = dig.hexdigest()

    flat = {k: jnp.asarray(a) for k, a in flat_np.items()}
    key = jax.random.PRNGKey(hash(tuple(s for _, s, _ in grid)) & 0x7FFFFFFF)
    warm_j = jnp.asarray(np.asarray(warmups + [0] * pad_b, np.int32))
    if ndev > 1:
        runner = _sharded_runner(spec, ndev, tuple(sorted(flat)))
        out, timing = timed_compiled(
            runner, None, tables, flat, key, warm_j,
            grid_points=b_real, key_extra=(spec, ndev, tab_digest))
    else:
        out, timing = timed_compiled(
            _run_flat, spec, tables, flat, key, warm_j,
            grid_points=b_real, key_extra=tab_digest)
    out = jax.tree_util.tree_map(np.asarray, out)
    if ndev > 1:
        # Host reassembly: shard outputs carry a leading device axis over
        # contiguous copy blocks, so per-copy/per-link vectors flatten
        # straight back into global copy-major order and ejection-log
        # rows concatenate along the lane axis.  Delivery records hold
        # *global* packet ids and are disjoint across shards (-1
        # elsewhere), so an axis-0 max merges them.
        out["deliver"] = out["deliver"].max(axis=0)
        out["ej_log"] = np.concatenate(list(out["ej_log"]), axis=1)
        for k in ("load_total", "load_window", "delivered_total",
                  "delivered_in_window", "in_flight"):
            out[k] = out[k].reshape(-1)
        out["phase_done"] = out["phase_done"].reshape(b_pad, -1)
        out["cycle"] = out["cycle"].max()

    total_m = max(1, int(sum(sizes)))
    if log_deliveries:
        # Reconstruct per-packet delivery cycles from the per-cycle
        # ejection log: row c holds the pids ejected at cycle c.
        log = out["ej_log"].ravel()
        q_per_cycle = out["ej_log"].shape[1]
        deliver_all = np.full(total_m, -1, np.int64)
        hit = np.flatnonzero(log >= 0)
        deliver_all[log[hit]] = hit // q_per_cycle
    else:
        deliver_all = out["deliver"].astype(np.int64)

    n_links = n * topo.num_ports
    if trace_cfg is not None:
        tr_valid = np.flatnonzero(out["tr_cycle"] >= 0)
        tr_cycles = out["tr_cycle"][tr_valid].astype(np.int64)
    results: list[RunStats] = []
    for i, (load, seed, tr) in enumerate(grid):
        m = int(packed[i]["m_real"])
        delivered_total = int(out["delivered_total"][i])
        if drain and delivered_total < m:
            raise RuntimeError(
                f"{topo.name}/{policy.name}: {m - delivered_total} packets "
                f"undelivered after {int(out['cycle'])} cycles "
                f"(deadlock or cutoff too small)")
        counter = LinkLoadCounter(links)
        counter.total = out["load_total"][
            i * n_links:(i + 1) * n_links].astype(np.int64)
        counter.window = out["load_window"][
            i * n_links:(i + 1) * n_links].astype(np.int64)
        deliver = deliver_all[int(bases[i]):int(bases[i]) + m]
        gen_arg = packed[i]["gen"][:m].astype(np.int64)
        cycles_arg = max(horizon, 1)
        if replaying:
            # Measure over the replay's own timeline (see
            # metrics.replay_timeline): horizon = completion cycle,
            # generation = the cycle each packet's phase released.
            pd = out["phase_done"][i, :wls[i].num_phases]
            cycles_arg, gen_arg = replay_timeline(pd, gen_arg)
        stats = build_stats(
            topology=topo, policy=policy, traffic=tr,
            cycles=cycles_arg, warmup=int(warmups[i]),
            terminals=terminals, gen=gen_arg,
            deliver=deliver, link_counter=counter,
            delivered_in_window=int(out["delivered_in_window"][i]),
            in_flight=int(out["in_flight"][i]))
        if replaying:
            attach_replay(stats, wls[i],
                          out["phase_done"][i, :wls[i].num_phases])
        if tr.request is not None:
            # Serving metrics need request ids in the engine's packet
            # order.  Recompute _pack_traffic's permutation (a stable
            # lexsort over identical inputs — bit-identical to the one
            # the packing used) host-side; the compiled program never
            # sees the request array.
            req = np.asarray(tr.request, dtype=np.int64)
            src64 = tr.src.astype(np.int64)
            gen64 = tr.gen.astype(np.int64)
            sort_key = src64 * (gen64.max(initial=0) + 1) + gen64
            if not np.all(sort_key[1:] >= sort_key[:-1]):
                req = req[np.lexsort((tr.gen, tr.src))]
            attach_serving(stats, req, packed[i]["gen"][:m].astype(np.int64),
                           deliver, slo=tr.slo)
        stats.timing = timing
        if trace_cfg is not None:
            # Slice copy i's columns out of the flat ring buffers; block
            # bounds come back to local pid space by removing the copy's
            # packet-id base.
            injected = out["tr_inj"][tr_valid][:, i * n:(i + 1) * n
                                               ].astype(np.int64)
            backlog = derive_backlog(
                tr_cycles, injected,
                packed[i]["gen"][:m].astype(np.int64),
                packed[i]["blk_start"].astype(np.int64) - int(bases[i]),
                packed[i]["blk_end"].astype(np.int64) - int(bases[i]),
                phase_done=(out["phase_done"][i, :wls[i].num_phases]
                            if replaying else None))
            stats.trace = Trace(
                stride=trace_cfg.stride, cycles=tr_cycles,
                link_load=out["tr_link"][tr_valid][
                    :, i * n_links:(i + 1) * n_links],
                queue_occ=out["tr_occ"][tr_valid][:, i * n:(i + 1) * n],
                injected=injected,
                delivered=out["tr_del"][tr_valid][:, i],
                backlog=backlog,
                meta={"topology": topo.name, "policy": policy.name,
                      "backend": "jax", "num_switches": n,
                      "num_ports": topo.num_ports, "terminals": terminals,
                      "load": load, "seed": seed})
        results.append(stats)
    return [results[li * len(seeds):(li + 1) * len(seeds)]
            for li in range(len(loads))]


def simulate_jax(topo: SimTopology, policy, traffic: Traffic, *,
                 terminals: int | None = None, eject_bw: int | None = None,
                 num_vcs: int | None = None, queue_capacity: int = 4,
                 cycles: int | None = None, warmup: int | None = None,
                 drain: bool | None = None, max_cycles: int | None = None,
                 seed: int = 0, trace=None, bucket: bool | None = None,
                 devices=None) -> RunStats:
    """One compiled run (a single-copy :func:`sweep`)."""
    if drain is None:
        drain = traffic.offered == 0
    return sweep(topo, policy, lambda _load: traffic, [traffic.offered],
                 seeds=(seed,), terminals=terminals, eject_bw=eject_bw,
                 num_vcs=num_vcs, queue_capacity=queue_capacity,
                 cycles=cycles, warmup=0 if warmup is None else warmup,
                 drain=drain, max_cycles=max_cycles, trace=trace,
                 bucket=bucket, devices=devices)[0][0]
