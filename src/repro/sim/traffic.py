"""Synthetic traffic for the packet simulator.

Open-loop generators produce a :class:`Traffic` — flat (src, dst,
generation-cycle) arrays — for a given *offered load*, expressed in
packets per terminal per cycle (each switch has ``terminals`` injectors
of unit bandwidth, so a switch's aggregate injection demand is
``terminals * offered``).

Patterns (the methodology of the Dragonfly/HyperX evaluation literature):

* :func:`uniform`      — independent uniform-random destinations;
* :func:`permutation`  — fixed one-to-one partner map;
* :func:`hotspot`      — fraction ``hot_fraction`` of each switch's packets
  go to its *hot partner* (distinct per source by default, concentrating
  load on N dedicated links — the pattern minimal CIN routing is worst at
  — or a single shared destination via ``hot_dst``), rest uniform;
* :func:`adversarial_same_group` — every switch in Dragonfly group ``g``
  targets group ``g+1``, funnelling all traffic through the single
  inter-group link (the classic Valiant motivator).

One-shot helpers produce closed workloads for validation against the
closed-form flow counts in :mod:`repro.core.simulate`.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.dragonfly import DragonflyConfig


@dataclass
class Traffic:
    """Flat packet descriptors; ``offered == 0`` marks a one-shot workload.

    ``terminals`` records the injector count the generator scaled its
    arrival rate by (``offered * terminals`` packets per switch per
    cycle); the engines default their own ``terminals`` to it and raise
    on an explicit mismatch, so the two can never silently disagree.
    ``None`` (one-shot workloads without an explicit ``terminals=``)
    leaves the engine default of 1.

    ``workload`` marks a phase-structured collective replay
    (:class:`repro.sim.workloads.Workload`): ``gen`` then holds each
    packet's *phase ordinal* — the barrier it waits behind — rather
    than a generation cycle, and the engines gate injection on phase
    completion instead of simulated time.

    ``request`` marks *serving* traffic (:mod:`repro.workload`): a
    per-packet request id grouping the packets of one inference request.
    The engines then report per-request latency percentiles and — when
    ``slo`` names a target in cycles — SLO attainment, on top of the
    usual per-packet statistics.  A request completes when its last
    packet delivers; its latency is measured from its arrival cycle.
    """
    name: str
    src: np.ndarray
    dst: np.ndarray
    gen: np.ndarray
    offered: float = 0.0        # packets / terminal / cycle
    horizon: int = 0            # generation window in cycles
    terminals: int | None = None  # injectors/switch the rate was scaled by
    workload: object | None = None  # repro.sim.workloads.Workload for replays
    request: np.ndarray | None = None  # per-packet request id (serving)
    slo: float | None = None    # request-latency SLO target in cycles

    @property
    def num_packets(self) -> int:
        return self.src.size


def resolve_terminals(traffic: Traffic, terminals: int | None) -> int:
    """The engine-side injector count for ``traffic``.

    ``terminals=None`` defaults to what the traffic was generated with
    (1 when the traffic does not record it); an explicit value must
    agree with the traffic object's record.
    """
    if terminals is None:
        return traffic.terminals if traffic.terminals is not None else 1
    if traffic.terminals is not None and terminals != traffic.terminals:
        raise ValueError(
            f"terminals={terminals} disagrees with the {traffic.name!r} "
            f"traffic object, which was generated for "
            f"terminals={traffic.terminals}; drop the explicit kwarg "
            f"(engines default to the traffic's value) or regenerate "
            f"the traffic")
    return terminals


def _random_dst_excluding_src(rng, src: np.ndarray, n: int) -> np.ndarray:
    """Uniform destination != source, via the shift-remap trick."""
    d = rng.integers(0, n - 1, size=src.size)
    return np.where(d >= src, d + 1, d)


def _poisson_arrivals(rng, n: int, rate: float, cycles: int
                      ) -> tuple[np.ndarray, np.ndarray]:
    """(src, gen) for Poisson(rate) arrivals per switch per cycle."""
    counts = rng.poisson(rate, size=(n, cycles))
    src = np.repeat(np.arange(n), counts.sum(axis=1))
    gen = np.repeat(np.tile(np.arange(cycles), n), counts.reshape(-1))
    return src.astype(np.int64), gen.astype(np.int64)


def uniform(n: int, *, offered: float, cycles: int, terminals: int = 1,
            seed: int = 0) -> Traffic:
    rng = np.random.default_rng(seed)
    src, gen = _poisson_arrivals(rng, n, offered * terminals, cycles)
    dst = _random_dst_excluding_src(rng, src, n)
    return Traffic("uniform", src, dst, gen, offered=offered,
                   horizon=cycles, terminals=terminals)


def permutation(n: int, *, offered: float, cycles: int, terminals: int = 1,
                perm: np.ndarray | None = None, seed: int = 0) -> Traffic:
    rng = np.random.default_rng(seed)
    if perm is None:
        perm = (np.arange(n) + n // 2) % n if n > 1 else np.arange(n)
    perm = np.asarray(perm)
    if (perm == np.arange(n)).any():
        raise ValueError("permutation traffic needs a fixed-point-free map")
    src, gen = _poisson_arrivals(rng, n, offered * terminals, cycles)
    return Traffic("permutation", src, perm[src], gen, offered=offered,
                   horizon=cycles, terminals=terminals)


def hotspot(n: int, *, offered: float, cycles: int, terminals: int = 1,
            hot_fraction: float = 0.8, hot_dst: int | None = None,
            partner_shift: int | None = None, seed: int = 0) -> Traffic:
    """Hot traffic rides N dedicated (src, partner) pairs by default
    (``partner_shift``), or converges on one destination via ``hot_dst``."""
    rng = np.random.default_rng(seed)
    src, gen = _poisson_arrivals(rng, n, offered * terminals, cycles)
    uniform_dst = _random_dst_excluding_src(rng, src, n)
    if hot_dst is not None:
        hot = np.full(src.size, hot_dst, dtype=np.int64)
    else:
        shift = partner_shift if partner_shift is not None else max(n // 2, 1)
        hot = (src + shift) % n
    take_hot = (rng.random(src.size) < hot_fraction) & (hot != src)
    dst = np.where(take_hot, hot, uniform_dst)
    return Traffic("hotspot", src, dst, gen, offered=offered,
                   horizon=cycles, terminals=terminals)


def adversarial_same_group(cfg: DragonflyConfig, *, offered: float,
                           cycles: int, terminals: int = 1, seed: int = 0
                           ) -> Traffic:
    """Dragonfly adversary: group ``g`` sends only to group ``g+1 mod G``."""
    a, g = cfg.group_size, cfg.num_groups
    rng = np.random.default_rng(seed)
    src, gen = _poisson_arrivals(rng, a * g, offered * terminals, cycles)
    peer_group = (src // a + 1) % g
    dst = peer_group * a + rng.integers(0, a, size=src.size)
    return Traffic("adversarial-same-group", src, dst, gen, offered=offered,
                   horizon=cycles, terminals=terminals)


# ---------------------------------------------------------------------------
# One-shot (closed) workloads for validation.
# ---------------------------------------------------------------------------

def one_shot_all_to_all(n: int, *, terminals: int | None = None) -> Traffic:
    """One packet per ordered switch pair, all generated at cycle 0 — the
    workload whose link loads :func:`repro.core.simulate.cin_link_loads`
    counts in closed form.

    ``terminals`` is recorded on the traffic object exactly like the
    open-loop generators record theirs (:func:`resolve_terminals`): the
    engines then default to it and raise on an explicit mismatch.
    ``None`` keeps the legacy behaviour (engine default of 1, any
    explicit value accepted).
    """
    a = np.repeat(np.arange(n), n)
    b = np.tile(np.arange(n), n)
    keep = a != b
    return Traffic("one-shot-a2a", a[keep].astype(np.int64),
                   b[keep].astype(np.int64),
                   np.zeros(int(keep.sum()), dtype=np.int64), horizon=1,
                   terminals=terminals)


def one_shot_permutation(partners: np.ndarray, *,
                         terminals: int | None = None) -> Traffic:
    """One packet per switch to ``partners[s]`` (self/negative = idle) — a
    single step of a 1-factor schedule.  ``terminals`` is recorded the
    same way as :func:`one_shot_all_to_all`'s."""
    partners = np.asarray(partners)
    s = np.arange(partners.size)
    keep = (partners >= 0) & (partners != s)
    return Traffic("one-shot-perm", s[keep].astype(np.int64),
                   partners[keep].astype(np.int64),
                   np.zeros(int(keep.sum()), dtype=np.int64), horizon=1,
                   terminals=terminals)
