"""Offered-load sweeps, saturation detection, and result serialization.

The central experiment shape of the interconnect literature: sweep offered
load, record accepted throughput + latency per point, find the knee.

:func:`saturation_sweep` and :func:`compare_policies` are **deprecated
shims** over :mod:`repro.studies` — the declarative experiment API that
replaced the repo's divergent sweep entry points.  They keep their exact
legacy behaviour (the specs they build resolve to the same engine calls)
but warn with :class:`repro.fabric.LacinDeprecationWarning` for one
release; see README's migration table.
"""
from __future__ import annotations

import json
import warnings
from typing import Callable, Sequence

import numpy as np

from repro._compat import LacinDeprecationWarning

from .metrics import RunStats
from .policies import RoutingPolicy
from .topology import SimTopology
from .traffic import Traffic


def _sweep_spec(topo: SimTopology, policy, traffic_factory, loads, seeds, *,
                terminals, cycles, warmup, sim_kw):
    """The :class:`repro.studies.ExperimentSpec` a legacy sweep call
    describes (inline traffic/policy carriers, so any callable works)."""
    from repro.studies import (ExperimentSpec, FabricSpec, RoutingSpec,
                               SweepSpec, TrafficSpec)
    return ExperimentSpec(
        fabric=FabricSpec.from_topology(topo),
        traffic=TrafficSpec.custom(traffic_factory),
        routing=RoutingSpec.custom(policy),
        sweep=SweepSpec(loads=tuple(loads), seeds=tuple(seeds),
                        cycles=cycles, warmup=warmup),
        terminals=terminals, engine=dict(sim_kw))


def saturation_sweep(topo: SimTopology,
                     policy_factory: Callable[[], RoutingPolicy],
                     traffic_factory: Callable[[float], Traffic],
                     loads: Sequence[float], *, terminals: int | None = None,
                     cycles: int | None = None, warmup: int | None = None,
                     seed: int = 0, backend: str = "numpy",
                     **sim_kw) -> list[RunStats]:
    """Deprecated shim: one run per offered load, through a Study.

    Build a :class:`repro.studies.ExperimentSpec` and run it with
    :class:`repro.studies.Study` instead — that adds multi-seed grids,
    JSONL persistence, resume, and spec files, and picks the backend
    automatically.
    """
    warnings.warn(
        "repro.sim.report.saturation_sweep is deprecated; describe the "
        "sweep as a repro.studies.ExperimentSpec and run it with "
        "repro.studies.Study (see README 'Running studies')",
        LacinDeprecationWarning, stacklevel=2)
    from repro.studies import Study
    spec = _sweep_spec(topo, policy_factory, traffic_factory, loads, (seed,),
                       terminals=terminals, cycles=cycles, warmup=warmup,
                       sim_kw=sim_kw)
    out = Study(spec, backend=backend).run()
    return [row[0].stats for row in out.grid()]


def saturation_point(stats: Sequence[RunStats], *, threshold: float = 0.95
                     ) -> float | None:
    """Smallest offered load whose accepted throughput falls below
    ``threshold * offered`` — ``None`` if the sweep never saturates.

    ``threshold`` is the accepted/offered fraction below which a point
    counts as saturated: 0.95 (the interconnect literature's knee
    convention) tolerates up to 5% shortfall as sampling noise on
    uncongested points while flagging the load where queueing starts
    rejecting offered traffic.  Raise it toward 1.0 for long-horizon
    runs with tight confidence intervals; lower it to ignore mild
    congestion.  Points are scanned in increasing offered-load order
    regardless of input order.
    """
    for s in sorted(stats, key=lambda s: s.offered):
        if s.offered > 0 and s.accepted < threshold * s.offered:
            return s.offered
    return None


def to_record(stats: RunStats) -> dict:
    """JSON-serializable summary (histograms/raw loads dropped).

    Collective-replay runs additionally carry ``completion_cycles`` /
    ``ideal_cycles`` / ``phase_cycles`` — the numbers a replay exists to
    measure — and every record keeps ``in_flight_at_end`` (0 on a
    drained run; anything else means undelivered residue).  When the
    run was timed (``stats.timing``) the record includes it verbatim.
    """
    rec = {
        "topology": stats.topology,
        "policy": stats.policy,
        "traffic": stats.traffic,
        "offered": stats.offered,
        "accepted": round(stats.accepted, 6),
        "cycles": stats.cycles,
        "warmup": stats.warmup,
        "num_switches": stats.num_switches,
        "terminals": stats.terminals,
        "packets_generated": stats.packets_generated,
        "packets_delivered": stats.packets_delivered,
        "latency_mean": round(stats.latency_mean, 3),
        "latency_p50": stats.latency_p50,
        "latency_p99": stats.latency_p99,
        "latency_max": stats.latency_max,
        "link_util_max": round(stats.link_util_max, 4),
        "link_util_mean": round(stats.link_util_mean, 4),
        "link_util_cv": round(stats.link_util_cv, 4),
        "in_flight_at_end": stats.in_flight_at_end,
        "saturated": stats.saturated,
    }
    if stats.completion_cycles is not None:
        rec["completion_cycles"] = stats.completion_cycles
    if stats.ideal_cycles is not None:
        rec["ideal_cycles"] = stats.ideal_cycles
    if stats.phase_cycles is not None:
        rec["phase_cycles"] = [int(x) for x in stats.phase_cycles]
    if stats.request_count is not None:
        rec["request_count"] = stats.request_count
        for f in ("request_latency_p50", "request_latency_p95",
                  "request_latency_p99", "slo_target", "slo_attainment"):
            v = getattr(stats, f)
            if v is not None:
                rec[f] = v
    if stats.timing is not None:
        rec["timing"] = dict(stats.timing)
    return rec


def save_json(stats: Sequence[RunStats], path: str, *, extra: dict | None = None
              ) -> None:
    payload = {"records": [to_record(s) for s in stats]}
    if extra:
        payload.update(extra)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")


def format_table(stats: Sequence[RunStats]) -> str:
    """Fixed-width text table of a sweep (for examples / benchmarks)."""
    hdr = (f"{'policy':<10} {'traffic':<14} {'offered':>8} {'accepted':>9} "
           f"{'lat_mean':>9} {'lat_p99':>8} {'max_util':>9} {'sat':>4}")
    lines = [hdr, "-" * len(hdr)]
    for s in stats:
        lines.append(
            f"{s.policy:<10} {s.traffic:<14} {s.offered:>8.3f} "
            f"{s.accepted:>9.3f} {s.latency_mean:>9.1f} {s.latency_p99:>8.0f} "
            f"{s.link_util_max:>9.3f} {'Y' if s.saturated else '-':>4}")
    return "\n".join(lines)


def compare_policies(topo: SimTopology, policies: Sequence[str],
                     traffic_factory: Callable[[float], Traffic],
                     loads: Sequence[float], *, terminals: int | None = None,
                     cycles: int | None = None, warmup: int | None = None,
                     seed: int = 0, backend: str = "numpy",
                     **sim_kw) -> dict[str, list[RunStats]]:
    """Deprecated shim: several named policies as one multi-experiment
    :class:`repro.studies.Study` over the same traffic factory."""
    warnings.warn(
        "repro.sim.report.compare_policies is deprecated; build one "
        "repro.studies.ExperimentSpec per policy and run them as a single "
        "repro.studies.Study (see README 'Running studies')",
        LacinDeprecationWarning, stacklevel=2)
    from repro.studies import Study
    specs = [_sweep_spec(topo, name, traffic_factory, loads, (seed,),
                         terminals=terminals, cycles=cycles, warmup=warmup,
                         sim_kw=sim_kw)
             for name in policies]
    out = Study(specs, backend=backend).run()
    return {name: [row[0].stats for row in out.grid(spec.name)]
            for name, spec in zip(policies, specs)}
