"""Offered-load sweeps, saturation detection, and result serialization.

The central experiment shape of the interconnect literature: sweep offered
load, record accepted throughput + latency per point, find the knee.
"""
from __future__ import annotations

import json
from typing import Callable, Sequence

import numpy as np

from .engine import simulate
from .metrics import RunStats
from .policies import RoutingPolicy, make_policy
from .topology import SimTopology
from .traffic import Traffic


def saturation_sweep(topo: SimTopology,
                     policy_factory: Callable[[], RoutingPolicy],
                     traffic_factory: Callable[[float], Traffic],
                     loads: Sequence[float], *, terminals: int = 1,
                     cycles: int | None = None, warmup: int | None = None,
                     seed: int = 0, backend: str = "numpy",
                     **sim_kw) -> list[RunStats]:
    """One run per offered load; a fresh policy and traffic object each.

    ``backend="jax"`` compiles the whole sweep into one batched program
    (:func:`repro.sim.xengine.sweep`) instead of looping runs in Python;
    pass ``cycles=`` explicitly in that case so every point shares one
    horizon.  For multi-seed grids use :func:`repro.sim.xengine.sweep`
    (or ``Fabric.sim_sweep``) directly.
    """
    if backend == "jax":
        from .xengine import sweep as xsweep
        grid = xsweep(topo, policy_factory, traffic_factory, loads,
                      seeds=(seed,), terminals=terminals, cycles=cycles,
                      warmup=warmup, **sim_kw)
        return [per_load[0] for per_load in grid]
    out = []
    for load in loads:
        traffic = traffic_factory(load)
        n_cycles = cycles if cycles is not None else traffic.horizon
        wu = warmup if warmup is not None else n_cycles // 4
        out.append(simulate(topo, policy_factory(), traffic,
                            terminals=terminals, cycles=n_cycles, warmup=wu,
                            seed=seed, backend=backend, **sim_kw))
    return out


def saturation_point(stats: Sequence[RunStats], *, threshold: float = 0.95
                     ) -> float | None:
    """Smallest offered load whose accepted throughput falls below
    ``threshold * offered`` — ``None`` if the sweep never saturates."""
    for s in sorted(stats, key=lambda s: s.offered):
        if s.offered > 0 and s.accepted < threshold * s.offered:
            return s.offered
    return None


def to_record(stats: RunStats) -> dict:
    """JSON-serializable summary (histograms/raw loads dropped)."""
    return {
        "topology": stats.topology,
        "policy": stats.policy,
        "traffic": stats.traffic,
        "offered": stats.offered,
        "accepted": round(stats.accepted, 6),
        "cycles": stats.cycles,
        "warmup": stats.warmup,
        "num_switches": stats.num_switches,
        "terminals": stats.terminals,
        "packets_generated": stats.packets_generated,
        "packets_delivered": stats.packets_delivered,
        "latency_mean": round(stats.latency_mean, 3),
        "latency_p50": stats.latency_p50,
        "latency_p99": stats.latency_p99,
        "latency_max": stats.latency_max,
        "link_util_max": round(stats.link_util_max, 4),
        "link_util_mean": round(stats.link_util_mean, 4),
        "link_util_cv": round(stats.link_util_cv, 4),
        "saturated": stats.saturated,
    }


def save_json(stats: Sequence[RunStats], path: str, *, extra: dict | None = None
              ) -> None:
    payload = {"records": [to_record(s) for s in stats]}
    if extra:
        payload.update(extra)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")


def format_table(stats: Sequence[RunStats]) -> str:
    """Fixed-width text table of a sweep (for examples / benchmarks)."""
    hdr = (f"{'policy':<10} {'traffic':<14} {'offered':>8} {'accepted':>9} "
           f"{'lat_mean':>9} {'lat_p99':>8} {'max_util':>9} {'sat':>4}")
    lines = [hdr, "-" * len(hdr)]
    for s in stats:
        lines.append(
            f"{s.policy:<10} {s.traffic:<14} {s.offered:>8.3f} "
            f"{s.accepted:>9.3f} {s.latency_mean:>9.1f} {s.latency_p99:>8.0f} "
            f"{s.link_util_max:>9.3f} {'Y' if s.saturated else '-':>4}")
    return "\n".join(lines)


def compare_policies(topo: SimTopology, policies: Sequence[str],
                     traffic_factory: Callable[[float], Traffic],
                     loads: Sequence[float], **kw) -> dict[str, list[RunStats]]:
    """Sweep several named policies over the same traffic factory."""
    return {name: saturation_sweep(topo, lambda n=name: make_policy(n),
                                   traffic_factory, loads, **kw)
            for name in policies}
