"""Metrics collection: latency distributions, link loads, throughput.

A :class:`RunStats` summarizes one simulator run.  Latency is measured
from *generation* (not injection), so source-queue backlog — the signature
of saturation — shows up in the tail; accepted throughput is the delivery
rate inside the measurement window, normalized per terminal per cycle so
it is directly comparable to the offered load.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

HIST_MAX_LATENCY = 4096     # histogram clip; percentiles use exact values


@dataclass
class RunStats:
    topology: str
    policy: str
    traffic: str
    offered: float
    cycles: int
    warmup: int
    num_switches: int
    terminals: int
    packets_generated: int
    packets_delivered: int
    delivered_in_window: int
    accepted: float             # packets / terminal / cycle in the window
    latency_mean: float
    latency_p50: float
    latency_p99: float
    latency_max: int
    latency_histogram: np.ndarray = field(repr=False)
    link_loads: np.ndarray = field(repr=False)          # lifetime totals (N*P)
    link_util_max: float = 0.0
    link_util_mean: float = 0.0
    link_util_cv: float = 0.0
    in_flight_at_end: int = 0

    @property
    def delivery_fraction(self) -> float:
        return self.packets_delivered / max(self.packets_generated, 1)

    @property
    def saturated(self) -> bool:
        """Accepted rate visibly below offered: the sweep's knee test."""
        return self.offered > 0 and self.accepted < 0.95 * self.offered


def latency_summary(lat: np.ndarray) -> dict:
    if lat.size == 0:
        return {"mean": 0.0, "p50": 0.0, "p99": 0.0, "max": 0,
                "histogram": np.zeros(1, dtype=np.int64)}
    hist = np.bincount(np.minimum(lat, HIST_MAX_LATENCY))
    p50, p99 = np.percentile(lat, [50, 99])
    return {
        "mean": float(lat.mean()),
        "p50": float(p50),
        "p99": float(p99),
        "max": int(lat.max()),
        "histogram": hist,
    }


def build_stats(*, topology, policy, traffic, cycles, warmup, terminals,
                gen, deliver, link_counter, delivered_in_window,
                in_flight) -> RunStats:
    n = topology.num_switches
    meas_cycles = max(cycles - warmup, 1)
    delivered = deliver >= 0
    measured = delivered & (gen >= warmup)
    if not measured.any():
        # Deep saturation: nothing generated after warmup ever delivered;
        # fall back to every delivered packet so latency stays meaningful.
        measured = delivered
    lat = (deliver[measured] - gen[measured] + 1).astype(np.int64)
    ls = latency_summary(lat)
    util = link_counter.utilization(meas_cycles)
    accepted = delivered_in_window / (n * terminals * meas_cycles)
    return RunStats(
        topology=topology.name, policy=policy.name, traffic=traffic.name,
        offered=traffic.offered, cycles=cycles, warmup=warmup,
        num_switches=n, terminals=terminals,
        packets_generated=int(gen.size),
        packets_delivered=int(delivered.sum()),
        delivered_in_window=int(delivered_in_window),
        accepted=float(accepted),
        latency_mean=ls["mean"], latency_p50=ls["p50"], latency_p99=ls["p99"],
        latency_max=ls["max"], latency_histogram=ls["histogram"],
        link_loads=link_counter.total.copy(),
        link_util_max=util["max"], link_util_mean=util["mean"],
        link_util_cv=util["cv"],
        in_flight_at_end=int(in_flight),
    )
