"""Metrics collection: latency distributions, link loads, throughput.

A :class:`RunStats` summarizes one simulator run.  Latency is measured
from *generation* (not injection), so source-queue backlog — the signature
of saturation — shows up in the tail; accepted throughput is the delivery
rate inside the measurement window, normalized per terminal per cycle so
it is directly comparable to the offered load.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

HIST_MAX_LATENCY = 4096     # histogram clip; percentiles use exact values


@dataclass
class RunStats:
    topology: str
    policy: str
    traffic: str
    offered: float
    cycles: int
    warmup: int
    num_switches: int
    terminals: int
    packets_generated: int
    packets_delivered: int
    delivered_in_window: int
    accepted: float             # packets / terminal / cycle in the window
    latency_mean: float
    latency_p50: float
    latency_p99: float
    latency_max: int
    latency_histogram: np.ndarray = field(repr=False)
    link_loads: np.ndarray = field(repr=False)          # lifetime totals (N*P)
    link_util_max: float = 0.0
    link_util_mean: float = 0.0
    link_util_cv: float = 0.0
    in_flight_at_end: int = 0
    # -- collective-replay fields (repro.sim.workloads); None elsewhere -----
    #: Per-phase durations in cycles (barrier-to-barrier).
    phase_cycles: tuple | None = None
    #: Cycle at which the workload's last packet delivered.
    completion_cycles: int | None = None
    #: The schedule algebra's contention-free lower bound
    #: (:attr:`repro.sim.workloads.Workload.ideal_cycles`).
    ideal_cycles: int | None = None
    # -- serving fields (repro.workload); None for non-serving traffic ------
    #: Requests whose packets were all generated inside the run.
    request_count: int | None = None
    #: Per-request latency percentiles in cycles (a request's latency is
    #: the delivery cycle of its *last* packet minus its arrival cycle,
    #: +1), over completed requests.
    request_latency_p50: float | None = None
    request_latency_p95: float | None = None
    request_latency_p99: float | None = None
    #: The SLO target (cycles) the traffic carried, if any.
    slo_target: float | None = None
    #: Fraction of requests that completed within ``slo_target`` cycles;
    #: a request that never completed counts as a miss.
    slo_attainment: float | None = None
    # -- observability (repro.obs); excluded from equality: two runs with
    # identical dynamics are the same run regardless of wall clock -----------
    #: Wall-clock/compile-vs-execute record
    #: (:func:`repro.obs.telemetry.timing_dict`); a batched sweep shares
    #: one dict across its grid points.
    timing: dict | None = field(default=None, compare=False)
    #: Sampled time series (:class:`repro.obs.trace.Trace`) when the run
    #: was traced; ``None`` otherwise.
    trace: object | None = field(default=None, repr=False, compare=False)

    @property
    def delivery_fraction(self) -> float:
        return self.packets_delivered / max(self.packets_generated, 1)

    @property
    def saturated(self) -> bool:
        """Accepted rate visibly below offered: the sweep's knee test."""
        return self.offered > 0 and self.accepted < 0.95 * self.offered


def latency_summary(lat: np.ndarray) -> dict:
    if lat.size == 0:
        return {"mean": 0.0, "p50": 0.0, "p99": 0.0, "max": 0,
                "histogram": np.zeros(1, dtype=np.int64)}
    hist = np.bincount(np.minimum(lat, HIST_MAX_LATENCY))
    p50, p99 = np.percentile(lat, [50, 99])
    return {
        "mean": float(lat.mean()),
        "p50": float(p50),
        "p99": float(p99),
        "max": int(lat.max()),
        "histogram": hist,
    }


def replay_timeline(phase_done, gen) -> tuple[int, np.ndarray]:
    """The replay measurement frame for :func:`build_stats`:
    ``(completion horizon, per-packet release cycles)``.

    A replay's packets are "generated" the cycle their phase barrier
    opens (phase ``k`` releases when phase ``k-1`` completes), so
    latency = deliver − release measures in-phase queueing + flight, and
    the run's measurement horizon is the completion cycle — not the
    phase count ``gen`` (a phase *ordinal*) would suggest.
    """
    done = np.asarray(phase_done, dtype=np.int64)
    completion = int(done[-1]) if done.size else 0
    release = (np.concatenate([[0], done[:-1]]) if done.size
               else np.zeros(1, dtype=np.int64))
    gen = np.asarray(gen, dtype=np.int64)
    return max(completion, 1), (release[gen] if gen.size else gen)


def attach_replay(stats: RunStats, workload, phase_done) -> RunStats:
    """Fill the collective-replay fields from the engine's per-phase
    completion record (``phase_done[k]`` = the cycle phase ``k``'s last
    packet delivered)."""
    done = np.asarray(phase_done, dtype=np.int64)
    starts = np.concatenate([[0], done[:-1]]) if done.size else done
    stats.phase_cycles = tuple(int(d - s) for s, d in zip(starts, done))
    stats.completion_cycles = int(done[-1]) if done.size else 0
    stats.ideal_cycles = int(workload.ideal_cycles)
    return stats


def request_latency_summary(request, gen, deliver) -> dict:
    """Per-request latency facts for serving traffic.

    ``request`` groups packets into requests; a request's arrival is the
    min ``gen`` over its packets and it completes the cycle its *last*
    packet delivers.  Returns request count, completed count, and the
    (count,) arrays of per-request arrival cycles and latencies (−1 for
    a request with an undelivered packet).
    """
    request = np.asarray(request, dtype=np.int64)
    if request.size == 0:
        return {"count": 0, "completed": 0,
                "arrival": np.zeros(0, np.int64),
                "latency": np.zeros(0, np.int64)}
    # Compact ids so min/max reductions index densely.
    uniq, dense = np.unique(request, return_inverse=True)
    count = uniq.size
    arrival = np.full(count, np.iinfo(np.int64).max, dtype=np.int64)
    np.minimum.at(arrival, dense, np.asarray(gen, dtype=np.int64))
    deliver = np.asarray(deliver, dtype=np.int64)
    last = np.full(count, -1, dtype=np.int64)
    np.maximum.at(last, dense, deliver)
    complete = np.ones(count, dtype=bool)
    # Any undelivered packet (deliver == -1) leaves its request open.
    np.logical_and.at(complete, dense, deliver >= 0)
    latency = np.where(complete, last - arrival + 1, -1)
    return {"count": count, "completed": int(complete.sum()),
            "arrival": arrival, "latency": latency}


def attach_serving(stats: RunStats, request, gen, deliver, *,
                   slo: float | None = None) -> RunStats:
    """Fill the serving fields from per-packet request ids + deliveries.

    Percentiles are over *completed* requests; SLO attainment counts an
    incomplete request (a packet still queued when the run stopped) as a
    miss, so a non-drained saturated run reports honestly low
    attainment rather than a survivor-biased tail.
    """
    rs = request_latency_summary(request, gen, deliver)
    stats.request_count = rs["count"]
    lat = rs["latency"][rs["latency"] >= 0]
    if lat.size:
        p50, p95, p99 = np.percentile(lat, [50, 95, 99])
        stats.request_latency_p50 = round(float(p50), 3)
        stats.request_latency_p95 = round(float(p95), 3)
        stats.request_latency_p99 = round(float(p99), 3)
    stats.slo_target = float(slo) if slo is not None else None
    if slo is not None and rs["count"]:
        met = int((lat <= float(slo)).sum())
        stats.slo_attainment = round(met / rs["count"], 4)
    return stats


def build_stats(*, topology, policy, traffic, cycles, warmup, terminals,
                gen, deliver, link_counter, delivered_in_window,
                in_flight) -> RunStats:
    n = topology.num_switches
    meas_cycles = max(cycles - warmup, 1)
    delivered = deliver >= 0
    measured = delivered & (gen >= warmup)
    if not measured.any():
        # Deep saturation: nothing generated after warmup ever delivered;
        # fall back to every delivered packet so latency stays meaningful.
        measured = delivered
    lat = (deliver[measured] - gen[measured] + 1).astype(np.int64)
    ls = latency_summary(lat)
    util = link_counter.utilization(meas_cycles)
    accepted = delivered_in_window / (n * terminals * meas_cycles)
    return RunStats(
        topology=topology.name, policy=policy.name, traffic=traffic.name,
        offered=traffic.offered, cycles=cycles, warmup=warmup,
        num_switches=n, terminals=terminals,
        packets_generated=int(gen.size),
        packets_delivered=int(delivered.sum()),
        delivered_in_window=int(delivered_in_window),
        accepted=float(accepted),
        latency_mean=ls["mean"], latency_p50=ls["p50"], latency_p99=ls["p99"],
        latency_max=ls["max"], latency_histogram=ls["histogram"],
        link_loads=link_counter.total.copy(),
        link_util_max=util["max"], link_util_mean=util["mean"],
        link_util_cv=util["cv"],
        in_flight_at_end=int(in_flight),
    )
