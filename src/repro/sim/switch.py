"""Input-queued switch model: vectorized VC queues + arbitration primitives.

Every (switch, input-port, VC) triple owns one fixed-capacity FIFO.  All
queues across the whole fabric live in three flat numpy arrays (a ring
buffer of packet ids plus head/occupancy counters), so a cycle's worth of
head-gathers, pushes, and pops are single fancy-indexing operations over
*all* switches at once — no per-packet or per-switch Python objects.

Credit flow control falls out of the occupancy array: a hop is feasible
iff the downstream queue's occupancy is below capacity (occupancy *is*
the credit count the upstream switch would track).
"""
from __future__ import annotations

import numpy as np


class QueueFabric:
    """``num_queues`` ring-buffer FIFOs of ``capacity`` packet ids each."""

    def __init__(self, num_queues: int, capacity: int):
        if capacity < 1:
            raise ValueError("queue capacity must be >= 1")
        self.num_queues = num_queues
        self.capacity = capacity
        self.buf = np.full((num_queues, capacity), -1, dtype=np.int64)
        self.head = np.zeros(num_queues, dtype=np.int64)
        self.occ = np.zeros(num_queues, dtype=np.int64)

    # -- vectorized FIFO ops -------------------------------------------------
    def active(self) -> np.ndarray:
        """Queue indices currently holding at least one packet."""
        return np.nonzero(self.occ > 0)[0]

    def heads(self, queues: np.ndarray) -> np.ndarray:
        """Head packet id of each (non-empty) queue in ``queues``."""
        return self.buf[queues, self.head[queues] % self.capacity]

    def pop(self, queues: np.ndarray) -> None:
        """Remove the head packet of each queue (queues must be unique)."""
        self.head[queues] += 1
        self.occ[queues] -= 1

    def push(self, queues: np.ndarray, pids: np.ndarray) -> None:
        """Append packets (queues must be unique and have free space)."""
        slot = (self.head[queues] + self.occ[queues]) % self.capacity
        self.buf[queues, slot] = pids
        self.occ[queues] += 1

    def has_space(self, queues: np.ndarray) -> np.ndarray:
        return self.occ[queues] < self.capacity

    @property
    def total_occupancy(self) -> int:
        return int(self.occ.sum())


def arbitrate(group: np.ndarray, *minor_keys: np.ndarray, k: int = 1
              ) -> np.ndarray:
    """Indices of up to ``k`` winners per group value.

    Requests are grouped by ``group`` (e.g. the contended output link); ties
    within a group break by the ``minor_keys`` in order of significance
    (first key most significant).  Returns positions into the request
    arrays, winners of all groups concatenated.
    """
    if group.size == 0:
        return np.empty(0, dtype=np.int64)
    order = np.lexsort(tuple(reversed(minor_keys)) + (group,))
    g = group[order]
    first = np.searchsorted(g, g, side="left")   # index of each group's start
    rank = np.arange(g.size) - first
    return order[rank < k]
