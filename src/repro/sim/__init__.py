"""``repro.sim`` — cycle-driven, packet-level simulator for LACIN fabrics.

Quantifies what the closed-form flow counting in
:mod:`repro.core.simulate` cannot: queueing, credit backpressure, virtual
channels, and the latency/throughput behaviour of minimal vs. Valiant vs.
adaptive routing under load, on CIN, HyperX, and Dragonfly compositions
built from the existing ``port_matrix`` / ``HyperXConfig`` /
``DragonflyConfig`` objects.

Quickstart::

    from repro import sim
    topo = sim.cin_topology("xor", 16)
    tr = sim.uniform(16, offered=0.6, cycles=1000, terminals=4)
    stats = sim.simulate(topo, sim.MinimalPolicy(), tr, warmup=250)
    print(stats.accepted, stats.latency_p99)

(``simulate`` defaults its ``terminals`` to the traffic object's record
and raises on an explicit mismatch.)  For experiment *grids* — loads x
seeds x policies, persisted and resumable — describe a
:class:`repro.studies.ExperimentSpec` and run it with
:class:`repro.studies.Study`; the sweep helpers here
(``saturation_sweep``/``compare_policies``) are deprecated shims over
that API.

Beyond open-loop synthetic traffic, :mod:`repro.sim.workloads` replays
the repo's *own* LACIN collective schedules — phase-barriered closed
workloads — through either engine, measuring completion against the
schedule algebra's contention-free bound::

    stats = fabric.make_fabric("xor", 16).replay("all_to_all")
    assert stats.completion_cycles == stats.ideal_cycles
"""
from .topology import (SimTopology, cin_topology, dragonfly_topology,
                       hyperx_topology, routed_link_loads)
from .switch import QueueFabric, arbitrate
from .link import LinkLoadCounter, LinkTable
from .policies import (AdaptivePolicy, MinimalPolicy, RoutingPolicy,
                       ValiantPolicy, make_policy)
from .traffic import (Traffic, adversarial_same_group, hotspot,
                      one_shot_all_to_all, one_shot_permutation, permutation,
                      uniform)
from .engine import Engine, simulate
from .metrics import RunStats, latency_summary
from .report import (compare_policies, format_table, saturation_point,
                     saturation_sweep, save_json, to_record)
from .workloads import Phase, Workload, collective_workload, replay
from . import xengine
from .xengine import simulate_jax, sweep as sim_sweep
