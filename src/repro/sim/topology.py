"""Switch-level topology adapters for the packet simulator.

A :class:`SimTopology` is the flattened, numpy-friendly view the engine
consumes: a ``(N, P)`` neighbour matrix (``-1`` = unwired port), the
far-end port index of every link (identical for isoport LACINs — the
paper's cabling discipline — and the registered ``peer_port`` rule for
anisoport instances like Swap), and a *vectorized* minimal-routing
function built from the table-free routing of :mod:`repro.core.routing`.
Instance names resolve through the :mod:`repro.fabric` registry, so
adapters work for any registered instance.

The adapters consume the existing construction objects unchanged:

* :func:`cin_topology`       — a single CIN from its P-matrix;
* :func:`hyperx_topology`    — a :class:`repro.core.hyperx.HyperXConfig`
  (per-dimension LACINs + dimension-order routing);
* :func:`dragonfly_topology` — a :class:`repro.core.dragonfly.DragonflyConfig`
  (local CIN + colour-owned global ports, minimal l-g-l routing).

:func:`routed_link_loads` walks the minimal route of every ordered
switch pair on any of these — the ground truth the closed forms in
:mod:`repro.core.simulate` are cross-checked against.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro.core.dragonfly import DragonflyConfig
from repro.core.hyperx import HyperXConfig
from repro.core.port_matrix import IDLE
from repro.core.routing import route
from repro.fabric.registry import get_instance


@dataclass
class SimTopology:
    """Flattened switch graph + vectorized minimal next-port function.

    ``minimal_port(cur, tgt)`` takes equal-length integer arrays with
    ``cur[i] != tgt[i]`` and returns the output-port index at ``cur[i]``
    on the minimal route towards ``tgt[i]``.
    """
    name: str
    num_switches: int
    num_ports: int
    neighbor: np.ndarray                  # (N, P) int64, IDLE = -1
    rev_port: np.ndarray                  # (N, P) int64, arrival port at far end
    minimal_port: Callable[[np.ndarray, np.ndarray], np.ndarray]
    diameter: int = 1
    meta: dict = field(default_factory=dict)

    @property
    def num_links(self) -> int:
        """Directed wired (switch, port) pairs / 2 = undirected links."""
        return int(np.sum(self.neighbor >= 0)) // 2

    def minimal_port_table(self) -> np.ndarray:
        """Dense ``(N, N)`` next-hop table: entry ``[cur, tgt]`` is the
        output port ``minimal_port`` picks at ``cur`` towards ``tgt``.

        The compiled engine (:mod:`repro.sim.xengine`) consumes routing as
        a gather, so the table-free route is evaluated once here for every
        ordered pair and cached on the topology.  The diagonal is unused
        (a packet at its target ejects) and filled with 0.
        """
        tbl = self.__dict__.get("_minimal_port_table")
        if tbl is None:
            n = self.num_switches
            cur = np.repeat(np.arange(n), n)
            tgt = np.tile(np.arange(n), n)
            off = cur != tgt
            flat = np.zeros(n * n, dtype=np.int64)
            flat[off] = np.asarray(self.minimal_port(cur[off], tgt[off]),
                                   dtype=np.int64)
            tbl = flat.reshape(n, n)
            self.__dict__["_minimal_port_table"] = tbl
        return tbl

    def degrade(self, failures) -> "SimTopology":
        """Degraded copy of this topology under a
        :class:`repro.faults.FailureSpec` (or its dict form): dead slots
        masked to ``-1``, ``minimal_port`` swapped for the fallback
        next-hop table over the surviving graph, ``diameter`` re-derived.
        A null spec (or ``None``) returns ``self`` unchanged.  See
        :func:`repro.faults.degrade`."""
        from repro.faults import degrade as _degrade
        return _degrade(self, failures)

    def validate(self) -> None:
        """Cheap structural sanity: links pair up (A's port i reaches B,
        and B's ``rev_port`` points back at A through the same wire)."""
        n, p = self.neighbor.shape
        s = np.repeat(np.arange(n), p)
        i = np.tile(np.arange(p), n)
        t = self.neighbor.reshape(-1)
        j = self.rev_port.reshape(-1)
        wired = t >= 0
        back = self.neighbor[t[wired], j[wired]]
        if not np.array_equal(back, s[wired]):
            raise ValueError(f"{self.name}: rev_port is not the link inverse")


# ---------------------------------------------------------------------------
# Single CIN.
# ---------------------------------------------------------------------------

def cin_topology(instance: str, n: int) -> SimTopology:
    """A CIN of ``n`` switches from its registered port-pairing rule."""
    spec = get_instance(instance)
    P = spec.matrix(n)
    ports = P.shape[1]
    # Isoport instances pair same-index ports (paper §2); anisoport ones
    # supply their peer_port rule via the registry.
    rev = spec.peer_matrix(n)

    def minimal_port(cur, tgt):
        return np.asarray(spec.route(cur, tgt, n), dtype=np.int64)

    topo = SimTopology(name=f"cin-{instance}-{n}", num_switches=n,
                       num_ports=ports, neighbor=P.astype(np.int64),
                       rev_port=rev, minimal_port=minimal_port, diameter=1,
                       meta={"instance": instance, "n": n})
    topo.validate()
    return topo


# ---------------------------------------------------------------------------
# HyperX: Cartesian product of CINs, dimension-order routing.
# ---------------------------------------------------------------------------

def hyperx_topology(cfg: HyperXConfig) -> SimTopology:
    """Network-port graph of a HyperX (terminals are modeled by the engine's
    injection/ejection bandwidth, not as graph ports)."""
    n = cfg.num_switches
    dims = cfg.dims
    coords = np.array([cfg.switch_coord(s) for s in range(n)], dtype=np.int64)
    index_of = {tuple(c): s for s, c in enumerate(coords.tolist())}

    spec = get_instance(cfg.instance)
    mats = [spec.matrix(k) for k in dims]
    peers = [spec.peer_matrix(k) for k in dims]
    cols = [m.shape[1] for m in mats]          # k-1, or k for odd-k Circle
    bases = np.concatenate([[0], np.cumsum(cols)[:-1]]).astype(np.int64)
    ports = int(sum(cols))

    neighbor = np.full((n, ports), -1, dtype=np.int64)
    rev = np.full((n, ports), -1, dtype=np.int64)
    for s in range(n):
        c = coords[s]
        for d, m in enumerate(mats):
            for i in range(cols[d]):
                digit = int(m[c[d], i])
                if digit == IDLE:
                    continue
                nc = c.copy()
                nc[d] = digit
                neighbor[s, bases[d] + i] = index_of[tuple(nc.tolist())]
                rev[s, bases[d] + i] = bases[d] + int(peers[d][c[d], i])

    def minimal_port(cur, tgt):
        cc = coords[cur]
        tc = coords[tgt]
        diff = cc != tc
        d = np.argmax(diff, axis=1)            # first differing dim = DOR order
        out = np.empty(len(cc), dtype=np.int64)
        for dd in range(len(dims)):
            m = d == dd
            if not m.any():
                continue
            out[m] = bases[dd] + np.asarray(
                route(cfg.instance, cc[m, dd], tc[m, dd], dims[dd]))
        return out

    topo = SimTopology(name=f"hyperx-{'x'.join(map(str, dims))}-{cfg.instance}",
                       num_switches=n, num_ports=ports, neighbor=neighbor,
                       rev_port=rev, minimal_port=minimal_port,
                       diameter=cfg.num_dims, meta={"config": cfg})
    topo.validate()
    return topo


# ---------------------------------------------------------------------------
# Dragonfly: local CIN per group + colour-owned global ports.
# ---------------------------------------------------------------------------

def dragonfly_topology(cfg: DragonflyConfig) -> SimTopology:
    """Switch graph of a Dragonfly; switch index = group * a + local index.

    Local ports come first (the local CIN's columns), then the ``h`` global
    ports.  Global colour ``c`` (the global CIN's port index) lives on
    switch ``c // h``, slot ``c % h`` in every group — an isoport global
    instance gives the same colour at both ends, so the far-end switch and
    slot coincide (§5's cabling discipline).
    """
    a, h, g = cfg.group_size, cfg.global_ports_per_switch, cfg.num_groups
    n = a * g
    lspec = get_instance(cfg.local_instance)
    Pl = lspec.matrix(a)
    Pl_rev = lspec.peer_matrix(a)
    Pg = get_instance(cfg.global_instance).matrix(g)
    la = Pl.shape[1]
    ports = la + h

    # Colour -> (owner switch, slot) assignment.  An odd-g construction
    # has g columns with one idle colour per group, so the g-1 *used*
    # colours are compacted around it — otherwise the top colour
    # (reachable when num_groups == a*h + 1) would land on switch a*h//h
    # == a, past the group.  The idle column is instance-specific
    # (Circle: grp; mirror: -grp mod g), so it is read off the P matrix.
    # Even/anisoport instances use colours 0..g-2 directly (identity).
    from repro.core.dragonfly import _idle_columns
    idle_cols = _idle_columns(cfg.global_instance, g)

    def colour_owner(grp, colour):
        eff = colour - (colour > idle_cols[grp]) if idle_cols else colour
        return eff // h, eff % h

    def slot_colour(grp, s, j):
        """Inverse of colour_owner for (switch s, slot j) in group grp."""
        k = s * h + j
        if idle_cols:
            k = k + (k >= idle_cols[grp])
        return k

    neighbor = np.full((n, ports), -1, dtype=np.int64)
    rev = np.full((n, ports), -1, dtype=np.int64)
    for grp in range(g):
        for s in range(a):
            sw = grp * a + s
            for i in range(la):
                t = int(Pl[s, i])
                if t == IDLE:
                    continue
                neighbor[sw, i] = grp * a + t
                rev[sw, i] = int(Pl_rev[s, i])
            for slot in range(h):
                colour = slot_colour(grp, s, slot)
                if colour >= Pg.shape[1]:
                    continue                    # spare global port
                peer = int(Pg[grp, colour])
                if peer == IDLE:
                    continue
                # Far-end colour: the unique global port of ``peer`` that
                # reaches back to ``grp`` (== colour for isoport instances).
                far = int(route(cfg.global_instance, peer, grp, g))
                far_sw, far_slot = colour_owner(peer, far)
                neighbor[sw, la + slot] = peer * a + far_sw
                rev[sw, la + slot] = la + far_slot

    def minimal_port(cur, tgt):
        cur = np.asarray(cur)
        tgt = np.asarray(tgt)
        gc, sc = cur // a, cur % a
        gd, sd = tgt // a, tgt % a
        out = np.empty(cur.shape, dtype=np.int64)

        same = gc == gd
        if same.any():
            out[same] = np.asarray(
                route(cfg.local_instance, sc[same], sd[same], a))
        diff = ~same
        if diff.any():
            colour = np.asarray(
                route(cfg.global_instance, gc[diff], gd[diff], g))
            if idle_cols:
                colour = colour - (colour > np.asarray(idle_cols)[gc[diff]])
            exit_sw = colour // h
            slot = colour % h
            at_exit = sc[diff] == exit_sw
            sub = np.empty(int(diff.sum()), dtype=np.int64)
            sub[at_exit] = la + slot[at_exit]
            if (~at_exit).any():
                sub[~at_exit] = np.asarray(
                    route(cfg.local_instance, sc[diff][~at_exit],
                          exit_sw[~at_exit], a))
            out[diff] = sub
        return out

    topo = SimTopology(name=f"dragonfly-a{a}h{h}g{g}", num_switches=n,
                       num_ports=ports, neighbor=neighbor, rev_port=rev,
                       minimal_port=minimal_port, diameter=3,
                       meta={"config": cfg})
    topo.validate()
    return topo


# ---------------------------------------------------------------------------
# Ground-truth link loads by walking every minimal route.
# ---------------------------------------------------------------------------

def routed_link_loads(topo: SimTopology) -> dict[tuple[int, int], int]:
    """Directed (src_switch, dst_switch) link loads under uniform switch
    all-to-all, by following ``minimal_port`` hop by hop on the wired
    graph.  This is the routed ground truth the closed forms in
    :mod:`repro.core.simulate` are checked against, link for link.
    """
    n = topo.num_switches
    loads: dict[tuple[int, int], int] = {}
    for src in range(n):
        for dst in range(n):
            if src == dst:
                continue
            cur = src
            for _ in range(topo.diameter):
                port = int(topo.minimal_port(np.array([cur]),
                                             np.array([dst]))[0])
                nxt = int(topo.neighbor[cur, port])
                assert nxt >= 0, (topo.name, cur, dst, port)
                loads[(cur, nxt)] = loads.get((cur, nxt), 0) + 1
                cur = nxt
                if cur == dst:
                    break
            assert cur == dst, (topo.name, src, dst)
    return loads
