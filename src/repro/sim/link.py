"""Link model: flat link ids, downstream-queue arithmetic, load counters.

A directed link is identified by ``link_id = switch * num_ports + port``.
Each physical link feeds exactly one input port at its far end, so the
downstream (switch, input-port, VC) queue of a hop is a pure function of
the link id and the virtual channel — which is what makes the per-cycle
credit check a single gather.

Links have unit bandwidth (one packet per cycle per direction) and unit
latency (a packet popped from the upstream queue at cycle ``c`` is at the
head of the downstream queue no earlier than cycle ``c+1``).
"""
from __future__ import annotations

import numpy as np

from .topology import SimTopology


class LinkTable:
    @classmethod
    def for_topology(cls, topo: SimTopology, num_vcs: int) -> "LinkTable":
        """Memoized constructor: one table per (topology, num_vcs).

        A saturation sweep builds a fresh :class:`~repro.sim.engine.Engine`
        per (load, seed) point over the *same* topology; the table is pure
        read-only topology data, so every point can share one instance
        instead of re-flattening the neighbour matrices each time.
        """
        cache = topo.__dict__.setdefault("_link_tables", {})
        table = cache.get(num_vcs)
        if table is None:
            table = cache[num_vcs] = cls(topo, num_vcs)
        return table

    def __init__(self, topo: SimTopology, num_vcs: int):
        self.topo = topo
        self.num_vcs = num_vcs
        self.num_ports = topo.num_ports
        self.neighbor_flat = topo.neighbor.reshape(-1)      # (N*P,)
        self.rev_flat = topo.rev_port.reshape(-1)           # (N*P,)
        self.wired = self.neighbor_flat >= 0
        self.num_link_slots = self.neighbor_flat.size

    def link_ids(self, switch: np.ndarray, port: np.ndarray) -> np.ndarray:
        return switch * self.num_ports + port

    def dest_queue(self, link_ids: np.ndarray, vc: np.ndarray) -> np.ndarray:
        """Queue index of the far-end (switch, input-port, VC) buffer."""
        nbr = self.neighbor_flat[link_ids]
        rp = self.rev_flat[link_ids]
        return (nbr * self.num_ports + rp) * self.num_vcs + vc

    def endpoints(self, link_ids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """(src_switch, dst_switch) of each directed link id."""
        return link_ids // self.num_ports, self.neighbor_flat[link_ids]


class LinkLoadCounter:
    """Per-directed-link traversal counts: lifetime totals plus a
    measurement window (reset at the end of warmup)."""

    def __init__(self, table: LinkTable):
        self.table = table
        self.total = np.zeros(table.num_link_slots, dtype=np.int64)
        self.window = np.zeros(table.num_link_slots, dtype=np.int64)

    def record(self, link_ids: np.ndarray) -> None:
        # One winner per link per cycle -> ids are unique within a call.
        self.total[link_ids] += 1
        self.window[link_ids] += 1

    def reset_window(self) -> None:
        self.window[:] = 0

    def by_switch_pair(self, counts: np.ndarray | None = None
                       ) -> dict[tuple[int, int], int]:
        """{(src_switch, dst_switch): traversals} over wired links, matching
        the key convention of :func:`repro.core.simulate.cin_link_loads`."""
        counts = self.total if counts is None else counts
        used = np.nonzero((counts > 0) & self.table.wired)[0]
        s, t = self.table.endpoints(used)
        return {(int(a), int(b)): int(c)
                for a, b, c in zip(s, t, counts[used])}

    def utilization(self, cycles: int) -> dict[str, float]:
        """Windowed per-link load summary, normalized to link bandwidth."""
        loads = self.window[self.table.wired] / max(cycles, 1)
        if loads.size == 0:
            return {"max": 0.0, "mean": 0.0, "cv": 0.0}
        mean = float(loads.mean())
        return {
            "max": float(loads.max()),
            "mean": mean,
            "cv": float(loads.std() / mean) if mean > 0 else 0.0,
        }
