"""Collective replay: drive the packet simulator with LACIN schedules.

The paper's central claim is algebraic: isoport wiring makes every
1-factor step of a LACIN schedule contention-free
(:meth:`~repro.core.schedule.LacinSchedule.is_contention_free`), so an
all-to-all completes in exactly ``num_steps`` link-serialization cycles.
This module *measures* that claim: it converts the repo's own schedules
— a flat :class:`~repro.core.schedule.LacinSchedule`, the dimension-order
``all_to_all_grid`` step sequence of a HyperX, or the two-level
``all_reduce_two_level`` sequence of a Dragonfly
(:mod:`repro.fabric.collectives`) — into a :class:`Workload` and replays
it through the cycle-driven engines with queueing, credits, and VCs in
the loop.

A :class:`Workload` is an ordered list of *phases*.  Phase ``k``'s
packets become injection-eligible only once every packet of phases
``< k`` has been **delivered** (ejected at its destination) — the
bulk-synchronous discipline of a stepwise collective, where step ``k+1``
exchanges data that step ``k`` produced.  Both engines implement the
barrier natively (:class:`repro.sim.engine.Engine` gates injection
candidates on the released phase; :mod:`repro.sim.xengine` compiles the
whole replay, barrier included, into one program), and both report the
cycle at which each phase completed.

The headline comparison is measured completion against the schedule
algebra's contention-free lower bound (:attr:`Workload.ideal_cycles` =
``sum of per-phase messages`` = ``num_steps * message_size`` for uniform
messages): a phase that is a matching on its fabric meets the bound
exactly; the Dragonfly global steps — ``group_size`` flows sharing one
global link — exceed it by precisely the serialization the hierarchy
trades for 1/a-sized payloads.

Entry points, lowest to highest level::

    w = Workload.from_schedule(make_schedule("xor", 16))
    w = collective_workload(fabric, "all_to_all", message_size=2)
    stats = replay(topo, "minimal", w)            # RunStats + replay fields
    stats = fabric.replay("all_to_all")           # one-call Fabric surface

and declaratively, ``TrafficSpec("workload", {"collective": ...})`` runs
replays through :mod:`repro.studies` (the bundled ``collective_replay``
spec compares CIN-16 / HyperX-256 / Dragonfly-72).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Mapping, Sequence

import numpy as np

from .metrics import RunStats
from .traffic import Traffic

__all__ = ["Phase", "Workload", "collective_workload", "replay"]


@dataclass(frozen=True)
class Phase:
    """One barrier-delimited step: ``messages`` packets per (src, dst) pair.

    ``src[i] -> dst[i]`` are the step's flows (idle devices simply do not
    appear).  A schedule step that is a matching has each switch at most
    once on each side; the replay machinery does not require that — the
    anisoport ``cyclic`` baseline and hierarchical global steps are plain
    permutations/flows — but every pair must be a real move
    (``src != dst``).
    """
    src: tuple[int, ...]
    dst: tuple[int, ...]
    messages: int = 1

    def __post_init__(self):
        if len(self.src) != len(self.dst):
            raise ValueError(f"phase src/dst length mismatch: "
                             f"{len(self.src)} != {len(self.dst)}")
        if self.messages < 1:
            raise ValueError(f"messages must be >= 1, got {self.messages}")
        if any(a == b for a, b in zip(self.src, self.dst)):
            raise ValueError("a phase pair must move between distinct "
                             "switches (drop idle devices instead)")

    @property
    def num_packets(self) -> int:
        return len(self.src) * self.messages


@dataclass(frozen=True)
class Workload:
    """A phase-structured closed workload over ``num_switches`` switches.

    Replay semantics: all of phase ``k``'s packets inject (at most one
    per terminal per cycle) once phases ``< k`` are fully delivered.
    The packet-level ``gen`` field of the emitted :class:`Traffic`
    stores the phase *ordinal* (the barrier it waits behind), not a
    wall-clock generation cycle.
    """
    name: str
    num_switches: int
    phases: tuple[Phase, ...]

    def __post_init__(self):
        for k, ph in enumerate(self.phases):
            for v in ph.src + ph.dst:
                if not 0 <= v < self.num_switches:
                    raise ValueError(
                        f"{self.name}: phase {k} references switch {v} "
                        f"outside [0, {self.num_switches})")

    @property
    def num_phases(self) -> int:
        return len(self.phases)

    @property
    def num_packets(self) -> int:
        return sum(ph.num_packets for ph in self.phases)

    @property
    def ideal_cycles(self) -> int:
        """Contention-free lower bound on completion, in cycles.

        Each phase needs at least ``messages`` cycles of link time on
        its busiest link (one packet per directed link per cycle), and
        phases are barrier-serialized, so completion cannot beat the sum
        — ``num_steps * message_size`` for uniform messages.  The bound
        is *met with equality* when every phase is contention-free on
        the fabric (one flow per directed link, e.g. 1-factor steps on
        the CIN that defined them, under minimal routing).
        """
        return sum(ph.messages for ph in self.phases)

    # -- engine-facing form -------------------------------------------------
    def traffic(self) -> Traffic:
        """The closed :class:`Traffic` the engines replay.

        ``gen`` holds each packet's phase ordinal (its barrier), which
        also keeps the per-terminal FIFO order phase-monotone;
        ``offered == 0`` marks the workload closed, so engines default
        to drain mode.
        """
        if self.num_phases:
            src = np.concatenate([
                np.repeat(np.asarray(ph.src, dtype=np.int64), ph.messages)
                for ph in self.phases])
            dst = np.concatenate([
                np.repeat(np.asarray(ph.dst, dtype=np.int64), ph.messages)
                for ph in self.phases])
            gen = np.concatenate([
                np.full(ph.num_packets, k, dtype=np.int64)
                for k, ph in enumerate(self.phases)])
        else:
            src = dst = gen = np.zeros(0, dtype=np.int64)
        return Traffic(f"replay-{self.name}", src, dst, gen,
                       offered=0.0, horizon=max(self.num_phases, 1),
                       workload=self)

    def phase_cum(self, num_phases: int | None = None) -> np.ndarray:
        """Cumulative packet counts per phase (padded to ``num_phases``
        by repeating the total — padding phases complete instantly)."""
        counts = np.array([ph.num_packets for ph in self.phases],
                          dtype=np.int64)
        cum = np.cumsum(counts) if counts.size else np.zeros(0, np.int64)
        if num_phases is not None and num_phases > cum.size:
            total = cum[-1] if cum.size else 0
            cum = np.concatenate(
                [cum, np.full(num_phases - cum.size, total, np.int64)])
        return cum

    # -- constructors -------------------------------------------------------
    @classmethod
    def from_schedule(cls, schedule, *, message_size: int = 1,
                      name: str | None = None) -> "Workload":
        """One phase per step of a :class:`~repro.core.schedule.LacinSchedule`
        (idle devices — odd-N Circle — are dropped from their step)."""
        return cls(name or f"{schedule.instance}-{schedule.n}-a2a",
                   schedule.n,
                   tuple(_schedule_phases(schedule, message_size)))

    def to_dict(self) -> dict:
        return {"name": self.name, "num_switches": self.num_switches,
                "phases": [{"src": list(ph.src), "dst": list(ph.dst),
                            "messages": ph.messages}
                           for ph in self.phases]}

    @classmethod
    def from_dict(cls, d: Mapping) -> "Workload":
        phases = tuple(
            Phase(tuple(int(v) for v in ph["src"]),
                  tuple(int(v) for v in ph["dst"]),
                  messages=int(ph.get("messages", 1)))
            for ph in d["phases"])
        return cls(str(d["name"]), int(d["num_switches"]), phases)


# ---------------------------------------------------------------------------
# Builders: the repo's own collective step sequences, per fabric family.
# ---------------------------------------------------------------------------

def _grid_phase_lists(dims: Sequence[int], schedules, coord_of, index_of,
                      message_size: int) -> list[list[Phase]]:
    """Per-dimension phase lists, innermost dimension first (the order
    :func:`repro.fabric.collectives.all_to_all_grid` composes): one
    phase per step of that dimension's schedule, exchanging along that
    dimension only."""
    n = math.prod(dims)
    coords = np.array([coord_of(s) for s in range(n)], dtype=np.int64)
    out = []
    for d in reversed(range(len(dims))):
        sched = schedules[d]
        phases = []
        for step in range(sched.num_steps):
            row = sched.partners(step)
            src, dst = [], []
            for s in range(n):
                digit = int(coords[s, d])
                partner = int(row[digit])
                if partner == digit:
                    continue                       # idle in this step
                nc = coords[s].copy()
                nc[d] = partner
                src.append(s)
                dst.append(index_of(tuple(nc.tolist())))
            phases.append(Phase(tuple(src), tuple(dst),
                                messages=message_size))
        out.append(phases)
    return out


def _grid_phases(dims: Sequence[int], schedules, coord_of, index_of,
                 message_size: int) -> list[Phase]:
    """Flattened dimension-order phases (the grid all-to-all sequence)."""
    return [ph for sub in _grid_phase_lists(dims, schedules, coord_of,
                                            index_of, message_size)
            for ph in sub]


def _cin_all_to_all(fab, message_size: int) -> Workload:
    return Workload.from_schedule(fab.schedule(), message_size=message_size,
                                  name=f"{fab.name}-a2a")


def _hyperx_all_to_all(fab, message_size: int) -> Workload:
    cfg = fab.config
    index_of = {tuple(cfg.switch_coord(s)): s
                for s in range(cfg.num_switches)}
    phases = _grid_phases(cfg.dims, fab.schedule(), cfg.switch_coord,
                          lambda c: index_of[c], message_size)
    return Workload(f"{fab.name}-a2a", cfg.num_switches, tuple(phases))


def _dragonfly_all_to_all(fab, message_size: int) -> Workload:
    """Dragonfly a2a as a (local x global) grid: local matching steps
    first (intra-group), then global steps pairing whole groups — each
    global step routes ``group_size`` flows l-g-l over one global link
    per group pair, the serialization the replay is there to measure."""
    c = fab.config
    a, g = c.group_size, c.num_groups
    sched = fab.schedule()
    phases = _grid_phases(
        (g, a), (sched["global"], sched["local"]),
        lambda s: (s // a, s % a),
        lambda coord: coord[0] * a + coord[1], message_size)
    return Workload(f"{fab.name}-a2a", c.switches, tuple(phases))


def _chain(*phase_lists) -> tuple[Phase, ...]:
    out: list[Phase] = []
    for pl in phase_lists:
        out.extend(pl)
    return tuple(out)


def _schedule_phases(sched, message_size: int, *, repeat: int = 1,
                     to_pairs=None) -> list[Phase]:
    """Phases of one schedule pass, optionally lifted to composite switch
    ids via ``to_pairs(step_row) -> (src, dst)`` lists."""
    phases = []
    for _ in range(repeat):
        for step in range(sched.num_steps):
            row = sched.partners(step)
            if to_pairs is None:
                s = np.arange(sched.n)
                live = row != s
                src = tuple(int(v) for v in s[live])
                dst = tuple(int(v) for v in row[live])
            else:
                src, dst = to_pairs(row)
            phases.append(Phase(src, dst, messages=message_size))
    return phases


def _cin_all_reduce(fab, message_size: int) -> Workload:
    """Flat all-reduce = reduce-scatter chain + all-gather chain: two
    passes over the 1-factor schedule."""
    sched = fab.schedule()
    phases = _schedule_phases(sched, message_size, repeat=2)
    return Workload(f"{fab.name}-allreduce", fab.num_switches, tuple(phases))


def _hyperx_all_reduce(fab, message_size: int) -> Workload:
    """Dimension-wise reduce-scatter (innermost dim first), then the
    all-gather passes in reverse dimension order."""
    cfg = fab.config
    index_of = {tuple(cfg.switch_coord(s)): s
                for s in range(cfg.num_switches)}
    # One phase list per dimension, innermost first (the RS order); the
    # AG passes replay them in reverse.
    per_dim = _grid_phase_lists(cfg.dims, fab.schedule(), cfg.switch_coord,
                                lambda c: index_of[c], message_size)
    phases = _chain(*per_dim, *reversed(per_dim))
    return Workload(f"{fab.name}-allreduce", cfg.num_switches, phases)


def _dragonfly_all_reduce(fab, message_size: int) -> Workload:
    """The :func:`repro.fabric.collectives.all_reduce_two_level` step
    sequence: local reduce-scatter -> global all-reduce of the scattered
    shards -> local all-gather.  Global phases carry
    ``ceil(message_size / group_size)`` messages per pair — the 1/a
    payload shrink the two-level hierarchy buys."""
    c = fab.config
    a, g = c.group_size, c.num_groups
    sched = fab.schedule()
    g_msg = max(1, -(-message_size // a))        # ceil(message_size / a)

    def local_pairs(row):
        src, dst = [], []
        for grp in range(g):
            for s in range(a):
                t = int(row[s])
                if t != s:
                    src.append(grp * a + s)
                    dst.append(grp * a + t)
        return tuple(src), tuple(dst)

    def global_pairs(row):
        src, dst = [], []
        for grp in range(g):
            peer = int(row[grp])
            if peer == grp:
                continue
            for s in range(a):
                src.append(grp * a + s)
                dst.append(peer * a + s)
        return tuple(src), tuple(dst)

    local_rs = _schedule_phases(sched["local"], message_size,
                                to_pairs=local_pairs)
    global_ar = _schedule_phases(sched["global"], g_msg, repeat=2,
                                 to_pairs=global_pairs)
    local_ag = _schedule_phases(sched["local"], message_size,
                                to_pairs=local_pairs)
    return Workload(f"{fab.name}-allreduce", c.switches,
                    _chain(local_rs, global_ar, local_ag))


def _cin_half_reduce(fab, message_size: int, tag: str) -> Workload:
    """One pass over the 1-factor schedule — the reduce-scatter (or,
    identically as a step sequence, the all-gather) half of the flat
    all-reduce."""
    phases = _schedule_phases(fab.schedule(), message_size)
    return Workload(f"{fab.name}-{tag}", fab.num_switches, tuple(phases))


def _hyperx_half_reduce(fab, message_size: int, tag: str,
                        gather: bool) -> Workload:
    """One dimension-order sweep: innermost-first for the reduce-scatter
    half, reversed (outermost-first) for the all-gather half — exactly
    the two halves :func:`_hyperx_all_reduce` chains."""
    cfg = fab.config
    index_of = {tuple(cfg.switch_coord(s)): s
                for s in range(cfg.num_switches)}
    per_dim = _grid_phase_lists(cfg.dims, fab.schedule(), cfg.switch_coord,
                                lambda c: index_of[c], message_size)
    phases = _chain(*(reversed(per_dim) if gather else per_dim))
    return Workload(f"{fab.name}-{tag}", cfg.num_switches, phases)


def _dragonfly_half_reduce(fab, message_size: int, tag: str,
                           gather: bool) -> Workload:
    """Half of the two-level sequence: local RS then one global pass
    (scatter), or one global pass then local AG (gather).  Global phases
    carry the 1/a-shrunk payload, as in :func:`_dragonfly_all_reduce`."""
    c = fab.config
    a, g = c.group_size, c.num_groups
    sched = fab.schedule()
    g_msg = max(1, -(-message_size // a))

    def local_pairs(row):
        src, dst = [], []
        for grp in range(g):
            for s in range(a):
                t = int(row[s])
                if t != s:
                    src.append(grp * a + s)
                    dst.append(grp * a + t)
        return tuple(src), tuple(dst)

    def global_pairs(row):
        src, dst = [], []
        for grp in range(g):
            peer = int(row[grp])
            if peer == grp:
                continue
            for s in range(a):
                src.append(grp * a + s)
                dst.append(peer * a + s)
        return tuple(src), tuple(dst)

    local = _schedule_phases(sched["local"], message_size,
                             to_pairs=local_pairs)
    global_half = _schedule_phases(sched["global"], g_msg,
                                   to_pairs=global_pairs)
    phases = (_chain(global_half, local) if gather
              else _chain(local, global_half))
    return Workload(f"{fab.name}-{tag}", c.switches, phases)


def collective_workload(fabric, collective: str = "all_to_all", *,
                        message_size: int = 1) -> Workload:
    """The replayable step sequence of ``collective`` on ``fabric``.

    * ``"all_to_all"`` — flat 1-factor schedule (CIN), dimension-order
      grid schedule (HyperX), or (local x global) grid (Dragonfly);
    * ``"all_reduce"`` — reduce-scatter + all-gather chains (CIN /
      HyperX per dimension), or the two-level Dragonfly sequence;
    * ``"reduce_scatter"`` / ``"all_gather"`` — the corresponding half
      of the all-reduce sequence (what GSPMD's ZeRO-style sharded DP
      and :func:`repro.runtime.manual_dp.lacin_grad_allreduce` emit as
      separate HLO ops — see :mod:`repro.workload`).

    ``message_size`` is the packets per (src, dst) pair per phase; the
    Dragonfly ``all_reduce``/half-sequence global phases carry
    ``ceil(message_size / group_size)`` (the hierarchical payload
    shrink).
    """
    from repro.fabric import (CINFabric, DragonflyFabric, HyperXFabric,
                              make_fabric)
    fabric = make_fabric(fabric)
    builders = {
        ("all_to_all", CINFabric): _cin_all_to_all,
        ("all_to_all", HyperXFabric): _hyperx_all_to_all,
        ("all_to_all", DragonflyFabric): _dragonfly_all_to_all,
        ("all_reduce", CINFabric): _cin_all_reduce,
        ("all_reduce", HyperXFabric): _hyperx_all_reduce,
        ("all_reduce", DragonflyFabric): _dragonfly_all_reduce,
        ("reduce_scatter", CINFabric):
            lambda f, m: _cin_half_reduce(f, m, "rs"),
        ("reduce_scatter", HyperXFabric):
            lambda f, m: _hyperx_half_reduce(f, m, "rs", gather=False),
        ("reduce_scatter", DragonflyFabric):
            lambda f, m: _dragonfly_half_reduce(f, m, "rs", gather=False),
        ("all_gather", CINFabric):
            lambda f, m: _cin_half_reduce(f, m, "ag"),
        ("all_gather", HyperXFabric):
            lambda f, m: _hyperx_half_reduce(f, m, "ag", gather=True),
        ("all_gather", DragonflyFabric):
            lambda f, m: _dragonfly_half_reduce(f, m, "ag", gather=True),
    }
    builder = builders.get((collective, type(fabric)))
    if builder is None:
        known = sorted({k for k, _ in builders})
        raise ValueError(
            f"no {collective!r} workload builder for "
            f"{type(fabric).__name__}; collectives: {known}")
    return builder(fabric, message_size)


# ---------------------------------------------------------------------------
# Replay entry point.
# ---------------------------------------------------------------------------

def replay(topo, policy, workload: Workload, *, backend: str = "numpy",
           terminals: int | None = None, eject_bw: int | None = None,
           num_vcs: int | None = None, queue_capacity: int = 4,
           max_cycles: int | None = None, seed: int = 0,
           trace=None, failures=None, bucket: bool | None = None,
           devices=None) -> RunStats:
    """Replay ``workload`` on ``topo`` under ``policy``; returns the
    engine's :class:`~repro.sim.metrics.RunStats` with the replay fields
    set: ``phase_cycles`` (per-phase durations), ``completion_cycles``
    (the cycle the last packet delivered), and ``ideal_cycles`` (the
    contention-free bound) — ``completion_cycles >= ideal_cycles``
    always, with equality iff no phase ever left its bottleneck link
    idle or contended.

    ``failures`` (a :class:`repro.faults.FailureSpec`) replays on the
    degraded fabric: routing falls back to the surviving graph's tables
    and pairs whose endpoints died or were disconnected are masked out
    of every phase (phase barriers then gate on the surviving packet
    counts, and ``ideal_cycles`` is recomputed for the masked workload).
    """
    from .engine import simulate
    from .policies import make_policy
    if isinstance(policy, str):
        policy = make_policy(policy)
    if workload.num_switches != topo.num_switches:
        raise ValueError(
            f"workload {workload.name!r} spans {workload.num_switches} "
            f"switches but topology {topo.name!r} has {topo.num_switches}")
    return simulate(topo, policy, workload.traffic(), terminals=terminals,
                    eject_bw=eject_bw, num_vcs=num_vcs,
                    queue_capacity=queue_capacity, warmup=0,
                    max_cycles=max_cycles, seed=seed, backend=backend,
                    trace=trace, failures=failures, bucket=bucket,
                    devices=devices)
