"""Cycle-driven engine: batched arbitration over the whole fabric per cycle.

Per-cycle pipeline (all stages are numpy operations over every switch at
once; there are no per-packet Python objects):

1. **Ejection** — queue heads that reached their final destination compete
   for the switch's ``eject_bw`` ejection slots.
2. **Routing** — remaining heads compute their output port with the
   topology's vectorized table-free minimal route (towards ``mid`` in
   phase 0, ``dst`` in phase 1).
3. **Injection candidates** — each terminal exposes the head of its source
   FIFO (open-loop: generation timestamps come from the traffic object);
   the policy picks minimal/Valiant itineraries for them, re-evaluating
   congestion every cycle until they win.
4. **Link arbitration + credits** — one packet per directed link per
   cycle; a request is feasible only if the downstream (port, VC) queue
   has a free slot (occupancy *is* the credit counter).  Transit beats
   injection; ties break by a per-cycle random key.
5. **Movement** — winners pop from their queue (or terminal), push into
   the far-end queue, flip to phase 1 on reaching ``mid``, and bump the
   link-load counters.

Packets advance at most one hop per cycle (unit link latency + bandwidth).
"""
from __future__ import annotations

import time

import numpy as np

from ..obs.telemetry import timing_dict
from ..obs.trace import Trace, TraceConfig, derive_backlog
from .link import LinkLoadCounter, LinkTable
from .metrics import (RunStats, attach_replay, attach_serving, build_stats,
                      replay_timeline)
from .policies import RoutingPolicy
from .switch import QueueFabric, arbitrate
from .topology import SimTopology
from .traffic import Traffic, resolve_terminals

_DRAIN_SLACK = 100_000   # safety cap on drain cycles for closed workloads


class Engine:
    """One simulation run; construct fresh per run."""

    def __init__(self, topo: SimTopology, policy: RoutingPolicy,
                 traffic: Traffic, *, terminals: int | None = None,
                 eject_bw: int | None = None, num_vcs: int | None = None,
                 queue_capacity: int = 4, seed: int = 0, trace=None):
        self.topo = topo
        self.policy = policy
        self.traffic = traffic
        # None defaults to the traffic object's record; an explicit value
        # must agree with it (the offered load is scaled by the traffic's
        # terminals, so a disagreement silently mis-normalizes accepted
        # throughput).
        terminals = resolve_terminals(traffic, terminals)
        self.terminals = terminals
        self.eject_bw = terminals if eject_bw is None else eject_bw
        if num_vcs is None:
            # Distance-class VC ladder: one class per hop of the longest
            # route (doubled when the policy may take a Valiant detour).
            # A packet in the top class is then on its final hop, whose
            # next buffer is the always-draining ejection port, so no
            # buffer-dependency cycle can close.  On a CIN this yields the
            # paper's §3 numbers exactly: 1 VC minimal, 2 VCs non-minimal.
            num_vcs = topo.diameter * (2 if policy.vc_required > 1 else 1)
        self.num_vcs = num_vcs
        self.queue_capacity = queue_capacity
        self.rng = np.random.default_rng(seed)

        n, p, v = topo.num_switches, topo.num_ports, self.num_vcs
        self.links = LinkTable.for_topology(topo, v)
        self.load = LinkLoadCounter(self.links)
        self.fabric = QueueFabric(n * p * v, queue_capacity)

        # -- packet state (structure-of-arrays), sorted by (src, gen) -------
        order = np.lexsort((traffic.gen, traffic.src))
        self.src = traffic.src[order].astype(np.int64)
        self.dst = traffic.dst[order].astype(np.int64)
        self.gen = traffic.gen[order].astype(np.int64)
        self.request = (traffic.request[order].astype(np.int64)
                        if traffic.request is not None else None)
        m = self.src.size
        self.mid = self.dst.copy()
        self.phase = np.ones(m, dtype=np.int64)
        self.hops = np.zeros(m, dtype=np.int64)
        self.loc = self.src.copy()
        self.deliver = np.full(m, -1, dtype=np.int64)

        # -- terminal source FIFOs: switch block + stride-t subsequences ----
        counts = np.bincount(self.src, minlength=n) if m else np.zeros(n, np.int64)
        self.blk_start = np.concatenate([[0], np.cumsum(counts)[:-1]]).astype(np.int64)
        self.blk_end = (self.blk_start + counts).astype(np.int64)
        t = terminals
        self.term_switch = np.repeat(np.arange(n), t)
        self.term_lane = np.tile(np.arange(t), n)
        self.term_next = np.zeros(n * t, dtype=np.int64)   # injected count

        # EWMA of per-link requested demand (packets/cycle wanting the link,
        # whether or not they won) — the local congestion signal adaptive
        # policies read.  Downstream credit occupancy alone cannot see
        # source-side contention: a saturated link's far-end queue drains
        # freely while its requesters pile up on this side.
        self.pressure = np.zeros(self.links.num_link_slots)
        self.pressure_alpha = 0.05

        self.delivered_total = 0
        self.delivered_in_window = 0
        self.cycle = 0
        self.warmup = 0

        # -- collective-replay phase barrier --------------------------------
        # For workload replays (traffic.workload set) gen holds each
        # packet's phase ordinal; a phase's packets become injection
        # candidates only once every earlier phase has fully delivered.
        # phase_done[k] records the cycle phase k's last packet ejected.
        if traffic.workload is not None:
            num_phases = traffic.workload.num_phases
            self.phase_cum = traffic.workload.phase_cum(num_phases)
            self.phase_done = np.full(num_phases, -1, dtype=np.int64)
            self.cur_phase = 0
            self._advance_barrier(0)         # release empty leading phases
        else:
            self.phase_cum = None
        # Measurement window is [warmup, meas_end): drain cycles past the
        # open-loop horizon deliver backlog without fresh offered load, so
        # counting them would inflate accepted throughput past offered.
        self.meas_end = float("inf")

        # -- time-series trace (repro.obs) ----------------------------------
        # Sampling happens at end-of-cycle, after movement, so every channel
        # reflects the state the next cycle starts from — the same point the
        # compiled engine's ring buffers capture.
        self.trace_cfg = TraceConfig.coerce(trace)
        self._span_mask = None
        if self.trace_cfg is not None:
            self._tr_cycles: list = []
            self._tr_link: list = []
            self._tr_occ: list = []
            self._tr_inj: list = []
            self._tr_del: list = []
            self._tr_events: list = []
            k = self.trace_cfg.packets
            if k > 0 and m > 0:
                # K packets spread evenly over the (src, gen)-sorted ids, so
                # the sample covers sources and phases rather than one block.
                ids = np.unique(np.linspace(0, m - 1, min(k, m)).astype(np.int64))
                self._span_mask = np.zeros(m, dtype=bool)
                self._span_mask[ids] = True

    def _advance_barrier(self, c: int) -> None:
        """Open the next phase barrier(s) whose packets are all delivered,
        recording the completion cycle (empty phases complete in place)."""
        while (self.cur_phase < self.phase_cum.size
               and self.delivered_total >= self.phase_cum[self.cur_phase]):
            self.phase_done[self.cur_phase] = c
            self.cur_phase += 1

    # -- congestion view for adaptive policies ------------------------------
    def port_backlog(self, switch: np.ndarray, port: np.ndarray) -> np.ndarray:
        """Occupancy (all VCs) of the downstream queue behind an output
        port — the credit-visible congestion signal."""
        link = self.links.link_ids(switch, port)
        base = self.links.dest_queue(link, np.zeros_like(link))
        per_port = self.fabric.occ.reshape(-1, self.num_vcs).sum(axis=1)
        return per_port[base // self.num_vcs]

    def link_pressure(self, switch: np.ndarray, port: np.ndarray) -> np.ndarray:
        """Smoothed requested demand (packets/cycle) on an output link."""
        return self.pressure[self.links.link_ids(switch, port)]

    # -- one simulated cycle -------------------------------------------------
    def step(self) -> None:
        self._step_core()
        cfg = self.trace_cfg
        if cfg is not None:
            c = self.cycle - 1
            if c % cfg.stride == 0 and c // cfg.stride < cfg.max_samples:
                self._sample(c)

    def _sample(self, c: int) -> None:
        n = self.topo.num_switches
        self._tr_cycles.append(c)
        self._tr_link.append(self.load.total.copy())
        self._tr_occ.append(self.fabric.occ.reshape(n, -1).sum(axis=1))
        self._tr_inj.append(self.term_next.reshape(n, -1).sum(axis=1))
        self._tr_del.append(self.delivered_total)

    def _finalize_trace(self) -> Trace:
        n = self.topo.num_switches
        s = len(self._tr_cycles)
        cycles = np.asarray(self._tr_cycles, dtype=np.int64)
        injected = np.asarray(self._tr_inj, dtype=np.int64).reshape(s, n)
        backlog = derive_backlog(
            cycles, injected, self.gen, self.blk_start, self.blk_end,
            phase_done=self.phase_done if self.phase_cum is not None else None)
        return Trace(
            stride=self.trace_cfg.stride, cycles=cycles,
            link_load=np.asarray(self._tr_link, np.int64).reshape(
                s, self.links.num_link_slots),
            queue_occ=np.asarray(self._tr_occ, np.int64).reshape(s, n),
            injected=injected,
            delivered=np.asarray(self._tr_del, np.int64),
            backlog=backlog,
            meta={"topology": self.topo.name, "policy": self.policy.name,
                  "backend": "numpy", "num_switches": n,
                  "num_ports": self.topo.num_ports,
                  "terminals": self.terminals},
            events=self._tr_events)

    def _step_core(self) -> None:
        topo, fab, links = self.topo, self.fabric, self.links
        p, v, cap = topo.num_ports, self.num_vcs, self.queue_capacity
        c = self.cycle

        # 1. ejection ------------------------------------------------------
        aq = fab.active()
        heads = fab.heads(aq)
        done = (self.loc[heads] == self.dst[heads]) & (self.phase[heads] == 1)
        if done.any():
            eq = aq[done]
            ep = heads[done]
            sw = eq // (p * v)
            win = arbitrate(sw, self.rng.random(eq.size), k=self.eject_bw)
            fab.pop(eq[win])
            pids = ep[win]
            if self._span_mask is not None:
                for pd in pids[self._span_mask[pids]]:
                    self._tr_events.append(
                        (int(pd), c, int(self.loc[pd]), -1))
            self.deliver[pids] = c
            self.delivered_total += win.size
            if self.warmup <= c < self.meas_end:
                self.delivered_in_window += win.size
            if self.phase_cum is not None:
                # Barrier opens in the same cycle the closing delivery
                # lands, so the next phase's injection (stage 3 below)
                # never loses a cycle to the bookkeeping.
                self._advance_barrier(c)

        # 2. transit requests ---------------------------------------------
        tq = aq[~done]
        tp = heads[~done]
        tgt = np.where(self.phase[tp] == 1, self.dst[tp], self.mid[tp])
        if tp.size:
            t_port = topo.minimal_port(self.loc[tp], tgt)
        else:
            t_port = np.empty(0, dtype=np.int64)
        t_vc = np.minimum(self.hops[tp], v - 1)

        # 3. injection candidates -----------------------------------------
        idx = (self.blk_start[self.term_switch] + self.term_lane
               + self.term_next * self.terminals)
        valid = idx < self.blk_end[self.term_switch]
        if self.gen.size:
            safe = np.where(valid, idx, 0)
            # Replays gate on the released phase (gen = phase ordinal);
            # open-loop traffic gates on simulated time (gen = cycle).
            limit = c if self.phase_cum is None else self.cur_phase
            valid &= self.gen[safe] <= limit
        cand_term = np.nonzero(valid)[0]
        ip = idx[cand_term]
        if ip.size:
            self.policy.on_inject(self, ip)
            i_tgt = np.where(self.phase[ip] == 1, self.dst[ip], self.mid[ip])
            i_port = topo.minimal_port(self.src[ip], i_tgt)
        else:
            i_port = np.empty(0, dtype=np.int64)
        i_vc = np.zeros(ip.size, dtype=np.int64)     # first hop = class 0

        # 4. link arbitration with credit check ---------------------------
        # The EWMA pressure update happens exactly once per cycle, on every
        # path out of this stage (an empty request set is demand == 0, a
        # fully-blocked cycle still counts its requesters), so adaptive
        # policies never read a stale congestion signal.
        nt = tp.size
        r_pid = np.concatenate([tp, ip])
        r_loc = np.concatenate([self.loc[tp], self.src[ip]])
        r_port = np.concatenate([t_port, i_port])
        r_link = links.link_ids(r_loc, r_port)
        demand = np.bincount(r_link, minlength=links.num_link_slots)
        self.pressure += self.pressure_alpha * (demand - self.pressure)
        if r_pid.size == 0:
            self.cycle += 1
            return
        r_vc = np.concatenate([t_vc, i_vc])
        r_cls = np.concatenate([np.zeros(nt, np.int64),
                                np.ones(ip.size, np.int64)])
        r_dq = links.dest_queue(r_link, r_vc)
        # Unwired slots (including links a FailureSpec killed) have no
        # downstream queue — they are permanently credit-starved.
        # Degraded fallback routing never requests them, so this guard
        # never fires on well-formed traffic; it keeps stray requests
        # from indexing a garbage queue.
        feasible = np.nonzero((fab.occ[r_dq] < cap)
                              & links.wired[r_link])[0]
        if feasible.size == 0:
            self.cycle += 1
            return
        win = feasible[arbitrate(r_link[feasible], r_cls[feasible],
                                 self.rng.random(feasible.size), k=1)]

        # 5. movement ------------------------------------------------------
        w_transit = win[win < nt]
        fab.pop(tq[w_transit])
        w_inject = win[win >= nt] - nt
        self.term_next[cand_term[w_inject]] += 1

        pid = r_pid[win]
        dq = r_dq[win]
        nbr = links.neighbor_flat[r_link[win]]
        if self._span_mask is not None:
            traced = self._span_mask[pid]
            if traced.any():
                frm = r_loc[win][traced]
                for a, b, d in zip(pid[traced], frm, nbr[traced]):
                    self._tr_events.append((int(a), c, int(b), int(d)))
        fab.push(dq, pid)
        self.loc[pid] = nbr
        self.hops[pid] += 1
        arrived_mid = (self.phase[pid] == 0) & (nbr == self.mid[pid])
        if arrived_mid.any():
            self.phase[pid[arrived_mid]] = 1
        if self.warmup <= c < self.meas_end:
            self.load.record(r_link[win])
        else:
            self.load.total[r_link[win]] += 1
        self.cycle += 1

    # -- full run -------------------------------------------------------------
    def run(self, *, cycles: int | None = None, warmup: int = 0,
            drain: bool | None = None, max_cycles: int | None = None
            ) -> RunStats:
        m = self.src.size
        horizon = cycles if cycles is not None else max(self.traffic.horizon, 1)
        if drain is None:
            drain = self.traffic.offered == 0
        cutoff = max_cycles if max_cycles is not None else horizon + _DRAIN_SLACK
        self.warmup = warmup
        # Replays measure the whole run: the "horizon" is only the phase
        # count, and every delivery belongs to the workload being timed.
        self.meas_end = horizon if self.phase_cum is None else float("inf")

        t0 = time.perf_counter()
        while self.cycle < horizon:
            if self.cycle == warmup:
                self.load.reset_window()
            self.step()
        while drain and self.delivered_total < m and self.cycle < cutoff:
            self.step()
        wall_s = time.perf_counter() - t0
        if drain and self.delivered_total < m:
            raise RuntimeError(
                f"{self.topo.name}/{self.policy.name}: "
                f"{m - self.delivered_total} packets undelivered after "
                f"{self.cycle} cycles (deadlock or cutoff too small)")
        if self.phase_cum is not None:
            # Summary stats over the *replay's* timeline: the run spans
            # [0, completion], and a packet's reference time is the cycle
            # its phase barrier opened (gen holds the phase ordinal), so
            # latency measures in-phase queueing + flight, and accepted /
            # utilization normalize by the measured completion.
            cycles_arg, gen_arg = replay_timeline(self.phase_done, self.gen)
            stats = build_stats(
                topology=self.topo, policy=self.policy, traffic=self.traffic,
                cycles=cycles_arg, warmup=warmup, terminals=self.terminals,
                gen=gen_arg, deliver=self.deliver, link_counter=self.load,
                delivered_in_window=self.delivered_in_window,
                in_flight=self.fabric.total_occupancy)
            stats = attach_replay(stats, self.traffic.workload,
                                  self.phase_done)
            return self._attach_obs(stats, wall_s)
        stats = build_stats(
            topology=self.topo, policy=self.policy, traffic=self.traffic,
            cycles=max(horizon, 1), warmup=warmup, terminals=self.terminals,
            gen=self.gen, deliver=self.deliver, link_counter=self.load,
            delivered_in_window=self.delivered_in_window,
            in_flight=self.fabric.total_occupancy)
        if self.request is not None:
            stats = attach_serving(stats, self.request, self.gen,
                                   self.deliver, slo=self.traffic.slo)
        return self._attach_obs(stats, wall_s)

    def _attach_obs(self, stats: RunStats, wall_s: float) -> RunStats:
        stats.timing = timing_dict("numpy", execute_s=wall_s)
        if self.trace_cfg is not None:
            stats.trace = self._finalize_trace()
        return stats


def simulate(topo: SimTopology, policy: RoutingPolicy, traffic: Traffic, *,
             terminals: int | None = None, eject_bw: int | None = None,
             num_vcs: int | None = None, queue_capacity: int = 4,
             cycles: int | None = None,
             warmup: int = 0, drain: bool | None = None,
             max_cycles: int | None = None, seed: int = 0,
             backend: str = "numpy", trace=None, failures=None,
             bucket: bool | None = None, devices=None) -> RunStats:
    """Run one simulation; ``backend`` picks the engine.

    ``terminals`` defaults to what the traffic object was generated with
    (:func:`repro.sim.traffic.resolve_terminals`); passing a disagreeing
    explicit value raises.

    ``failures`` (a :class:`repro.faults.FailureSpec`, or its dict form)
    runs the simulation on the degraded fabric: the topology is masked
    and re-routed via :func:`repro.faults.degrade` and packets whose
    endpoints died or were disconnected are dropped from ``traffic``
    before the engine ever sees them — uniformly for all three backends.
    ``None`` (or a null spec) is exactly the pristine run.

    * ``"numpy"`` — the interpreted oracle :class:`Engine` (one Python
      iteration per cycle; reference semantics).
    * ``"jax"``   — the compiled engine (:mod:`repro.sim.xengine`): same
      pipeline as a jit-compiled fixed-shape program.  Statistically
      equivalent, not bit-identical (arbitration tie-breaks draw from a
      different RNG).  Prefer :func:`repro.sim.xengine.sweep` when running
      many (load, seed) points — it batches them into one program.
    * ``"flow"``  — the analytical fair-share model (:mod:`repro.flow`):
      a different *fidelity tier*, not another cycle engine.  Rates and
      replay completion are cross-validated estimates; latency fields
      are hop-count lower bounds, and queue-level knobs
      (``queue_capacity``, ``num_vcs``, ``eject_bw``, ``seed``,
      ``trace``) are accepted but ignored.  Scales to 10k+ switches.

    ``trace`` turns on time-series recording (anything
    :meth:`repro.obs.TraceConfig.coerce` accepts: ``True``, a config, or
    a kwargs dict); the sampled :class:`~repro.obs.Trace` lands on
    ``stats.trace``.  Both backends also stamp ``stats.timing`` with the
    run's wall-clock (and, for ``"jax"``, compile-vs-execute) split.

    ``bucket`` / ``devices`` are compiled-engine program knobs
    (shape bucketing and ``shard_map`` device sharding — see
    :func:`repro.sim.xengine.sweep`); both are bit-identity-preserving,
    and both are accepted-but-ignored by the other backends, which have
    no compiled program to shape.
    """
    if failures is not None:
        from repro.faults import degrade, mask_traffic
        topo = degrade(topo, failures)
        traffic = mask_traffic(traffic, topo)
    if backend == "jax":
        from . import xengine
        return xengine.simulate_jax(
            topo, policy, traffic, terminals=terminals, eject_bw=eject_bw,
            num_vcs=num_vcs, queue_capacity=queue_capacity, cycles=cycles,
            warmup=warmup, drain=drain, max_cycles=max_cycles, seed=seed,
            trace=trace, bucket=bucket, devices=devices)
    if backend == "flow":
        from repro.flow import simulate_flow
        return simulate_flow(topo, policy, traffic, terminals=terminals,
                             cycles=cycles, warmup=warmup)
    if backend != "numpy":
        raise ValueError(f"unknown simulator backend {backend!r}; "
                         f"expected 'numpy', 'jax' or 'flow'")
    eng = Engine(topo, policy, traffic, terminals=terminals,
                 eject_bw=eject_bw, num_vcs=num_vcs,
                 queue_capacity=queue_capacity, seed=seed, trace=trace)
    return eng.run(cycles=cycles, warmup=warmup, drain=drain,
                   max_cycles=max_cycles)
