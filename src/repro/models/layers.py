"""Shared neural building blocks: norms, RoPE, GQA attention, MLPs.

Everything is functional: ``init_*`` builds parameter pytrees (jnp arrays —
usable under ``jax.eval_shape`` for allocation-free dry-runs) and the apply
functions are pure.  Sharding is communicated with
``jax.lax.with_sharding_constraint`` through the :class:`AxisRules`
indirection so the same model code runs on 1 CPU device and on the
(2, 16, 16) production mesh.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# Sharding rules.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AxisRules:
    """Logical-to-mesh axis mapping.

    ``dp``   — batch-parallel axes (("pod","data") on the multi-pod mesh).
    ``tp``   — tensor/expert-parallel axis ("model").
    ``mesh`` — the device mesh (needed by shard_map sub-regions, e.g. the
               LACIN expert-parallel MoE dispatch).
    Default-constructed rules are no-ops (single-device / test mode).
    """
    dp: tuple[str, ...] = ()
    tp: str | None = None
    mesh: object = None

    @property
    def enabled(self) -> bool:
        return bool(self.dp) or self.tp is not None

    @property
    def tp_size(self) -> int:
        if self.tp is None or self.mesh is None:
            return 1
        return self.mesh.shape[self.tp]

    @property
    def dp_size(self) -> int:
        if not self.dp or self.mesh is None:
            return 1
        out = 1
        for a in self.dp:
            out *= self.mesh.shape[a]
        return out

    def spec(self, *axes) -> P:
        """Build a PartitionSpec from logical axis tags.

        Tags: 'dp' -> the dp mesh axes, 'tp' -> the tp axis, None -> unsharded.
        """
        out = []
        for a in axes:
            if a == "dp":
                out.append(self.dp if self.dp else None)
            elif a == "tp":
                out.append(self.tp)
            else:
                out.append(None)
        return P(*out)

    def constrain(self, x, *axes):
        if not self.enabled:
            return x
        spec = self.spec(*axes)
        if self.mesh is not None:
            from jax.sharding import NamedSharding
            return lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))
        return lax.with_sharding_constraint(x, spec)


NO_SHARD = AxisRules()


# ---------------------------------------------------------------------------
# Initializers.
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    scale = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms.
# ---------------------------------------------------------------------------

def init_norm(cfg, dtype) -> dict:
    p = {"scale": jnp.zeros((cfg.d_model,), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def apply_norm(p: dict, x, eps: float = 1e-6):
    """RMSNorm (scale stored as offset-from-1) or LayerNorm."""
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * lax.rsqrt(var + eps)
        y = y * (1.0 + p["scale"].astype(jnp.float32)) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * lax.rsqrt(ms + eps) * (1.0 + p["scale"].astype(jnp.float32))
    return y.astype(x.dtype)


def rms_norm_head(x, eps: float = 1e-6):
    """Parameter-light qk-norm over the head dim."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * lax.rsqrt(ms + eps)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE.
# ---------------------------------------------------------------------------

def rope_cos_sin(positions, head_dim: int, theta):
    """cos/sin tables for rotary embeddings.

    ``theta`` may be a traced scalar (per-layer theta inside a scanned stack).
    positions: (..., T) int32 -> (..., T, head_dim/2) each.
    """
    half = head_dim // 2
    freq_exponents = jnp.arange(half, dtype=jnp.float32) / half
    inv_freq = jnp.asarray(theta, jnp.float32) ** -freq_exponents
    angles = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x, cos, sin):
    """x: (B, T, H, D). cos/sin: (B, T, D/2) or (T, D/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA; full or sliding window via per-layer ``window`` scalar).
# ---------------------------------------------------------------------------

def init_attention(key, cfg, dtype) -> dict:
    d, h, kv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h, dh), dtype),
        "wk": dense_init(ks[1], (d, kv, dh), dtype),
        "wv": dense_init(ks[2], (d, kv, dh), dtype),
        "wo": dense_init(ks[3], (h, dh, d), dtype, fan_in=h * dh),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((h, dh), dtype)
        p["bk"] = jnp.zeros((kv, dh), dtype)
        p["bv"] = jnp.zeros((kv, dh), dtype)
        p["bo"] = jnp.zeros((d,), dtype)
    return p


def qkv_proj(p, x, cfg, rules: AxisRules):
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    tp = max(rules.tp_size, 1)
    # Head sharding is only clean if the GQA grouping reshape (kvh, g)
    # preserves it, i.e. kv heads divide the axis.  Otherwise q/k/v stay
    # head-replicated here and _self_attention may expand KV to full heads.
    if cfg.num_kv_heads % tp == 0 and cfg.num_heads % tp == 0:
        q = rules.constrain(q, "dp", None, "tp", None)
        k = rules.constrain(k, "dp", None, "tp", None)
        v = rules.constrain(v, "dp", None, "tp", None)
    else:
        q = rules.constrain(q, "dp", None, None, None)
        k = rules.constrain(k, "dp", None, None, None)
        v = rules.constrain(v, "dp", None, None, None)
    return q, k, v


def maybe_expand_kv(q, k, v, rules: AxisRules):
    """GQA -> MHA expansion when kv heads don't divide the model axis but
    full heads do: the expanded (sharded) K/V is *smaller per device* than
    replicated GQA K/V, and the attention einsums shard cleanly.
    Used for train/prefill only (decode shards the cache on sequence)."""
    tp = max(rules.tp_size, 1)
    h, kvh = q.shape[2], k.shape[2]
    if tp > 1 and kvh % tp and h % tp == 0 and h != kvh:
        g = h // kvh
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
        k = rules.constrain(k, "dp", None, "tp", None)
        v = rules.constrain(v, "dp", None, "tp", None)
        q = rules.constrain(q, "dp", None, "tp", None)
    return q, k, v


def out_proj(p, o, rules: AxisRules):
    y = jnp.einsum("bthk,hkd->btd", o, p["wo"])
    if "bo" in p:
        y = y + p["bo"]
    return rules.constrain(y, "dp", None, None)


#: Sentinel position marking padded KV slots (always masked).
KV_PAD = jnp.iinfo(jnp.int32).max


def _mask_bias(q_pos, kv_pos, window, causal: bool):
    """(..., T, S) additive mask. window: traced scalar, 0 = unlimited."""
    dq = q_pos[..., :, None]
    dk = kv_pos[..., None, :]
    ok = dk != KV_PAD
    if causal:
        ok &= dk <= dq
    winf = jnp.asarray(window, jnp.int32)
    ok &= (winf <= 0) | (dq - dk < winf)
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def attention_naive(q, k, v, *, q_pos, kv_pos, window=0, causal=True,
                    softcap: float = 0.0):
    """Reference O(T·S)-memory attention.  q: (B,T,H,D), k/v: (B,S,KV,D)."""
    b, t, h, dh = q.shape
    s, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, t, kvh, g, dh)
    logits = jnp.einsum("btkgd,bskd->bkgts", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(dh)
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    bias = _mask_bias(q_pos, kv_pos, window, causal)  # (T, S) or (B,T,S)
    while bias.ndim < logits.ndim:
        bias = bias[None]
    logits = logits + bias
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgts,bskd->btkgd", w, v.astype(jnp.float32))
    return o.reshape(b, t, h, dh).astype(q.dtype)


def attention_chunked(q, k, v, *, q_pos, kv_pos, window=0, causal=True,
                      softcap: float = 0.0, kv_chunk: int = 1024,
                      q_block: int = 1024, skip_above_diagonal: bool = False):
    """Online-softmax attention, blocked over both Q and KV (bounded memory).

    Pure-JAX 'flash attention': an outer scan over Q blocks, an inner scan
    over KV chunks keeping running (max, sum, acc).  The Pallas kernel
    implements the same contract for TPU execution.

    ``skip_above_diagonal``: for causal self-attention where ``q_pos`` and
    ``kv_pos`` are the *same* monotonically increasing range, unroll the Q
    blocks in Python and statically bound each block's KV scan at the
    diagonal — saves ~2x masked-out FLOPs at the cost of a larger HLO.
    """
    b, t, h, dh = q.shape
    s, kvh = k.shape[1], k.shape[2]
    pad_t = (-t) % q_block
    if pad_t:
        q = jnp.pad(q, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad_t))
    pad_s = (-s) % kv_chunk
    if pad_s:
        k = jnp.pad(k, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pad_s), constant_values=KV_PAD)
    tp, sp = t + pad_t, s + pad_s
    g = h // kvh
    nq, nk = tp // q_block, sp // kv_chunk
    qg = (q.reshape(b, nq, q_block, kvh, g, dh).astype(jnp.float32)
          / np.sqrt(dh))
    kc = k.reshape(b, nk, kv_chunk, kvh, dh)
    vc = v.reshape(b, nk, kv_chunk, kvh, dh)
    pq = q_pos.reshape(nq, q_block)
    pc = kv_pos.reshape(nk, kv_chunk)

    def kv_step(carry, inp, qb, pqb):
        m, l, acc = carry
        kb, vb, pb = inp
        logits = jnp.einsum("btkgd,bckd->bkgtc", qb, kb.astype(jnp.float32))
        if softcap:
            logits = jnp.tanh(logits / softcap) * softcap
        bias = _mask_bias(pqb, pb, window, causal)
        logits = logits + bias[None, None, None]
        m_new = jnp.maximum(m, logits.max(axis=-1))
        scale = jnp.exp(m - m_new)
        p_ = jnp.exp(logits - m_new[..., None])
        l_new = l * scale + p_.sum(axis=-1)
        acc_new = acc * scale[..., None] + jnp.einsum(
            "bkgtc,bckd->bkgtd", p_, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    def q_block_out(qb, pqb, n_kv_chunks):
        m0 = jnp.full((b, kvh, g, q_block), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, q_block, dh), jnp.float32)
        xs = (jnp.moveaxis(kc[:, :n_kv_chunks], 1, 0),
              jnp.moveaxis(vc[:, :n_kv_chunks], 1, 0), pc[:n_kv_chunks])
        (m, l, acc), _ = lax.scan(
            lambda c, i: kv_step(c, i, qb, pqb), (m0, l0, a0), xs)
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(o, (1, 2), (2, 3))      # (b, Q, kv, g, d)

    if skip_above_diagonal and causal and nq > 1:
        outs = []
        for i in range(nq):
            hi = min(nk, -(-((i + 1) * q_block) // kv_chunk))
            outs.append(q_block_out(qg[:, i], pq[i], hi))
        o = jnp.stack(outs, axis=1)                  # (b, nq, Q, kv, g, d)
    else:
        o = lax.map(lambda args: q_block_out(args[0], args[1], nk),
                    (jnp.moveaxis(qg, 1, 0), pq))    # (nq, b, Q, kv, g, d)
        o = jnp.moveaxis(o, 0, 1)
    o = o.reshape(b, tp, h, dh)[:, :t]
    return o.astype(q.dtype)


def attention_banded(q, k, v, *, q_pos, kv_pos, window, w_max: int,
                     q_block: int = 1024):
    """Sliding-window attention via banded KV gather (prefill path).

    For window <= w_max (static), each Q block of length Q only sees keys
    in [block_start - w_max, block_end): gather a (nq, Q + w_max) banded
    view of K/V once, then scan Q blocks against their bands — executed
    FLOPs drop from O(T*S) to O(T * (Q + w_max)) while the traced
    ``window`` still masks exactly.
    """
    b, t, h, dh = q.shape
    s, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    pad_t = (-t) % q_block
    if pad_t:
        q = jnp.pad(q, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad_t))
    nq = (t + pad_t) // q_block
    band = q_block + w_max
    starts = jnp.arange(nq) * q_block - w_max
    idx = starts[:, None] + jnp.arange(band)[None, :]      # (nq, band)
    valid = (idx >= 0) & (idx < s)
    idx_c = jnp.clip(idx, 0, s - 1)
    kb = jnp.take(k, idx_c, axis=1)                        # (b,nq,band,kv,d)
    vb = jnp.take(v, idx_c, axis=1)
    pb = jnp.where(valid, kv_pos[idx_c], KV_PAD)           # (nq, band)
    qb = q.reshape(b, nq, q_block, h, dh)
    pq = q_pos.reshape(nq, q_block)

    def block(args):
        qi, ki, vi, pqi, pbi = args
        return attention_naive(qi, ki, vi, q_pos=pqi, kv_pos=pbi,
                               window=window, causal=True)

    o = lax.map(block, (jnp.moveaxis(qb, 1, 0), jnp.moveaxis(kb, 1, 0),
                        jnp.moveaxis(vb, 1, 0), pq, pb))
    o = jnp.moveaxis(o, 0, 1).reshape(b, t + pad_t, h, dh)[:, :t]
    return o


def attention(q, k, v, *, q_pos, kv_pos, window=0, causal=True,
              softcap: float = 0.0, impl: str = "auto", kv_chunk: int = 1024,
              q_block: int = 1024, bands=None):
    """Dispatch: naive for small-S / decode, blocked for long, pallas on ask.

    Decode (T == 1) always uses the naive path: with a sequence-sharded KV
    cache XLA partitions the softmax reductions across the "model" axis —
    distributed flash-decoding for free (SP decode).

    ``bands``: static per-Q-block KV ranges (diagonal skipping / window
    banding); only valid for aligned causal self-attention.
    """
    s, t = k.shape[1], q.shape[1]
    if impl == "pallas" and t > 1:
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                                    window=window, causal=causal)
    if impl == "naive" or t == 1 or (s <= 2048 and bands is None):
        return attention_naive(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                               window=window, causal=causal, softcap=softcap)
    # long-sequence path: flash with FA2-style custom VJP (O(block) memory
    # in the backward; plain reverse mode through the online-softmax scan
    # would save the full (T, S) probability matrix per layer).
    from .flash import flash_attention_jnp
    return flash_attention_jnp(q, k, v, q_pos, kv_pos, window, causal,
                               q_block, kv_chunk, bands)


# ---------------------------------------------------------------------------
# MLPs.
# ---------------------------------------------------------------------------

def init_mlp(key, cfg, dtype, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    ks = jax.random.split(key, 3)
    gated = cfg.mlp in ("swiglu", "geglu")
    p = {"wi": dense_init(ks[0], (d, f), dtype),
         "wo": dense_init(ks[1], (f, d), dtype, fan_in=f)}
    if gated:
        p["wg"] = dense_init(ks[2], (d, f), dtype)
    if cfg.mlp_bias:
        p["bi"] = jnp.zeros((f,), dtype)
        p["bo"] = jnp.zeros((d,), dtype)
    return p


def apply_mlp(p: dict, x, cfg, rules: AxisRules):
    h = x @ p["wi"]
    if "bi" in p:
        h = h + p["bi"]
    h = rules.constrain(h, "dp", None, "tp")
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * h
    elif cfg.mlp == "geglu":
        h = jax.nn.gelu(x @ p["wg"], approximate=True) * h
    elif cfg.mlp == "squared_relu":
        r = jax.nn.relu(h)
        h = r * r
    elif cfg.mlp == "gelu":
        h = jax.nn.gelu(h, approximate=True)
    else:
        raise ValueError(f"unknown mlp {cfg.mlp!r}")
    h = rules.constrain(h, "dp", None, "tp")
    y = h @ p["wo"]
    if "bo" in p:
        y = y + p["bo"]
    return rules.constrain(y, "dp", None, None)


# ---------------------------------------------------------------------------
# Embedding / unembedding.
# ---------------------------------------------------------------------------

def init_embed(key, cfg, dtype) -> dict:
    """Embedding store padded to ``cfg.vocab_padded`` rows (Megatron-style)
    so the vocab dim shards evenly; pad logits are masked at the unembed."""
    p = {"table": embed_init(key, (cfg.vocab_padded, cfg.d_model), dtype)}
    return p


def embed_tokens(p, tokens, cfg, rules: AxisRules):
    x = jnp.take(p["table"], tokens, axis=0)
    if cfg.name.startswith("gemma"):
        x = x * np.sqrt(cfg.d_model)
    return rules.constrain(x.astype(cfg.dtype), "dp", None, None)


def logits_from_hidden(x, embed_params, head_params, cfg, rules: AxisRules):
    if cfg.tie_embeddings:
        w = embed_params["table"].astype(cfg.dtype)
        logits = jnp.einsum("btd,vd->btv", x, w)
    else:
        logits = jnp.einsum("btd,dv->btv", x, head_params["w"].astype(cfg.dtype))
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    if cfg.vocab_padded != cfg.vocab_size:  # mask padding rows to -inf
        viota = lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        logits = jnp.where(viota < cfg.vocab_size,
                           logits, jnp.asarray(-1e30, logits.dtype))
    return rules.constrain(logits, "dp", None, "tp")
