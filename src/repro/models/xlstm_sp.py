"""Context-parallel mLSTM: sequence parallelism for the recurrent arch.

Beyond-paper feature (LASP-style, adapted to xLSTM's stabilized matrix
memory): the sequence is sharded across a mesh axis; every device runs the
zero-init chunkwise pass on its segment, the per-segment affine state
summaries ``(F, C, n, m)`` are prefix-combined across devices with a
log2(S)-step Hillis–Steele scan of ``ppermute`` shifts, and each position
is then corrected with its inbound prefix state:

    m'   = max(m_loc, b + m_in)
    num' = e^{m_loc - m'} num + e^{b + m_in - m'} (q C_in)
    dot' = e^{m_loc - m'} dot + e^{b + m_in - m'} (q n_in)
    h    = num' / max(|dot'|, e^{-m'})

The state-combine is associative, so the scan is exact (tested against
the sequential oracle).  On the paper's fabric each scan step's shift
permutation is contention-free (subset of a 1-factor), and total state
traffic is log2(S) * |state| instead of S * |state| for a sequential
segment chain.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from .xlstm import mlstm_chunkwise_raw


def _combine(a, b):
    """Sequential composition: segment ``a`` then segment ``b``.

    States are (F, C, n, m) with true_C = e^m * C_stored.
    """
    Fa, Ca, na, ma = a
    Fb, Cb, nb, mb = b
    m_new = jnp.maximum(Fb + ma, mb)
    sa = jnp.exp(Fb + ma - m_new)
    sb = jnp.exp(mb - m_new)
    C = sa[..., None, None] * Ca + sb[..., None, None] * Cb
    n = sa[..., None] * na + sb[..., None] * nb
    return (Fa + Fb, C, n, m_new)


def _identity_like(state):
    F, C, n, m = state
    return (jnp.zeros_like(F), jnp.zeros_like(C), jnp.zeros_like(n),
            jnp.full_like(m, -jnp.inf))


def distributed_exclusive_scan(state, axis_name: str, axis_size: int):
    """Exclusive prefix of the segment states along ``axis_name``
    (Hillis–Steele, log2(S) ppermute steps).  Must run inside shard_map."""
    idx = lax.axis_index(axis_name)
    ident = _identity_like(state)
    # inclusive scan of own aggregate, then shift right by one for exclusive
    agg = state
    prefix = state  # inclusive prefix so far
    k = 1
    while k < axis_size:
        perm = [(i, i + k) for i in range(axis_size - k)]
        recv = jax.tree_util.tree_map(
            lambda x: lax.ppermute(x, axis_name, perm), prefix)
        use = idx >= k
        combined = _combine(recv, prefix)
        prefix = jax.tree_util.tree_map(
            lambda c, p: jnp.where(use, c, p), combined, prefix)
        k *= 2
    # exclusive = inclusive prefix of the PREVIOUS device
    shift = [(i, i + 1) for i in range(axis_size - 1)]
    excl = jax.tree_util.tree_map(
        lambda x: lax.ppermute(x, axis_name, shift), prefix)
    excl = jax.tree_util.tree_map(
        lambda e, i: jnp.where(idx == 0, i, e), excl, ident)
    return excl


def mlstm_context_parallel(q, k, v, log_i, log_f, *, axis_name: str,
                           axis_size: int, chunk: int = 64):
    """q/k/v: (B, T_local, H, D) — this device's sequence segment.
    Returns h (B, T_local, H, D) equal to the sequential mLSTM over the
    concatenated sequence.  Call inside shard_map (sequence sharded)."""
    d = q.shape[-1]
    num, dot, m_loc, bg, state = mlstm_chunkwise_raw(q, k, v, log_i, log_f,
                                                     chunk=chunk)
    F_in, C_in, n_in, m_in = distributed_exclusive_scan(state, axis_name,
                                                        axis_size)
    qs = q.astype(jnp.float32) / np.sqrt(d)
    corr_num = jnp.einsum("bthd,bhde->bthe", qs, C_in)
    corr_dot = jnp.einsum("bthd,bhd->bth", qs, n_in)
    expo = bg + m_in[:, None, :]                       # (B,T,H)
    m_tot = jnp.maximum(m_loc, expo)
    s_loc = jnp.exp(m_loc - m_tot)
    s_in = jnp.exp(expo - m_tot)
    num2 = s_loc[..., None] * num + s_in[..., None] * corr_num
    dot2 = s_loc * dot + s_in * corr_dot
    den = jnp.maximum(jnp.abs(dot2), jnp.exp(-m_tot))[..., None]
    return (num2 / den).astype(q.dtype)
