"""Model assembly: block bodies, scanned stacks, LM / enc-dec / VLM wiring.

Layer stacks are grouped into *runs* of consecutive identical block kinds
(:class:`RunSpec`); each run executes as one ``lax.scan`` over stacked
parameters, with per-layer attention window and RoPE theta passed as traced
scan inputs — so e.g. gemma3's 5:1 local:global pattern compiles to a
single while-loop body.

Three entry points per model (all pure functions of (params, batch)):

* ``forward_train`` — teacher-forced CE for ``train_4k`` cells;
* ``prefill``       — build KV caches + last-position logits (``prefill_*``);
* ``decode_step``   — one-token step against caches (``decode_* / long_*``).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from .config import ATTN, ATTN_CROSS, HYMBA, MLSTM, SLSTM, ModelConfig
from . import layers as L
from .layers import AxisRules
from .moe import apply_moe, init_moe
from .ssm import apply_ssm, init_ssm, init_ssm_cache
from .xlstm import (apply_mlstm_block, apply_slstm_block, init_mlstm_block,
                    init_mlstm_cache, init_slstm_block, init_slstm_cache)


# ---------------------------------------------------------------------------
# Run grouping.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RunSpec:
    kind: str
    count: int
    windows: tuple[int, ...]
    thetas: tuple[float, ...]


def build_runs(cfg: ModelConfig) -> tuple[RunSpec, ...]:
    """Group consecutive layers into scanned runs (by kind: mixed windows
    ride along as traced scan inputs; the banded path selects per layer
    with lax.cond so the stack still compiles as one scan — splitting runs
    by window was measured to break XLA's weight-gather hoisting, see
    EXPERIMENTS.md §Perf cell 3 it2)."""
    runs = []
    pat, wins = cfg.block_pattern, cfg.windows

    def key(i):
        return pat[i]

    i = 0
    while i < len(pat):
        j = i
        while j < len(pat) and key(j) == key(i):
            j += 1
        windows = wins[i:j]
        thetas = tuple(
            (cfg.rope_theta_global if (w == 0 and cfg.rope_theta_global)
             else cfg.rope_theta) for w in windows)
        runs.append(RunSpec(pat[i], j - i, windows, thetas))
        i = j
    return tuple(runs)


def _cast(p, dtype, keep=("A_log", "D", "dt_bias")):
    """Cast float params to the compute dtype, keeping listed leaves fp32."""
    def go(path, a):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        if name in keep or not jnp.issubdtype(a.dtype, jnp.floating):
            return a
        return a.astype(dtype)
    return jax.tree_util.tree_map_with_path(go, p)


# ---------------------------------------------------------------------------
# Block init.
# ---------------------------------------------------------------------------

def init_block(kind: str, key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 6)
    if kind in (ATTN, ATTN_CROSS):
        p = {
            "ln1": L.init_norm(cfg, dtype),
            "attn": L.init_attention(ks[0], cfg, dtype),
            "ln2": L.init_norm(cfg, dtype),
        }
        if kind == ATTN_CROSS:
            p["lnx"] = L.init_norm(cfg, dtype)
            p["xattn"] = L.init_attention(ks[1], cfg, dtype)
        if cfg.is_moe:
            p["moe"] = init_moe(ks[2], cfg, dtype)
        else:
            p["mlp"] = L.init_mlp(ks[2], cfg, dtype)
        if cfg.qk_norm:
            p["q_scale"] = jnp.zeros((cfg.head_dim,), dtype)
            p["k_scale"] = jnp.zeros((cfg.head_dim,), dtype)
        return p
    if kind == HYMBA:
        return {
            "ln1": L.init_norm(cfg, dtype),
            "attn": L.init_attention(ks[0], cfg, dtype),
            "ssm": init_ssm(ks[1], cfg, dtype),
            "ln2": L.init_norm(cfg, dtype),
            "mlp": L.init_mlp(ks[2], cfg, dtype),
            "attn_out_scale": jnp.zeros((cfg.d_model,), dtype),
            "ssm_out_scale": jnp.zeros((cfg.d_model,), dtype),
        }
    if kind == MLSTM:
        return init_mlstm_block(ks[0], cfg, dtype)
    if kind == SLSTM:
        return init_slstm_block(ks[0], cfg, dtype)
    raise ValueError(f"unknown block kind {kind!r}")


# ---------------------------------------------------------------------------
# Attention plumbing shared by block kinds.
# ---------------------------------------------------------------------------

def _self_attention(p, y, cfg, rules, *, window, theta, q_pos, kv_pos,
                    cache, causal=True, static_window=None):
    """qkv + qk-norm + rope + (cache update) + attend + out-proj.

    ``q_pos``: (T,) for train/prefill; scalar fill-position for decode
    (uniform across the batch).  ``static_window``: python int when the
    run's window is uniform — enables static KV-block skipping.
    """
    q, k, v = L.qkv_proj(p["attn"], y, cfg, rules)
    if cfg.qk_norm:
        q = L.rms_norm_head(q) * (1 + p["q_scale"])
        k = L.rms_norm_head(k) * (1 + p["k_scale"])
    decode = q_pos.ndim == 0
    qvec = q_pos[None] if decode else q_pos           # (T,)
    if theta is not None:
        cos, sin = L.rope_cos_sin(qvec, cfg.head_dim, theta)
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
    bands = None
    if cache is not None:
        ck = lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), q_pos, axis=1)
        cv = lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), q_pos, axis=1)
        k_all, v_all = ck, cv
        new_cache = {"k": ck, "v": cv}
    else:
        new_cache = {"k": k, "v": v}       # cache keeps UNexpanded GQA kv
        q, k, v = L.maybe_expand_kv(q, k, v, rules)
        k_all, v_all = k, v
        kv_pos = qvec
        t = q.shape[1]
        # aligned self-attention: static diagonal skipping (beyond-paper)
        if causal and t > 2048 and cfg.attn_skip_diagonal:
            from .flash import block_bounds
            bands = block_bounds(t, t, causal=True, window=0,
                                 q_block=1024, kv_chunk=1024)
        # banded sliding-window path: per-layer lax.cond keeps the stack a
        # single scan (static band width = cfg.sliding_window; the traced
        # window masks exactly).  Prefill/inference only (naive-block bwd
        # would re-materialize probabilities in training).
        if (causal and t > 2048 and cfg.attn_banded and cfg.sliding_window
                and t == k_all.shape[1]):
            band_fn = lambda ops: L.attention_banded(
                *ops[:3], q_pos=ops[3], kv_pos=ops[4], window=window,
                w_max=cfg.sliding_window, q_block=1024)
            full_fn = lambda ops: L.attention(
                *ops[:3], q_pos=ops[3], kv_pos=ops[4], window=window,
                causal=True, impl=cfg.attention_impl, bands=bands)
            o = lax.cond(jnp.asarray(window, jnp.int32) > 0, band_fn,
                         full_fn, (q, k_all, v_all, qvec, kv_pos))
            return L.out_proj(p["attn"], o, rules), new_cache
    o = L.attention(q, k_all, v_all, q_pos=qvec, kv_pos=kv_pos,
                    window=window, causal=causal, impl=cfg.attention_impl,
                    softcap=0.0, bands=bands)
    return L.out_proj(p["attn"], o, rules), new_cache


def _cross_attention(p, x, cfg, rules, cross_src, cache):
    """Cross-attention against encoder output (or cached cross K/V)."""
    y = L.apply_norm(p["lnx"], x)
    q = jnp.einsum("btd,dhk->bthk", y, p["xattn"]["wq"])
    if cache is not None and "ck" in cache:
        ck, cv = cache["ck"], cache["cv"]
    else:
        ck = jnp.einsum("bsd,dhk->bshk", cross_src, p["xattn"]["wk"])
        cv = jnp.einsum("bsd,dhk->bshk", cross_src, p["xattn"]["wv"])
    s = ck.shape[1]
    kv_pos = jnp.arange(s, dtype=jnp.int32)
    o = L.attention(q, ck, cv, q_pos=jnp.zeros((q.shape[1],), jnp.int32),
                    kv_pos=kv_pos, window=0, causal=False, impl="auto")
    out = jnp.einsum("bthk,hkd->btd", o, p["xattn"]["wo"])
    return rules.constrain(out, "dp", None, None), {"ck": ck, "cv": cv}


# ---------------------------------------------------------------------------
# Block bodies.
# ---------------------------------------------------------------------------

def apply_attn_block(p, x, cfg, rules, *, kind, window, theta, q_pos, kv_pos,
                     cache=None, causal=True, cross_src=None,
                     static_window=None):
    metrics = {}
    y = L.apply_norm(p["ln1"], x)
    attn_cache = None
    if cache is not None:
        attn_cache = {"k": cache["k"], "v": cache["v"]}
    attn_out, new_cache = _self_attention(
        p, y, cfg, rules, window=window, theta=theta, q_pos=q_pos,
        kv_pos=kv_pos, cache=attn_cache, causal=causal,
        static_window=static_window)
    x = x + attn_out
    if kind == ATTN_CROSS:
        xo, xcache = _cross_attention(p, x, cfg, rules, cross_src, cache)
        x = x + xo
        new_cache.update(xcache)
    y = L.apply_norm(p["ln2"], x)
    if cfg.is_moe:
        m, aux = apply_moe(p["moe"], y, cfg, rules)
        metrics.update(aux)
    else:
        m = L.apply_mlp(p["mlp"], y, cfg, rules)
    return x + m, new_cache, metrics


def apply_hymba_block(p, x, cfg, rules, *, window, theta, q_pos, kv_pos,
                      cache=None):
    """Parallel attention ∥ SSM heads, fused by normalized mean [Hymba]."""
    y = L.apply_norm(p["ln1"], x)
    attn_cache = None if cache is None else {"k": cache["k"], "v": cache["v"]}
    attn_out, new_attn_cache = _self_attention(
        p, y, cfg, rules, window=window, theta=theta, q_pos=q_pos,
        kv_pos=kv_pos, cache=attn_cache, causal=True)
    ssm_cache = None if cache is None else {"conv": cache["conv"],
                                            "state": cache["state"]}
    ssm_out, new_ssm_cache = apply_ssm(p["ssm"], y, cfg, rules,
                                       cache=ssm_cache)
    fused = 0.5 * (L.rms_norm_head(attn_out) * (1 + p["attn_out_scale"])
                   + L.rms_norm_head(ssm_out) * (1 + p["ssm_out_scale"]))
    x = x + fused.astype(x.dtype)
    y = L.apply_norm(p["ln2"], x)
    x = x + L.apply_mlp(p["mlp"], y, cfg, rules)
    return x, {**new_attn_cache, **new_ssm_cache}, {}


# ---------------------------------------------------------------------------
# Cache construction.
# ---------------------------------------------------------------------------

def init_run_cache(run: RunSpec, cfg: ModelConfig, batch: int, seq_len: int,
                   dtype, cross_len: int = 0):
    """Per-run stacked cache pytree (leading dim = run.count)."""
    def stack(tree):
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (run.count,) + a.shape), tree)
    kv = {"k": jnp.zeros((batch, seq_len, cfg.num_kv_heads, cfg.head_dim), dtype),
          "v": jnp.zeros((batch, seq_len, cfg.num_kv_heads, cfg.head_dim), dtype)}
    if run.kind == ATTN:
        return stack(kv)
    if run.kind == ATTN_CROSS:
        kv["ck"] = jnp.zeros((batch, cross_len, cfg.num_kv_heads,
                              cfg.head_dim), dtype)
        kv["cv"] = jnp.zeros_like(kv["ck"])
        return stack(kv)
    if run.kind == HYMBA:
        return stack({**kv, **init_ssm_cache(cfg, batch)})
    if run.kind == MLSTM:
        return stack(init_mlstm_cache(cfg, batch))
    if run.kind == SLSTM:
        return stack(init_slstm_cache(cfg, batch))
    raise ValueError(run.kind)


def init_caches(cfg: ModelConfig, batch: int, seq_len: int, dtype=None):
    dtype = jnp.dtype(dtype or cfg.dtype)
    runs = build_runs(cfg)
    return [init_run_cache(r, cfg, batch, seq_len, dtype,
                           cross_len=cfg.encoder_seq_len) for r in runs]


# ---------------------------------------------------------------------------
# Stack execution (scan over layers within each run).
# ---------------------------------------------------------------------------

def _run_body(run: RunSpec, cfg, rules, *, q_pos, kv_pos, causal, cross_src,
              mode: str):
    """mode: 'train' (no cache out), 'prefill' (cache out), 'decode'
    (cache in+out)."""
    # static window when the whole run shares one (enables block skipping)
    static_window = (run.windows[0]
                     if len(set(run.windows)) == 1 else None)

    def body(x, per_layer):
        p, window, theta, cache = per_layer
        p = _cast(p, cfg.dtype)
        cache = cache if mode == "decode" else None
        if run.kind in (ATTN, ATTN_CROSS):
            x, new_cache, metrics = apply_attn_block(
                p, x, cfg, rules, kind=run.kind, window=window, theta=theta,
                q_pos=q_pos, kv_pos=kv_pos, cache=cache, causal=causal,
                cross_src=cross_src, static_window=static_window)
        elif run.kind == HYMBA:
            x, new_cache, metrics = apply_hymba_block(
                p, x, cfg, rules, window=window, theta=theta, q_pos=q_pos,
                kv_pos=kv_pos, cache=cache)
        elif run.kind == MLSTM:
            x, new_cache = apply_mlstm_block(p, x, cfg, rules, cache=cache)
            metrics = {}
        elif run.kind == SLSTM:
            x, new_cache = apply_slstm_block(p, x, cfg, rules, cache=cache)
            metrics = {}
        else:
            raise ValueError(run.kind)
        aux = jnp.stack([metrics["moe_aux"], metrics["moe_z"]]) \
            if metrics else jnp.zeros((2,), jnp.float32)
        cache_out = new_cache if mode in ("prefill", "decode") \
            else jnp.zeros((), jnp.float32)
        return x, (cache_out, aux)
    return body


def apply_stack(stack_params: list, x, cfg: ModelConfig, rules: AxisRules,
                runs: tuple[RunSpec, ...], *, q_pos, kv_pos, causal=True,
                caches=None, cross_src=None, mode: str = "train"):
    """Run all runs; returns (x, new_caches | None, aux_losses (2,))."""
    aux_total = jnp.zeros((2,), jnp.float32)
    new_caches = []
    for ridx, run in enumerate(runs):
        p_run = stack_params[ridx]
        windows = jnp.asarray(run.windows, jnp.int32)
        thetas = jnp.asarray(run.thetas, jnp.float32)
        cache_in = (caches[ridx] if caches is not None
                    else jnp.zeros((run.count,), jnp.float32))
        body = _run_body(run, cfg, rules, q_pos=q_pos, kv_pos=kv_pos,
                         causal=causal, cross_src=cross_src, mode=mode)
        if cfg.remat == "full" and mode == "train":
            body = jax.checkpoint(body, prevent_cse=False)
        elif cfg.remat == "dots" and mode == "train":
            body = jax.checkpoint(
                body, prevent_cse=False,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        if cfg.scan_layers and run.count > 1:
            x, (cache_out, aux) = lax.scan(body, x,
                                           (p_run, windows, thetas, cache_in))
            aux_total = aux_total + aux.sum(axis=0)
        else:
            outs = []
            for i in range(run.count):
                sl = jax.tree_util.tree_map(
                    lambda a: a[i], (p_run, windows, thetas, cache_in))
                x, (c_out, aux) = body(x, sl)
                outs.append(c_out)
                aux_total = aux_total + aux
            cache_out = jax.tree_util.tree_map(lambda *cs: jnp.stack(cs),
                                               *outs)
        new_caches.append(cache_out)
    return x, (new_caches if mode in ("prefill", "decode") else None), aux_total


# ---------------------------------------------------------------------------
# Whole-model parameter init.
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    runs = build_runs(cfg)
    keys = jax.random.split(key, len(runs) + 6)
    params: dict = {"embed": L.init_embed(keys[0], cfg, dtype)}
    stack = []
    for ridx, run in enumerate(runs):
        layer_keys = jax.random.split(keys[ridx + 1], run.count)
        stacked = jax.vmap(lambda k, kind=run.kind: init_block(kind, k, cfg,
                                                               dtype))(layer_keys)
        stack.append(stacked)
    params["stack"] = stack
    params["final_norm"] = L.init_norm(cfg, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "w": L.dense_init(keys[-1], (cfg.d_model, cfg.vocab_padded), dtype)}
    if cfg.is_encdec:
        enc_keys = jax.random.split(keys[-2], cfg.encoder_layers)
        params["encoder"] = jax.vmap(
            lambda k: init_block(ATTN, k, cfg, dtype))(enc_keys)
        params["enc_norm"] = L.init_norm(cfg, dtype)
    if cfg.num_meta_tokens:
        params["meta_tokens"] = L.embed_init(
            keys[-3], (cfg.num_meta_tokens, cfg.d_model), dtype)
    return params


# ---------------------------------------------------------------------------
# Front ends.
# ---------------------------------------------------------------------------

def sinusoidal_positions(seq_len: int, d: int):
    pos = np.arange(seq_len)[:, None]
    dim = np.arange(d // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * dim / d)
    return jnp.asarray(np.concatenate([np.sin(angle), np.cos(angle)], -1),
                       jnp.float32)


def encode_frames(params, frames, cfg: ModelConfig, rules: AxisRules):
    """Whisper-style encoder over precomputed (stub) frame embeddings."""
    s = frames.shape[1]
    x = frames.astype(cfg.dtype) + sinusoidal_positions(
        s, cfg.d_model).astype(cfg.dtype)
    x = rules.constrain(x, "dp", None, None)
    run = RunSpec(ATTN, cfg.encoder_layers, (0,) * cfg.encoder_layers,
                  (cfg.rope_theta,) * cfg.encoder_layers)
    pos = jnp.arange(s, dtype=jnp.int32)
    x, _, _ = apply_stack([params["encoder"]], x, cfg, rules, (run,),
                          q_pos=pos, kv_pos=pos, causal=False, mode="train")
    return L.apply_norm(params["enc_norm"], x)


def _prepare_prefix(params, tokens, cfg, rules, extra):
    """Embed tokens and prepend any prefix streams (patches / meta tokens)."""
    x = L.embed_tokens(params["embed"], tokens, cfg, rules)
    prefix_len = 0
    if cfg.num_patch_tokens and extra is not None and "patch_embeds" in extra:
        pe = extra["patch_embeds"].astype(cfg.dtype)
        x = jnp.concatenate([pe, x], axis=1)
        prefix_len += pe.shape[1]
    if cfg.num_meta_tokens:
        mt = jnp.broadcast_to(
            params["meta_tokens"].astype(cfg.dtype),
            (x.shape[0], cfg.num_meta_tokens, cfg.d_model))
        x = jnp.concatenate([mt, x], axis=1)
        prefix_len += cfg.num_meta_tokens
    return rules.constrain(x, "dp", None, None), prefix_len


# ---------------------------------------------------------------------------
# Entry points.
# ---------------------------------------------------------------------------

def forward_train(params, batch, cfg: ModelConfig, rules: AxisRules):
    """Teacher-forced forward: returns (loss, metrics)."""
    runs = build_runs(cfg)
    x, prefix_len = _prepare_prefix(params, batch["tokens"], cfg, rules, batch)
    cross_src = (encode_frames(params, batch["frames"], cfg, rules)
                 if cfg.is_encdec else None)
    t = x.shape[1]
    pos = jnp.arange(t, dtype=jnp.int32)
    x, _, aux = apply_stack(params["stack"], x, cfg, rules, runs,
                            q_pos=pos, kv_pos=pos, causal=True,
                            cross_src=cross_src, mode="train")
    x = L.apply_norm(params["final_norm"], x)
    if prefix_len:
        x = x[:, prefix_len:]
    logits = L.logits_from_hidden(x, params["embed"],
                                  params.get("lm_head"), cfg, rules)
    loss, n_tok = cross_entropy(logits, batch["labels"])
    aux_loss = 0.01 * aux[0] + 0.001 * aux[1]
    metrics = {"ce_loss": loss, "aux_loss": aux_loss, "tokens": n_tok}
    return loss + aux_loss, metrics


def cross_entropy(logits, labels):
    """Masked CE; labels < 0 are ignored.  fp32 reduction.

    The label logit is picked with a broadcast-iota select (not
    take_along_axis) so a vocab-sharded logits tensor never has to be
    all-gathered — the select fuses into the partial-vocab reduction and
    GSPMD only all-reduces the (B, T) partials.
    """
    mask = labels >= 0
    lab = jnp.maximum(labels, 0)
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    iota = lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    picked = jnp.sum(jnp.where(iota == lab[..., None], logits, 0.0), axis=-1)
    ce = (lse - picked) * mask
    n = jnp.maximum(mask.sum(), 1)
    return ce.sum() / n, n


def prefill(params, batch, cfg: ModelConfig, rules: AxisRules, seq_len: int):
    """Prefill caches of length ``seq_len``; returns (last_logits, caches)."""
    runs = build_runs(cfg)
    x, _ = _prepare_prefix(params, batch["tokens"], cfg, rules, batch)
    cross_src = (encode_frames(params, batch["frames"], cfg, rules)
                 if cfg.is_encdec else None)
    t = x.shape[1]
    pos = jnp.arange(t, dtype=jnp.int32)
    x, new_caches, _ = apply_stack(params["stack"], x, cfg, rules, runs,
                                   q_pos=pos, kv_pos=pos, causal=True,
                                   cross_src=cross_src, mode="prefill")
    caches = []
    for run, c in zip(runs, new_caches):
        def pad_kv(path, a):
            name = path[-1].key if hasattr(path[-1], "key") else ""
            if name in ("k", "v"):
                pad = [(0, 0)] * a.ndim
                pad[2] = (0, seq_len - t)
                return jnp.pad(a, pad)
            return a
        caches.append(jax.tree_util.tree_map_with_path(pad_kv, c))
    x = L.apply_norm(params["final_norm"], x[:, -1:])
    logits = L.logits_from_hidden(x, params["embed"], params.get("lm_head"),
                                  cfg, rules)
    return logits, caches


def decode_step(params, tokens, caches, pos, cfg: ModelConfig,
                rules: AxisRules, seq_len: int, cross_src=None):
    """One decode step.  tokens: (B, 1); pos: scalar int32 cache fill level.

    Returns (logits (B, 1, V), new_caches).
    """
    runs = build_runs(cfg)
    x = L.embed_tokens(params["embed"], tokens, cfg, rules)
    kv_pos = jnp.arange(seq_len, dtype=jnp.int32)
    q_pos = jnp.asarray(pos, jnp.int32)
    x, new_caches, _ = apply_stack(params["stack"], x, cfg, rules, runs,
                                   q_pos=q_pos, kv_pos=kv_pos, causal=True,
                                   caches=caches, cross_src=cross_src,
                                   mode="decode")
    x = L.apply_norm(params["final_norm"], x)
    logits = L.logits_from_hidden(x, params["embed"], params.get("lm_head"),
                                  cfg, rules)
    return logits, new_caches
