"""Mamba-style selective SSM branch (used by hymba's parallel heads).

Train path: sequential ``lax.scan`` over time with an fp32 state carry
(B, inner, state) — O(1) memory in T, exact.  Decode path: single-step
state update against a cached (conv window, ssm state) pair.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from .layers import AxisRules, dense_init


DT_RANK_DIV = 16  # dt_rank = max(d_model // 16, 8)


def init_ssm(key, cfg, dtype) -> dict:
    d = cfg.d_model
    inner = cfg.ssm_expand * d
    state = cfg.ssm_state
    dt_rank = max(d // DT_RANK_DIV, 8)
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A.
    a_init = jnp.broadcast_to(jnp.arange(1, state + 1, dtype=jnp.float32),
                              (inner, state))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * inner), dtype),
        "conv_w": dense_init(ks[1], (cfg.conv_kernel, inner), dtype,
                             fan_in=cfg.conv_kernel),
        "x_proj": dense_init(ks[2], (inner, dt_rank + 2 * state), dtype),
        "dt_proj": dense_init(ks[3], (dt_rank, inner), dtype, fan_in=dt_rank),
        "dt_bias": jnp.zeros((inner,), dtype),
        "A_log": jnp.log(a_init).astype(jnp.float32),
        "D": jnp.ones((inner,), jnp.float32),
        "out_proj": dense_init(ks[4], (inner, d), dtype, fan_in=inner),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv over time.  x: (B,T,C), w: (K,C).

    ``state`` (B, K-1, C) holds the trailing inputs for decode; returns
    (y, new_state).
    """
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros(x.shape[:1] + (k - 1,) + x.shape[2:], x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else jnp.zeros_like(pad)
    return y, new_state


def _ssm_params(p, xc, cfg):
    """Input-dependent dt, B, C from the conv output."""
    state = cfg.ssm_state
    dt_rank = p["dt_proj"].shape[0]
    proj = xc @ p["x_proj"]
    dt_lowrank = proj[..., :dt_rank]
    b_t = proj[..., dt_rank:dt_rank + state].astype(jnp.float32)
    c_t = proj[..., dt_rank + state:].astype(jnp.float32)
    dt = jax.nn.softplus((dt_lowrank @ p["dt_proj"]).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    return dt, b_t, c_t


def apply_ssm(p: dict, x, cfg, rules: AxisRules, *, cache=None, pos=None):
    """x: (B, T, d) -> (y (B, T, d), new_cache).

    cache = {"conv": (B, K-1, inner), "state": (B, inner, state)} or None.
    """
    inner = cfg.ssm_expand * cfg.d_model
    xz = x @ p["in_proj"]
    xs, z = xz[..., :inner], xz[..., inner:]
    xs = rules.constrain(xs, "dp", None, "tp")
    conv_state = None if cache is None else cache["conv"]
    xc, new_conv = _causal_conv(xs, p["conv_w"], conv_state)
    xc = jax.nn.silu(xc)

    dt, b_t, c_t = _ssm_params(p, xc, cfg)          # (B,T,inner), (B,T,S)x2
    a = -jnp.exp(p["A_log"])                         # (inner, S) fp32
    xf = xc.astype(jnp.float32)

    h0 = (jnp.zeros((x.shape[0], inner, cfg.ssm_state), jnp.float32)
          if cache is None else cache["state"])

    def step(h, inp):
        # decay/drive are formed per-step from (T,B,...)-sliced inputs so
        # the (B, T, inner, S) tensors are never materialized.
        dt_t, bt_t, ct_t, x_t = inp  # (B,inner), (B,S), (B,S), (B,inner)
        dec = jnp.exp(dt_t[..., None] * a)               # (B,inner,S)
        drv = (dt_t * x_t)[..., None] * bt_t[:, None, :]
        h = dec * h + drv
        y = jnp.einsum("bis,bs->bi", h, ct_t)
        return h, y

    t = x.shape[1]
    xs = (jnp.moveaxis(dt, 1, 0), jnp.moveaxis(b_t, 1, 0),
          jnp.moveaxis(c_t, 1, 0), jnp.moveaxis(xf, 1, 0))
    chunk = 128
    if t > chunk and t % chunk == 0:
        # Two-level scan: the backward pass of a flat T-step scan would
        # save every (B, inner, S) carry (T x state bytes).  Checkpointing
        # a chunk-level body keeps only chunk-boundary states and
        # recomputes in-chunk carries during the chunk's backward.
        nc = t // chunk
        xs_c = jax.tree_util.tree_map(
            lambda a_: a_.reshape((nc, chunk) + a_.shape[1:]), xs)

        @jax.checkpoint
        def chunk_step(h, inp):
            return lax.scan(step, h, inp)

        h_last, ys = lax.scan(chunk_step, h0, xs_c)
        ys = ys.reshape((t,) + ys.shape[2:])
    else:
        h_last, ys = lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)                        # (B,T,inner)
    y = y + p["D"] * xf
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    new_cache = {"conv": new_conv, "state": h_last}
    return rules.constrain(out, "dp", None, None), new_cache


def init_ssm_cache(cfg, batch: int, dtype=jnp.float32) -> dict:
    inner = cfg.ssm_expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, inner), dtype),
        "state": jnp.zeros((batch, inner, cfg.ssm_state), jnp.float32),
    }
