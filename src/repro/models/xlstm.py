"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, sequential with block-diagonal recurrence).  [arXiv:2405.04517]

The mLSTM recurrence (per batch, per head; stabilizer ``m``):

    m_t = max(lf_t + m_{t-1}, li_t)
    C_t = e^{lf_t + m_{t-1} - m_t} C_{t-1} + e^{li_t - m_t} k_t v_t^T
    n_t = e^{lf_t + m_{t-1} - m_t} n_{t-1} + e^{li_t - m_t} k_t
    h_t = (q_t C_t) / max(|q_t n_t|, e^{-m_t})          q pre-scaled 1/sqrt(dk)

``mlstm_sequential`` is the exact oracle (also the decode step);
``mlstm_chunkwise`` computes the same quantity chunk-parallel:  within a
chunk, intra-chunk terms form a decay-weighted attention matrix and the
carried state contributes a rank-]one[ correction, all stabilized by a
per-row max.  Equivalence is tested to fp32 tolerance.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from .layers import AxisRules, dense_init, apply_norm


# ---------------------------------------------------------------------------
# mLSTM cell math.
# ---------------------------------------------------------------------------

def mlstm_sequential(q, k, v, log_i, log_f, state=None):
    """Exact recurrence.  q,k,v: (B,T,H,D); log_i/log_f: (B,T,H).

    Returns (h (B,T,H,D), state) with state = (C (B,H,D,D), n (B,H,D),
    m (B,H)).  All math in fp32.
    """
    b, t, h, d = q.shape
    q = q.astype(jnp.float32) / np.sqrt(d)
    k, v = k.astype(jnp.float32), v.astype(jnp.float32)
    li, lf = log_i.astype(jnp.float32), log_f.astype(jnp.float32)
    if state is None:
        state = (jnp.zeros((b, h, d, d), jnp.float32),
                 jnp.zeros((b, h, d), jnp.float32),
                 jnp.full((b, h), -jnp.inf, jnp.float32))

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, lit, lft = inp
        m_new = jnp.maximum(lft + m, lit)
        a = jnp.exp(lft + m - m_new)[..., None]          # (B,H,1)
        bcoef = jnp.exp(lit - m_new)[..., None]
        C = a[..., None] * C + bcoef[..., None] * kt[..., None] * vt[..., None, :]
        n = a * n + bcoef * kt
        num = jnp.einsum("bhd,bhde->bhe", qt, C)
        den = jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n))
        den = jnp.maximum(den, jnp.exp(-m_new))[..., None]
        return (C, n, m_new), num / den

    (C, n, m), hs = lax.scan(
        step, state,
        (jnp.moveaxis(q, 1, 0), jnp.moveaxis(k, 1, 0), jnp.moveaxis(v, 1, 0),
         jnp.moveaxis(li, 1, 0), jnp.moveaxis(lf, 1, 0)))
    return jnp.moveaxis(hs, 0, 1), (C, n, m)


def mlstm_chunkwise_raw(q, k, v, log_i, log_f, chunk: int = 256):
    """Zero-init chunkwise mLSTM returning UN-normalized per-position terms
    for cross-device (context-parallel) state correction:

    (num (B,T,H,D), dot (B,T,H), m_loc (B,T,H), b_global (B,T,H),
     (F_total (B,H), C, n, m))

    where ``h = num / max(|dot|, exp(-m_loc))`` reproduces the local
    result, ``b_global`` is the inclusive cumulative log-forget within the
    segment, and ``F_total = b_global[:, -1]``.  See models/xlstm_sp.py.
    """
    b, t, h, d = q.shape
    nc = t // chunk
    qs = (q.astype(jnp.float32) / np.sqrt(d)).reshape(b, nc, chunk, h, d)
    ks = k.astype(jnp.float32).reshape(b, nc, chunk, h, d)
    vs = v.astype(jnp.float32).reshape(b, nc, chunk, h, d)
    lis = log_i.astype(jnp.float32).reshape(b, nc, chunk, h)
    lfs = log_f.astype(jnp.float32).reshape(b, nc, chunk, h)
    state = (jnp.zeros((b, h, d, d), jnp.float32),
             jnp.zeros((b, h, d), jnp.float32),
             jnp.full((b, h), -jnp.inf, jnp.float32),
             jnp.zeros((b, h), jnp.float32))       # (+ F accumulator)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def chunk_step(carry, inp):
        C0, n0, m0, f0 = carry
        qc, kc, vc, lic, lfc = inp
        bcum = jnp.cumsum(lfc, axis=1)
        btot = bcum[:, -1]
        e = (bcum[:, :, None, :] - bcum[:, None, :, :] + lic[:, None, :, :])
        e = jnp.where(tri[None, :, :, None], e, -jnp.inf)
        g = bcum + m0[:, None, :]
        m_row = jnp.maximum(jnp.max(e, axis=2), g)
        m_row = jnp.maximum(m_row, -1e30)
        s_mat = jnp.einsum("bthd,bshd->btsh", qc, kc) * jnp.exp(
            e - m_row[:, :, None, :])
        s_mat = jnp.where(tri[None, :, :, None], s_mat, 0.0)
        c_inter = jnp.exp(g - m_row)
        num = (jnp.einsum("btsh,bshd->bthd", s_mat, vc)
               + c_inter[..., None] * jnp.einsum("bthd,bhde->bthe", qc, C0))
        dot = (jnp.sum(s_mat, axis=2)
               + c_inter * jnp.einsum("bthd,bhd->bth", qc, n0))
        m_new = jnp.maximum(btot + m0, jnp.max(btot[:, None] - bcum + lic,
                                               axis=1))
        scale0 = jnp.exp(btot + m0 - m_new)
        w_s = jnp.exp(btot[:, None] - bcum + lic - m_new[:, None])
        C1 = (scale0[..., None, None] * C0
              + jnp.einsum("bsh,bshd,bshe->bhde", w_s, kc, vc))
        n1 = scale0[..., None] * n0 + jnp.einsum("bsh,bshd->bhd", w_s, kc)
        return (C1, n1, m_new, f0 + btot), (num, dot, m_row,
                                            bcum + f0[:, None, :])

    (C, n, m, F), (nums, dots, m_rows, bglob) = lax.scan(
        chunk_step, state,
        (jnp.moveaxis(qs, 1, 0), jnp.moveaxis(ks, 1, 0),
         jnp.moveaxis(vs, 1, 0), jnp.moveaxis(lis, 1, 0),
         jnp.moveaxis(lfs, 1, 0)))

    def unfold(x):
        return jnp.moveaxis(x, 0, 1).reshape((b, t) + x.shape[3:])

    return (unfold(nums), unfold(dots), unfold(m_rows), unfold(bglob),
            (F, C, n, m))


def mlstm_chunkwise(q, k, v, log_i, log_f, state=None, chunk: int = 256):
    """Chunk-parallel mLSTM, identical semantics to ``mlstm_sequential``."""
    b, t, h, d = q.shape
    if t % chunk:
        raise ValueError(f"T={t} must be a multiple of chunk={chunk}")
    nc = t // chunk
    q = (q.astype(jnp.float32) / np.sqrt(d)).reshape(b, nc, chunk, h, d)
    k = k.astype(jnp.float32).reshape(b, nc, chunk, h, d)
    v = v.astype(jnp.float32).reshape(b, nc, chunk, h, d)
    li = log_i.astype(jnp.float32).reshape(b, nc, chunk, h)
    lf = log_f.astype(jnp.float32).reshape(b, nc, chunk, h)
    if state is None:
        state = (jnp.zeros((b, h, d, d), jnp.float32),
                 jnp.zeros((b, h, d), jnp.float32),
                 jnp.full((b, h), -jnp.inf, jnp.float32))

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))            # s <= t
    tri_strict = jnp.tril(jnp.ones((chunk, chunk), bool), -1)

    def chunk_step(carry, inp):
        C0, n0, m0 = carry
        qc, kc, vc, lic, lfc = inp          # (B,chunk,H,*)
        bcum = jnp.cumsum(lfc, axis=1)      # inclusive sum of log_f, (B,C,H)
        btot = bcum[:, -1]                  # (B,H)
        # intra-chunk log weights: e_ts = bcum_t - bcum_s + li_s   (s <= t,
        # decay excludes step s's own forget gate? recurrence applies f_t
        # when *adding* at t then decays forward:  product_{tau=s+1..t} f_tau
        # = exp(bcum_t - bcum_s);  contribution enters with i_s.
        e = (bcum[:, :, None, :] - bcum[:, None, :, :]
             + lic[:, None, :, :])          # (B,t,s,H)
        e = jnp.where(tri[None, :, :, None], e, -jnp.inf)
        g = bcum + m0[:, None, :]           # inter exponent (B,C,H)
        m_row = jnp.maximum(jnp.max(e, axis=2), g)        # (B,C,H)
        m_row = jnp.maximum(m_row, -1e30)   # guard -inf rows
        s_mat = jnp.einsum("bthd,bshd->btsh", qc, kc) * jnp.exp(
            e - m_row[:, :, None, :])
        s_mat = jnp.where(tri[None, :, :, None], s_mat, 0.0)
        c_inter = jnp.exp(g - m_row)                      # (B,C,H)
        num = (jnp.einsum("btsh,bshd->bthd", s_mat, vc)
               + c_inter[..., None] * jnp.einsum("bthd,bhde->bthe", qc, C0))
        dot = (jnp.sum(s_mat, axis=2)
               + c_inter * jnp.einsum("bthd,bhd->bth", qc, n0))
        den = jnp.maximum(jnp.abs(dot), jnp.exp(-m_row))[..., None]
        h_out = num / den
        # chunk-end state update
        m_new = jnp.maximum(btot + m0, jnp.max(btot[:, None] - bcum + lic, axis=1))
        scale0 = jnp.exp(btot + m0 - m_new)               # (B,H)
        w_s = jnp.exp(btot[:, None] - bcum + lic - m_new[:, None])  # (B,C,H)
        C1 = (scale0[..., None, None] * C0
              + jnp.einsum("bsh,bshd,bshe->bhde", w_s, kc, vc))
        n1 = scale0[..., None] * n0 + jnp.einsum("bsh,bshd->bhd", w_s, kc)
        return (C1, n1, m_new), h_out

    (C, n, m), hs = lax.scan(
        chunk_step, state,
        (jnp.moveaxis(q, 1, 0), jnp.moveaxis(k, 1, 0), jnp.moveaxis(v, 1, 0),
         jnp.moveaxis(li, 1, 0), jnp.moveaxis(lf, 1, 0)))
    h_out = jnp.moveaxis(hs, 0, 1).reshape(b, t, h, d)
    return h_out, (C, n, m)


# ---------------------------------------------------------------------------
# mLSTM block.
# ---------------------------------------------------------------------------

def init_mlstm_block(key, cfg, dtype) -> dict:
    d = cfg.d_model
    inner = cfg.ssm_expand * d
    dh = inner // cfg.num_heads
    ks = jax.random.split(key, 8)
    return {
        "norm_scale": jnp.zeros((d,), dtype),
        "up": dense_init(ks[0], (d, 2 * inner), dtype),
        "conv_w": dense_init(ks[1], (cfg.conv_kernel, inner), dtype,
                             fan_in=cfg.conv_kernel),
        "wq": dense_init(ks[2], (inner, inner), dtype),
        "wk": dense_init(ks[3], (inner, inner), dtype),
        "wv": dense_init(ks[4], (inner, inner), dtype),
        "w_i": dense_init(ks[5], (inner, cfg.num_heads), dtype),
        "w_f": dense_init(ks[6], (inner, cfg.num_heads), dtype),
        "b_i": jnp.zeros((cfg.num_heads,), dtype),
        "b_f": jnp.full((cfg.num_heads,), 3.0, dtype),   # open forget gates
        "hnorm_scale": jnp.zeros((inner,), dtype),
        "down": dense_init(ks[7], (inner, d), dtype, fan_in=inner),
    }


def apply_mlstm_block(p, x, cfg, rules: AxisRules, *, cache=None,
                      chunk: int = 256):
    """Pre-norm residual mLSTM block.  cache: {"conv", "C", "n", "m"}."""
    from .ssm import _causal_conv
    b, t, d = x.shape
    inner = cfg.ssm_expand * d
    nh = cfg.num_heads
    dh = inner // nh
    y = apply_norm({"scale": p["norm_scale"]}, x)
    up = y @ p["up"]
    xin, z = up[..., :inner], up[..., inner:]
    xin = rules.constrain(xin, "dp", None, "tp")
    conv_state = None if cache is None else cache["conv"]
    xc, new_conv = _causal_conv(xin, p["conv_w"], conv_state)
    xc = jax.nn.silu(xc)
    q = (xc @ p["wq"]).reshape(b, t, nh, dh)
    k = (xc @ p["wk"]).reshape(b, t, nh, dh)
    v = (xin @ p["wv"]).reshape(b, t, nh, dh)
    log_i = (xc @ p["w_i"] + p["b_i"]).astype(jnp.float32)
    log_f = jax.nn.log_sigmoid((xc @ p["w_f"] + p["b_f"]).astype(jnp.float32))
    state = None if cache is None else (cache["C"], cache["n"], cache["m"])
    if t == 1 or t % chunk:
        h, (C, n, m) = mlstm_sequential(q, k, v, log_i, log_f, state)
    else:
        h, (C, n, m) = mlstm_chunkwise(q, k, v, log_i, log_f, state, chunk)
    h = h.reshape(b, t, inner).astype(x.dtype)
    h = apply_norm({"scale": p["hnorm_scale"]}, h)        # output norm
    h = h * jax.nn.silu(z)
    out = h @ p["down"]
    out = rules.constrain(out, "dp", None, None)
    new_cache = {"conv": new_conv, "C": C, "n": n, "m": m}
    return x + out, new_cache


def init_mlstm_cache(cfg, batch, dtype=jnp.float32) -> dict:
    inner = cfg.ssm_expand * cfg.d_model
    dh = inner // cfg.num_heads
    return {
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, inner), jnp.float32),
        "C": jnp.zeros((batch, cfg.num_heads, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, cfg.num_heads, dh), jnp.float32),
        "m": jnp.full((batch, cfg.num_heads), -jnp.inf, jnp.float32),
    }


# ---------------------------------------------------------------------------
# sLSTM block.
# ---------------------------------------------------------------------------

def init_slstm_block(key, cfg, dtype) -> dict:
    d = cfg.d_model
    nh = cfg.num_heads
    dh = d // nh
    ff = int(d * 4 / 3)
    ks = jax.random.split(key, 8)
    return {
        "norm_scale": jnp.zeros((d,), dtype),
        "w_gates": dense_init(ks[0], (d, 4 * d), dtype),      # z, i, f, o
        "r_gates": dense_init(ks[1], (nh, dh, 4 * dh), dtype, fan_in=dh),
        "b_gates": jnp.concatenate([
            jnp.zeros((2 * d,)), jnp.full((d,), 3.0), jnp.zeros((d,))
        ]).astype(dtype),
        "hnorm_scale": jnp.zeros((d,), dtype),
        "ffn_wi": dense_init(ks[2], (d, ff), dtype),
        "ffn_wg": dense_init(ks[3], (d, ff), dtype),
        "ffn_wo": dense_init(ks[4], (ff, d), dtype, fan_in=ff),
        "ffn_norm_scale": jnp.zeros((d,), dtype),
    }


def slstm_scan(wx, r_gates, h0, c0, n0, m0, nh):
    """Sequential sLSTM.  wx: (B,T,4d) input-driven gate preactivations.

    Per step, recurrent contribution uses block-diagonal R per head.
    Returns (h (B,T,d), (h,c,n,m) final).  fp32 math.
    """
    b, t, d4 = wx.shape
    d = d4 // 4
    dh = d // nh

    def step(carry, wxt):
        h, c, n, m = carry                          # (B,d) fp32, m:(B,d)
        hh = h.reshape(b, nh, dh)
        rec = jnp.einsum("bhd,hde->bhe", hh, r_gates).reshape(b, 4 * d)
        pre = wxt.astype(jnp.float32) + rec
        zt, it, ft, ot = jnp.split(pre, 4, axis=-1)
        zt = jnp.tanh(zt)
        ot = jax.nn.sigmoid(ot)
        lf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(lf + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(lf + m - m_new)
        c = f_p * c + i_p * zt
        n = f_p * n + i_p
        h_new = ot * c / jnp.maximum(n, 1e-6)
        return (h_new, c, n, m_new), h_new

    (h, c, n, m), hs = lax.scan(step, (h0, c0, n0, m0),
                                jnp.moveaxis(wx, 1, 0))
    return jnp.moveaxis(hs, 0, 1), (h, c, n, m)


def apply_slstm_block(p, x, cfg, rules: AxisRules, *, cache=None):
    b, t, d = x.shape
    nh = cfg.num_heads
    y = apply_norm({"scale": p["norm_scale"]}, x)
    wx = y @ p["w_gates"] + p["b_gates"]
    if cache is None:
        zeros = jnp.zeros((b, d), jnp.float32)
        state = (zeros, zeros, zeros, jnp.full((b, d), -jnp.inf, jnp.float32))
    else:
        state = (cache["h"], cache["c"], cache["n"], cache["m"])
    r = p["r_gates"].astype(jnp.float32)
    hs, (h, c, n, m) = slstm_scan(wx, r, *state, nh=nh)
    hs = apply_norm({"scale": p["hnorm_scale"]}, hs.astype(x.dtype))
    x = x + rules.constrain(hs, "dp", None, None)
    # gated FFN (factor 4/3)
    y = apply_norm({"scale": p["ffn_norm_scale"]}, x)
    hff = jax.nn.silu(y @ p["ffn_wg"]) * (y @ p["ffn_wi"])
    hff = rules.constrain(hff, "dp", None, "tp")
    x = x + rules.constrain(hff @ p["ffn_wo"], "dp", None, None)
    return x, {"h": h, "c": c, "n": n, "m": m}


def init_slstm_cache(cfg, batch, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"h": z, "c": z, "n": z,
            "m": jnp.full((batch, d), -jnp.inf, jnp.float32)}
